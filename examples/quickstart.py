"""Quickstart: build a CubeGraph index and run hybrid filtered AKNN queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import BoxFilter, CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_dataset, recall

# 1. A dataset of (embedding, spatio-temporal metadata) pairs:
#    5k objects, 48-d embeddings, metadata = (lon, lat) in [0,1]^2.
x, s = make_dataset(n=5000, d=48, m=2, seed=0)

# 2. Build the hierarchical-grid stitched-graph index (Alg. 1 + Alg. 2).
index = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=4, m_intra=16,
                                                   m_cross=4))
print("index stats:", index.stats())

# 3. A hybrid query: top-10 nearest neighbors inside a spatial box.
queries = x[:8] + 0.02
filt = BoxFilter(lo=np.asarray([0.2, 0.3], np.float32),
                 hi=np.asarray([0.5, 0.6], np.float32))
ids, dists = index.query(queries, filt, k=10, ef=64)
print("result ids[0]:", ids[0])

# 4. Verify against brute force.
gt, _ = ground_truth(x, s, queries, filt, 10)
print(f"recall@10 = {recall(ids, gt):.3f}")

# 5. Every result satisfies the filter:
import jax.numpy as jnp
assert bool(filt.contains(jnp.asarray(s[ids[ids >= 0]])).all())
print("all results inside the filter ✓")
