"""Train a ~100M-param dense LM for a few hundred steps on the synthetic
learnable stream, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(The full driver with mesh/sharding lives in repro.launch.train; this
example keeps a visible loss curve on one CPU device. A ~100M config is
d_model=512, 12 layers, vocab 32k — adjust down with --tiny if slow.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.models import build_model, init_params
from repro.models.common import ArchConfig
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true",
                help="4-layer 128-wide variant (fast CPU demo)")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train100m")
args = ap.parse_args()

if args.tiny:
    cfg = ArchConfig(name="demo-8m", family="dense", n_layers=4, d_model=128,
                     n_heads=4, n_kv=4, d_ff=512, vocab=4096, remat=False)
else:
    cfg = ArchConfig(name="demo-100m", family="dense", n_layers=12,
                     d_model=512, n_heads=8, n_kv=8, d_ff=2048, vocab=32768,
                     remat=False)

model = build_model(cfg)
params = init_params(model.param_specs(), jax.random.key(0))
n = sum(p.size for p in jax.tree.leaves(params))
print(f"{cfg.name}: {n/1e6:.1f}M params")

state = init_train_state(params)
opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                schedule="wsd")
step_fn = jax.jit(make_train_step(model, opt))
pipe = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                         global_batch=8, seed=0))
cm = CheckpointManager(args.ckpt_dir)

restored, manifest = cm.restore(state)
start = 0
if restored is not None:
    state = jax.tree.map(jnp.asarray, restored)
    start = manifest["extra"]["data_step"]
    print(f"resumed from step {start}")

t0 = time.time()
for i in range(start, args.steps):
    state, m = step_fn(state, jax.tree.map(jnp.asarray, pipe.batch(i)))
    if i % 25 == 0 or i == args.steps - 1:
        print(f"step {i:4d} loss {float(m['loss']):.4f} "
              f"lr {float(m['lr']):.2e}", flush=True)
    if i and i % 100 == 0:
        cm.save(i, state, extra={"data_step": i + 1})
print(f"trained {args.steps - start} steps in {time.time()-t0:.0f}s; "
      "loss should approach 0 on the learnable stream")
