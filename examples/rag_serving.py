"""End-to-end spatio-temporal RAG (the paper's application): geo-tagged
document store -> CubeGraph filtered retrieval -> LM generation.

    PYTHONPATH=src python examples/rag_serving.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import CubeGraphConfig
from repro.core.filters import BoxFilter
from repro.core.workloads import make_dataset
from repro.models import build_model, init_params
from repro.serving.rag import Document, DocumentStore, RAGPipeline

# Corpus: 2000 geo-tagged "reports" (embedding + (lon, lat, t) + token span)
x, s = make_dataset(2000, 32, 3, seed=0)
rng = np.random.default_rng(1)
docs = [Document(doc_id=i, tokens=rng.integers(2, 250, 16).astype(np.int32),
                 embedding=x[i], metadata=s[i]) for i in range(2000)]
store = DocumentStore(docs, CubeGraphConfig(n_layers=3))

# Generator backbone: any assigned arch (reduced config on CPU).
cfg = get_config("gemma3-1b", smoke=True)
model = build_model(cfg)
params = init_params(model.param_specs(), jax.random.key(0))
pipe = RAGPipeline(store, model, params, max_context=96)

# "flooded streets in this district during the last week"
district = BoxFilter(lo=np.asarray([0.1, 0.2, 0.6], np.float32),
                     hi=np.asarray([0.4, 0.5, 0.9], np.float32))
query_tokens = rng.integers(2, 250, 8).astype(np.int32)
answer, retrieved = pipe.answer(query_tokens, district, k=4, max_new=12)

print(f"retrieved {len(retrieved)} docs inside the district filter:")
for d in retrieved:
    print(f"  doc {d.doc_id}: meta={np.round(d.metadata, 3)}")
print("generated token ids:", answer[-12:])

# Streaming ingestion (paper §4.4): insert fresh reports, query again.
fresh = [Document(doc_id=2000 + i,
                  tokens=rng.integers(2, 250, 16).astype(np.int32),
                  embedding=x[i] + 0.01, metadata=np.asarray([0.25, 0.35, 0.7]))
         for i in range(16)]
store.insert(fresh)
answer2, retrieved2 = pipe.answer(query_tokens, district, k=4, max_new=12)
print("after insert, retrieved ids:", [d.doc_id for d in retrieved2])
