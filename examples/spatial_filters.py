"""Complex spatio-temporal filters: circles, polygons, compositions, and the
two query strategies (predetermined Alg. 3 vs on-the-fly Alg. 4).

    PYTHONPATH=src python examples/spatial_filters.py
"""
import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_compose_filter, make_dataset,
                                  make_polygon_filter, recall)

# 3D metadata: (lon, lat, timestamp)
x, s = make_dataset(n=6000, d=32, m=3, seed=1)
index = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=4))
queries = x[:8] + 0.02

for name, filt in [
    ("circle+time-window", make_ball_filter(3, 0.08, seed=2)),
    ("polygon-5", make_polygon_filter(3, 0.08, n_vertices=5, seed=3)),
    ("box-minus-circle", make_compose_filter(3, 0.08, seed=4)),
]:
    gt, _ = ground_truth(x, s, queries, filt, 10)
    for mode in ("predetermined", "onthefly"):
        ids, _, st = index.query(queries, filt, k=10, ef=96, mode=mode,
                                 return_stats=True)
        print(f"{name:20s} {mode:14s} layer={st.layer} "
              f"cubes={st.n_active_cubes:3d} recall={recall(ids, gt):.3f} "
              f"search={st.search_ms:.0f}ms")
