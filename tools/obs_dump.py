#!/usr/bin/env python
"""Render a CubeGraph observability snapshot as Prometheus text exposition.

Input (positional, or stdin with ``-``) is a JSON file holding any of:

* a ``DocumentStore.metrics_snapshot()`` export — ``{enabled, metrics,
  buckets}``;
* a full ``SegmentManager.stats()`` dump — the ``obs`` block is used and
  the top-level liveness/occupancy numbers become gauges;
* a bare ``MetricsRegistry.snapshot()`` — ``{counters, gauges,
  histograms}``.

Counters/gauges map 1:1; histograms are exposed as summaries (quantile
labels + ``_sum``/``_count``); per-capacity ``BucketStats`` rows become
``cubegraph_bucket_*{cap="..."}`` gauges so the planner-contract numbers
(pruning rate, selectivity, scanned rows) are scrapeable per bucket.  A
``MultiTenantStore.stats()`` dump additionally carries a ``tenants``
block, rendered as ``cubegraph_tenant_*{tenant="..."}`` gauges (plus
``{tenant=,cap=}`` rows for each collection's own bucket stats).

Usage::

    PYTHONPATH=src python tools/obs_dump.py snapshot.json
    PYTHONPATH=src python tools/obs_dump.py --demo      # tiny live workload

``--demo`` ingests a small synthetic stream, runs a few filtered queries,
and dumps the resulting snapshot — a smoke test for the whole export path.
"""
from __future__ import annotations

import argparse
import json
import sys

_REPO_SRC = __file__.rsplit("/", 2)[0] + "/src"
if _REPO_SRC not in sys.path:           # allow running without PYTHONPATH
    sys.path.insert(0, _REPO_SRC)

from repro.obs import prometheus_text  # noqa: E402


def bucket_text(buckets: dict, prefix: str = "cubegraph") -> str:
    """``BucketStats.snapshot()`` -> per-capacity labeled gauge lines."""
    lines = []
    keys = sorted({k for row in buckets.values() for k in row})
    for key in keys:
        name = f"{prefix}_bucket_{key}"
        lines.append(f"# TYPE {name} gauge")
        for cap in sorted(buckets, key=int):
            value = buckets[cap].get(key)
            if value is None:
                continue
            lines.append(f'{name}{{cap="{cap}"}} {value}')
    return "\n".join(lines) + ("\n" if lines else "")


def tenant_text(tenants: dict, prefix: str = "cubegraph") -> str:
    """``MultiTenantStore.stats()['tenants']`` -> per-tenant labeled gauges.

    Scalar per-collection fields (live points, quota...) become
    ``{prefix}_tenant_*{tenant="..."}`` gauges; each collection's
    per-capacity ``BucketStats`` rows keep their ``cap`` label and gain a
    ``tenant`` label, so per-tenant scan behaviour is scrapeable next to
    the shared-substrate totals.
    """
    lines = []
    scalar_keys = sorted({k for row in tenants.values()
                          for k, v in row.items()
                          if isinstance(v, (int, float))
                          and not isinstance(v, bool)})
    for key in scalar_keys:
        name = f"{prefix}_tenant_{key}"
        lines.append(f"# TYPE {name} gauge")
        for tenant in sorted(tenants):
            value = tenants[tenant].get(key)
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            lines.append(f'{name}{{tenant="{tenant}"}} {value}')
    bucket_keys = sorted({k for row in tenants.values()
                          for cap_row in (row.get("buckets") or {}).values()
                          for k in cap_row})
    for key in bucket_keys:
        name = f"{prefix}_tenant_bucket_{key}"
        lines.append(f"# TYPE {name} gauge")
        for tenant in sorted(tenants):
            caps = tenants[tenant].get("buckets") or {}
            for cap in sorted(caps, key=int):
                value = caps[cap].get(key)
                if value is None:
                    continue
                lines.append(
                    f'{name}{{tenant="{tenant}",cap="{cap}"}} {value}')
    return "\n".join(lines) + ("\n" if lines else "")


def _top_level_gauges(stats: dict, prefix: str = "cubegraph") -> str:
    """Scalar ``stats()`` fields (liveness, pack bytes...) as gauges; the
    nested ``tier`` block (budget / resident / host bytes — present when
    tiered storage is on) flattens to ``{prefix}_tier_*`` gauges."""
    lines = []
    flat = dict(stats)
    tier = flat.pop("tier", None)
    if isinstance(tier, dict):
        flat.update({f"tier_{k}": v for k, v in tier.items()})
    for key, value in sorted(flat.items()):
        if key == "obs" or not isinstance(value, (int, float)) \
                or isinstance(value, bool):
            continue
        lines.append(f"# TYPE {prefix}_{key} gauge")
        lines.append(f"{prefix}_{key} {value}")
    return "\n".join(lines) + ("\n" if lines else "")


def render(blob: dict, prefix: str = "cubegraph") -> str:
    """Dispatch on the snapshot shape and render everything it carries."""
    out = []
    tenants = blob.get("tenants")        # MultiTenantStore.stats()
    if isinstance(tenants, dict):
        out.append(tenant_text(tenants, prefix))
    if "obs" in blob:                    # full SegmentManager.stats()
        out.append(_top_level_gauges(blob, prefix))
        blob = blob["obs"]
    if "metrics" in blob:                # StreamObs / metrics_snapshot()
        out.append(prometheus_text(blob["metrics"], prefix))
        out.append(bucket_text(blob.get("buckets", {}), prefix))
    else:                                # bare registry snapshot
        out.append(prometheus_text(blob, prefix))
    return "".join(part for part in out if part)


def _demo() -> dict:
    """Tiny live workload whose snapshot exercises every metric family."""
    import numpy as np

    from repro.core import CubeGraphConfig, IntervalFilter
    from repro.streaming import SegmentManager, StreamConfig

    cfg = StreamConfig(time_dim=2, seal_max_points=256, n_shards=2,
                       device_budget_bytes=1 << 20,
                       index_cfg=CubeGraphConfig(n_layers=2, m_intra=8,
                                                 m_cross=4))
    rng = np.random.default_rng(0)
    mgr = SegmentManager(16, 3, cfg)
    for i in range(4):
        x = rng.normal(size=(200, 16)).astype(np.float32)
        s = rng.uniform(size=(200, 3))
        s[:, 2] = i + np.linspace(0, 0.9, 200)
        mgr.ingest(x, s)
    mgr.maintenance()
    filt = IntervalFilter(dim=2, lo=0.5, hi=2.5)
    for _ in range(4):
        mgr.query(rng.normal(size=(4, 16)).astype(np.float32), filt, k=5)
    return mgr.stats()


def main(argv=None) -> int:
    """CLI entry point."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", nargs="?",
                    help="JSON snapshot file ('-' for stdin)")
    ap.add_argument("--demo", action="store_true",
                    help="run a tiny live workload instead of reading a file")
    ap.add_argument("--prefix", default="cubegraph",
                    help="metric name prefix (default: cubegraph)")
    args = ap.parse_args(argv)
    if args.demo:
        blob = _demo()
    elif args.snapshot is None:
        ap.error("provide a snapshot file or --demo")
    elif args.snapshot == "-":
        blob = json.load(sys.stdin)
    else:
        with open(args.snapshot) as f:
            blob = json.load(f)
    sys.stdout.write(render(blob, args.prefix))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
