#!/usr/bin/env python3
"""Docs-consistency gate (run by CI and by ``tests/test_docs.py``).

Three checks, no third-party dependencies:

1. every ``benchmarks/bench_*.py`` experiment is documented in
   ``docs/benchmarks.md`` (mentioned by file name);
2. ``README.md`` links the architecture, benchmarks, observability, and
   serving docs;
3. docstring lint over ``src/repro/streaming``, ``src/repro/distributed``,
   and the multi-tenant serving tier: every module, public class, and
   public function/method carries a docstring (AST-based, pydocstyle's
   D100/D101/D102/D103 subset).

Exit code 0 when clean; prints one line per violation otherwise.
"""
from __future__ import annotations

import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
# Directories are linted recursively; single-file entries pull one module
# into the lint without sweeping in its siblings.
LINT_DIRS = ("src/repro/streaming", "src/repro/distributed",
             "src/repro/quant", "src/repro/obs",
             "src/repro/kernels/graph_topk.py",
             "src/repro/serving/service.py",
             "src/repro/serving/tenancy.py",
             "src/repro/serving/workload.py")
# Files the docstring lint MUST cover — guards against a rename/move
# silently dropping a linted subsystem out of LINT_DIRS.
REQUIRED_LINTED = ("src/repro/streaming/persistence.py",
                   "src/repro/streaming/manager.py",
                   "src/repro/streaming/planner.py",
                   "src/repro/streaming/resilience.py",
                   "src/repro/streaming/tiering.py",
                   "src/repro/distributed/segment_shards.py",
                   "src/repro/quant/codec.py",
                   "src/repro/quant/rerank.py",
                   "src/repro/obs/metrics.py",
                   "src/repro/obs/trace.py",
                   "src/repro/kernels/graph_topk.py",
                   "src/repro/serving/service.py",
                   "src/repro/serving/tenancy.py",
                   "src/repro/serving/workload.py")


def check_bench_docs() -> list:
    """Each bench_*.py must appear (by name) in docs/benchmarks.md."""
    doc_path = REPO / "docs" / "benchmarks.md"
    if not doc_path.exists():
        return ["docs/benchmarks.md is missing"]
    doc = doc_path.read_text()
    errors = []
    for bench in sorted((REPO / "benchmarks").glob("bench_*.py")):
        if bench.name not in doc:
            errors.append(f"docs/benchmarks.md does not mention {bench.name}")
    return errors


def check_readme_links() -> list:
    """README must link the architecture and benchmarks docs."""
    readme = (REPO / "README.md").read_text()
    errors = []
    for target in ("docs/architecture.md", "docs/benchmarks.md",
                   "docs/observability.md", "docs/serving.md"):
        if not (REPO / target).exists():
            errors.append(f"{target} is missing")
        if target not in readme:
            errors.append(f"README.md does not link {target}")
    return errors


def _lint_node(node, path, errors, prefix=""):
    """Recurse over public defs collecting missing-docstring violations."""
    for child in getattr(node, "body", []):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            name = child.name
            if name.startswith("_"):
                continue                     # private / dunder: exempt
            if ast.get_docstring(child) is None:
                kind = ("class" if isinstance(child, ast.ClassDef)
                        else "function")
                errors.append(
                    f"{path}:{child.lineno} public {kind} "
                    f"{prefix}{name} has no docstring")
            if isinstance(child, ast.ClassDef):
                _lint_node(child, path, errors, prefix=f"{name}.")


def check_docstrings() -> list:
    """AST docstring lint over the dirs/files named in LINT_DIRS."""
    errors = []
    linted = set()
    for d in LINT_DIRS:
        root = REPO / d
        for py in ([root] if root.is_file() else sorted(root.rglob("*.py"))):
            rel = py.relative_to(REPO)
            linted.add(str(rel))
            tree = ast.parse(py.read_text())
            if ast.get_docstring(tree) is None:
                errors.append(f"{rel}:1 module has no docstring")
            _lint_node(tree, rel, errors)
    for required in REQUIRED_LINTED:
        if required not in linted:
            errors.append(f"{required} was not covered by the docstring "
                          "lint (moved or deleted?)")
    return errors


def main() -> int:
    """Run all checks; print violations; return a process exit code."""
    errors = check_bench_docs() + check_readme_links() + check_docstrings()
    for e in errors:
        print(f"docs-check: {e}")
    if errors:
        print(f"docs-check: {len(errors)} violation(s)")
        return 1
    print("docs-check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
