"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].
24L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408 vocab=151936."""
from ..models.common import ArchConfig

ARCH_ID = "qwen2-moe-a2.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe", n_layers=24, d_model=2048, n_heads=16,
        n_kv=16, d_ff=1408, vocab=151936, head_dim=128,
        n_experts=60, top_k=4, n_shared_experts=4, d_expert=1408,
        tie_embeddings=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=96, vocab=256, head_dim=16,
        n_experts=8, top_k=2, n_shared_experts=2, d_expert=96, remat=False)
