"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
94L d_model=4096 64H (GQA kv=4) d_ff(expert)=1536 vocab=151936."""
from ..models.common import ArchConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe", n_layers=94, d_model=4096, n_heads=64,
        n_kv=4, d_ff=1536, vocab=151936, head_dim=128,
        n_experts=128, top_k=8, n_shared_experts=0, d_expert=1536,
        rope_theta=1_000_000.0, tie_embeddings=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=8, n_kv=2, d_ff=64, vocab=256, head_dim=8,
        n_experts=8, top_k=2, n_shared_experts=0, d_expert=64, remat=False)
