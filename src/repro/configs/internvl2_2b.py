"""internvl2-2b [vlm] — InternViT frontend STUB (input_specs provides
precomputed patch embeddings) + InternLM2 backbone [arXiv:2404.16821; hf].
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553."""
from ..models.common import ArchConfig

ARCH_ID = "internvl2-2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="vlm", n_layers=24, d_model=2048, n_heads=16,
        n_kv=8, d_ff=8192, vocab=92553, head_dim=128, n_patches=256,
        tie_embeddings=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=256, head_dim=16, n_patches=8,
        tie_embeddings=False, remat=False)
