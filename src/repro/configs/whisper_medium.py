"""whisper-medium [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified].
24L(dec)+24L(enc) d_model=1024 16H d_ff=4096 vocab=51865."""
from ..models.common import ArchConfig

ARCH_ID = "whisper-medium"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="audio", n_layers=24, n_enc_layers=24,
        d_model=1024, n_heads=16, n_kv=16, d_ff=4096, vocab=51865,
        head_dim=64, n_frames=1500, tie_embeddings=True, mlp_gated=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="audio", n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256, head_dim=16,
        n_frames=16, remat=False, mlp_gated=False)
