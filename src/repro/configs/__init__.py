"""Assigned architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from ..models.common import ArchConfig
from .shapes import SHAPES, ShapeSpec, cell_supported

_MODULES = {
    "codeqwen1.5-7b": "codeqwen15_7b",
    "starcoder2-15b": "starcoder2_15b",
    "minicpm-2b": "minicpm_2b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "whisper-medium": "whisper_medium",
    "zamba2-2.7b": "zamba2_2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.smoke_config() if smoke else mod.config()


def all_configs(smoke: bool = False) -> Dict[str, ArchConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}

__all__ = ["ARCH_IDS", "get_config", "all_configs", "SHAPES", "ShapeSpec",
           "cell_supported"]
