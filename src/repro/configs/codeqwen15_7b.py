"""codeqwen1.5-7b [dense] — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf].
32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416."""
from ..models.common import ArchConfig

ARCH_ID = "codeqwen1.5-7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=32, d_model=4096, n_heads=32,
        n_kv=32, d_ff=13440, vocab=92416, head_dim=128, rope_theta=1_000_000.0,
        tie_embeddings=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=256, head_dim=16,
        tie_embeddings=False, remat=False)
