"""falcon-mamba-7b [ssm] — attention-free mamba1 arch [arXiv:2410.05355;
unverified].  64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16."""
from ..models.common import ArchConfig

ARCH_ID = "falcon-mamba-7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm", n_layers=64, d_model=4096, n_heads=1,
        n_kv=1, d_ff=0, vocab=65024, ssm_type="mamba1", d_state=16, expand=2,
        conv_kernel=4, dt_rank=256, tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=1, n_kv=1, d_ff=0, vocab=256, ssm_type="mamba1", d_state=8,
        expand=2, conv_kernel=4, dt_rank=8, remat=False)
