"""Assigned input shapes (one set for all LM-family archs) + applicability.

  train_4k     seq 4096   x global_batch 256   (training: train_step)
  prefill_32k  seq 32768  x global_batch 32    (inference prefill)
  decode_32k   seq 32768  x global_batch 128   (one token, 32k KV cache)
  long_500k    seq 524288 x global_batch 1     (long-context decode)

``long_500k`` requires sub-quadratic attention: it runs for SSM / hybrid /
sliding-window archs and is SKIPPED for pure full-attention archs
(DESIGN.md §3.2 — a 500k dense-causal KV step is architecturally
unsupported without a sub-quadratic mechanism).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(supported, reason-if-not) for an (arch x shape) cell."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.sliding_window is not None)
        if not sub_quadratic:
            return False, ("long_500k skipped: pure full-attention arch "
                           "(no sub-quadratic mechanism)")
    return True, ""
