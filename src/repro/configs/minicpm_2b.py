"""minicpm-2b [dense] — WSD schedule, llama-like arch [arXiv:2404.06395; hf].
40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753."""
from ..models.common import ArchConfig

ARCH_ID = "minicpm-2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=40, d_model=2304, n_heads=36,
        n_kv=36, d_ff=5760, vocab=122753, head_dim=64, tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=72,
        n_heads=6, n_kv=6, d_ff=144, vocab=256, head_dim=12, remat=False)
