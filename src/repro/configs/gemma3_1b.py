"""gemma3-1b [dense] — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified].
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144."""
from ..models.common import ArchConfig

ARCH_ID = "gemma3-1b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=26, d_model=1152, n_heads=4,
        n_kv=1, d_ff=6912, vocab=262144, head_dim=256,
        sliding_window=512, global_every=6,   # layers 6,12,18,24 global
        rope_theta=1_000_000.0, tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=6, d_model=64,
        n_heads=4, n_kv=1, d_ff=128, vocab=256, head_dim=16,
        sliding_window=8, global_every=3, remat=False)
