"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].
54L d_model=2560 32H (shared attn) d_ff=10240 vocab=32000 ssm_state=64."""
from ..models.common import ArchConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="hybrid", n_layers=54, d_model=2560, n_heads=32,
        n_kv=32, d_ff=10240, vocab=32000, head_dim=80,
        ssm_type="mamba2", d_state=64, expand=2, conv_kernel=4,
        ssm_head_dim=64, attn_every=6, tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv=4, d_ff=128, vocab=256, head_dim=16,
        ssm_type="mamba2", d_state=16, expand=2, conv_kernel=4,
        ssm_head_dim=16, attn_every=2, remat=False)
