"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173; hf].
40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152."""
from ..models.common import ArchConfig

ARCH_ID = "starcoder2-15b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=40, d_model=6144, n_heads=48,
        n_kv=4, d_ff=24576, vocab=49152, head_dim=128, rope_theta=100_000.0,
        tie_embeddings=False, mlp_gated=False)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv=2, d_ff=128, vocab=256, head_dim=8,
        tie_embeddings=False, remat=False)
