"""CubeGraph core: the paper's primary contribution in JAX.

Hierarchical-grid stitched-graph index for hybrid AKNN queries with
arbitrary spatio-temporal filters (boxes, balls, polygons, compositions),
plus the paper's baselines (PostFiltering / PreFiltering / ACORN / TreeGraph).
"""
from .cubegraph import (CubeGraphConfig, CubeGraphIndex, load_index,
                        load_index_extras, save_index)
from .filters import (BallFilter, BoxFilter, ComposeFilter, Filter,
                      IntervalFilter, PolygonFilter)
from .grid import GridSpec, Layer
from .search import SearchParams, beam_search

__all__ = [
    "CubeGraphConfig", "CubeGraphIndex",
    "BallFilter", "BoxFilter", "ComposeFilter", "Filter", "IntervalFilter",
    "PolygonFilter",
    "GridSpec", "Layer", "SearchParams", "beam_search",
    "load_index", "load_index_extras", "save_index",
]
