"""Spatio-temporal filter predicates φ : R^m -> {0,1} (paper §2.1).

Filters are JAX pytrees: their parameters are arrays (traced inside jitted
search loops) while their *type* is static — each filter class gets its own
specialization of the search kernel, mirroring the paper's "predicate applied
during node traversal" with the metadata gathered alongside the node block
(Fig. 3 alignment).

Supported shapes (paper §6.1 query workloads): axis-aligned boxes, circles /
balls, simple polygons (2D, over metadata dims 0-1, with optional box bounds on
the remaining dims), and boolean compositions (e.g. "inside box but outside
circle").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BoxFilter", "BallFilter", "IntervalFilter", "PolygonFilter",
           "ComposeFilter", "Filter"]

# Sentinel for "unconstrained" bounding-box edges (planning only: the grid
# clips boxes to the dataset bounds, so any value >> data range works).
UNBOUNDED = 1e18


class Filter:
    """Base class (interface only)."""

    def contains(self, s: jnp.ndarray) -> jnp.ndarray:   # [n, m] -> bool [n]
        raise NotImplementedError

    def bounding_box(self) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def characteristic_length(self) -> float:
        """Paper §5.1: max side length for boxes/hulls, diameter for balls."""
        lo, hi = self.bounding_box()
        return float(np.max(np.asarray(hi) - np.asarray(lo)))


def _register(cls, fields):
    jax.tree_util.register_pytree_node(
        cls,
        lambda f: (tuple(getattr(f, n) for n in fields), None),
        lambda aux, ch: cls(*ch),
    )
    return cls


@dataclasses.dataclass(frozen=True)
class BoxFilter(Filter):
    """Axis-aligned box [lo, hi] over all m metadata dims."""

    lo: jnp.ndarray   # [m]
    hi: jnp.ndarray   # [m]

    def contains(self, s):
        s = jnp.asarray(s)
        return jnp.all((s >= self.lo) & (s <= self.hi), axis=-1)

    def bounding_box(self):
        return np.asarray(self.lo), np.asarray(self.hi)


@dataclasses.dataclass(frozen=True)
class IntervalFilter(Filter):
    """Interval on a single metadata dim (typically time), either end open.

    A temporal half-open window ``[t0, ∞)`` is expressed directly as
    ``IntervalFilter(dim=time_dim, lo=t0)`` — no fake ``+inf`` box edge needs
    to be synthesized by the caller.  ``dim`` is static (part of the pytree
    structure); the bounds are traced arrays.
    """

    dim: int
    lo: Optional[jnp.ndarray] = None    # scalar, None = unbounded below
    hi: Optional[jnp.ndarray] = None    # scalar, None = unbounded above

    def contains(self, s):
        s = jnp.asarray(s)
        v = s[..., self.dim]
        ok = jnp.ones(v.shape, bool)
        if self.lo is not None:
            ok = ok & (v >= self.lo)
        if self.hi is not None:
            ok = ok & (v <= self.hi)
        return ok

    def bounding_box(self):
        lo = np.full(self.dim + 1, -UNBOUNDED)
        hi = np.full(self.dim + 1, UNBOUNDED)
        if self.lo is not None:
            lo[self.dim] = float(np.asarray(self.lo))
        if self.hi is not None:
            hi[self.dim] = float(np.asarray(self.hi))
        return lo, hi


@dataclasses.dataclass(frozen=True)
class BallFilter(Filter):
    """Euclidean ball over the first ``ndim(center)`` metadata dims."""

    center: jnp.ndarray   # [mc] — ball applies to dims [0, mc)
    radius: jnp.ndarray   # scalar

    def contains(self, s):
        s = jnp.asarray(s)
        mc = self.center.shape[-1]
        d2 = jnp.sum((s[..., :mc] - self.center) ** 2, axis=-1)
        return d2 <= self.radius ** 2

    def bounding_box(self):
        c = np.asarray(self.center)
        r = float(np.asarray(self.radius))
        return c - r, c + r

    def characteristic_length(self):
        return 2.0 * float(np.asarray(self.radius))


@dataclasses.dataclass(frozen=True)
class PolygonFilter(Filter):
    """Simple polygon over metadata dims (0, 1); optional box on higher dims.

    Point-in-polygon by the crossing-number (ray casting) rule, fully
    vectorized over both points and edges so it can run inside the search loop
    (and inside the Pallas filtered-scan kernel's jnp fallback).
    """

    vertices: jnp.ndarray     # [k, 2] polygon vertices in order
    rest_lo: jnp.ndarray      # [m-2] box bounds on remaining dims (may be empty)
    rest_hi: jnp.ndarray      # [m-2]

    def contains(self, s):
        s = jnp.asarray(s)
        x, y = s[..., 0], s[..., 1]
        vx, vy = self.vertices[:, 0], self.vertices[:, 1]
        wx, wy = jnp.roll(vx, -1), jnp.roll(vy, -1)
        # Edge (v -> w) crosses the horizontal ray from (x, y) going +x?
        x_, y_ = x[..., None], y[..., None]
        cond = (vy[None] > y_) != (wy[None] > y_)
        # x coordinate of the edge at height y
        t = (y_ - vy[None]) / jnp.where(wy[None] == vy[None], 1.0, wy[None] - vy[None])
        xint = vx[None] + t * (wx[None] - vx[None])
        crossings = jnp.sum(cond & (x_ < xint), axis=-1)
        inside = (crossings % 2) == 1
        if self.rest_lo.shape[-1] > 0:
            rest = s[..., 2:]
            inside = inside & jnp.all((rest >= self.rest_lo) & (rest <= self.rest_hi), axis=-1)
        return inside

    def bounding_box(self):
        v = np.asarray(self.vertices)
        lo2, hi2 = v.min(axis=0), v.max(axis=0)
        lo = np.concatenate([lo2, np.asarray(self.rest_lo)])
        hi = np.concatenate([hi2, np.asarray(self.rest_hi)])
        return lo, hi


@dataclasses.dataclass(frozen=True)
class ComposeFilter(Filter):
    """Boolean composition of two filters. op is static ('and'|'or'|'andnot')."""

    a: Filter
    b: Filter
    op: str = "and"

    def contains(self, s):
        ca, cb = self.a.contains(s), self.b.contains(s)
        if self.op == "and":
            return ca & cb
        if self.op == "or":
            return ca | cb
        if self.op == "andnot":
            return ca & ~cb
        raise ValueError(f"unknown op {self.op!r}")

    def bounding_box(self):
        alo, ahi = self.a.bounding_box()
        blo, bhi = self.b.bounding_box()
        # sub-filters may constrain different dimension prefixes (e.g. a 2D
        # geo ball AND a 3D box with a time window): pad the shorter bounds
        # to "unconstrained" before combining.
        m = max(len(alo), len(blo))

        def pad(lo, hi):
            k = m - len(lo)
            if k:
                lo = np.concatenate([lo, np.full(k, -1e18)])
                hi = np.concatenate([hi, np.full(k, 1e18)])
            return lo, hi

        alo, ahi = pad(np.asarray(alo), np.asarray(ahi))
        blo, bhi = pad(np.asarray(blo), np.asarray(bhi))
        if self.op == "or":
            return np.minimum(alo, blo), np.maximum(ahi, bhi)
        if self.op == "and":
            return np.maximum(alo, blo), np.minimum(ahi, bhi)
        return alo, ahi   # andnot: bounded by a


_register(BoxFilter, ("lo", "hi"))
jax.tree_util.register_pytree_node(
    IntervalFilter,
    lambda f: ((f.lo, f.hi), f.dim),
    lambda dim, ch: IntervalFilter(dim, ch[0], ch[1]),
)
_register(BallFilter, ("center", "radius"))
_register(PolygonFilter, ("vertices", "rest_lo", "rest_hi"))
jax.tree_util.register_pytree_node(
    ComposeFilter,
    lambda f: ((f.a, f.b), f.op),
    lambda op, ch: ComposeFilter(ch[0], ch[1], op),
)
