"""Baselines the paper compares against (§6.1): PostFiltering, PreFiltering,
ACORN-γ, and Tree-Graph (KD-tree of per-leaf graph indices).

All baselines reuse the same batched beam-search executor as CubeGraph
(`core/search.py`) with different graphs / routing modes, so efficiency
comparisons measure the *algorithmic* differences the paper studies, not
implementation differences.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .filters import Filter
from .graph import (LayerGraph, build_layer_graph, squared_norms)
from .grid import Layer
from .search import SearchParams, beam_search

__all__ = ["MonolithicGraphIndex", "PostFilteringIndex", "PreFilteringIndex",
           "AcornIndex", "TreeGraphIndex"]


def _monolithic_layer(lo: np.ndarray, hi: np.ndarray) -> Layer:
    """A single cube covering the whole metadata space (g = 1)."""
    return Layer(level=-1, g=1, lo=np.asarray(lo, np.float64),
                 width=np.asarray(hi, np.float64) - np.asarray(lo, np.float64))


class MonolithicGraphIndex:
    """A single flat proximity graph over the full dataset (HNSW-equivalent
    base index for the PostFiltering / PreFiltering / ACORN baselines)."""

    def __init__(self, x, s, m_intra: int = 16, metric: str = "l2",
                 point_chunk: int = 2048, col_chunk: int = 2048):
        t0 = time.perf_counter()
        self.x = jnp.asarray(x, jnp.float32)
        s_np = np.asarray(s, np.float64)
        self.s = jnp.asarray(s_np, jnp.float32)
        self.norms = squared_norms(self.x)
        self.metric = metric
        self.valid = np.ones(self.x.shape[0], bool)
        layer = _monolithic_layer(s_np.min(0) - 1e-6, s_np.max(0) + 1e-6)
        self.graph: LayerGraph = build_layer_graph(
            self.x, s_np, self.norms, layer, m_intra=m_intra, m_cross=0,
            point_chunk=point_chunk, col_chunk=col_chunk, metric=metric,
            k_entry=16)
        self.build_seconds = time.perf_counter() - t0

    def index_bytes(self) -> int:
        return int(self.graph.nbrs.size * 4)

    def _search(self, queries, filt: Filter, params: SearchParams):
        seeds = np.asarray(self.graph.cubes.entry[0], np.int64)
        active = np.asarray([0], np.int64)   # the single cube is always active
        return beam_search(
            self.x, self.s, self.norms, jnp.asarray(self.valid),
            jnp.asarray(self.graph.cube_of, jnp.int32), self.graph.all_nbrs,
            queries, filt, active, seeds, params)


class PostFilteringIndex(MonolithicGraphIndex):
    """Traverse ignoring φ, apply φ post-hoc to the top-ef candidates
    (paper §2.2 — wastes distance computations; recall suffers when the
    filter is selective because the unfiltered top-ef may contain < k
    qualifying points)."""

    def query(self, queries, filt: Filter, k: int = 10, ef: int = 64,
              width: int = 4, max_iters: int = 512):
        params = SearchParams(k=ef, ef=ef, width=width, max_iters=max_iters,
                              metric=self.metric, route_mode="all",
                              collect_all=True)
        ids, dists = self._search(queries, filt, params)
        ids_np, d_np = np.asarray(ids), np.asarray(dists)
        ok = np.asarray(filt.contains(self.s[np.maximum(ids_np, 0)])) & (ids_np >= 0)
        d_np = np.where(ok, d_np, np.inf)
        order = np.argsort(d_np, axis=1)[:, :k]
        out_i = np.take_along_axis(ids_np, order, axis=1)
        out_d = np.take_along_axis(d_np, order, axis=1)
        return np.where(np.isfinite(out_d), out_i, -1), out_d


class PreFilteringIndex(MonolithicGraphIndex):
    """Route only through φ-passing nodes (paper §2.2 — the effective
    subgraph fragments at low selectivity => catastrophic recall)."""

    def query(self, queries, filt: Filter, k: int = 10, ef: int = 64,
              width: int = 4, max_iters: int = 512):
        params = SearchParams(k=k, ef=ef, width=width, max_iters=max_iters,
                              metric=self.metric, route_mode="filter")
        ids, dists = self._search(queries, filt, params)
        return np.asarray(ids), np.asarray(dists)


class AcornIndex(MonolithicGraphIndex):
    """ACORN-γ-style baseline: a γ×-denser predicate-agnostic graph searched
    with predicate-gated traversal (Patel et al., 2024). Our emulation keeps
    the full γ·M degree at search time (ACORN-1 search over the ACORN-γ
    graph), which upper-bounds ACORN's recall."""

    def __init__(self, x, s, m_intra: int = 16, gamma: int = 4,
                 metric: str = "l2", **kw):
        super().__init__(x, s, m_intra=m_intra * gamma, metric=metric, **kw)
        self.gamma = gamma

    def query(self, queries, filt: Filter, k: int = 10, ef: int = 64,
              width: int = 4, max_iters: int = 512):
        params = SearchParams(k=k, ef=ef, width=width, max_iters=max_iters,
                              metric=self.metric, route_mode="filter")
        ids, dists = self._search(queries, filt, params)
        return np.asarray(ids), np.asarray(dists)


# ---------------------------------------------------------------------------
# Tree-Graph: KD-tree over metadata with an isolated graph per leaf (§3).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _KDNode:
    lo: np.ndarray
    hi: np.ndarray
    dim: int = -1
    split: float = 0.0
    left: Optional["_KDNode"] = None
    right: Optional["_KDNode"] = None
    leaf_id: int = -1


class TreeGraphIndex:
    """KD-tree of per-leaf graphs. A query traverses the tree to find the
    leaves overlapping bbox(φ) and runs an *independent* graph search per
    leaf (the subquery explosion of Observation 2)."""

    def __init__(self, x, s, leaf_size: int = 512, m_intra: int = 16,
                 metric: str = "l2", point_chunk: int = 2048,
                 col_chunk: int = 2048):
        t0 = time.perf_counter()
        self.x = jnp.asarray(x, jnp.float32)
        s_np = np.asarray(s, np.float64)
        self.s = jnp.asarray(s_np, jnp.float32)
        self.s_np = s_np
        self.norms = squared_norms(self.x)
        self.metric = metric
        n, m = s_np.shape
        self.valid = np.ones(n, bool)

        # ---- build KD tree (median splits, cycling dims) ------------------
        self.leaf_of = np.zeros(n, np.int64)
        self._leaves: List[_KDNode] = []

        def split(ids: np.ndarray, depth: int, lo, hi) -> _KDNode:
            node = _KDNode(lo=lo, hi=hi)
            if len(ids) <= leaf_size:
                node.leaf_id = len(self._leaves)
                self.leaf_of[ids] = node.leaf_id
                self._leaves.append(node)
                return node
            dim = depth % m
            med = float(np.median(s_np[ids, dim]))
            node.dim, node.split = dim, med
            mask = s_np[ids, dim] <= med
            if mask.all() or (~mask).all():     # degenerate: force leaf
                node.dim = -1
                node.leaf_id = len(self._leaves)
                self.leaf_of[ids] = node.leaf_id
                self._leaves.append(node)
                return node
            lhi, rlo = hi.copy(), lo.copy()
            lhi[dim] = med
            rlo[dim] = med
            node.left = split(ids[mask], depth + 1, lo, lhi)
            node.right = split(ids[~mask], depth + 1, rlo, hi)
            return node

        self.root = split(np.arange(n), 0,
                          s_np.min(0) - 1e-6, s_np.max(0) + 1e-6)
        self.n_leaves = len(self._leaves)

        # ---- per-leaf graphs: reuse the layer builder with cube = leaf ----
        from .graph import _cube_map
        self.cubes = _cube_map(self.leaf_of, np.asarray(self.x))
        members = jnp.asarray(self.cubes.members)
        from .graph import occlusion_prune, topk_over_candidates
        nbrs = np.full((n, m_intra), -1, np.int32)
        rows = self.cubes.row_of(self.leaf_of)
        ids_all = np.arange(n, dtype=np.int32)
        k_cand = int(min(2 * m_intra, max(2, self.cubes.members.shape[1] - 1)))
        for lo_i in range(0, n, point_chunk):
            sel = ids_all[lo_i:lo_i + point_chunk]
            cand = members[jnp.asarray(rows[sel])]
            knn_ids, knn_d = topk_over_candidates(
                self.x[sel], cand, self.x, self.norms, k_cand,
                exclude=jnp.asarray(sel), col_chunk=col_chunk, metric=metric)
            nbrs[sel] = np.asarray(occlusion_prune(knn_ids, knn_d, self.x, m_intra))
        self.nbrs = jnp.asarray(nbrs)
        self.leaf_of_dev = jnp.asarray(self.leaf_of, jnp.int32)
        self.build_seconds = time.perf_counter() - t0

    def index_bytes(self) -> int:
        return int(self.nbrs.size * 4 + self.cubes.members.size * 4)

    def _overlapping_leaves(self, blo, bhi) -> List[int]:
        out: List[int] = []

        def rec(node: _KDNode):
            if node is None:
                return
            if np.any(node.hi < blo) or np.any(node.lo > bhi):
                return
            if node.leaf_id >= 0:
                out.append(node.leaf_id)
                return
            rec(node.left)
            rec(node.right)

        rec(self.root)
        return out

    def query(self, queries, filt: Filter, k: int = 10, ef: int = 32,
              width: int = 4, max_iters: int = 256,
              return_n_subqueries: bool = False):
        """One *independent* beam search per overlapping leaf, results merged
        post-hoc — the decoupled architecture of §3."""
        blo, bhi = filt.bounding_box()
        leaves = self._overlapping_leaves(np.asarray(blo), np.asarray(bhi))
        b = len(queries)
        all_ids = [np.full((b, k), -1)]
        all_d = [np.full((b, k), np.inf)]
        params = SearchParams(k=k, ef=ef, width=width, max_iters=max_iters,
                              metric=self.metric, route_mode="cube")
        for leaf in leaves:
            row = self.cubes.row_of(np.asarray([leaf]))[0]
            if row < 0:
                continue
            seeds = np.asarray(self.cubes.entry[row], np.int64)
            active = np.asarray([leaf], np.int64)
            ids, dists = beam_search(
                self.x, self.s, self.norms, jnp.asarray(self.valid),
                self.leaf_of_dev, self.nbrs, queries, filt, active, seeds,
                params)
            all_ids.append(np.asarray(ids))
            all_d.append(np.asarray(dists))
        ids = np.concatenate(all_ids, axis=1)
        d = np.concatenate(all_d, axis=1)
        order = np.argsort(d, axis=1)[:, :k]
        out = (np.take_along_axis(ids, order, axis=1),
               np.take_along_axis(d, order, axis=1))
        if return_n_subqueries:
            return out[0], out[1], len(leaves)
        return out
