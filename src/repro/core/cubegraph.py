"""CubeGraph index — public API (paper §4: construction, query, updates).

``CubeGraphIndex.build`` runs Alg. 1 + Alg. 2 over L grid layers;
``query`` plans (layer selection per Prop. 1 + cube identification §4.3) on
the host and executes the batched stitched-graph beam search on device;
``insert_batch`` / ``delete`` implement §4.4 dynamic updates (incremental
insertion + lazy deletion with validity mask).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .filters import BoxFilter, Filter
from .graph import (CubeMap, LayerGraph, build_layer_graph, occlusion_prune,
                    squared_norms, topk_over_candidates)
from .grid import GridSpec
from .search import SearchParams, beam_search

__all__ = ["CubeGraphConfig", "CubeGraphIndex", "QueryStats"]


def _next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class CubeGraphConfig:
    n_layers: int = 4
    m_intra: int = 16              # max intra-cube degree  (paper: M)
    m_cross: int = 4               # cross-cube degree       (paper: M_cross)
    metric: str = "l2"
    min_cube_size: int = 50        # hierarchy termination (paper Exp-4)
    point_chunk: int = 2048
    col_chunk: int = 2048


@dataclasses.dataclass
class QueryStats:
    layer: int
    n_active_cubes: int
    elastic_capacity: int
    mode: str
    plan_ms: float = 0.0
    search_ms: float = 0.0


class CubeGraphIndex:
    """Hierarchical-grid stitched-graph index (the paper's contribution)."""

    def __init__(self, cfg: CubeGraphConfig, grid: GridSpec,
                 layers: List[LayerGraph], x, s, norms, valid):
        self.cfg = cfg
        self.grid = grid
        self.layers = layers
        self.x = x                       # jnp [n, d] fp32
        self.s = s                       # jnp [n, m] fp32
        self.s_np = np.asarray(s)
        self.norms = norms               # jnp [n]
        self.valid = valid               # np bool [n]
        self.build_seconds: float = 0.0

    # ------------------------------------------------------------------
    # Construction (Alg. 1 + Alg. 2)
    # ------------------------------------------------------------------
    @staticmethod
    def build(x, s, cfg: CubeGraphConfig = CubeGraphConfig()) -> "CubeGraphIndex":
        t0 = time.perf_counter()
        x = jnp.asarray(x, jnp.float32)
        s_np = np.asarray(s, np.float64)
        n, m = s_np.shape
        # int32 cube ids must not overflow: g^m < 2^31.
        max_layers = cfg.n_layers
        while (2 ** (max_layers)) ** m >= 2 ** 31:
            max_layers -= 1
        grid = GridSpec.fit(s_np, n_layers=max_layers)
        norms = squared_norms(x)
        layers: List[LayerGraph] = []
        for level in range(grid.n_layers):
            layer = grid.layer(level)
            lg = build_layer_graph(
                x, s_np, norms, layer, m_intra=cfg.m_intra, m_cross=cfg.m_cross,
                point_chunk=cfg.point_chunk, col_chunk=cfg.col_chunk,
                metric=cfg.metric)
            layers.append(lg)
            # Hierarchy termination: stop when typical cubes get too small.
            if len(lg.cubes.counts) and np.median(lg.cubes.counts) < cfg.min_cube_size:
                break
        idx = CubeGraphIndex(cfg, grid, layers, x, jnp.asarray(s_np, jnp.float32),
                             norms, np.ones(n, bool))
        idx.build_seconds = time.perf_counter() - t0
        return idx

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    @property
    def m(self) -> int:
        return int(self.s.shape[1])

    @property
    def n_built_layers(self) -> int:
        return len(self.layers)

    # ------------------------------------------------------------------
    # Query planning (§4.3: layer selection + cube identification)
    # ------------------------------------------------------------------
    def select_layer(self, filt: Filter, layer: Optional[int] = None) -> int:
        if layer is not None:
            return int(np.clip(layer, 0, self.n_built_layers - 1))
        lsel = self.grid.select_layer(filt.characteristic_length())
        return int(np.clip(lsel, 0, self.n_built_layers - 1))

    def _bounds(self, filt: Filter):
        """Filter bounding box conformed to the grid: padded to m dims when
        the filter constrains only a prefix (BallFilter) or a single dim
        (IntervalFilter), then clipped to the global box."""
        blo, bhi = filt.bounding_box()
        blo = np.asarray(blo, np.float64)
        bhi = np.asarray(bhi, np.float64)
        pad = self.grid.m - len(blo)
        if pad > 0:
            blo = np.concatenate([blo, np.full(pad, -np.inf)])
            bhi = np.concatenate([bhi, np.full(pad, np.inf)])
        blo = np.clip(blo[: self.grid.m], self.grid.lo, self.grid.hi)
        bhi = np.clip(bhi[: self.grid.m], self.grid.lo, self.grid.hi)
        return blo, bhi

    def _plan_predetermined(self, filt: Filter, level: int):
        lg = self.layers[level]
        blo, bhi = self._bounds(filt)
        cube_ids = lg.layer.cubes_overlapping_box(blo, bhi)
        rows = lg.cubes.row_of(cube_ids)
        cube_ids = cube_ids[rows >= 0]                     # drop empty cubes
        entries = lg.entry_of_cubes(cube_ids).reshape(-1)
        entries = entries[entries >= 0]
        cap = _next_pow2(max(len(cube_ids), 3 ** self.m, 8))
        active = np.full(cap, -1, np.int64)
        active[: len(cube_ids)] = cube_ids
        seeds = np.full(_next_pow2(max(len(entries), 4)), -1, np.int64)
        seeds[: len(entries)] = entries
        return active, seeds, len(cube_ids)

    def _plan_onthefly(self, filt: Filter, level: int):
        lg = self.layers[level]
        blo, bhi = self._bounds(filt)
        center = (np.asarray(blo) + np.asarray(bhi)) / 2.0
        c0 = int(lg.layer.cube_of(center[None])[0])
        if lg.cubes.row_of(np.asarray([c0]))[0] < 0:
            # entry cube empty: fall back to the nonempty cube nearest (in
            # grid coords) to the filter center.
            cand = lg.cubes.uniq
            cc = lg.layer.unflatten(cand).astype(np.float64)
            target = lg.layer.coords_of(center[None])[0].astype(np.float64)
            c0 = int(cand[np.argmin(((cc - target) ** 2).sum(axis=1))])
        cap = _next_pow2(max(4 * (3 ** self.m), 16))
        active = np.full(cap, -1, np.int64)
        active[0] = c0
        seeds = lg.entry_of_cubes(np.asarray([c0]))[0]
        return active, seeds, 1

    # ------------------------------------------------------------------
    # Query execution
    # ------------------------------------------------------------------
    def query(
        self,
        queries,                        # [b, d]
        filt: Filter,
        k: int = 10,
        ef: int = 64,
        mode: str = "auto",             # auto | predetermined | onthefly
        layer: Optional[int] = None,
        width: int = 4,
        max_iters: int = 512,
        return_stats: bool = False,
        tie_gids=None,                  # [n] optional (dist, gid) tie-break key
    ) -> Tuple[np.ndarray, np.ndarray]:
        t0 = time.perf_counter()
        level = self.select_layer(filt, layer)
        lg = self.layers[level]
        if mode == "auto":
            mode = "predetermined" if isinstance(filt, BoxFilter) else "onthefly"
        if mode == "predetermined":
            active, seeds, n_active = self._plan_predetermined(filt, level)
            dynamic = False
        else:
            active, seeds, n_active = self._plan_onthefly(filt, level)
            dynamic = True
        t1 = time.perf_counter()
        params = SearchParams(k=k, ef=ef, width=width, max_iters=max_iters,
                              metric=self.cfg.metric, route_mode="cube",
                              dynamic_cubes=dynamic)
        ids, dists = beam_search(
            self.x, self.s, self.norms, jnp.asarray(self.valid),
            jnp.asarray(lg.cube_of, jnp.int32), lg.all_nbrs,
            queries, filt, active, seeds, params, tie_key=tie_gids)
        ids = np.asarray(ids)
        dists = np.asarray(dists)
        t2 = time.perf_counter()
        if return_stats:
            stats = QueryStats(layer=level, n_active_cubes=n_active,
                               elastic_capacity=len(active), mode=mode,
                               plan_ms=(t1 - t0) * 1e3, search_ms=(t2 - t1) * 1e3)
            return ids, dists, stats
        return ids, dists

    # ------------------------------------------------------------------
    # Dynamic updates (§4.4)
    # ------------------------------------------------------------------
    def insert_batch(self, x_new, s_new) -> None:
        """Incremental insertion: per layer, connect new points to their cube
        (occlusion-pruned), add reverse edges (re-pruned), add cross edges."""
        x_new = jnp.asarray(x_new, jnp.float32)
        s_new_np = np.asarray(s_new, np.float64)
        n_old, n_add = self.n, x_new.shape[0]
        self.x = jnp.concatenate([self.x, x_new], axis=0)
        self.s = jnp.concatenate([self.s, jnp.asarray(s_new_np, jnp.float32)], axis=0)
        self.s_np = np.concatenate([self.s_np, s_new_np.astype(self.s_np.dtype)], axis=0)
        self.norms = jnp.concatenate([self.norms, squared_norms(x_new)])
        self.valid = np.concatenate([self.valid, np.ones(n_add, bool)])
        new_ids = np.arange(n_old, n_old + n_add, dtype=np.int32)
        x_all_np = np.asarray(self.x)

        for li, lg in enumerate(self.layers):
            m = self.m
            cfg = self.cfg
            coords = lg.layer.coords_of(s_new_np)
            cubes_new = lg.layer.flat_of(coords)
            # -- extend membership table (may add new cubes / grow padding) --
            cube_of = np.concatenate([lg.cube_of, cubes_new])
            from .graph import _cube_map, _face_adjacent_flat   # reuse internals
            cubes = _cube_map(cube_of, x_all_np)

            nbrs = np.concatenate(
                [np.asarray(lg.nbrs),
                 np.full((n_add, cfg.m_intra), -1, np.int32)], axis=0)
            xn = np.asarray(lg.xnbrs).reshape(n_old, 2 * m, cfg.m_cross)
            xnbrs = np.concatenate(
                [xn, np.full((n_add, 2 * m, cfg.m_cross), -1, np.int32)], axis=0)

            members = jnp.asarray(cubes.members)
            rows_new = cubes.row_of(cubes_new)
            adj_new = _face_adjacent_flat(coords, lg.layer.g)
            adj_rows = cubes.row_of(adj_new)

            k_cand = int(min(2 * cfg.m_intra, max(2, cubes.members.shape[1] - 1)))
            for lo in range(0, n_add, cfg.point_chunk):
                sel = new_ids[lo:lo + cfg.point_chunk]
                qv = self.x[sel]
                cand = members[jnp.asarray(rows_new[lo:lo + cfg.point_chunk])]
                knn_ids, knn_d = topk_over_candidates(
                    qv, cand, self.x, self.norms, k_cand,
                    exclude=jnp.asarray(sel), col_chunk=cfg.col_chunk,
                    metric=cfg.metric)
                pruned = np.asarray(occlusion_prune(knn_ids, knn_d, self.x,
                                                    cfg.m_intra))
                nbrs[sel] = pruned
                for direction in range(2 * m):
                    rr = adj_rows[lo:lo + cfg.point_chunk, direction]
                    if np.all(rr < 0):
                        continue
                    cd = cubes.members[np.maximum(rr, 0)].copy()
                    cd[rr < 0] = -1
                    xi, _ = topk_over_candidates(
                        qv, jnp.asarray(cd), self.x, self.norms, cfg.m_cross,
                        col_chunk=cfg.col_chunk, metric=cfg.metric)
                    xnbrs[sel, direction] = np.asarray(xi)

            # -- reverse edges: make new points discoverable -----------------
            src = np.repeat(new_ids, cfg.m_intra)
            dst = nbrs[new_ids].reshape(-1)
            ok = dst >= 0
            src, dst = src[ok], dst[ok]
            if len(dst):
                affected = np.unique(dst)
                # candidates per affected node: current nbrs + new backlinks
                back: dict = {}
                for s_, d_ in zip(src, dst):
                    back.setdefault(d_, []).append(s_)
                r_max = max(len(v) for v in back.values())
                cand_rows = np.full((len(affected), cfg.m_intra + r_max), -1,
                                    np.int32)
                cand_rows[:, :cfg.m_intra] = nbrs[affected]
                for i, a in enumerate(affected):
                    bl = back[a]
                    cand_rows[i, cfg.m_intra:cfg.m_intra + len(bl)] = bl
                ci, cd_ = topk_over_candidates(
                    self.x[affected], jnp.asarray(cand_rows), self.x,
                    self.norms, min(cfg.m_intra + r_max, cand_rows.shape[1]),
                    exclude=jnp.asarray(affected.astype(np.int32)),
                    metric=cfg.metric)
                nbrs[affected] = np.asarray(
                    occlusion_prune(ci, cd_, self.x, cfg.m_intra))

            self.layers[li] = LayerGraph(
                level=lg.level, layer=lg.layer, cube_of=cube_of, cubes=cubes,
                nbrs=jnp.asarray(nbrs),
                xnbrs=jnp.asarray(xnbrs.reshape(n_old + n_add, 2 * m * cfg.m_cross)))

    def delete(self, ids: Sequence[int]) -> None:
        """Lazy deletion (§4.4): O(1) validity-mask update per id."""
        self.valid[np.asarray(ids, np.int64)] = False

    def deleted_fraction(self) -> float:
        return float(1.0 - self.valid.mean())

    def compact(self) -> "CubeGraphIndex":
        """Rebuild over live points (paper: periodic reclamation)."""
        keep = np.nonzero(self.valid)[0]
        return CubeGraphIndex.build(np.asarray(self.x)[keep],
                                    self.s_np[keep], self.cfg)

    # ------------------------------------------------------------------
    def index_bytes(self) -> int:
        total = 0
        for lg in self.layers:
            total += lg.nbrs.size * 4 + lg.xnbrs.size * 4
            total += lg.cube_of.size * 8 + lg.cubes.members.size * 4
        return int(total)

    def stats(self) -> dict:
        return {
            "n": self.n, "m": self.m, "layers": self.n_built_layers,
            "index_MB": self.index_bytes() / 1e6,
            "vector_MB": self.x.size * 4 / 1e6,
            "build_seconds": self.build_seconds,
            "per_layer_cubes": [int(lg.cubes.n_nonempty) for lg in self.layers],
        }


# ---------------------------------------------------------------------------
# Persistence (production serving: build offline, load in serving replicas)
# ---------------------------------------------------------------------------
def save_index(idx: CubeGraphIndex, directory: str,
               extra_arrays: Optional[dict] = None,
               extra_meta: Optional[dict] = None) -> None:
    """Serialize the full index (vectors, metadata, per-layer graphs).

    The big point arrays (``x``, ``s``, ``valid``) are written as standalone
    ``.npy`` files so replicas can warm-start them with
    ``np.load(mmap_mode="r")``; the (compressible) graph arrays go into one
    ``arrays.npz``.  ``extra_arrays`` / ``extra_meta`` let callers attach
    artifact-level payloads (the streaming layer stores per-segment gid
    maps, time ranges, and segment ids this way): each extra array lands in
    ``<name>.npy`` and ``extra_meta`` round-trips through ``meta.json``.
    """
    import json
    import os
    os.makedirs(directory, exist_ok=True)
    np.save(os.path.join(directory, "x.npy"), np.asarray(idx.x))
    np.save(os.path.join(directory, "s.npy"), idx.s_np)
    np.save(os.path.join(directory, "valid.npy"), idx.valid)
    for name, arr in (extra_arrays or {}).items():
        np.save(os.path.join(directory, f"{name}.npy"), np.asarray(arr))
    np.savez_compressed(
        os.path.join(directory, "arrays.npz"),
        **{f"l{i}_nbrs": np.asarray(lg.nbrs) for i, lg in enumerate(idx.layers)},
        **{f"l{i}_xnbrs": np.asarray(lg.xnbrs) for i, lg in enumerate(idx.layers)},
        **{f"l{i}_cube_of": lg.cube_of for i, lg in enumerate(idx.layers)},
        **{f"l{i}_uniq": lg.cubes.uniq for i, lg in enumerate(idx.layers)},
        **{f"l{i}_members": lg.cubes.members for i, lg in enumerate(idx.layers)},
        **{f"l{i}_counts": lg.cubes.counts for i, lg in enumerate(idx.layers)},
        **{f"l{i}_entry": lg.cubes.entry for i, lg in enumerate(idx.layers)},
    )
    meta = {"cfg": dataclasses.asdict(idx.cfg), "n_layers": len(idx.layers),
            "grid": {"lo": idx.grid.lo.tolist(), "hi": idx.grid.hi.tolist(),
                     "n_layers": idx.grid.n_layers},
            "levels": [lg.level for lg in idx.layers],
            "extra": dict(extra_meta or {})}
    with open(os.path.join(directory, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_index(directory: str, mmap_mode: Optional[str] = None
               ) -> CubeGraphIndex:
    """Deserialize an index saved by :func:`save_index`.

    Every array pulled from ``arrays.npz`` is materialized *inside* the
    ``np.load`` context, so nothing the returned index holds aliases the
    (closed) archive handle — the index stays queryable after the on-disk
    artifact is deleted.  ``mmap_mode`` (e.g. ``"r"``) memory-maps the
    standalone ``x.npy`` / ``s.npy`` point arrays: the fp64 metadata
    (``s_np``, used for host-side planning) then stays disk-backed and
    lazily paged, while the vectors are still uploaded to the device here
    (queries need them resident; the mmap only spares the intermediate
    host copy).  ``valid`` is always a fresh writable copy (lazy deletion
    mutates it in place).
    """
    import json
    import os
    from .graph import CubeMap, LayerGraph, squared_norms
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    cfg = CubeGraphConfig(**meta["cfg"])
    grid = GridSpec(lo=np.asarray(meta["grid"]["lo"]),
                    hi=np.asarray(meta["grid"]["hi"]),
                    n_layers=meta["grid"]["n_layers"])
    x_path = os.path.join(directory, "x.npy")
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        if os.path.exists(x_path):
            x_np = np.load(x_path, mmap_mode=mmap_mode)
            s_np = np.load(os.path.join(directory, "s.npy"),
                           mmap_mode=mmap_mode)
            valid = np.array(np.load(os.path.join(directory, "valid.npy")))
        else:                       # legacy artifacts: everything in the npz
            x_np, s_np, valid = z["x"], z["s"], np.array(z["valid"])
        layers = []
        for i, level in enumerate(meta["levels"]):
            cubes = CubeMap(uniq=np.array(z[f"l{i}_uniq"]),
                            members=np.array(z[f"l{i}_members"]),
                            counts=np.array(z[f"l{i}_counts"]),
                            entry=np.array(z[f"l{i}_entry"]))
            layers.append(LayerGraph(
                level=level, layer=grid.layer(level),
                cube_of=np.array(z[f"l{i}_cube_of"]), cubes=cubes,
                nbrs=jnp.asarray(np.array(z[f"l{i}_nbrs"])),
                xnbrs=jnp.asarray(np.array(z[f"l{i}_xnbrs"]))))
    x = jnp.asarray(x_np)
    idx = CubeGraphIndex(cfg, grid, layers, x,
                         jnp.asarray(s_np, jnp.float32),
                         squared_norms(x), valid)
    idx.s_np = s_np          # fresh array (or caller-requested memmap view)
    return idx


def load_index_extras(directory: str, names: Sequence[str],
                      mmap_mode: Optional[str] = None):
    """(arrays dict for ``names``, extra_meta dict) attached by
    :func:`save_index` — the artifact-level payload without the index."""
    import json
    import os
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    arrays = {name: np.load(os.path.join(directory, f"{name}.npy"),
                            mmap_mode=mmap_mode) for name in names}
    return arrays, meta.get("extra", {})
