"""Synthetic datasets + query workloads mirroring the paper's Exp setup (§6.1).

Metadata distributions (Exp-8): uniform, normal, clustered, skewed, hollow.
Filter workloads: axis-aligned boxes (with ~20% edge-length fluctuation),
circles, random 3-5 vertex polygons, and composed filters ("inside box but
outside circle"), each targeting a requested filter ratio (fraction of the
metadata-space volume, §6.1 Filter Ratios).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .filters import BallFilter, BoxFilter, ComposeFilter, Filter, PolygonFilter

__all__ = [
    "make_dataset", "make_box_filter", "make_ball_filter",
    "make_polygon_filter", "make_compose_filter", "ground_truth", "recall",
]


def make_dataset(n: int, d: int, m: int, distribution: str = "uniform",
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Vectors ~ unit-normalized gaussian mixture (SIFT-like clusterable
    embeddings); metadata in [0, 1]^m under the requested distribution."""
    rng = np.random.default_rng(seed)
    # Vectors: mixture of 32 gaussian clusters (graph-friendly structure).
    n_clusters = min(32, max(2, n // 64))
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + 0.3 * rng.normal(size=(n, d)).astype(np.float32)

    if distribution == "uniform":
        s = rng.uniform(0, 1, size=(n, m))
    elif distribution == "normal":
        s = np.clip(rng.normal(0.5, 0.15, size=(n, m)), 0, 1)
    elif distribution == "clustered":
        n_sc = 8
        sc = rng.uniform(0.1, 0.9, size=(n_sc, m))
        sa = rng.integers(0, n_sc, size=n)
        s = np.clip(sc[sa] + rng.normal(0, 0.03, size=(n, m)), 0, 1)
    elif distribution == "skewed":
        s = rng.beta(0.5, 2.0, size=(n, m))
    elif distribution == "hollow":
        # points pushed away from the center (annulus-like in every dim pair)
        s = rng.uniform(0, 1, size=(n, m))
        ctr = s - 0.5
        r = np.linalg.norm(ctr, axis=1, keepdims=True) + 1e-9
        s = 0.5 + ctr / r * np.maximum(r, 0.25 + 0.25 * rng.uniform(size=(n, 1)))
        s = np.clip(s, 0, 1)
    else:
        raise ValueError(distribution)
    return x.astype(np.float32), s.astype(np.float64)


def _box_from_ratio(rng, m, ratio, aspect: float = 1.0):
    """Box with volume ~= ratio of [0,1]^m; aspect = r_max/r_min (2D dims 0,1)."""
    side = ratio ** (1.0 / m)
    sides = np.full(m, side)
    if aspect > 1.0 and m >= 2:
        f = aspect ** 0.5
        sides[0] = min(side * f, 0.999)
        sides[1] = ratio / np.prod(np.delete(sides, 1)[:m - 1]) if m > 1 else side
        sides[1] = min(max(sides[1], 1e-4), 0.999)
    sides = sides * rng.uniform(0.9, 1.1, size=m)          # ~20% fluctuation
    sides = np.clip(sides, 1e-4, 0.999)
    lo = rng.uniform(0, 1 - sides)
    return lo, lo + sides


def make_box_filter(m: int, ratio: float, seed: int = 0,
                    aspect: float = 1.0) -> BoxFilter:
    rng = np.random.default_rng(seed)
    lo, hi = _box_from_ratio(rng, m, ratio, aspect)
    return BoxFilter(lo=lo.astype(np.float32), hi=hi.astype(np.float32))


def make_ball_filter(m: int, ratio: float, seed: int = 0) -> Filter:
    """Ball over the first two dims (geo circle), box over the rest."""
    rng = np.random.default_rng(seed)
    mc = min(m, 2)
    # volume of 2D disc = pi r^2; choose rest-dims box side so total ~= ratio
    if m > mc:
        rest_side = (ratio ** (1.0 / m))
        area2d = ratio / (rest_side ** (m - mc))
    else:
        area2d = ratio
    r = float(np.sqrt(area2d / np.pi))
    r = min(r, 0.49)
    center = rng.uniform(r, 1 - r, size=mc)
    ball = BallFilter(center=center.astype(np.float32), radius=np.float32(r))
    if m == mc:
        return ball
    lo = rng.uniform(0, 1 - rest_side, size=m - mc)
    box_lo = np.concatenate([np.zeros(mc), lo])
    box_hi = np.concatenate([np.ones(mc), lo + rest_side])
    return ComposeFilter(ball, BoxFilter(lo=box_lo.astype(np.float32),
                                         hi=box_hi.astype(np.float32)), "and")


def make_polygon_filter(m: int, ratio: float, n_vertices: int = 5,
                        seed: int = 0) -> PolygonFilter:
    """Random star-convex polygon over dims (0,1), box over the rest."""
    rng = np.random.default_rng(seed)
    if m > 2:
        rest_side = ratio ** (1.0 / m)
        area2d = ratio / (rest_side ** (m - 2))
    else:
        rest_side = None
        area2d = ratio
    # polygon ~ regular n-gon area = 1/2 n R^2 sin(2pi/n); randomize radii
    base_r = np.sqrt(2 * area2d / (n_vertices * np.sin(2 * np.pi / n_vertices)))
    base_r = min(base_r, 0.45)
    ctr = rng.uniform(base_r, 1 - base_r, size=2)
    angles = np.sort(rng.uniform(0, 2 * np.pi, size=n_vertices))
    radii = base_r * rng.uniform(0.7, 1.3, size=n_vertices)
    verts = ctr + np.stack([radii * np.cos(angles), radii * np.sin(angles)], -1)
    verts = np.clip(verts, 0, 1)
    if m == 2:
        rest_lo = np.zeros(0)
        rest_hi = np.zeros(0)
    else:
        lo = rng.uniform(0, 1 - rest_side, size=m - 2)
        rest_lo, rest_hi = lo, lo + rest_side
    return PolygonFilter(vertices=verts.astype(np.float32),
                         rest_lo=rest_lo.astype(np.float32),
                         rest_hi=rest_hi.astype(np.float32))


def make_compose_filter(m: int, ratio: float, seed: int = 0) -> ComposeFilter:
    """Paper Exp-3 'Compose': inside a box but outside a circle."""
    rng = np.random.default_rng(seed)
    lo, hi = _box_from_ratio(rng, m, min(ratio * 1.5, 0.6))
    box = BoxFilter(lo=lo.astype(np.float32), hi=hi.astype(np.float32))
    ctr2 = (lo[:2] + hi[:2]) / 2
    hole_r = 0.25 * float(np.min(hi[:2] - lo[:2]))
    hole = BallFilter(center=ctr2.astype(np.float32), radius=np.float32(hole_r))
    return ComposeFilter(box, hole, "andnot")


def ground_truth(x: np.ndarray, s: np.ndarray, queries: np.ndarray,
                 filt: Optional[Filter], k: int,
                 valid: Optional[np.ndarray] = None,
                 metric: str = "l2") -> Tuple[np.ndarray, np.ndarray]:
    """Exact filtered top-k by brute force (numpy oracle).  ``filt=None``
    means unfiltered."""
    import jax.numpy as jnp
    if filt is None:
        mask = np.ones(len(s), bool)
    else:
        mask = np.asarray(filt.contains(jnp.asarray(s)))
    if valid is not None:
        mask = mask & valid
    idx = np.nonzero(mask)[0]
    if len(idx) == 0:
        b = len(queries)
        return np.full((b, k), -1), np.full((b, k), np.inf)
    xv = x[idx]
    if metric == "l2":
        d = ((queries[:, None, :] - xv[None, :, :]) ** 2).sum(-1)
    else:
        d = -queries @ xv.T
    kk = min(k, len(idx))
    part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
    dd = np.take_along_axis(d, part, axis=1)
    order = np.argsort(dd, axis=1)
    ids = idx[np.take_along_axis(part, order, axis=1)]
    dd = np.take_along_axis(dd, order, axis=1)
    b = len(queries)
    out_i = np.full((b, k), -1)
    out_d = np.full((b, k), np.inf)
    out_i[:, :kk] = ids
    out_d[:, :kk] = dd
    return out_i, out_d


def recall(result_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """recall@k = |R ∩ A| / |R_valid| averaged over queries (paper §6.1)."""
    total, hit = 0, 0
    for r, g in zip(result_ids, gt_ids):
        gset = set(int(v) for v in g if v >= 0)
        if not gset:
            continue
        hit += len(gset & set(int(v) for v in r if v >= 0))
        total += len(gset)
    return hit / max(total, 1)
