"""Hierarchical grid over the spatio-temporal metadata space (paper §4.1).

Layer ``l`` (0-based) partitions the global bounding box into ``(2**(l+1))**m``
uniform cubes of side ``w_l = |B| / 2**(l+1)`` per dimension (Alg. 1 line 3-4).

All planning math here is host-side numpy: cube identification and layer
selection are query *planning* (O(3^m) work), while the search itself runs as
jitted JAX (see ``core/search.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "GridSpec",
    "Layer",
]


@dataclasses.dataclass(frozen=True)
class Layer:
    """One grid layer: granularity ``g`` cubes per dimension."""

    level: int
    g: int                      # cubes per dimension
    lo: np.ndarray              # [m] box lower corner
    width: np.ndarray           # [m] cube side length per dimension

    @property
    def n_cubes(self) -> int:
        return int(self.g ** len(self.lo))

    # -- cube id math ------------------------------------------------------
    def coords_of(self, s: np.ndarray) -> np.ndarray:
        """Metadata ``[n, m]`` -> integer grid coordinates ``[n, m]``."""
        c = np.floor((np.asarray(s) - self.lo) / self.width).astype(np.int64)
        return np.clip(c, 0, self.g - 1)

    def flat_of(self, coords: np.ndarray) -> np.ndarray:
        """Grid coordinates ``[n, m]`` -> flat cube ids ``[n]`` (row-major)."""
        m = coords.shape[-1]
        flat = np.zeros(coords.shape[:-1], dtype=np.int64)
        for d in range(m):
            flat = flat * self.g + coords[..., d]
        return flat

    def cube_of(self, s: np.ndarray) -> np.ndarray:
        return self.flat_of(self.coords_of(s))

    def unflatten(self, flat: np.ndarray) -> np.ndarray:
        m = len(self.lo)
        flat = np.asarray(flat)
        out = np.zeros(flat.shape + (m,), dtype=np.int64)
        for d in reversed(range(m)):
            out[..., d] = flat % self.g
            flat = flat // self.g
        return out

    def cube_bounds(self, flat: np.ndarray):
        """Flat ids -> (lo, hi) corner arrays ``[..., m]``."""
        coords = self.unflatten(flat)
        lo = self.lo + coords * self.width
        return lo, lo + self.width

    # -- adjacency ---------------------------------------------------------
    def face_neighbors(self, flat: int) -> np.ndarray:
        """Up to ``2m`` face-adjacent cube ids; -1 where out of bounds.

        Order: [dim0-, dim0+, dim1-, dim1+, ...] — fixed so cross-edge
        column blocks line up with directions (Fig. 3 layout).
        """
        m = len(self.lo)
        coords = self.unflatten(np.asarray([flat]))[0]
        out = np.full(2 * m, -1, dtype=np.int64)
        for d in range(m):
            for j, delta in enumerate((-1, +1)):
                c = coords.copy()
                c[d] += delta
                if 0 <= c[d] < self.g:
                    out[2 * d + j] = self.flat_of(c[None])[0]
        return out

    # -- filter planning ---------------------------------------------------
    def cubes_overlapping_box(self, blo: np.ndarray, bhi: np.ndarray) -> np.ndarray:
        """All flat cube ids whose cell intersects the closed box [blo, bhi]."""
        m = len(self.lo)
        lo_c = np.clip(np.floor((np.asarray(blo) - self.lo) / self.width).astype(np.int64), 0, self.g - 1)
        hi_c = np.clip(np.floor((np.asarray(bhi) - self.lo) / self.width - 1e-12).astype(np.int64), 0, self.g - 1)
        ranges = [np.arange(lo_c[d], hi_c[d] + 1) for d in range(m)]
        grids = np.meshgrid(*ranges, indexing="ij")
        coords = np.stack([g.reshape(-1) for g in grids], axis=-1)
        return self.flat_of(coords)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The full hierarchy: L layers over a global bounding box (Alg. 1)."""

    lo: np.ndarray              # [m]
    hi: np.ndarray              # [m]
    n_layers: int

    @staticmethod
    def fit(metadata: np.ndarray, n_layers: int = 4, pad: float = 1e-6) -> "GridSpec":
        """Compute the global bounding box B over the dataset (Alg. 1 line 1)."""
        s = np.asarray(metadata, dtype=np.float64)
        lo = s.min(axis=0) - pad
        hi = s.max(axis=0) + pad
        return GridSpec(lo=lo, hi=hi, n_layers=int(n_layers))

    @property
    def m(self) -> int:
        return int(len(self.lo))

    @property
    def extent(self) -> np.ndarray:
        return self.hi - self.lo

    def layer(self, level: int) -> Layer:
        g = 2 ** (level + 1)
        return Layer(level=level, g=g, lo=self.lo,
                     width=self.extent / g)

    def layers(self) -> Sequence[Layer]:
        return [self.layer(l) for l in range(self.n_layers)]

    # -- layer selection (paper §4.3 + Prop. 1) ----------------------------
    def select_layer(self, characteristic_length: float) -> int:
        """Largest-width layer with ``w <= r`` — i.e. ``r/2 < w_l* <= r`` when
        such a layer exists; clamps to [0, L-1] otherwise (filters smaller than
        the deepest cube width route to the bottom layer, §5.1)."""
        r = float(characteristic_length)
        # Use the max per-dimension width as "the" cube width (anisotropic
        # boxes: widths differ per dim; the bound argument applies per-dim).
        widths = [float(self.layer(l).width.max()) for l in range(self.n_layers)]
        for l in range(self.n_layers):          # widths decrease with l
            if widths[l] <= r:
                return l
        return self.n_layers - 1
