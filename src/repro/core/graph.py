"""Proximity-graph construction for CubeGraph (paper §4.2, Alg. 1 + Alg. 2).

TPU-native adaptation (see DESIGN.md §2): instead of incremental HNSW
insertion (pointer-chasing, data-dependent control flow), each cube's local
graph is built from an *exact* kNN candidate set computed with tiled MXU
matmuls, then pruned with the standard occlusion heuristic (MRNG / HNSW
"select-neighbors-heuristic").  Cross-cube edges (Alg. 2) are exact
top-``M_cross`` neighbors in each face-adjacent cube — a strictly stronger
version of the paper's ``ef_cross`` approximate search, affordable because
brute-force distance blocks run at MXU speed.

All neighbor arrays are dense ``int32`` with ``-1`` padding and are indexed by
**original dataset ids**, so the vector / metadata / norm arrays are stored
once and shared by every layer (paper Fig. 3 memory layout).  Cube-id lookup
structures are *sparse* (sorted nonempty-cube table + searchsorted) so deep
layers in high metadata dimension (g^m cubes) never allocate O(g^m) arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .grid import Layer

__all__ = [
    "CubeMap",
    "LayerGraph",
    "build_layer_graph",
    "topk_over_candidates",
    "occlusion_prune",
    "squared_norms",
]

INF = jnp.float32(np.inf)


def squared_norms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.asarray(x, jnp.float32) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# Generic primitive: running top-k over a padded candidate-id matrix.
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "col_chunk", "metric"))
def _topk_over_candidates(qv, qn, cand, x, norms, exclude, k, col_chunk, metric):
    b, s = cand.shape
    pad = (-s) % col_chunk
    cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = cand.shape[1] // col_chunk
    cand = cand.reshape(b, n_chunks, col_chunk)

    def body(i, state):
        run_ids, run_d = state
        ids = cand[:, i, :]                                   # [b, c]
        safe = jnp.maximum(ids, 0)
        xv = x[safe]                                          # [b, c, d]
        if metric == "l2":
            d = norms[safe] - 2.0 * jnp.einsum("bcd,bd->bc", xv, qv) + qn[:, None]
        else:  # inner product (negated => smaller is better)
            d = -jnp.einsum("bcd,bd->bc", xv, qv)
        bad = (ids < 0) | (ids == exclude[:, None])
        d = jnp.where(bad, INF, d)
        all_ids = jnp.concatenate([run_ids, ids], axis=1)
        all_d = jnp.concatenate([run_d, d], axis=1)
        nd, sel = jax.lax.top_k(-all_d, k)
        return jnp.take_along_axis(all_ids, sel, axis=1), -nd

    init = (jnp.full((b, k), -1, jnp.int32), jnp.full((b, k), INF))
    ids, d = jax.lax.fori_loop(0, n_chunks, body, init)
    return jnp.where(d < INF, ids, -1), d


def topk_over_candidates(
    query_vecs: jnp.ndarray,        # [b, d]
    cand_ids: jnp.ndarray,          # [b, s] int32, -1 padded
    x: jnp.ndarray,                 # [n, d] full vector store
    norms: jnp.ndarray,             # [n]
    k: int,
    exclude: Optional[jnp.ndarray] = None,   # [b] ids to mask (e.g. self)
    col_chunk: int = 1024,
    metric: str = "l2",
):
    """Exact top-k by (squared L2 | negated IP) among per-row candidate lists."""
    qv = jnp.asarray(query_vecs, jnp.float32)
    qn = squared_norms(qv)
    if exclude is None:
        exclude = jnp.full((qv.shape[0],), -1, jnp.int32)
    cc = int(min(col_chunk, max(8, cand_ids.shape[1])))
    return _topk_over_candidates(qv, qn, jnp.asarray(cand_ids, jnp.int32),
                                 x, norms, jnp.asarray(exclude, jnp.int32),
                                 int(k), cc, metric)


# ---------------------------------------------------------------------------
# Occlusion pruning (HNSW select-neighbors-heuristic / MRNG rule).
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("m_out", "backfill"))
def _occlusion_prune(cand, cand_d, x, m_out, backfill):
    b, kc = cand.shape
    safe = jnp.maximum(cand, 0)
    cv = x[safe]                                              # [b, kc, d]
    n2 = jnp.sum(cv * cv, axis=-1)
    pd = n2[:, :, None] - 2.0 * jnp.einsum("bid,bjd->bij", cv, cv) + n2[:, None, :]
    valid = cand >= 0

    def body(j, keep):
        # candidate j survives if no already-kept neighbor is closer to it
        # than the query point is: keep_i and d(c_i, c_j) < d(p, c_j) occludes.
        occluded = jnp.any(keep & (pd[:, :, j] < cand_d[:, j][:, None]), axis=1)
        kj = valid[:, j] & ~occluded
        return keep.at[:, j].set(kj)

    keep = jax.lax.fori_loop(0, kc, body, jnp.zeros((b, kc), bool))
    # order: kept (by distance rank) first, then (optionally) pruned backfill.
    rank = jnp.arange(kc)[None, :] + jnp.where(keep, 0, kc if backfill else 10 * kc)
    rank = jnp.where(valid, rank, 100 * kc)
    sel = jnp.argsort(rank, axis=1)[:, :m_out]
    out = jnp.take_along_axis(cand, sel, axis=1)
    ok = jnp.take_along_axis(rank, sel, axis=1) < (10 * kc if backfill else kc)
    return jnp.where(ok, out, -1)


def occlusion_prune(cand_ids, cand_dists, x, m_out: int, backfill: bool = True):
    """Prune a sorted-by-distance candidate list [b, kc] to degree ``m_out``."""
    return _occlusion_prune(jnp.asarray(cand_ids, jnp.int32),
                            jnp.asarray(cand_dists, jnp.float32),
                            x, int(m_out), bool(backfill))


# ---------------------------------------------------------------------------
# Sparse cube bookkeeping (no O(g^m) allocations)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CubeMap:
    """Sorted table of nonempty flat cube ids with searchsorted row lookup."""

    uniq: np.ndarray               # [n_ne] sorted nonempty flat cube ids
    members: np.ndarray            # [n_ne, p_max] int32, -1 padded (orig ids)
    counts: np.ndarray             # [n_ne]
    entry: np.ndarray              # [n_ne, k_entry] entry points (-1 pad)

    def row_of(self, cubes: np.ndarray) -> np.ndarray:
        """Flat cube ids -> member rows; -1 for empty/unknown cubes."""
        cubes = np.asarray(cubes)
        pos = np.searchsorted(self.uniq, cubes)
        pos_c = np.clip(pos, 0, len(self.uniq) - 1)
        ok = (len(self.uniq) > 0) & (self.uniq[pos_c] == cubes) & (cubes >= 0)
        return np.where(ok, pos_c, -1)

    @property
    def n_nonempty(self) -> int:
        return len(self.uniq)


def _fps_entries(v: np.ndarray, ids: np.ndarray, k: int) -> np.ndarray:
    """Greedy farthest-point-sampled entry points, seeded at the medoid.

    Multiple spread-out entries per cube keep the beam search navigable even
    when the intra-cube kNN graph has several vector-space components (the
    role HNSW's upper layers play in the reference implementation)."""
    n = len(ids)
    k = min(k, n)
    c = v.mean(axis=0, keepdims=True)
    first = int(np.argmin(((v - c) ** 2).sum(axis=1)))
    chosen = [first]
    mind = ((v - v[first]) ** 2).sum(axis=1)
    for _ in range(k - 1):
        nxt = int(np.argmax(mind))
        chosen.append(nxt)
        mind = np.minimum(mind, ((v - v[nxt]) ** 2).sum(axis=1))
    out = np.full(k, -1, dtype=np.int64)
    out[: len(chosen)] = ids[chosen]
    return out


def _cube_map(cube_of: np.ndarray, x_np: np.ndarray, k_entry: int = 4) -> CubeMap:
    order = np.argsort(cube_of, kind="stable")
    sorted_cubes = cube_of[order]
    uniq, starts, counts = np.unique(sorted_cubes, return_index=True, return_counts=True)
    p_max = int(counts.max()) if len(counts) else 1
    members = np.full((max(len(uniq), 1), p_max), -1, dtype=np.int32)
    entry = np.full((max(len(uniq), 1), k_entry), -1, dtype=np.int64)
    for row, (st, ct) in enumerate(zip(starts, counts)):
        ids = order[st:st + ct]
        members[row, :ct] = ids
        e = _fps_entries(x_np[ids], ids, k_entry)
        entry[row, : len(e)] = e
    return CubeMap(uniq=uniq, members=members, counts=counts, entry=entry)


def _face_adjacent_flat(coords: np.ndarray, g: int) -> np.ndarray:
    """[n, m] integer coords -> [n, 2m] flat ids of face-adjacent cubes (-1 OOB).

    Direction order: [dim0-, dim0+, dim1-, dim1+, ...] (matches Fig. 3 blocks).
    """
    n, m = coords.shape
    out = np.full((n, 2 * m), -1, dtype=np.int64)
    weights = g ** np.arange(m - 1, -1, -1)
    base = coords @ weights
    for d in range(m):
        for j, delta in enumerate((-1, +1)):
            nd = coords[:, d] + delta
            ok = (nd >= 0) & (nd < g)
            out[:, 2 * d + j] = np.where(ok, base + delta * weights[d], -1)
    return out


# ---------------------------------------------------------------------------
# Layer graph container + construction
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LayerGraph:
    """One grid layer's stitched-graph data (all ids = original dataset ids)."""

    level: int
    layer: Layer
    cube_of: np.ndarray            # [n] flat cube id per point
    cubes: CubeMap
    nbrs: jnp.ndarray              # [n, m_intra] intra-cube edges
    xnbrs: jnp.ndarray             # [n, 2m * m_cross] cross-cube edges

    @property
    def all_nbrs(self) -> jnp.ndarray:
        return jnp.concatenate([self.nbrs, self.xnbrs], axis=1)

    def entry_of_cubes(self, cube_ids: np.ndarray) -> np.ndarray:
        """[c] cube ids -> [c, k_entry] entry points (-1 for empty cubes)."""
        rows = self.cubes.row_of(cube_ids)
        e = self.cubes.entry[np.maximum(rows, 0)].copy()
        e[rows < 0] = -1
        return e


def build_layer_graph(
    x: jnp.ndarray,                # [n, d] fp32
    s: np.ndarray,                 # [n, m] metadata (host)
    norms: jnp.ndarray,            # [n]
    layer: Layer,
    m_intra: int = 16,
    m_cross: int = 4,
    point_chunk: int = 2048,
    col_chunk: int = 2048,
    metric: str = "l2",
    k_entry: int = 4,
    n_random: int = 8,
    seed: int = 0,
) -> LayerGraph:
    """Alg. 1 (per-cube local graphs) + Alg. 2 (cross-cube edges) for one layer.

    ``n_random`` random same-cube candidates are appended to each point's
    exact-kNN pool before occlusion pruning; the surviving ones provide the
    long-range edges that incremental HNSW insertion produces implicitly
    (without them a kNN graph over well-separated vector clusters is
    disconnected and un-navigable)."""
    n = x.shape[0]
    m = s.shape[1]
    x_np = np.asarray(x)
    coords = layer.coords_of(s)
    cube_of = layer.flat_of(coords)
    cubes = _cube_map(cube_of, x_np, k_entry=k_entry)
    members = jnp.asarray(cubes.members)
    rng = np.random.default_rng(seed + 7919 * max(layer.level, 0))

    adj_flat = _face_adjacent_flat(coords, layer.g)         # [n, 2m]
    adj_rows = cubes.row_of(adj_flat)                        # [n, 2m] member rows
    own_rows = cubes.row_of(cube_of)                         # [n]

    ids_all = np.arange(n, dtype=np.int32)
    k_cand = int(min(2 * m_intra, max(2, cubes.members.shape[1] - 1)))
    nbrs_out = np.full((n, m_intra), -1, dtype=np.int32)
    xnbrs_out = np.full((n, 2 * m, m_cross), -1, dtype=np.int32)

    counts_of_row = cubes.counts

    for lo in range(0, n, point_chunk):
        sel = ids_all[lo:lo + point_chunk]
        qv = x[sel]
        rows_sel = own_rows[sel]
        cand = members[jnp.asarray(rows_sel)]                # [c, p_max]
        knn_ids, knn_d = topk_over_candidates(
            qv, cand, x, norms, k_cand, exclude=jnp.asarray(sel),
            col_chunk=col_chunk, metric=metric)
        if n_random > 0:
            # random same-cube candidates -> long-range edge pool
            cnt = counts_of_row[rows_sel][:, None]           # [c, 1]
            pos = rng.integers(0, np.maximum(cnt, 1), size=(len(sel), n_random))
            rand_ids = cubes.members[rows_sel[:, None], pos].astype(np.int32)
            rand_ids = np.where(rand_ids == sel[:, None], -1, rand_ids)
            rj = jnp.asarray(rand_ids)
            safe = jnp.maximum(rj, 0)
            xv = x[safe]
            if metric == "l2":
                qn = jnp.sum(qv * qv, axis=-1)
                rd = norms[safe] - 2.0 * jnp.einsum("bcd,bd->bc", xv, qv) + qn[:, None]
            else:
                rd = -jnp.einsum("bcd,bd->bc", xv, qv)
            rd = jnp.where(rj < 0, INF, rd)
            all_ids = jnp.concatenate([knn_ids, rj], axis=1)
            all_d = jnp.concatenate([knn_d, rd], axis=1)
            order = jnp.argsort(all_d, axis=1)
            knn_ids = jnp.take_along_axis(all_ids, order, axis=1)
            knn_d = jnp.take_along_axis(all_d, order, axis=1)
        pruned = occlusion_prune(knn_ids, knn_d, x, m_intra)
        nbrs_out[sel] = np.asarray(pruned)

        # Alg. 2: exact top-m_cross into each face-adjacent cube
        for direction in range(2 * m):
            rows = adj_rows[sel, direction]
            if np.all(rows < 0):
                continue
            cand_dir = cubes.members[np.maximum(rows, 0)].copy()
            cand_dir[rows < 0] = -1
            xids, _ = topk_over_candidates(
                qv, jnp.asarray(cand_dir), x, norms, m_cross,
                col_chunk=col_chunk, metric=metric)
            xnbrs_out[sel, direction] = np.asarray(xids)

    return LayerGraph(
        level=layer.level,
        layer=layer,
        cube_of=cube_of,
        cubes=cubes,
        nbrs=jnp.asarray(nbrs_out),
        xnbrs=jnp.asarray(xnbrs_out.reshape(n, 2 * m * m_cross)),
    )
