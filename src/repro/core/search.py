"""Batched stitched-graph beam search (paper §4.3, Alg. 3 + Alg. 4).

TPU-native execution model (DESIGN.md §2): a `lax.while_loop` over fixed-shape
state, expanding the best ``W`` unexpanded beam nodes *per query batch* each
iteration.  Neighbor gathers, distance evaluation (one einsum on the MXU),
predicate evaluation (VPU), and the beam/result merges (masked top-k) are all
batched over queries.

Routing modes unify the paper's method and its baselines:

* ``route_mode='cube'``   — CubeGraph: follow an edge iff the target's cube is
  in the active-cube set **or** the target satisfies φ (the latter only
  matters with ``dynamic_cubes=True``, Alg. 4's discovery rule).  NB: Alg. 4's
  pseudocode checks ``B[n.cube]=0 → skip`` *before* the φ test that would set
  the bit, which would make discovery unreachable; per the prose ("the search
  naturally expands into relevant cubes as qualifying points are
  encountered") we route through φ-passing nodes and then activate their
  cubes.
* ``route_mode='all'``    — PostFiltering traversal (filter ignored while
  routing).
* ``route_mode='filter'`` — PreFiltering / ACORN-style predicate-gated
  traversal.

``collect_all=True`` makes the result set ignore φ (true post-hoc
PostFiltering; the caller applies φ afterwards).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .filters import Filter

__all__ = ["beam_search", "SearchParams"]

INF = jnp.float32(np.inf)


def _unique_mask(ids: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask keeping the first occurrence of each id per row. [b, k]"""
    order = jnp.argsort(ids, axis=1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=1)
    first = jnp.concatenate(
        [jnp.ones_like(sorted_ids[:, :1], bool),
         sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=1)
    out = jnp.zeros_like(first)
    b = ids.shape[0]
    return out.at[jnp.arange(b)[:, None], order].set(first)


def _merge_topk(ids_a, d_a, ids_b, d_b, k):
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    nd, sel = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(ids, sel, axis=1), -nd


class SearchParams:
    """Static search configuration (hashable; part of the jit cache key)."""

    def __init__(self, k: int = 10, ef: int = 64, width: int = 4,
                 max_iters: int = 512, metric: str = "l2",
                 route_mode: str = "cube", dynamic_cubes: bool = False,
                 collect_all: bool = False):
        self.k = int(k)
        self.ef = int(max(ef, k))
        self.width = int(width)
        self.max_iters = int(max_iters)
        self.metric = metric
        self.route_mode = route_mode
        self.dynamic_cubes = bool(dynamic_cubes)
        self.collect_all = bool(collect_all)

    def _key(self):
        return (self.k, self.ef, self.width, self.max_iters, self.metric,
                self.route_mode, self.dynamic_cubes, self.collect_all)

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return isinstance(other, SearchParams) and self._key() == other._key()


@partial(jax.jit, static_argnames=("p",))
def _beam_search(x, s, norms, valid, cube_of, all_nbrs,
                 q, filt: Filter, active_cubes, seeds, tie_key, p: SearchParams):
    """Core loop.  Shapes:
    x [n,d], s [n,m], norms [n], valid bool[n], cube_of int32[n],
    all_nbrs int32[n, deg], q [b,d], active_cubes int32[cmax] (-1 pad,
    shared across the batch — one filter per call), seeds int32[e],
    tie_key int32[n] or None (see ``beam_search``).
    Returns (ids [b,k], dists [b,k]) sorted ascending; -1/inf padded.
    """
    n, d = x.shape
    b = q.shape[0]
    k, ef, w = p.k, p.ef, p.width
    q = jnp.asarray(q, jnp.float32)
    qn = jnp.sum(q * q, axis=-1)

    def distances(cand):                               # [b, kc] ids -> dists
        safe = jnp.maximum(cand, 0)
        xv = x[safe]
        if p.metric == "l2":
            return norms[safe] - 2.0 * jnp.einsum("bcd,bd->bc", xv, q) + qn[:, None]
        return -jnp.einsum("bcd,bd->bc", xv, q)

    def phi(cand):                                     # [b, kc] ids -> bool
        meta = s[jnp.maximum(cand, 0)]
        return filt.contains(meta)

    # ---- init from seed entry points (shared across batch) ----------------
    seed_b = jnp.broadcast_to(seeds[None, :], (b, seeds.shape[0]))
    seed_ok = (seed_b >= 0) & valid[jnp.maximum(seed_b, 0)]
    sd = jnp.where(seed_ok, distances(seed_b), INF)
    sphi = phi(seed_b) & seed_ok

    visited = jnp.zeros((b, n), bool)
    visited = visited.at[:, jnp.maximum(seeds, 0)].max(
        jnp.broadcast_to(seeds >= 0, (b, seeds.shape[0])))

    pad_i = jnp.full((b, ef), -1, jnp.int32)
    pad_d = jnp.full((b, ef), INF)
    beam_ids, beam_d = _merge_topk(pad_i, pad_d, jnp.where(seed_ok, seed_b, -1), sd, ef)
    beam_exp = jnp.zeros((b, ef), bool)

    res_keep = sphi | (jnp.bool_(p.collect_all) & seed_ok)
    res_ids, res_d = _merge_topk(
        jnp.full((b, k), -1, jnp.int32), jnp.full((b, k), INF),
        jnp.where(res_keep, seed_b, -1), jnp.where(res_keep, sd, INF), k)

    state = (beam_ids, beam_d, beam_exp, res_ids, res_d, visited,
             active_cubes, jnp.int32(0))

    def cond(st):
        beam_ids, beam_d, beam_exp, res_ids, res_d, *_, it = st
        frontier = jnp.where(beam_exp | (beam_ids < 0), INF, beam_d)
        best = jnp.min(frontier, axis=1)
        kth = res_d[:, k - 1]
        return (it < p.max_iters) & jnp.any(best < kth)

    def body(st):
        beam_ids, beam_d, beam_exp, res_ids, res_d, visited, cubes, it = st

        # -- pick top-W unexpanded beam entries (Alg. 3/4 line 6) ----------
        frontier = jnp.where(beam_exp | (beam_ids < 0), INF, beam_d)
        kth = res_d[:, k - 1]
        negd, sel = jax.lax.top_k(-frontier, w)
        exp_ok = (-negd) < kth[:, None]                 # only expand improving
        exp_ids = jnp.take_along_axis(beam_ids, sel, axis=1)
        exp_ids = jnp.where(exp_ok, exp_ids, -1)
        beam_exp = beam_exp.at[jnp.arange(b)[:, None], sel].set(True)

        # -- gather intra + cross neighbors (Fig. 3 node block) ------------
        nb = all_nbrs[jnp.maximum(exp_ids, 0)]          # [b, w, deg]
        nb = jnp.where(exp_ids[:, :, None] >= 0, nb, -1)
        cand = nb.reshape(b, -1)                        # [b, kc]

        fresh = (cand >= 0) & valid[jnp.maximum(cand, 0)]
        fresh &= ~jnp.take_along_axis(visited, jnp.maximum(cand, 0), axis=1)
        fresh &= _unique_mask(cand)

        # -- predicate + cube gating (Alg. 3 l.8-11 / Alg. 4 l.7-11) --------
        phi_pass = phi(cand) & fresh
        ccube = cube_of[jnp.maximum(cand, 0)]
        in_active = jnp.any(ccube[:, :, None] == cubes[None, None, :], axis=-1)
        if p.route_mode == "cube":
            route = fresh & (in_active | phi_pass)
        elif p.route_mode == "all":
            route = fresh
        else:                                           # 'filter'
            route = fresh & phi_pass

        dval = distances(cand)
        droute = jnp.where(route, dval, INF)

        visited = visited.at[jnp.arange(b)[:, None], jnp.maximum(cand, 0)].max(route)

        if p.dynamic_cubes:
            # Alg. 4 line 10: activate cubes of φ-passing points (set-insert
            # with dedupe; cube set is shared across the batch — one filter).
            disc = jnp.where(phi_pass, ccube, -1).reshape(-1)
            comb = jnp.concatenate([cubes, disc.astype(jnp.int32)])
            comb = -jnp.sort(-comb)                     # descending
            dup = jnp.concatenate([jnp.zeros((1,), bool), comb[1:] == comb[:-1]])
            comb = jnp.where(dup, -1, comb)
            cubes = -jnp.sort(-comb)[: cubes.shape[0]]

        # -- beam + result merges (keep top ef / top k) ---------------------
        beam_ids, beam_d, beam_exp = _merge_beam(
            beam_ids, beam_d, beam_exp, cand, droute, ef)
        res_keep = phi_pass | (jnp.bool_(p.collect_all) & route)
        res_ids, res_d = _merge_topk(
            res_ids, res_d, jnp.where(res_keep, cand, -1),
            jnp.where(res_keep, dval, INF), k)

        return (beam_ids, beam_d, beam_exp, res_ids, res_d, visited,
                cubes, it + 1)

    def _merge_beam(bi, bd, be, ci, cd, ef):
        ids = jnp.concatenate([bi, ci], axis=1)
        dd = jnp.concatenate([bd, cd], axis=1)
        ee = jnp.concatenate([be, jnp.zeros_like(ci, bool)], axis=1)
        nd, sel = jax.lax.top_k(-dd, ef)
        take = lambda a: jnp.take_along_axis(a, sel, axis=1)
        return take(ids), -nd, take(ee)

    final = jax.lax.while_loop(cond, body, state)
    res_ids, res_d = final[3], final[4]
    res_ids = jnp.where(jnp.isfinite(res_d), res_ids, -1)
    # Deterministic (dist, tie-key) output order.  `lax.top_k` breaks
    # distance ties by *position in the merge buffer*, which depends on the
    # order candidates were encountered — i.e. on seed order, route mode, and
    # (for duplicated vectors across segments) on segment order.  A final
    # stable lexsort on (distance, key) pins the emitted list; the caller's
    # global-id key makes the invariant hold across segments (mirrors
    # `host_topk`'s np.lexsort((gid, dist)) tie-break on the merge side).
    key = res_ids if tie_key is None else tie_key[jnp.maximum(res_ids, 0)]
    key = jnp.where(res_ids >= 0, key, jnp.iinfo(jnp.int32).max)
    order = jnp.lexsort((key, res_d), axis=-1)
    res_ids = jnp.take_along_axis(res_ids, order, axis=1)
    res_d = jnp.take_along_axis(res_d, order, axis=1)
    return res_ids, res_d


def beam_search(
    x: jnp.ndarray, s: jnp.ndarray, norms: jnp.ndarray, valid: jnp.ndarray,
    cube_of: jnp.ndarray, all_nbrs: jnp.ndarray,
    queries: jnp.ndarray, filt: Filter,
    active_cubes: jnp.ndarray, seeds: jnp.ndarray,
    params: SearchParams, tie_key: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Public entry point; see `_beam_search` for shapes.

    ``tie_key`` (optional, int [n]) supplies a per-point sort key used only
    to break exact distance ties in the final result ordering; pass the
    segment's global ids so that duplicated vectors land in a stable
    (dist, gid) order regardless of local id assignment.  Defaults to the
    local id, which already makes a single index's output deterministic.
    """
    tk = None if tie_key is None else jnp.asarray(tie_key, jnp.int32)
    return _beam_search(
        jnp.asarray(x, jnp.float32), jnp.asarray(s, jnp.float32),
        jnp.asarray(norms, jnp.float32), jnp.asarray(valid, bool),
        jnp.asarray(cube_of, jnp.int32), jnp.asarray(all_nbrs, jnp.int32),
        jnp.asarray(queries, jnp.float32), filt,
        jnp.asarray(active_cubes, jnp.int32), jnp.asarray(seeds, jnp.int32),
        tk, params)
