"""Deterministic sharded synthetic-token data pipeline.

Production shape without external deps: per-host sharding, background
prefetch, and an explicit ``(step, shard)`` cursor so training resumes
bit-identically after checkpoint restore or elastic resharding.

The synthetic stream is *learnable* (affine-recurrent sequences mod vocab)
so end-to-end training tests can assert the loss actually decreases.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    learnable: bool = True          # affine-recurrent (else iid uniform)
    n_hosts: int = 1
    host_id: int = 0


class SyntheticTokenPipeline:
    """Stateless batch generator: batch(step) is a pure function of
    (config, step), so any host can regenerate any shard at any time —
    the property fault-tolerant resume and elastic scaling rely on."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        base = step * cfg.global_batch + self.cfg.host_id * self.local_batch
        # the affine rule is FIXED per dataset seed (x -> a*x+b mod V is then
        # a static vocab permutation a small model learns quickly); only the
        # starting point varies per row.
        rule = np.random.default_rng((cfg.seed, 0xA11CE))
        a = int(rule.integers(2, 8))
        b = int(rule.integers(0, cfg.vocab))
        for r in range(self.local_batch):
            rng = np.random.default_rng((cfg.seed, base + r))
            if cfg.learnable:
                x0 = int(rng.integers(0, cfg.vocab))
                seq = np.empty(cfg.seq_len + 1, np.int32)
                seq[0] = x0
                for t in range(cfg.seq_len):
                    seq[t + 1] = (a * seq[t] + b) % cfg.vocab
            else:
                seq = rng.integers(0, cfg.vocab,
                                   size=cfg.seq_len + 1).astype(np.int32)
            rows.append(seq)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PrefetchingLoader:
    """Background-thread prefetch (double buffering the host->device copy)."""

    def __init__(self, pipeline: SyntheticTokenPipeline, start_step: int = 0,
                 depth: int = 2):
        self.pipeline = pipeline
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.pipeline.batch(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
