"""Structured per-query tracing: nested spans that block on device work.

A :class:`QueryTrace` is a tree of :class:`Span` context managers opened
along the query path (delta scan, per-bucket dispatch, rerank, merge).
Two rules make the numbers honest under JAX's async dispatch:

* every span body that launches device work calls :func:`block_ready` on
  its results **before** the span closes, so the recorded duration covers
  the device computation, not just the Python-side enqueue;
* every span wraps ``jax.profiler.TraceAnnotation``, so the same span
  names line up with XLA's own timeline in a captured profile.

The disabled path is a set of shared singletons (:data:`NULL_TRACE` /
its no-op span): opening a span on a disabled trace allocates nothing
and touches no clocks, which is what keeps tracing per-query opt-in
(``SegmentManager.query(..., return_trace=True)``) rather than a
standing tax.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax

__all__ = ["NULL_TRACE", "QueryTrace", "Span", "block_ready"]


def block_ready(value):
    """``jax.block_until_ready`` that tolerates numpy/None pytrees.

    The query path's timer-stop pattern: call on every dispatch result
    before reading a clock, so measured time includes device execution.
    Returns ``value`` unchanged.
    """
    if value is None:
        return value
    return jax.block_until_ready(value)


class Span:
    """One timed node of a trace tree (use via ``QueryTrace.span``)."""

    __slots__ = ("name", "attrs", "children", "_t0", "duration_ms",
                 "_annotation")

    def __init__(self, name: str, attrs: Optional[dict] = None):
        self.name = name
        self.attrs = attrs or {}
        self.children: List[Span] = []
        self._t0 = 0.0
        self.duration_ms = 0.0
        self._annotation = None

    def annotate(self, **attrs) -> None:
        """Attach key/value attributes (bucket cap, candidate counts...)."""
        self.attrs.update(attrs)

    def start(self) -> "Span":
        """Open the XLA trace annotation and start the wall clock."""
        self._annotation = jax.profiler.TraceAnnotation(self.name)
        self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def stop(self) -> None:
        """Stop the wall clock and close the XLA annotation.  Callers must
        :func:`block_ready` device results first — that ordering is the
        whole point of the tracer."""
        self.duration_ms = (time.perf_counter() - self._t0) * 1e3
        if self._annotation is not None:
            self._annotation.__exit__(None, None, None)
            self._annotation = None

    def to_dict(self) -> dict:
        """JSON-safe ``{name, ms, attrs?, spans?}`` subtree."""
        out = {"name": self.name, "ms": round(self.duration_ms, 4)}
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["spans"] = [c.to_dict() for c in self.children]
        return out


class _SpanCtx:
    """Context manager that pushes/pops one span on its trace's stack."""

    __slots__ = ("_trace", "_span")

    def __init__(self, trace: "QueryTrace", span: Span):
        self._trace = trace
        self._span = span

    def __enter__(self) -> Span:
        self._trace._stack.append(self._span)
        return self._span.start()

    def __exit__(self, exc_type, exc, tb):
        self._span.stop()
        self._trace._stack.pop()
        return False


class QueryTrace:
    """Span tree for one query; the root span times the whole call.

    Created by ``SegmentManager.query(..., return_trace=True)`` (or
    directly) and threaded through ``streaming.query.query_segments`` and
    ``distributed.segment_shards.pack_search*``.  :meth:`finish` stops
    the root; :meth:`to_dict` exports the tree.
    """

    enabled = True

    def __init__(self, name: str = "query"):
        self.root = Span(name)
        self._stack: List[Span] = [self.root]
        self.root.start()

    def span(self, name: str, **attrs) -> _SpanCtx:
        """Open a child span of the innermost active span."""
        sp = Span(name, attrs)
        self._stack[-1].children.append(sp)
        return _SpanCtx(self, sp)

    def finish(self) -> "QueryTrace":
        """Stop the root span (idempotent enough for one query's life)."""
        if self.root._annotation is not None:
            self.root.stop()
        return self

    @property
    def total_ms(self) -> float:
        """Root span duration (finish first)."""
        return self.root.duration_ms

    def to_dict(self) -> dict:
        """JSON-safe span tree (root node)."""
        return self.root.to_dict()


class _NullSpan:
    """Shared no-op span for the disabled trace."""

    __slots__ = ()
    name = "null"
    attrs: dict = {}
    children: list = []
    duration_ms = 0.0

    def annotate(self, **attrs) -> None:
        """No-op."""

    def to_dict(self) -> dict:
        """Empty subtree."""
        return {}


class _NullSpanCtx:
    """Shared no-op span context: no clocks, no allocations."""

    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return _NULL_SPAN

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()
_NULL_CTX = _NullSpanCtx()


class _NullTrace:
    """Shared disabled tracer (the default for every query)."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, **attrs) -> _NullSpanCtx:
        """Return the shared no-op span context."""
        return _NULL_CTX

    def finish(self) -> "_NullTrace":
        """No-op."""
        return self

    @property
    def total_ms(self) -> float:
        """Always zero."""
        return 0.0

    def to_dict(self) -> dict:
        """Empty tree."""
        return {}


NULL_TRACE = _NullTrace()
