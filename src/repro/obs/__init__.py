"""Observability substrate: metrics registry, query tracer, bucket stats.

Two small modules give every layer of the streaming/serving stack a shared
measurement vocabulary without adding dependencies:

* :mod:`repro.obs.metrics` — named counters, gauges, and log-bucketed
  latency histograms behind a thread-safe :class:`MetricsRegistry`; the
  rolling per-capacity-bucket :class:`BucketStats` accumulator whose
  snapshot schema is the input contract for the cost-based planner
  (ROADMAP item 1); Prometheus text rendering and a strict-JSON
  sanitizer shared with ``SegmentManager.stats()``.
* :mod:`repro.obs.trace` — per-query :class:`QueryTrace` span trees whose
  timers stop only after ``jax.block_until_ready`` (so spans measure
  device work, not async enqueue) and wrap
  ``jax.profiler.TraceAnnotation`` for XLA profile alignment.

Disabled instances (``MetricsRegistry(enabled=False)``, ``NULL_TRACE``)
hand out shared no-op singletons, so the instrumented hot paths cost a
few attribute lookups and no per-query allocations when observability is
off.  See ``docs/observability.md`` for the metric catalog and the span
tree.
"""
from .metrics import (NULL_METRIC, NULL_REGISTRY, BucketStats, Counter,
                      Gauge, Histogram, MetricsRegistry, StreamObs,
                      json_sanitize, prometheus_text)
from .trace import NULL_TRACE, QueryTrace, Span, block_ready

__all__ = ["NULL_METRIC", "NULL_REGISTRY", "NULL_TRACE", "BucketStats",
           "Counter", "Gauge", "Histogram", "MetricsRegistry", "QueryTrace",
           "Span", "StreamObs", "block_ready", "json_sanitize",
           "prometheus_text"]
