"""Thread-safe metrics registry: counters, gauges, log-bucketed histograms.

Design constraints (in priority order):

* **Near-zero cost when disabled.**  A disabled registry hands out one
  shared :data:`NULL_METRIC` singleton for every name, so instrumented
  code keeps calling ``counter(...).inc()`` unconditionally and pays one
  attribute lookup + no-op call — no branches at call sites, no per-call
  allocations.
* **Bounded memory when enabled.**  Histograms are log-bucketed —
  :data:`SUBBUCKETS` buckets per octave (power of two), so bucket ``i``
  spans ``(V0 * 2**((i-1)/SUBBUCKETS), V0 * 2**(i/SUBBUCKETS)]`` — which
  bounds the relative error of any reported percentile at
  ``2**(1/SUBBUCKETS) - 1`` (~19% with the default 4) while storing only
  a handful of non-empty buckets per metric, independent of observation
  count.
* **Strict JSON end-to-end.**  Every snapshot is serializable with
  ``json.dumps(..., allow_nan=False)``; :func:`json_sanitize` applies the
  persistence layer's inf→null convention to arbitrary stats payloads
  (``SegmentManager.stats()`` reuses it).

:class:`BucketStats` is the rolling per-capacity-bucket observation
accumulator fed by the sharded query path; its :meth:`BucketStats.snapshot`
schema is **the input contract for the cost-based planner** (ROADMAP
item 1) — see ``docs/observability.md`` for the field-by-field contract.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Optional

__all__ = ["NULL_METRIC", "NULL_REGISTRY", "SUBBUCKETS", "BucketStats",
           "Counter", "Gauge", "Histogram", "MetricsRegistry", "StreamObs",
           "json_sanitize", "prometheus_text"]

SUBBUCKETS = 4                   # histogram buckets per octave (see above)
_V0 = 1e-6                       # smallest resolvable histogram value
_LOG2_V0 = math.log2(_V0)


class _NullMetric:
    """Shared no-op stand-in for every metric type (disabled registry)."""

    __slots__ = ()

    def inc(self, n: float = 1) -> None:
        """No-op counter increment."""

    def set(self, value: float) -> None:
        """No-op gauge assignment."""

    def observe(self, value: float) -> None:
        """No-op histogram observation."""


NULL_METRIC = _NullMetric()


class Counter:
    """Monotone named count (thread-safe)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1) -> None:
        """Add ``n`` (default 1) to the count."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current count."""
        return self._value

    def snapshot(self):
        """JSON-safe value (int when integral)."""
        v = self._value
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-write-wins named level (thread-safe enough: one float slot)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: float) -> None:
        """Record the current level."""
        self._value = float(value)

    def inc(self, n: float = 1) -> None:
        """Adjust the level by ``n`` (for resource-held style gauges)."""
        self._value += n

    @property
    def value(self) -> float:
        """Current level."""
        return self._value

    def snapshot(self) -> float:
        """JSON-safe value (non-finite levels become None)."""
        return self._value if math.isfinite(self._value) else None


class Histogram:
    """Log-bucketed distribution with p50/p95/p99 snapshots (thread-safe).

    Bucket index for a value ``v > V0`` is
    ``ceil(SUBBUCKETS * log2(v / V0))``; values at or below ``V0``
    (including 0) land in a dedicated underflow bucket.  A reported
    percentile is the containing bucket's upper edge clamped into
    ``[min, max]``, so it is always >= the true percentile and at most
    ``2**(1/SUBBUCKETS)`` times it (the property ``tests/test_obs.py``
    checks).
    """

    __slots__ = ("name", "_lock", "_buckets", "_under", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._buckets: Dict[int, int] = {}
        self._under = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        """Record one observation."""
        v = float(value)
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if v <= _V0:
                self._under += 1
            else:
                idx = math.ceil(SUBBUCKETS * (math.log2(v) - _LOG2_V0))
                self._buckets[idx] = self._buckets.get(idx, 0) + 1

    @property
    def count(self) -> int:
        """Number of observations."""
        return self._count

    def percentile(self, q: float) -> Optional[float]:
        """Estimate the ``q``-quantile (``0 < q <= 1``); None when empty.

        Returns the upper edge of the bucket holding the ``ceil(q*n)``-th
        smallest observation, clamped into ``[min, max]``.
        """
        with self._lock:
            if self._count == 0:
                return None
            rank = max(math.ceil(q * self._count), 1)
            if rank <= self._under:
                return max(min(_V0, self._max), self._min)
            seen = self._under
            for idx in sorted(self._buckets):
                seen += self._buckets[idx]
                if seen >= rank:
                    edge = 2.0 ** (idx / SUBBUCKETS + _LOG2_V0)
                    return max(min(edge, self._max), self._min)
            return self._max               # pragma: no cover - defensive

    def snapshot(self) -> dict:
        """JSON-safe summary: count/sum/min/max + p50/p95/p99."""
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": None, "max": None,
                        "p50": None, "p95": None, "p99": None}
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
        return {"count": count, "sum": total, "min": lo, "max": hi,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Named-metric factory + snapshot/export surface.

    ``counter`` / ``gauge`` / ``histogram`` get-or-create by name
    (thread-safe); a disabled registry returns :data:`NULL_METRIC` for
    everything and snapshots empty.  Metric names may carry a Prometheus
    label suffix (``'pack_bucket_rows{cap="512"}'``) which the text
    exposition keeps verbatim.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, table, cls, name):
        if not self.enabled:
            return NULL_METRIC
        m = table.get(name)
        if m is None:
            with self._lock:
                m = table.setdefault(name, cls(name))
        return m

    def counter(self, name: str) -> Counter:
        """Get-or-create the named counter."""
        return self._get(self._counters, Counter, name)

    def gauge(self, name: str) -> Gauge:
        """Get-or-create the named gauge."""
        return self._get(self._gauges, Gauge, name)

    def histogram(self, name: str) -> Histogram:
        """Get-or-create the named histogram."""
        return self._get(self._histograms, Histogram, name)

    def drop_prefix(self, prefix: str) -> None:
        """Forget metrics whose name starts with ``prefix`` — used for
        families whose member set shrinks (per-bucket occupancy gauges
        after a capacity class is released)."""
        with self._lock:
            for table in (self._counters, self._gauges, self._histograms):
                for name in [n for n in table if n.startswith(prefix)]:
                    del table[name]

    def snapshot(self) -> dict:
        """JSON-safe ``{counters, gauges, histograms}`` dump."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.snapshot() for n, c in sorted(counters.items())},
            "gauges": {n: g.snapshot() for n, g in sorted(gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(histograms.items())},
        }

    def prometheus_text(self, prefix: str = "cubegraph") -> str:
        """Render the current state as Prometheus text exposition."""
        return prometheus_text(self.snapshot(), prefix=prefix)


NULL_REGISTRY = MetricsRegistry(enabled=False)


class BucketStats:
    """Rolling per-capacity-bucket observations from the sharded read path.

    One :meth:`observe` call records one (query batch, capacity bucket)
    encounter.  The :meth:`snapshot` schema is the **planner input
    contract** (ROADMAP item 1 — scan-vs-traversal cost model): per
    bucket capacity it reports, cumulatively,

    * ``queries`` — batches that considered the bucket,
    * ``dispatches`` — batches that actually launched its kernel,
    * ``rows`` / ``blocks_pruned`` — allocated shard rows seen vs rows
      skipped by whole-block temporal pruning; ``pruning_rate`` is their
      ratio (the temporal-pruning history term),
    * ``rows_scanned`` — padded kernel work actually dispatched
      (active rows × capacity — what a scan-cost term must charge),
    * ``candidates`` / ``candidate_slots`` — returned top-k entries that
      passed the filter vs list capacity; ``selectivity`` is their
      ratio, a *censored* observation of true filter selectivity (exact
      when the bucket under-fills its lists, a lower bound once they
      saturate),
    * ``cache_hits`` / ``cache_misses`` — dispatches that reused a
      compiled kernel vs forced a trace
      (``kernels.ops.dispatch_trace_count`` delta).
    """

    _COUNTS = ("queries", "dispatches", "rows", "blocks_pruned",
               "rows_scanned", "candidates", "candidate_slots",
               "cache_hits", "cache_misses")

    def __init__(self):
        self._lock = threading.Lock()
        self._buckets: Dict[int, Dict[str, int]] = {}

    def observe(self, cap: int, rows: int, active_rows: int,
                candidates: int = 0, candidate_slots: int = 0,
                cache_hit: Optional[bool] = None) -> None:
        """Record one query batch's encounter with one capacity bucket."""
        with self._lock:
            d = self._buckets.get(cap)
            if d is None:
                d = self._buckets[cap] = dict.fromkeys(self._COUNTS, 0)
            d["queries"] += 1
            d["rows"] += rows
            d["blocks_pruned"] += rows - active_rows
            if active_rows:
                d["dispatches"] += 1
                d["rows_scanned"] += active_rows * cap
                d["candidates"] += candidates
                d["candidate_slots"] += candidate_slots
                if cache_hit is not None:
                    d["cache_hits" if cache_hit else "cache_misses"] += 1

    def snapshot(self) -> Dict[str, dict]:
        """``{str(cap): {counts..., pruning_rate, selectivity}}`` —
        JSON-safe; rates are None until their denominator is non-zero."""
        with self._lock:
            buckets = {cap: dict(d) for cap, d in self._buckets.items()}
        out: Dict[str, dict] = {}
        for cap in sorted(buckets):
            d = buckets[cap]
            d["pruning_rate"] = (round(d["blocks_pruned"] / d["rows"], 4)
                                 if d["rows"] else None)
            d["selectivity"] = (round(d["candidates"]
                                      / d["candidate_slots"], 4)
                                if d["candidate_slots"] else None)
            out[str(cap)] = d
        return out


class StreamObs:
    """One manager's observability state: registry + bucket accumulator.

    Disabled (``StreamConfig(obs_enabled=False)``) both collapse to the
    shared no-op singletons, so the query/write paths stay allocation-free.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.registry = MetricsRegistry(enabled=enabled)
        self.bucket_stats = BucketStats() if enabled else None

    def snapshot(self) -> dict:
        """JSON-safe ``{enabled, metrics, buckets}`` export."""
        return {
            "enabled": self.enabled,
            "metrics": self.registry.snapshot(),
            "buckets": (self.bucket_stats.snapshot()
                        if self.bucket_stats is not None else {}),
        }


def json_sanitize(obj):
    """Deep-copy ``obj`` into strict-JSON territory.

    Applies the persistence layer's inf→null convention to every float
    (NaN included), converts numpy scalars/arrays to python scalars/lists,
    tuples to lists, and non-string dict keys to strings — the guarantee
    ``json.dumps(..., allow_nan=False)`` needs, end-to-end.
    """
    if isinstance(obj, dict):
        return {str(k): json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, bool) or obj is None or isinstance(obj, str):
        return obj
    if isinstance(obj, int):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if hasattr(obj, "item") and getattr(obj, "ndim", 0) == 0:
        return json_sanitize(obj.item())  # numpy scalar
    if hasattr(obj, "tolist"):            # numpy array
        return json_sanitize(obj.tolist())
    return obj


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(prefix: str, name: str):
    """Split a registry name into (sanitized metric name, label suffix)."""
    base, labels = name, ""
    if "{" in name:
        base, rest = name.split("{", 1)
        labels = "{" + rest
    base = _NAME_RE.sub("_", f"{prefix}_{base}" if prefix else base)
    return base, labels


def prometheus_text(snapshot: dict, prefix: str = "cubegraph") -> str:
    """Render a :meth:`MetricsRegistry.snapshot` (or the ``metrics`` block
    of a ``metrics_snapshot()`` export) as Prometheus text exposition.

    Histograms are exposed as summaries (``quantile`` labels + ``_sum`` /
    ``_count``); non-finite and empty values are omitted, never emitted.
    """
    lines = []
    for name, value in snapshot.get("counters", {}).items():
        base, labels = _prom_name(prefix, name)
        lines.append(f"# TYPE {base} counter")
        lines.append(f"{base}{labels} {value}")
    for name, value in snapshot.get("gauges", {}).items():
        if value is None:
            continue
        base, labels = _prom_name(prefix, name)
        lines.append(f"# TYPE {base} gauge")
        lines.append(f"{base}{labels} {value}")
    for name, h in snapshot.get("histograms", {}).items():
        base, labels = _prom_name(prefix, name)
        inner = labels[1:-1] if labels else ""
        lines.append(f"# TYPE {base} summary")
        for q in ("p50", "p95", "p99"):
            if h.get(q) is not None:
                lab = f'quantile="0.{q[1:]}"'
                lab = "{" + (inner + "," if inner else "") + lab + "}"
                lines.append(f"{base}{lab} {h[q]}")
        lines.append(f"{base}_sum{labels} {h.get('sum', 0.0)}")
        lines.append(f"{base}_count{labels} {h.get('count', 0)}")
    return "\n".join(lines) + ("\n" if lines else "")
