"""Roofline accounting from compiled artifacts.

XLA's ``cost_analysis`` counts while-loop (scan) bodies ONCE, so naive
FLOP/byte readings under-count by ~n_layers (verified empirically in this
container).  We therefore derive per-layer costs with the **depth-delta
method**: compile the same full-width config at depth u and u+1; the
difference is exactly one layer-unit's cost, so

    total(d) = base + d * delta,   base = cost(u) - u * delta.

Collective bytes are not in ``cost_analysis`` at all: ``collective_bytes``
parses the HLO text and sums operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (per shard), with the same
depth-delta correction applied by the caller.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes; tuples handled by caller via findall."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum *output* operand bytes per collective kind over the whole module.

    Each HLO line looks like:
      %x = f32[a,b] all-reduce(f32[a,b] %y), replica_groups=...
    We count the result shape (left of '='), which for all-gather reflects
    the gathered size and for reduce-scatter the scattered size — a
    reasonable single-number proxy for link traffic per participating shard.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    # strict: "<var> = <shape> <collective-op>(" — the opcode must be the
    # instruction itself (fusions merely *consuming* a collective operand
    # must not match).
    pat = re.compile(
        r"%?[\w.\-]+\s*=\s*"
        r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.match(line.strip())
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def flops_and_bytes(cost) -> Dict[str, float]:
    """Extract per-device flops / bytes from compiled.cost_analysis().

    Older jax returns a one-element list of dicts (one per device), newer
    returns the dict directly; accept both."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


def depth_delta(cost_u, cost_u1, coll_u, coll_u1, u: int, full_depth: int
                ) -> Dict[str, float]:
    """Linear extrapolation: total(full) = base + full_depth * delta."""
    out = {}
    for key in ("flops", "bytes"):
        delta = cost_u1[key] - cost_u[key]
        base = cost_u[key] - u * delta
        out[key] = base + full_depth * delta
        out[key + "_per_layer"] = delta
    dcol = coll_u1["total"] - coll_u["total"]
    bcol = coll_u["total"] - u * dcol
    out["collective_bytes"] = bcol + full_depth * dcol
    out["collective_bytes_per_layer"] = dcol
    return out


def roofline_terms(flops: float, bytes_: float, coll_bytes: float,
                   chips: int, peak_flops: float, hbm_bw: float,
                   ici_bw: float, per_device: bool = True) -> Dict[str, float]:
    """The three §Roofline terms in seconds.  cost_analysis numbers on the
    host backend are per-shard (= per device), so divide only when asked."""
    div = 1 if per_device else chips
    t_compute = flops / div / peak_flops
    t_memory = bytes_ / div / hbm_bw
    t_coll = coll_bytes / div / ici_bw
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    return {"compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "bottleneck": dom[0]}
