"""Mesh-sharded sealed-segment search: segments × shards, bucketed by size.

Each sealed segment's live point set is partitioned round-robin into
``n_shards`` equal-capacity shards and answered by the fused
filtered-top-k kernel (``kernels.ops.sharded_filtered_topk``) over a
stacked ``[rows, cap, ·]`` device block, followed by an exact merge of the
shard-local ``(gid, dist)`` top-k lists.

Two pack layouts exist:

* :class:`BucketedShardPack` (the default serving structure) groups
  segments into **capacity buckets** — power-of-two multiples of the
  kernel tile (``cap_multiple``) — so a jumbo post-compaction segment pads
  only its own bucket, never the small ones.  The pack is **incrementally
  maintained**: a seal appends one segment's rows into its bucket with a
  ``dynamic_update_slice`` (the block grows geometrically, so uploads are
  amortized O(changed segment)), a compaction publish removes the merged
  inputs and inserts the output into its (likely larger) bucket, an expiry
  tombstones rows without touching device data, and deletes scatter the
  ``PAD_META`` sentinel into the metadata block.  All device updates are
  *functional* (new ``jnp`` arrays, shared buffers): an in-flight query
  holding a :class:`PackView` keeps reading the arrays it captured, which
  is what makes delta application safe against the owner's epoch/lock
  machinery.  A full rebuild happens only on cold start (first sharded
  query, restore from a snapshot) or when delta application fails.

* :class:`ShardPack` — the legacy monolithic layout (one block, every
  shard padded to the single largest shard's capacity), rebuilt whole per
  epoch.  Kept for A/B benchmarking (``StreamConfig(incremental_pack=
  False)``) and as the simplest exactness oracle.

Placed on a mesh with a ``"shard"`` axis (``make_shard_mesh``), the stacked
arrays are partitioned across devices along the shard axis, so each device
scans only its resident shards and only the tiny ``[rows, b, k]`` candidate
lists cross the interconnect for the merge — the TigerVector-style
decoupling of partitioned vector storage from query fan-out.

Exactness: every shard computes the same fp32 distance the monolithic
kernel would for the same point, each true global top-k member is by
definition inside its own shard's top-k, and global ids are disjoint across
shards — so concatenating the per-shard (and per-bucket) lists and taking
the global top-k reproduces the single-device result bit-for-bit.

Quantized read path (``quantize="int8"``): a bucketed pack can instead hold
**int8 segment codes** in a transposed layout (``[rows, dq, cap]`` codes +
``[rows, mq, cap]`` metadata-with-norms, see ``repro.kernels.quant_topk``)
— ~4x fewer vector bytes and ~16x fewer metadata bytes on device than the
fp32 blocks.  The per-segment scales ride the same functional delta
protocol, the scan over-fetches ``rerank_multiple * k`` candidates per
bucket with asymmetric (fp32 query × int8 code) distances, and the caller
reranks the union exactly at fp32 (``repro.quant.rerank``) before the
standard ``(dist, gid)`` merge.  With ``quantize=None`` nothing changes:
the fp32 blocks and kernel path are byte-for-byte the pre-quantization
ones.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import Filter
from ..kernels import (PAD_META, dispatch_trace_count, next_pow2,
                       quant_meta_rows, round_up, sharded_filtered_topk,
                       sharded_filtered_topk_grouped,
                       sharded_quant_filtered_topk)
from ..obs.trace import NULL_TRACE, block_ready

__all__ = ["BucketedShardPack", "PackView", "SegmentShardSource",
           "ShardPack", "bucket_cap_for", "bucket_graph_seeds",
           "build_bucketed_pack", "build_shard_pack", "host_topk",
           "make_shard_mesh", "pack_search", "pack_search_blocks",
           "pack_search_blocks_grouped"]

_MPAD = 128                      # metadata lane padding (kernel layout)


@dataclasses.dataclass(frozen=True)
class SegmentShardSource:
    """One segment's live points, ready to be sharded (plain arrays so this
    module stays import-independent of ``repro.streaming``).

    ``codes`` / ``scales`` / ``xsq`` carry the segment's int8 codec payload
    (rows parallel to ``x``) when the owner runs the quantized read path;
    a quantized pack falls back to encoding on the fly when they are
    absent (e.g. sources rebuilt from a pre-quantization snapshot).
    """

    seg_id: int
    x: np.ndarray                # [n, d] fp32 live vectors
    s: np.ndarray                # [n, m] metadata
    gids: np.ndarray             # [n] int64 global ids
    t_min: float
    t_max: float
    codes: Optional[np.ndarray] = None    # [n, d] int8 segment codes
    scales: Optional[np.ndarray] = None   # [d] fp32 per-dim scales
    xsq: Optional[np.ndarray] = None      # [n] fp32 dequantized sq. norms
    nbrs: Optional[np.ndarray] = None     # [n, deg] int32 local adjacency
    entries: Optional[np.ndarray] = None  # [e] int32 local entry points


def make_shard_mesh(n_devices: Optional[int] = None) -> Mesh:
    """A 1-D device mesh with axis ``"shard"`` over (up to) ``n_devices``.

    On a single-device host this degenerates to a mesh of one — the pack
    code path is identical, which is how the sharded search is exercised in
    CI while production runs hand in a real multi-device mesh.
    """
    from ..launch.mesh import mesh_compat_kwargs
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(int(n_devices), len(devs))
    return Mesh(np.asarray(devs[:n]).reshape(n), ("shard",),
                **mesh_compat_kwargs(1))


@dataclasses.dataclass
class ShardPack:
    """Stacked, padded, device-resident shards of a set of sealed segments.

    A pack is immutable in shape: built once per segment-list generation
    (``epoch``) and reused for every query until the segment list changes.
    Deletions between rebuilds are applied with :meth:`mark_dead` (metadata
    sentinel overwrite + lazy re-upload) — no restacking.
    """

    epoch: int
    n_shards: int                    # shards per segment
    m: int                           # real metadata dimension
    seg_ids: np.ndarray              # [g] owning segment id per pack row
    t_min: np.ndarray                # [g] owning segment's time span
    t_max: np.ndarray
    x: jnp.ndarray                   # [g, cap, dpad] device stack
    gids_dev: jnp.ndarray            # [g, cap] int32 (-1 padding)
    _s_host: np.ndarray              # [g, cap, MPAD] host master copy
    _sharding: Optional[NamedSharding]
    _gid_sorted: np.ndarray          # sorted live gids (for mark_dead)
    _gid_flat_pos: np.ndarray        # flat (row*cap + col) per sorted gid
    _s_dev: Optional[jnp.ndarray] = None

    @property
    def n_rows(self) -> int:
        """Pack rows = segments × shards-per-segment."""
        return int(self.x.shape[0])

    @property
    def cap(self) -> int:
        """Padded per-shard point capacity."""
        return int(self.x.shape[1])

    @property
    def nbytes(self) -> int:
        """Device bytes held by the pack (vectors + metadata + gids)."""
        return int(self.x.size * 4 + self._s_host.size * 4
                   + self.gids_dev.size * 4)

    def _put(self, arr: np.ndarray) -> jnp.ndarray:
        if self._sharding is not None:
            return jax.device_put(arr, self._sharding)
        return jnp.asarray(arr)

    @property
    def s_dev(self) -> jnp.ndarray:
        """Device metadata stack, re-uploaded lazily after `mark_dead`."""
        if self._s_dev is None:
            self._s_dev = self._put(self._s_host)
        return self._s_dev

    def mark_dead(self, gids: Sequence[int]) -> int:
        """Mask points by global id: their metadata rows become ``PAD_META``
        so every subsequent query's predicate rejects them.  Returns the
        number of pack rows touched; the device copy refreshes on the next
        query (one upload, not one per delete)."""
        g = np.asarray(gids, np.int64)
        if len(g) == 0 or len(self._gid_sorted) == 0:
            return 0
        pos = np.searchsorted(self._gid_sorted, g)
        pos_c = np.clip(pos, 0, len(self._gid_sorted) - 1)
        ok = self._gid_sorted[pos_c] == g
        flat = self._gid_flat_pos[pos_c[ok]]
        if len(flat) == 0:
            return 0
        rows, cols = np.divmod(flat, self.cap)
        self._s_host[rows, cols, :] = PAD_META
        self._s_dev = None
        return len(flat)

    def sync_alive(self, alive: np.ndarray) -> int:
        """Mask every packed point whose gid is dead in ``alive`` (the
        manager's liveness bitmap).  Used once at pack installation to catch
        deletions that raced the build; later deletions arrive one-by-one
        through :meth:`mark_dead`."""
        dead = self._gid_sorted[~alive[self._gid_sorted]]
        return self.mark_dead(dead)

    def active_rows(self, t_lo: float, t_hi: float) -> np.ndarray:
        """[g] bool — pack rows whose segment span overlaps [t_lo, t_hi]."""
        return (self.t_max >= t_lo) & (self.t_min <= t_hi)


def build_shard_pack(sources: Sequence[SegmentShardSource], n_shards: int,
                     epoch: int = 0, mesh: Optional[Mesh] = None,
                     cap_multiple: int = 256) -> ShardPack:
    """Partition each segment round-robin into ``n_shards`` shards and stack
    all of them into one padded device pack.

    ``cap_multiple`` matches the kernel's candidate-tile size so row padding
    is settled here once instead of on every query.  With ``mesh`` given,
    the stack is placed with the shard axis partitioned across the mesh
    (requires ``g % mesh devices == 0``, which holds whenever ``n_shards``
    is a multiple of the device count).
    """
    n_shards = max(int(n_shards), 1)
    if not sources:
        raise ValueError("build_shard_pack needs at least one segment")
    m = sources[0].s.shape[1]
    d = sources[0].x.shape[1]
    dpad = round_up(d, 128)
    per_row: List[Tuple[int, np.ndarray, SegmentShardSource]] = []
    for src in sources:
        order = np.arange(len(src.gids))
        for sh in range(n_shards):
            per_row.append((src.seg_id, order[sh::n_shards], src))
    g = len(per_row)
    cap = round_up(max(len(idx) for _, idx, _ in per_row), cap_multiple)
    x = np.zeros((g, cap, dpad), np.float32)
    s = np.full((g, cap, _MPAD), PAD_META, np.float32)
    gid = np.full((g, cap), -1, np.int32)
    seg_ids = np.zeros(g, np.int64)
    t_min = np.zeros(g, np.float64)
    t_max = np.zeros(g, np.float64)
    for row, (sid, idx, src) in enumerate(per_row):
        nn = len(idx)
        x[row, :nn, :d] = src.x[idx]
        s[row, :nn, :] = 0.0
        s[row, :nn, :m] = src.s[idx]
        gid[row, :nn] = src.gids[idx]
        seg_ids[row] = sid
        t_min[row], t_max[row] = src.t_min, src.t_max
    sharding = None
    if mesh is not None and g % mesh.devices.size == 0:
        sharding = NamedSharding(mesh, P("shard", None, None))
    flat_gid = gid.reshape(-1).astype(np.int64)
    live = np.nonzero(flat_gid >= 0)[0]
    order = np.argsort(flat_gid[live])
    pack = ShardPack(
        epoch=epoch, n_shards=n_shards, m=m, seg_ids=seg_ids,
        t_min=t_min, t_max=t_max,
        x=jnp.zeros(1), gids_dev=jnp.zeros(1),   # placed below
        _s_host=s, _sharding=sharding,
        _gid_sorted=flat_gid[live][order], _gid_flat_pos=live[order])
    pack.x = pack._put(x)
    gid_sharding = (NamedSharding(mesh, P("shard", None))
                    if sharding is not None else None)
    pack.gids_dev = (jax.device_put(gid, gid_sharding)
                     if gid_sharding is not None else jnp.asarray(gid))
    return pack


# ---------------------------------------------------------------------------
# Size-bucketed, incrementally maintained pack
# ---------------------------------------------------------------------------
def bucket_cap_for(n_points: int, n_shards: int,
                   cap_multiple: int = 256) -> int:
    """Padded per-shard row capacity class for a segment of ``n_points``
    live rows: the smallest power-of-two multiple of ``cap_multiple`` that
    fits the segment's largest round-robin shard.  Power-of-two classes
    bound padding waste at 2× the tile-aligned shard size while keeping the
    number of distinct device-block shapes (= jit cache entries) to
    O(log max-segment)."""
    n_shards = max(int(n_shards), 1)
    shard_rows = -(-max(int(n_points), 1) // n_shards)
    return cap_multiple * next_pow2(-(-shard_rows // cap_multiple))


@jax.jit
def _write_rows(block, rows, row0):
    """Functional row-range write: ``block[row0:row0+len(rows)] = rows``.
    Returns a new array sharing unchanged buffers — in-flight views of the
    old block stay valid."""
    start = (row0,) + (0,) * (block.ndim - 1)
    return jax.lax.dynamic_update_slice(block, rows, start)


@jax.jit
def _mask_meta(s, rows, cols):
    """Functional scatter of the ``PAD_META`` sentinel into metadata rows
    ``(rows[i], cols[i])`` — how deletions reach the device block without a
    re-upload (duplicate indices are fine: every write stores the same
    sentinel)."""
    return s.at[rows, cols, :].set(PAD_META)


@jax.jit
def _mask_meta_t(st, rows, cols):
    """Transposed-layout sibling of :func:`_mask_meta`: sets every metadata
    sublane (including the xsq row) of the quantized block's columns
    ``(rows[i], :, cols[i])`` to ``PAD_META``, so every predicate —
    including ``filt=None`` — rejects the point."""
    return st.at[rows, :, cols].set(PAD_META)


@dataclasses.dataclass
class _SegEntry:
    """Where one segment's points live inside the pack (host bookkeeping
    for deltas and deletions)."""

    seg_id: int
    cap: int                     # owning bucket key
    slot: int                    # slot index inside the bucket
    gid_sorted: np.ndarray       # sorted gids of the segment's packed rows
    rows_sorted: np.ndarray      # bucket row per sorted gid
    cols_sorted: np.ndarray      # bucket column per sorted gid
    entry_pos: Optional[np.ndarray] = None  # flattened graph entry positions


@dataclasses.dataclass
class _Bucket:
    """One capacity class: a padded ``[rows, cap, ·]`` block whose rows are
    allocated in slots of ``n_shards`` consecutive rows.

    Exactly one of the two layouts is populated: the fp32 blocks
    (``x`` / ``s``) or the quantized transposed blocks (``codes`` / ``st``
    / ``scales``) — never both, which is where the quantized pack's device
    bytes go from ~1 KiB/point to ~70 B/point.

    Residency (tiered storage): a **resident** bucket holds its blocks as
    device ``jnp`` arrays; an evicted one holds byte-identical host ``np``
    copies in ``host`` instead (and ``gids_h`` doubles as its gid block).
    Cold mutations are copy-on-write — the touched host array is replaced,
    never edited in place — so a :class:`BucketView` captured before the
    mutation keeps reading the pre-mutation bytes, exactly like the
    functional device updates.  ``gen`` counts mutations/transitions so an
    off-lock admission upload can detect it went stale before installing.
    """

    cap: int
    seg_ids: np.ndarray          # [rows] int64 owning segment (-1 = free)
    t_min: np.ndarray            # [rows] owning segment's span (+inf free)
    t_max: np.ndarray            # [rows] (-inf free)
    free_slots: List[int]
    gids_h: np.ndarray           # [rows, cap] int32 host mirror (-1 padding)
    gids: Optional[jnp.ndarray] = None    # [rows, cap] int32 (resident only)
    x: Optional[jnp.ndarray] = None       # [rows, cap, dpad] fp32
    s: Optional[jnp.ndarray] = None       # [rows, cap, MPAD] fp32
    codes: Optional[jnp.ndarray] = None   # [rows, dq, cap] int8
    st: Optional[jnp.ndarray] = None      # [rows, mq, cap] fp32 (+xsq row)
    scales: Optional[jnp.ndarray] = None  # [rows, dq] fp32 per-dim scales
    nbrs: Optional[jnp.ndarray] = None    # [rows, cap, degp] int32 adjacency
    resident: bool = True
    host: Optional[Dict[str, np.ndarray]] = None  # cold block arrays
    gen: int = 0                 # bumps on every mutation / tier transition

    @property
    def n_rows(self) -> int:
        """Allocated rows (live + free) in this bucket's block."""
        return int(self.gids_h.shape[0])

    def _arrs(self) -> Dict[str, object]:
        """The populated block arrays (device when resident, host when
        cold), keyed by field name; the gid block rides under ``gids``."""
        if not self.resident:
            return dict(self.host, gids=self.gids_h)
        names = ("codes", "st", "scales") if self.codes is not None \
            else ("x", "s")
        out = {name: getattr(self, name) for name in names}
        if self.nbrs is not None:
            out["nbrs"] = self.nbrs
        out["gids"] = self.gids
        return out

    @property
    def full_nbytes(self) -> int:
        """Bytes this bucket's blocks occupy (on whichever tier they
        live) — also the upload size of admitting it."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in self._arrs().values())

    @property
    def nbytes(self) -> int:
        """Device bytes held by this bucket (0 when evicted)."""
        return self.full_nbytes if self.resident else 0

    @property
    def host_nbytes(self) -> int:
        """Host bytes held by this bucket's cold copy (0 when resident)."""
        return 0 if self.resident else self.full_nbytes


@dataclasses.dataclass(frozen=True)
class BucketView:
    """Immutable per-bucket snapshot handed to the lock-free query path.

    The ``jnp`` arrays are captured by reference (functional updates never
    mutate them); the host-side row metadata is copied because delta
    application edits it in place.  Quantized buckets expose
    ``codes`` / ``st`` / ``scales`` instead of ``x`` / ``s``.

    A **cold** bucket (``resident=False`` — its block was evicted under the
    device budget, see ``streaming/tiering.py``) exposes the same fields as
    host ``np`` arrays holding byte-identical content; dispatching them
    through the same kernels streams the block to the device transiently,
    so cold answers are bit-for-bit the resident ones.  ``stage_bytes`` is
    what admitting the block would upload (the planner's staging cost) and
    ``fill`` counts filled slots per row (the planner's live-point
    estimate)."""

    cap: int
    gids: jnp.ndarray
    seg_ids: np.ndarray
    t_min: np.ndarray
    t_max: np.ndarray
    x: Optional[jnp.ndarray] = None
    s: Optional[jnp.ndarray] = None
    codes: Optional[jnp.ndarray] = None
    st: Optional[jnp.ndarray] = None
    scales: Optional[jnp.ndarray] = None
    nbrs: Optional[jnp.ndarray] = None    # [rows, cap, degp] int32 adjacency
    # per-packed-segment graph entry points for the stitched traversal:
    # ((row0, flattened positions), ...) — row0 identifies the owning slot's
    # first bucket row, so the temporal active mask decides seed inclusion
    entries: Tuple[Tuple[int, np.ndarray], ...] = ()
    resident: bool = True
    stage_bytes: int = 0                  # device bytes if admitted
    fill: Optional[np.ndarray] = None     # [rows] filled slots per row

    @property
    def quantized(self) -> bool:
        """Whether this bucket holds int8 codes instead of fp32 blocks."""
        return self.codes is not None

    @property
    def graph_ready(self) -> bool:
        """Whether this bucket carries a stitched graph block with at least
        one segment exposing entry points (the graph read path's gate)."""
        return self.nbrs is not None and any(
            len(pos) for _, pos in self.entries)

    def active_rows(self, t_lo: float, t_hi: float) -> np.ndarray:
        """[rows] bool — allocated rows whose segment span overlaps the
        query window.  All-False means the whole device block is pruned
        (no kernel dispatch for this bucket)."""
        return ((self.seg_ids >= 0) & (self.t_max >= t_lo)
                & (self.t_min <= t_hi))


@dataclasses.dataclass(frozen=True)
class PackView:
    """Consistent snapshot of a :class:`BucketedShardPack` at one epoch —
    what queries actually search while deltas keep mutating the pack."""

    epoch: int
    n_shards: int
    m: int
    buckets: Tuple[BucketView, ...]
    nbytes: int                           # device-resident bytes
    quantize: Optional[str] = None
    host_nbytes: int = 0                  # cold (evicted) bucket bytes

    @property
    def n_rows(self) -> int:
        """Total allocated pack rows across buckets."""
        return sum(b.gids.shape[0] for b in self.buckets)


class BucketedShardPack:
    """Size-bucketed, delta-maintained device pack of sealed segments.

    Segments land in capacity buckets (:func:`bucket_cap_for`); each bucket
    owns one padded ``[rows, cap, ·]`` device block that grows
    geometrically in slots of ``n_shards`` rows.  Mutations —
    :meth:`add_segment` (seal), :meth:`remove_segment` (compaction victim /
    expiry), :meth:`mark_dead` (deletes) — are **functional** on the device
    arrays, so a :class:`PackView` captured before a mutation keeps
    answering from the pre-mutation state.  The owner (``SegmentManager``)
    serializes mutations and view capture under its lock and stamps
    ``epoch`` after each applied delta.
    """

    def __init__(self, n_shards: int, d: int, m: int, epoch: int = 0,
                 mesh: Optional[Mesh] = None, cap_multiple: int = 256,
                 quantize: Optional[str] = None, metrics=None,
                 graph_degree: Optional[int] = None,
                 resident_default: bool = True):
        from ..obs.metrics import NULL_REGISTRY
        self.metrics = NULL_REGISTRY if metrics is None else metrics
        # tiered storage: buckets created while False start cold (host
        # arrays, no device upload) — how a budgeted cold build / restore
        # avoids staging the whole corpus before the first query
        self.resident_default = bool(resident_default)
        self.n_shards = max(int(n_shards), 1)
        self.d = int(d)
        self.m = int(m)
        self.dpad = round_up(d, 128)
        self.dq = round_up(d, 32)           # int8 code sublane padding
        self.mq = quant_meta_rows(m)         # meta sublanes (+1 xsq row)
        # graph read path: when set, every bucket also carries a
        # [rows, cap, degp] adjacency block of flattened bucket positions
        # (row * cap + col), staged from each segment's sealed CubeGraph
        # layer at add time; None keeps the pack byte-for-byte scan-only
        self.graph_degree = None if not graph_degree else int(graph_degree)
        self.degp = (round_up(max(self.graph_degree, 1), 8)
                     if self.graph_degree else 0)
        self.epoch = int(epoch)
        self.mesh = mesh
        self.cap_multiple = max(int(cap_multiple), 8)
        self.quantize = quantize
        self.buckets: Dict[int, _Bucket] = {}
        self._entries: Dict[int, _SegEntry] = {}
        # resilience: when the manager installs a FaultInjector it is
        # threaded here so the admission trio's named fault points fire
        # (streaming/resilience.py); None — the default — costs nothing
        self.fault_hook = None
        # block shapes created since the last drain — the manager hands
        # them to kernels.ops.warm_sharded_shapes so a grown bucket's
        # dispatch is pre-traced off the query path
        self._new_shapes: List[dict] = []

    # -- geometry ------------------------------------------------------
    @property
    def n_segments(self) -> int:
        """Segments currently packed."""
        return len(self._entries)

    @property
    def n_rows(self) -> int:
        """Total allocated pack rows (live + free) across buckets."""
        return sum(b.n_rows for b in self.buckets.values())

    @property
    def nbytes(self) -> int:
        """Device bytes held by all resident bucket blocks."""
        return sum(b.nbytes for b in self.buckets.values())

    @property
    def host_nbytes(self) -> int:
        """Host bytes held by all evicted (cold) bucket blocks."""
        return sum(b.host_nbytes for b in self.buckets.values())

    def bucket_stats(self) -> Dict[int, Dict[str, int]]:
        """Per-bucket occupancy:
        ``{cap: {rows, live_rows, segments, resident}}``."""
        out = {}
        for cap, b in sorted(self.buckets.items()):
            out[cap] = {"rows": b.n_rows,
                        "live_rows": int((b.seg_ids >= 0).sum()),
                        "segments": int(len({int(s) for s in b.seg_ids
                                             if s >= 0})),
                        "resident": int(b.resident)}
        return out

    # -- placement -----------------------------------------------------
    def _place(self, arr: jnp.ndarray) -> jnp.ndarray:
        """(Re-)pin a bucket block's sharding after a functional update:
        shard-axis partitioned when a mesh is attached and the row count
        divides the device count — which :meth:`_init_slots` guarantees
        for every bucket block it allocates (the check stays defensive)."""
        if self.mesh is not None \
                and int(arr.shape[0]) % self.mesh.devices.size == 0:
            spec = P("shard", *([None] * (arr.ndim - 1)))
            return jax.device_put(arr, NamedSharding(self.mesh, spec))
        return arr

    def _new_block(self, rows: int, cap: int):
        """Fresh zero/PAD device arrays for ``rows`` bucket rows, in the
        layout the pack's mode needs (fp32 blocks or int8 code blocks),
        plus the adjacency block when the graph read path is on."""
        g = self._place(jnp.full((rows, cap), -1, jnp.int32))
        if self.quantize:
            c = self._place(jnp.zeros((rows, self.dq, cap), jnp.int8))
            st = self._place(jnp.full((rows, self.mq, cap), PAD_META,
                                      jnp.float32))
            sc = self._place(jnp.zeros((rows, self.dq), jnp.float32))
            out = dict(codes=c, st=st, scales=sc, gids=g)
        else:
            x = self._place(jnp.zeros((rows, cap, self.dpad), jnp.float32))
            s = self._place(jnp.full((rows, cap, _MPAD), PAD_META,
                                     jnp.float32))
            out = dict(x=x, s=s, gids=g)
        if self.graph_degree:
            out["nbrs"] = self._place(jnp.full((rows, cap, self.degp), -1,
                                               jnp.int32))
        return out

    def _new_block_host(self, rows: int, cap: int) -> Dict[str, np.ndarray]:
        """Host (``np``) twin of :meth:`_new_block` for cold buckets —
        byte-identical zero/PAD content, no device upload, and no ``gids``
        entry (the always-maintained ``gids_h`` mirror plays that role)."""
        if self.quantize:
            out = dict(codes=np.zeros((rows, self.dq, cap), np.int8),
                       st=np.full((rows, self.mq, cap), PAD_META,
                                  np.float32),
                       scales=np.zeros((rows, self.dq), np.float32))
        else:
            out = dict(x=np.zeros((rows, cap, self.dpad), np.float32),
                       s=np.full((rows, cap, _MPAD), PAD_META, np.float32))
        if self.graph_degree:
            out["nbrs"] = np.full((rows, cap, self.degp), -1, np.int32)
        return out

    def _note_shape(self, rows: int, cap: int) -> None:
        """Record a freshly created block geometry for compile warming.
        The mesh rides along so the warm-up's zero blocks are placed with
        the same sharding as the real blocks — jit caches per input
        sharding, so an unsharded warm would not pre-compile the
        mesh-placed dispatch."""
        if self.quantize:
            self._new_shapes.append({"mode": "int8", "rows": rows,
                                     "cap": cap, "dq": self.dq,
                                     "mq": self.mq, "mesh": self.mesh})
        else:
            self._new_shapes.append({"mode": "fp32", "rows": rows,
                                     "cap": cap, "dpad": self.dpad,
                                     "mesh": self.mesh})

    def drain_warm_shapes(self) -> List[dict]:
        """Pop the block geometries created since the last drain (call
        under the owner's lock; feed to
        ``kernels.ops.warm_sharded_shapes`` off the query path)."""
        out, self._new_shapes = self._new_shapes, []
        return out

    def _init_slots(self) -> int:
        """Slot count for a fresh bucket block: the smallest number whose
        row total divides the mesh device count, so every bucket block is
        shard-axis partitionable for *any* ``n_shards`` (doubling growth
        preserves divisibility).  1 without a mesh."""
        if self.mesh is None:
            return 1
        nd = int(self.mesh.devices.size)
        return nd // math.gcd(self.n_shards, nd)

    def _bucket_for(self, cap: int) -> _Bucket:
        b = self.buckets.get(cap)
        if b is None:
            slots = self._init_slots()
            rows = slots * self.n_shards
            kw = dict(seg_ids=np.full(rows, -1, np.int64),
                      t_min=np.full(rows, np.inf, np.float64),
                      t_max=np.full(rows, -np.inf, np.float64),
                      free_slots=list(range(slots)),
                      gids_h=np.full((rows, cap), -1, np.int32))
            if self.resident_default:
                b = _Bucket(cap, **kw, **self._new_block(rows, cap))
                self._note_shape(rows, cap)
            else:
                b = _Bucket(cap, **kw, resident=False,
                            host=self._new_block_host(rows, cap))
            self.buckets[cap] = b
        return b

    def _alloc_slot(self, b: _Bucket) -> int:
        """Pop the lowest free slot, doubling the block when none is left
        (geometric growth keeps appends amortized O(changed segment))."""
        if not b.free_slots:
            old_slots = b.n_rows // self.n_shards
            add_slots = max(old_slots, 1)
            add_rows = add_slots * self.n_shards
            if b.resident:
                add = self._new_block(add_rows, b.cap)
                for name, arr in add.items():
                    grown = jnp.concatenate([getattr(b, name), arr])
                    setattr(b, name, self._place(grown))
            else:
                add = self._new_block_host(add_rows, b.cap)
                host = dict(b.host)
                for name, arr in add.items():
                    host[name] = np.concatenate([host[name], arr])
                b.host = host
            b.gids_h = np.concatenate(
                [b.gids_h, np.full((add_rows, b.cap), -1, np.int32)])
            b.seg_ids = np.concatenate(
                [b.seg_ids, np.full(add_rows, -1, np.int64)])
            b.t_min = np.concatenate(
                [b.t_min, np.full(add_rows, np.inf, np.float64)])
            b.t_max = np.concatenate(
                [b.t_max, np.full(add_rows, -np.inf, np.float64)])
            b.free_slots.extend(range(old_slots, old_slots + add_slots))
            b.gen += 1
            if b.resident:
                self._note_shape(b.n_rows, b.cap)
        b.free_slots.sort()
        return b.free_slots.pop(0)

    # -- delta protocol ------------------------------------------------
    def _stage_fp32(self, src: SegmentShardSource, cap: int):
        """Host-stage one segment's fp32 rows as ``[n_shards, cap, ·]``
        blocks ready for the delta write."""
        n = len(src.gids)
        d = src.x.shape[1]
        xb = np.zeros((self.n_shards, cap, self.dpad), np.float32)
        sb = np.full((self.n_shards, cap, _MPAD), PAD_META, np.float32)
        for sh in range(self.n_shards):
            idx = np.arange(sh, n, self.n_shards)
            nn = len(idx)
            xb[sh, :nn, :d] = src.x[idx]
            sb[sh, :nn, :] = 0.0
            sb[sh, :nn, : self.m] = src.s[idx]
        return dict(x=xb, s=sb)

    def _stage_quant(self, src: SegmentShardSource, cap: int):
        """Host-stage one segment's int8 codes in the transposed quant
        layout (codes ``[n_shards, dq, cap]``, metadata+norms
        ``[n_shards, mq, cap]``, per-row scales).  Uses the segment's
        sealed codec payload when present; otherwise encodes on the fly
        (pre-quantization snapshot restored into a quantized config)."""
        from ..quant import encode_segment
        n = len(src.gids)
        d = src.x.shape[1]
        if src.codes is not None:
            codes, scales, xsq = src.codes, src.scales, src.xsq
        else:
            q = encode_segment(src.x, self.quantize)
            codes, scales, xsq = q.codes, q.scales, q.xsq
        cb = np.zeros((self.n_shards, self.dq, cap), np.int8)
        stb = np.full((self.n_shards, self.mq, cap), PAD_META, np.float32)
        scb = np.zeros((self.n_shards, self.dq), np.float32)
        scb[:, :d] = np.asarray(scales, np.float32)[None, :]
        for sh in range(self.n_shards):
            idx = np.arange(sh, n, self.n_shards)
            nn = len(idx)
            cb[sh, :d, :nn] = codes[idx].T
            stb[sh, :, :nn] = 0.0
            stb[sh, : self.m, :nn] = src.s[idx].T
            stb[sh, self.mq - 1, :nn] = xsq[idx]
        return dict(codes=cb, st=stb, scales=scb)

    def _stage_graph(self, src: SegmentShardSource, cap: int, row0: int):
        """Host-stage one segment's adjacency as a ``[n_shards, cap, degp]``
        block of *flattened bucket positions* (``row * cap + col``), plus
        the segment's entry points in the same coordinate space.

        Positions bake in the slot's ``row0``, so they survive later block
        doubling (cap is fixed per bucket; growth only appends rows).
        Segments packed without a graph payload (e.g. sources rebuilt from
        an old snapshot) stage an all ``-1`` block and no entries — the
        planner then keeps that bucket on the scan path."""
        n = len(src.gids)
        nb = np.full((self.n_shards, cap, self.degp), -1, np.int32)
        entry_pos = np.empty(0, np.int64)
        if src.nbrs is not None and n:
            l = np.arange(n)
            pos_of = ((row0 + l % self.n_shards) * cap
                      + l // self.n_shards).astype(np.int64)
            deg = min(src.nbrs.shape[1], self.degp)
            nbr = np.asarray(src.nbrs[:, :deg], np.int64)
            npos = np.where(nbr >= 0, pos_of[np.minimum(np.maximum(nbr, 0),
                                                        n - 1)],
                            -1).astype(np.int32)
            for sh in range(self.n_shards):
                idx = np.arange(sh, n, self.n_shards)
                nb[sh, : len(idx), :deg] = npos[idx]
            if src.entries is not None and len(src.entries):
                e = np.asarray(src.entries, np.int64)
                e = e[(e >= 0) & (e < n)]
                entry_pos = pos_of[e]
        return nb, entry_pos

    def add_segment(self, src: SegmentShardSource) -> None:
        """Append one segment's live points into its capacity bucket:
        O(segment) host staging + one ``dynamic_update_slice`` per device
        array — never touches other segments' rows."""
        n = len(src.gids)
        if n == 0:
            return
        if src.seg_id in self._entries:
            raise ValueError(f"segment {src.seg_id} is already packed")
        cap = bucket_cap_for(n, self.n_shards, self.cap_multiple)
        b = self._bucket_for(cap)
        slot = self._alloc_slot(b)
        row0 = slot * self.n_shards
        staged = (self._stage_quant(src, cap) if self.quantize
                  else self._stage_fp32(src, cap))
        entry_pos = None
        if self.graph_degree:
            staged["nbrs"], entry_pos = self._stage_graph(src, cap, row0)
        gb = np.full((self.n_shards, cap), -1, np.int32)
        for sh in range(self.n_shards):
            idx = np.arange(sh, n, self.n_shards)
            gb[sh, : len(idx)] = src.gids[idx]
        staged["gids"] = gb
        if b.resident:
            # delta upload volume: what this seal/publish actually shipped
            # to the device (the occupancy gauges are the owner's job — it
            # knows when a transition is complete)
            self.metrics.counter("pack_delta_bytes_total").inc(
                sum(arr.nbytes for arr in staged.values()))
            r0 = jnp.int32(row0)
            for name, block in staged.items():
                written = _write_rows(getattr(b, name), jnp.asarray(block),
                                      r0)
                setattr(b, name, self._place(written))
        else:
            # cold bucket: the delta lands in the host copy without forcing
            # an admission — copy-on-write so in-flight views of a reused
            # slot keep reading the pre-mutation bytes, mirroring the
            # functional device updates
            host = dict(b.host)
            for name, block in staged.items():
                if name == "gids":
                    continue
                arr = host[name].copy()
                arr[row0: row0 + self.n_shards] = block
                host[name] = arr
            b.host = host
        b.gids_h = b.gids_h.copy()
        b.gids_h[row0: row0 + self.n_shards] = gb
        b.gen += 1
        b.seg_ids[row0: row0 + self.n_shards] = src.seg_id
        b.t_min[row0: row0 + self.n_shards] = src.t_min
        b.t_max[row0: row0 + self.n_shards] = src.t_max
        order = np.argsort(src.gids, kind="stable")
        self._entries[src.seg_id] = _SegEntry(
            int(src.seg_id), cap, slot,
            np.asarray(src.gids, np.int64)[order],
            (row0 + order % self.n_shards).astype(np.int64),
            (order // self.n_shards).astype(np.int64),
            entry_pos=entry_pos)

    def remove_segment(self, seg_id: int) -> bool:
        """Tombstone one segment (compaction victim or expiry): host-only —
        the slot is freed and its rows drop out of every later view's
        active mask, so the stale device rows are never merged and get
        overwritten when the slot is reused."""
        e = self._entries.pop(int(seg_id), None)
        if e is None:
            return False
        b = self.buckets[e.cap]
        row0 = e.slot * self.n_shards
        b.seg_ids[row0: row0 + self.n_shards] = -1
        b.t_min[row0: row0 + self.n_shards] = np.inf
        b.t_max[row0: row0 + self.n_shards] = -np.inf
        b.free_slots.append(e.slot)
        b.gen += 1
        if not (b.seg_ids >= 0).any():
            # last live slot gone: release the whole capacity class, so a
            # retired jumbo bucket doesn't pin device memory at its
            # historical peak (in-flight views keep their own references;
            # a later segment of this class re-creates the bucket at one
            # slot and regrows geometrically)
            del self.buckets[e.cap]
        return True

    def mark_dead(self, gids: Sequence[int]) -> int:
        """Mask points by global id: their metadata rows become
        ``PAD_META`` (scattered functionally into each touched bucket's
        device block), so every subsequent view's predicate rejects them.
        Returns the number of pack positions masked."""
        g = np.asarray(gids, np.int64)
        if len(g) == 0:
            return 0
        g_lo, g_hi = int(g.min()), int(g.max())
        per_bucket: Dict[int, List[Tuple[np.ndarray, np.ndarray]]] = {}
        total = 0
        # per-segment lookup keeps the index maintainable in O(changed
        # segment) at add/remove time; the segment count itself is bounded
        # by the compaction policy, and the gid-range prefilter makes
        # non-overlapping segments (the common case — gids are
        # ingestion-ordered) an O(1) skip
        for e in self._entries.values():
            if len(e.gid_sorted) == 0 or e.gid_sorted[-1] < g_lo \
                    or e.gid_sorted[0] > g_hi:
                continue
            pos = np.searchsorted(e.gid_sorted, g)
            pos_c = np.clip(pos, 0, len(e.gid_sorted) - 1)
            ok = e.gid_sorted[pos_c] == g
            if not ok.any():
                continue
            sel = pos_c[ok]
            per_bucket.setdefault(e.cap, []).append(
                (e.rows_sorted[sel], e.cols_sorted[sel]))
            total += int(sel.size)
        for cap, hits in per_bucket.items():
            b = self.buckets[cap]
            rows = np.concatenate([r for r, _ in hits]).astype(np.int32)
            cols = np.concatenate([c for _, c in hits]).astype(np.int32)
            # pad the index vectors to a power of two (repeating the first
            # hit — the scatter is idempotent) so the jit cache sees
            # O(log n) distinct scatter shapes, not one per delete batch
            want = next_pow2(len(rows))
            pad = want - len(rows)
            if pad:
                rows = np.concatenate([rows, np.full(pad, rows[0], np.int32)])
                cols = np.concatenate([cols, np.full(pad, cols[0], np.int32)])
            if not b.resident:
                # same sentinel scatter, applied copy-on-write to the cold
                # host copy — a later admission uploads bytes identical to
                # what the device scatter would have produced
                key = "st" if self.quantize else "s"
                host = dict(b.host)
                arr = host[key].copy()
                if self.quantize:
                    arr[rows, :, cols] = PAD_META
                else:
                    arr[rows, cols, :] = PAD_META
                host[key] = arr
                b.host = host
            elif self.quantize:
                b.st = self._place(_mask_meta_t(b.st, jnp.asarray(rows),
                                                jnp.asarray(cols)))
            else:
                b.s = self._place(_mask_meta(b.s, jnp.asarray(rows),
                                             jnp.asarray(cols)))
            b.gen += 1
        return total

    def sync_alive(self, alive: np.ndarray) -> int:
        """Mask every packed point whose gid is dead in ``alive`` (the
        manager's liveness bitmap) — used once at cold-build installation
        to catch deletions that raced the build."""
        dead = [e.gid_sorted[~alive[e.gid_sorted]]
                for e in self._entries.values()]
        dead = np.concatenate(dead) if dead else np.empty(0, np.int64)
        return self.mark_dead(dead) if len(dead) else 0

    # -- tier transitions (tiered storage, streaming/tiering.py) -------
    def evict_bucket(self, cap: int) -> int:
        """Demote one resident bucket's device block to host ``np`` copies
        (call under the owner's lock).  In-flight views keep the device
        arrays they captured alive; new views of this bucket read the
        byte-identical host copy.  Returns the device bytes released."""
        b = self.buckets.get(cap)
        if b is None or not b.resident:
            return 0
        freed = b.nbytes
        host = {}
        names = ("codes", "st", "scales") if self.quantize else ("x", "s")
        for name in names + (("nbrs",) if self.graph_degree else ()):
            host[name] = np.asarray(getattr(b, name))
            setattr(b, name, None)
        b.gids = None
        b.host = host
        b.resident = False
        b.gen += 1
        return freed

    def _fault(self, point: str) -> None:
        """Fire the named fault point when an injector is attached (the
        manager threads its ``FaultInjector`` here via
        ``install_fault_injector``; None — the default — is free)."""
        if self.fault_hook is not None:
            self.fault_hook(point)

    def stage_admission(self, cap: int):
        """Host half of an admission: snapshot a cold bucket's host arrays
        (call under the owner's lock).  Returns ``(gen, arrays)`` or None
        when the bucket is missing / already resident.  Fault point
        ``admission.stage`` fires before the snapshot — a crash here
        mutates nothing."""
        self._fault("admission.stage")
        b = self.buckets.get(cap)
        if b is None or b.resident:
            return None
        arrs = dict(b.host)
        arrs["gids"] = b.gids_h
        return b.gen, arrs

    def upload_admission(self, staged):
        """Device half of an admission: place the staged host arrays
        (lock-free — the expensive upload happens here, off the owner's
        lock, mirroring ``compact_async``'s execute step).  Fault point
        ``admission.upload`` fires before the upload — a crash strands
        nothing (the staged host copy still lives in the bucket)."""
        self._fault("admission.upload")
        gen, arrs = staged
        return gen, {name: self._place(jnp.asarray(a))
                     for name, a in arrs.items()}

    def install_admission(self, cap: int, gen: int, dev) -> int:
        """Publish an uploaded admission iff the bucket is still cold and
        unchanged since :meth:`stage_admission` (call under the owner's
        lock).  Returns admitted device bytes; 0 means the upload went
        stale (a delta landed mid-upload) and was discarded.  Fault point
        ``admission.install`` fires before the gen check — a crash leaves
        the bucket cold, consistent, and re-admittable."""
        self._fault("admission.install")
        b = self.buckets.get(cap)
        if b is None or b.resident or b.gen != gen:
            return 0
        for name, arr in dev.items():
            setattr(b, name, arr)
        b.host = None
        b.resident = True
        b.gen += 1
        self._note_shape(b.n_rows, cap)
        return b.nbytes

    def admit_bucket(self, cap: int) -> int:
        """Synchronous admission (owner's lock held throughout): upload a
        cold bucket's host copy back to the device.  Returns admitted
        device bytes (0 = missing or already resident)."""
        staged = self.stage_admission(cap)
        if staged is None:
            return 0
        return self.install_admission(cap, *self.upload_admission(staged))

    # -- read side -----------------------------------------------------
    def _bucket_view(self, cap: int, b: _Bucket) -> BucketView:
        """One bucket's immutable snapshot (caller holds the owner's
        lock); cold buckets expose their host arrays in the same fields."""
        entries = tuple(
            (e.slot * self.n_shards, e.entry_pos)
            for e in self._entries.values()
            if e.cap == cap and e.entry_pos is not None
            and len(e.entry_pos))
        fill = (b.gids_h >= 0).sum(axis=1).astype(np.int64)
        common = dict(seg_ids=b.seg_ids.copy(), t_min=b.t_min.copy(),
                      t_max=b.t_max.copy(), entries=entries, fill=fill,
                      stage_bytes=b.full_nbytes)
        if b.resident:
            return BucketView(cap, b.gids, x=b.x, s=b.s, codes=b.codes,
                              st=b.st, scales=b.scales, nbrs=b.nbrs,
                              **common)
        h = b.host
        return BucketView(cap, b.gids_h, x=h.get("x"), s=h.get("s"),
                          codes=h.get("codes"), st=h.get("st"),
                          scales=h.get("scales"), nbrs=h.get("nbrs"),
                          resident=False, **common)

    def bucket_view(self, cap: int) -> Optional[BucketView]:
        """Fresh snapshot of one bucket (e.g. right after an admission so
        the in-flight query dispatches the resident block)."""
        b = self.buckets.get(cap)
        if b is None or not (b.seg_ids >= 0).any():
            return None
        return self._bucket_view(cap, b)

    def view(self) -> PackView:
        """Immutable snapshot for one query (capture under the owner's
        lock).  Buckets with no live slot are dropped, so an all-free
        bucket costs queries nothing.  Cold buckets are included — their
        host arrays dispatch through the same kernels (streamed to the
        device transiently), keeping answers bit-for-bit resident."""
        views = []
        for cap in sorted(self.buckets):
            b = self.buckets[cap]
            if (b.seg_ids >= 0).any():
                views.append(self._bucket_view(cap, b))
        return PackView(self.epoch, self.n_shards, self.m, tuple(views),
                        self.nbytes, quantize=self.quantize,
                        host_nbytes=self.host_nbytes)


def build_bucketed_pack(sources: Sequence[SegmentShardSource], n_shards: int,
                        epoch: int = 0, mesh: Optional[Mesh] = None,
                        cap_multiple: int = 256,
                        quantize: Optional[str] = None,
                        metrics=None,
                        graph_degree: Optional[int] = None,
                        resident_default: bool = True
                        ) -> BucketedShardPack:
    """Cold-build a :class:`BucketedShardPack` (restore / first query /
    bucket-geometry change): the same :meth:`~BucketedShardPack.add_segment`
    delta applied once per segment, so an incrementally maintained pack and
    a from-scratch build of the same segments answer identically.

    ``resident_default=False`` builds every bucket host-side (no device
    uploads) — the budgeted-tier path then admits only the buckets that fit
    ``StreamConfig.device_budget_bytes`` instead of staging the whole
    corpus before the first restored query."""
    if not sources:
        raise ValueError("build_bucketed_pack needs at least one segment")
    pack = BucketedShardPack(n_shards, sources[0].x.shape[1],
                             sources[0].s.shape[1], epoch=epoch, mesh=mesh,
                             cap_multiple=cap_multiple, quantize=quantize,
                             metrics=metrics, graph_degree=graph_degree,
                             resident_default=resident_default)
    for src in sources:
        pack.add_segment(src)
    return pack


def bucket_graph_seeds(bv: BucketView, t_lo: float, t_hi: float
                       ) -> np.ndarray:
    """Flattened seed positions for one bucket's stitched traversal: the
    union of graph entry points of every temporally active packed segment
    (this is the stitching rule — one beam, seeded in every unpruned
    segment's component, instead of per-segment sub-searches)."""
    if bv.nbrs is None or not bv.entries:
        return np.empty(0, np.int64)
    active = bv.active_rows(t_lo, t_hi)
    parts = [pos for row0, pos in bv.entries
             if row0 < len(active) and active[row0]]
    return np.concatenate(parts) if parts else np.empty(0, np.int64)


def host_topk(g: np.ndarray, d: np.ndarray, k: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact host-side top-k over concatenated ``(gid, dist)`` candidate
    rows: ``argpartition`` narrows each row to ``k`` candidates, then one
    ``lexsort`` orders the slice by ``(dist, gid)``.  The order is total —
    rows where a *finite* distance tie straddles the k-th position (where
    argpartition's selection would be input-order-dependent) are
    re-selected by the full ``(dist, gid)`` order — so the result is
    deterministic regardless of block concatenation order.  Returns
    ``(gids [b, k] int64, dists [b, k] fp32)`` padded with
    ``-1`` / ``+inf``."""
    d = np.where(g >= 0, np.asarray(d, np.float32), np.inf)
    g = np.asarray(g, np.int64)
    if d.shape[1] > k:
        part = np.argpartition(d, k - 1, axis=1)
        g_sel = np.take_along_axis(g, part[:, :k], axis=1)
        d_sel = np.take_along_axis(d, part[:, :k], axis=1)
        kth = d_sel.max(axis=1)
        d_rest = np.take_along_axis(d, part[:, k:], axis=1)
        # +inf boundary ties are harmless (every +inf selection emits
        # gid -1 below); finite ones get the rare full-sort path
        amb = np.isfinite(kth) & (d_rest == kth[:, None]).any(axis=1)
        if amb.any():
            full = np.lexsort((g[amb], d[amb]))[:, :k]
            g_sel[amb] = np.take_along_axis(g[amb], full, axis=1)
            d_sel[amb] = np.take_along_axis(d[amb], full, axis=1)
        g, d = g_sel, d_sel
    order = np.lexsort((g, d))           # per-row: dist, then gid
    out_g = np.take_along_axis(g, order, axis=1)
    out_d = np.take_along_axis(d, order, axis=1)
    out_g = np.where(np.isfinite(out_d), out_g, -1)
    b, w = out_g.shape
    if w < k:
        out_g = np.concatenate(
            [out_g, np.full((b, k - w), -1, np.int64)], axis=1)
        out_d = np.concatenate(
            [out_d, np.full((b, k - w), np.inf, np.float32)], axis=1)
    return out_g, out_d


@partial(jax.jit, static_argnames=("k",))
def _merge_shard_topk(ids, dd, gid_stack, active, k):
    """Shard-local (ids, dists) [g, b, k'] -> exact global (gids, dists)
    [b, k].  Inactive rows and misses are masked to +inf before one
    ``top_k`` over the concatenated shard axis."""
    g = jax.vmap(lambda gr, im: gr[jnp.maximum(im, 0)])(gid_stack, ids)
    valid = (ids >= 0) & active[:, None, None]
    dd = jnp.where(valid, dd, jnp.inf)
    b = dd.shape[1]
    alld = dd.transpose(1, 0, 2).reshape(b, -1)
    allg = g.transpose(1, 0, 2).reshape(b, -1)
    neg, sel = jax.lax.top_k(-alld, k)
    out_d = -neg
    out_g = jnp.take_along_axis(allg, sel, axis=1)
    return jnp.where(jnp.isfinite(out_d), out_g, -1), out_d


def pack_search_blocks(view: PackView, queries: np.ndarray,
                       filt: Optional[Filter], k: int,
                       t_lo: float = -np.inf, t_hi: float = np.inf,
                       metric: str = "l2", trace=None, observe=None,
                       on_cold=None
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """One fused-kernel dispatch per non-empty, temporally unpruned bucket.

    A bucket whose segment spans all miss ``[t_lo, t_hi]`` is skipped
    entirely — temporal pruning drops whole device blocks, not just rows.
    Each dispatched fp32 bucket contributes one exact ``(gids [b, k_b],
    dists [b, k_b])`` candidate block, ready for the caller's exact
    ``(gid, dist)`` merge (``streaming.query.merge_topk`` /
    :func:`host_topk`).  Quantized buckets dispatch the asymmetric int8
    kernel instead and their blocks carry *approximate* distances — the
    caller over-fetches (``k = rerank_multiple * final_k``) and must
    rerank the union exactly at fp32 (``repro.quant.rerank.rerank_exact``)
    before merging with exact blocks.

    ``trace`` (a ``repro.obs.trace.QueryTrace``) opens one span per
    dispatched bucket, stopping its timer only after the bucket's device
    results are ready; ``observe`` (``BucketStats.observe``-compatible
    callable) receives one per-bucket observation per call — rows seen,
    rows temporally pruned, candidate fill, and whether the dispatch hit
    the jit cache.  Both default to off with zero overhead.

    Cold (non-resident) buckets dispatch the *same* kernels over their
    host-held block copies — jax stages the arrays to the device for the
    dispatch and drops them after — so their answers are bit-for-bit what
    the resident block would return.  ``on_cold`` (``f(cap, stage_bytes)``)
    fires once per dispatched cold bucket for tier-miss accounting.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    trace = NULL_TRACE if trace is None else trace
    want_obs = observe is not None or trace.enabled
    blocks: List[Tuple[np.ndarray, np.ndarray]] = []
    for bv in view.buckets:
        active = bv.active_rows(t_lo, t_hi)
        rows = int(bv.gids.shape[0])
        n_active = int(active.sum())
        if n_active == 0:
            if observe is not None:       # whole-block temporal prune
                observe(bv.cap, rows=rows, active_rows=0)
            continue
        if not bv.resident and on_cold is not None:
            on_cold(bv.cap, bv.stage_bytes)
        kk = min(k, bv.cap)               # per-shard list length
        # merged width: for k > cap the per-shard lists (= whole shards)
        # still hold up to rows * kk candidates, so the top-k stays exact
        k_out = min(k, rows * kk)
        traces0 = dispatch_trace_count() if want_obs else 0
        with trace.span("bucket_dispatch", cap=bv.cap, rows=rows,
                        active_rows=n_active, k_out=k_out,
                        quantized=bv.quantized,
                        resident=bv.resident) as sp:
            if bv.quantized:
                ids, dd = sharded_quant_filtered_topk(
                    queries, bv.codes, bv.st, bv.scales, filt, kk,
                    metric=metric, m=view.m)
            else:
                ids, dd = sharded_filtered_topk(queries, bv.x, bv.s, filt,
                                                kk, metric=metric, m=view.m)
            out_g, out_d = _merge_shard_topk(ids, dd, bv.gids,
                                             jnp.asarray(active), k_out)
            block_ready((out_g, out_d))
        out_g = np.asarray(out_g, np.int64)
        out_d = np.asarray(out_d, np.float32)
        if want_obs:
            cache_hit = dispatch_trace_count() == traces0
            n_cand = int((out_g >= 0).sum())
            sp.annotate(candidates=n_cand, cache_hit=cache_hit)
            if observe is not None:
                observe(bv.cap, rows=rows, active_rows=n_active,
                        candidates=n_cand,
                        candidate_slots=queries.shape[0] * k_out,
                        cache_hit=cache_hit)
        blocks.append((out_g, out_d))
    return blocks


def pack_search_blocks_grouped(view: PackView, groups,
                               metric: str = "l2", trace=None,
                               observe=None, on_cold=None,
                               deadlines=None, on_expired=None,
                               fault=None, observe_group=None
                               ) -> List[List[Tuple[np.ndarray, np.ndarray]]]:
    """Heterogeneous-request sibling of :func:`pack_search_blocks`: several
    ``(queries, filt, k, t_lo, t_hi)`` request groups scan the pack's fp32
    buckets in ONE pass, sharing each bucket's device block across every
    group that is temporally active there.

    Per bucket, the groups whose temporal window intersects the bucket
    (exactly the groups for which a solo :func:`pack_search_blocks` call
    would dispatch it) are batched into one
    :func:`repro.kernels.sharded_filtered_topk_grouped` call — the bucket's
    ``[rows, cap, ·]`` block is read once, not once per distinct filter —
    and each group's shard-local lists are merged with the group's own
    temporal ``active`` mask and ``k``.  Because the grouped kernel
    dispatch is a ``vmap`` of the solo dispatch over the group axis, every
    group's candidate block is **bit-for-bit** what its solo call would
    have produced; callers may therefore merge the returned blocks exactly
    as if each group had scanned alone.

    ``deadlines`` (parallel to ``groups``, entries with an ``expired()``
    method or ``None``) drops a group from all remaining buckets once its
    deadline passes, reporting via ``on_expired(group_idx,
    buckets_remaining)`` exactly once; ``fault()`` fires before each
    bucket's dispatch (the owner's ``query.bucket`` fault point);
    ``observe`` gets one union observation per bucket (cache accounting),
    while ``observe_group(group_idx, cap, rows=, active_rows=,
    candidates=, candidate_slots=, cache_hit=)`` attributes the same
    dispatch per group — the per-tenant ``BucketStats`` hook.  Returns one
    candidate-block list per group (a dropped group keeps the blocks
    gathered before its deadline expired).
    """
    trace = NULL_TRACE if trace is None else trace
    groups = [(np.atleast_2d(np.asarray(q, np.float32)), f, int(k),
               float(t_lo), float(t_hi)) for q, f, k, t_lo, t_hi in groups]
    want_obs = (observe is not None or observe_group is not None
                or trace.enabled)
    blocks: List[List[Tuple[np.ndarray, np.ndarray]]] = \
        [[] for _ in groups]
    expired = [False] * len(groups)
    buckets = list(view.buckets)
    for bi, bv in enumerate(buckets):
        if deadlines is not None:
            for gi, dl in enumerate(deadlines):
                if not expired[gi] and dl is not None and dl.expired():
                    expired[gi] = True
                    if on_expired is not None:
                        on_expired(gi, len(buckets) - bi)
        rows = int(bv.gids.shape[0])
        actives = {}
        live: List[int] = []
        for gi, (_, _, _, t_lo, t_hi) in enumerate(groups):
            if expired[gi]:
                continue
            act = bv.active_rows(t_lo, t_hi)
            if act.any():
                actives[gi] = act
                live.append(gi)
            elif observe_group is not None:   # whole-block temporal prune
                observe_group(gi, bv.cap, rows=rows, active_rows=0)
        if not live:
            if observe is not None:
                observe(bv.cap, rows=rows, active_rows=0)
            continue
        if fault is not None:
            fault()
        if not bv.resident and on_cold is not None:
            on_cold(bv.cap, bv.stage_bytes)
        union_active = int(np.logical_or.reduce(
            [actives[gi] for gi in live]).sum())
        traces0 = dispatch_trace_count() if want_obs else 0
        with trace.span("bucket_dispatch_grouped", cap=bv.cap, rows=rows,
                        active_rows=union_active, n_groups=len(live),
                        resident=bv.resident) as sp:
            sub = [(groups[gi][0], groups[gi][1], min(groups[gi][2], bv.cap))
                   for gi in live]
            results = sharded_filtered_topk_grouped(sub, bv.x, bv.s,
                                                    metric=metric, m=view.m)
            merged = []
            for (ids, dd), gi in zip(results, live):
                kk = min(groups[gi][2], bv.cap)
                k_out = min(groups[gi][2], rows * kk)
                merged.append(_merge_shard_topk(ids, dd, bv.gids,
                                                jnp.asarray(actives[gi]),
                                                k_out))
            block_ready(merged[-1])
        cache_hit = (dispatch_trace_count() == traces0) if want_obs \
            else False
        n_cand_total = 0
        for (out_g, out_d), gi in zip(merged, live):
            out_g = np.asarray(out_g, np.int64)
            out_d = np.asarray(out_d, np.float32)
            blocks[gi].append((out_g, out_d))
            if want_obs:
                n_cand = int((out_g >= 0).sum())
                n_cand_total += n_cand
                if observe_group is not None:
                    observe_group(
                        gi, bv.cap, rows=rows,
                        active_rows=int(actives[gi].sum()),
                        candidates=n_cand,
                        candidate_slots=out_g.shape[0] * out_g.shape[1],
                        cache_hit=cache_hit)
        if want_obs:
            sp.annotate(candidates=n_cand_total, cache_hit=cache_hit)
            if observe is not None:
                observe(bv.cap, rows=rows, active_rows=union_active,
                        candidates=n_cand_total,
                        candidate_slots=sum(
                            g.shape[0] * g.shape[1]
                            for g, _ in (blocks[gi][-1] for gi in live)),
                        cache_hit=cache_hit)
    return blocks


def pack_search(pack, queries: np.ndarray, filt: Optional[Filter],
                k: int, t_lo: float = -np.inf, t_hi: float = np.inf,
                metric: str = "l2", lookup=None,
                rerank_multiple: int = 4, trace=None,
                observe=None, on_cold=None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Fan one query batch out over every active shard of the pack and merge
    the shard-local top-k exactly.

    ``pack`` is a legacy :class:`ShardPack`, a :class:`BucketedShardPack`,
    or a :class:`PackView`.  Temporal pruning happens via the ``active``
    mask (host-computed from the per-row segment spans) — and, for the
    bucketed layouts, by skipping whole bucket blocks — so the jit cache
    sees one static shape per pack/bucket.  A quantized pack additionally
    needs ``lookup(gids) -> (x, s, present)`` (the manager's point-store
    getter) for the exact fp32 rerank of its over-fetched
    (``rerank_multiple * k``) candidates.  Returns ``(gids [b, k] int64,
    dists [b, k] fp32)`` with ``-1`` / ``+inf`` padding.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    b = queries.shape[0]
    trace = NULL_TRACE if trace is None else trace
    if isinstance(pack, (BucketedShardPack, PackView)):
        view = pack.view() if isinstance(pack, BucketedShardPack) else pack
        quantized = view.quantize is not None
        k_fetch = max(k * max(int(rerank_multiple), 1), k) if quantized \
            else k
        blocks = pack_search_blocks(view, queries, filt, k_fetch, t_lo=t_lo,
                                    t_hi=t_hi, metric=metric, trace=trace,
                                    observe=observe, on_cold=on_cold)
        if not blocks:
            return (np.full((b, k), -1, np.int64),
                    np.full((b, k), np.inf, np.float32))
        g = np.concatenate([bg for bg, _ in blocks], axis=1)
        if quantized:
            # the approximate distances are never read past this point —
            # the rerank re-scores candidates from their gids alone
            if lookup is None:
                raise ValueError("a quantized pack needs lookup= for the "
                                 "exact fp32 rerank")
            from ..quant import rerank_exact
            with trace.span("rerank_fp32", overfetch=int(g.shape[1]),
                            k=k) as sp:
                out = rerank_exact(queries, g, k, lookup, metric=metric)
                block_ready(out)
                sp.annotate(candidates=int((out[0] >= 0).sum()))
            return out
        d = np.concatenate([bd for _, bd in blocks], axis=1)
        return host_topk(g, d, k)
    kk = min(k, pack.cap)                 # per-shard list length
    # merged width: for k > cap the per-shard lists (= whole shards) still
    # hold up to n_rows * kk candidates, so the global top-k stays exact
    k_out = min(k, pack.n_rows * kk)
    with trace.span("pack_dispatch", rows=pack.n_rows, cap=pack.cap,
                    k_out=k_out):
        ids, dd = sharded_filtered_topk(queries, pack.x, pack.s_dev, filt,
                                        kk, metric=metric, m=pack.m)
        active = jnp.asarray(pack.active_rows(t_lo, t_hi))
        out_g, out_d = _merge_shard_topk(ids, dd, pack.gids_dev, active,
                                         k_out)
        block_ready((out_g, out_d))
    gids = np.full((b, k), -1, np.int64)
    dists = np.full((b, k), np.inf, np.float32)
    gids[:, :k_out] = np.asarray(out_g, np.int64)
    dists[:, :k_out] = np.asarray(out_d, np.float32)
    return gids, dists
