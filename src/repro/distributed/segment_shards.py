"""Mesh-sharded sealed-segment search: segments × shards in one dispatch.

Each sealed segment's live point set is partitioned round-robin into
``n_shards`` equal-capacity shards; all shards of all segments are stacked
into one ``[g, cap, ·]`` pack (``g = n_segments × n_shards``) so a query
fans out over every shard with a single jitted dispatch of the fused
filtered-top-k kernel (``kernels.ops.sharded_filtered_topk``), followed by
an exact in-jit merge of the shard-local ``(gid, dist)`` top-k lists.

Placed on a mesh with a ``"shard"`` axis (``make_shard_mesh``), the stacked
arrays are partitioned across devices along the shard axis, so each device
scans only its resident shards and only the tiny ``[g, b, k]`` candidate
lists cross the interconnect for the merge — the TigerVector-style
decoupling of partitioned vector storage from query fan-out.

Exactness: every shard computes the same fp32 distance the monolithic
kernel would for the same point, each true global top-k member is by
definition inside its own shard's top-k, and global ids are disjoint across
shards — so concatenating the per-shard lists and taking the global top-k
reproduces the single-device result bit-for-bit.

Dead points are masked by overwriting their metadata rows with the
``PAD_META`` sentinel (rejected by every predicate, including ``None``), so
deletions never require restacking the pack.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import Filter
from ..kernels import PAD_META, sharded_filtered_topk

__all__ = ["SegmentShardSource", "ShardPack", "build_shard_pack",
           "make_shard_mesh", "pack_search"]

_MPAD = 128                      # metadata lane padding (kernel layout)


@dataclasses.dataclass(frozen=True)
class SegmentShardSource:
    """One segment's live points, ready to be sharded (plain arrays so this
    module stays import-independent of ``repro.streaming``)."""

    seg_id: int
    x: np.ndarray                # [n, d] fp32 live vectors
    s: np.ndarray                # [n, m] metadata
    gids: np.ndarray             # [n] int64 global ids
    t_min: float
    t_max: float


def make_shard_mesh(n_devices: Optional[int] = None) -> Mesh:
    """A 1-D device mesh with axis ``"shard"`` over (up to) ``n_devices``.

    On a single-device host this degenerates to a mesh of one — the pack
    code path is identical, which is how the sharded search is exercised in
    CI while production runs hand in a real multi-device mesh.
    """
    from ..launch.mesh import mesh_compat_kwargs
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(int(n_devices), len(devs))
    return Mesh(np.asarray(devs[:n]).reshape(n), ("shard",),
                **mesh_compat_kwargs(1))


def _round_up(v: int, mult: int) -> int:
    return ((max(v, 1) + mult - 1) // mult) * mult


@dataclasses.dataclass
class ShardPack:
    """Stacked, padded, device-resident shards of a set of sealed segments.

    A pack is immutable in shape: built once per segment-list generation
    (``epoch``) and reused for every query until the segment list changes.
    Deletions between rebuilds are applied with :meth:`mark_dead` (metadata
    sentinel overwrite + lazy re-upload) — no restacking.
    """

    epoch: int
    n_shards: int                    # shards per segment
    m: int                           # real metadata dimension
    seg_ids: np.ndarray              # [g] owning segment id per pack row
    t_min: np.ndarray                # [g] owning segment's time span
    t_max: np.ndarray
    x: jnp.ndarray                   # [g, cap, dpad] device stack
    gids_dev: jnp.ndarray            # [g, cap] int32 (-1 padding)
    _s_host: np.ndarray              # [g, cap, MPAD] host master copy
    _sharding: Optional[NamedSharding]
    _gid_sorted: np.ndarray          # sorted live gids (for mark_dead)
    _gid_flat_pos: np.ndarray        # flat (row*cap + col) per sorted gid
    _s_dev: Optional[jnp.ndarray] = None

    @property
    def n_rows(self) -> int:
        """Pack rows = segments × shards-per-segment."""
        return int(self.x.shape[0])

    @property
    def cap(self) -> int:
        """Padded per-shard point capacity."""
        return int(self.x.shape[1])

    @property
    def nbytes(self) -> int:
        """Device bytes held by the pack (vectors + metadata + gids)."""
        return int(self.x.size * 4 + self._s_host.size * 4
                   + self.gids_dev.size * 4)

    def _put(self, arr: np.ndarray) -> jnp.ndarray:
        if self._sharding is not None:
            return jax.device_put(arr, self._sharding)
        return jnp.asarray(arr)

    @property
    def s_dev(self) -> jnp.ndarray:
        """Device metadata stack, re-uploaded lazily after `mark_dead`."""
        if self._s_dev is None:
            self._s_dev = self._put(self._s_host)
        return self._s_dev

    def mark_dead(self, gids: Sequence[int]) -> int:
        """Mask points by global id: their metadata rows become ``PAD_META``
        so every subsequent query's predicate rejects them.  Returns the
        number of pack rows touched; the device copy refreshes on the next
        query (one upload, not one per delete)."""
        g = np.asarray(gids, np.int64)
        if len(g) == 0 or len(self._gid_sorted) == 0:
            return 0
        pos = np.searchsorted(self._gid_sorted, g)
        pos_c = np.clip(pos, 0, len(self._gid_sorted) - 1)
        ok = self._gid_sorted[pos_c] == g
        flat = self._gid_flat_pos[pos_c[ok]]
        if len(flat) == 0:
            return 0
        rows, cols = np.divmod(flat, self.cap)
        self._s_host[rows, cols, :] = PAD_META
        self._s_dev = None
        return len(flat)

    def sync_alive(self, alive: np.ndarray) -> int:
        """Mask every packed point whose gid is dead in ``alive`` (the
        manager's liveness bitmap).  Used once at pack installation to catch
        deletions that raced the build; later deletions arrive one-by-one
        through :meth:`mark_dead`."""
        dead = self._gid_sorted[~alive[self._gid_sorted]]
        return self.mark_dead(dead)

    def active_rows(self, t_lo: float, t_hi: float) -> np.ndarray:
        """[g] bool — pack rows whose segment span overlaps [t_lo, t_hi]."""
        return (self.t_max >= t_lo) & (self.t_min <= t_hi)


def build_shard_pack(sources: Sequence[SegmentShardSource], n_shards: int,
                     epoch: int = 0, mesh: Optional[Mesh] = None,
                     cap_multiple: int = 256) -> ShardPack:
    """Partition each segment round-robin into ``n_shards`` shards and stack
    all of them into one padded device pack.

    ``cap_multiple`` matches the kernel's candidate-tile size so row padding
    is settled here once instead of on every query.  With ``mesh`` given,
    the stack is placed with the shard axis partitioned across the mesh
    (requires ``g % mesh devices == 0``, which holds whenever ``n_shards``
    is a multiple of the device count).
    """
    n_shards = max(int(n_shards), 1)
    if not sources:
        raise ValueError("build_shard_pack needs at least one segment")
    m = sources[0].s.shape[1]
    d = sources[0].x.shape[1]
    dpad = _round_up(d, 128)
    per_row: List[Tuple[int, np.ndarray, SegmentShardSource]] = []
    for src in sources:
        order = np.arange(len(src.gids))
        for sh in range(n_shards):
            per_row.append((src.seg_id, order[sh::n_shards], src))
    g = len(per_row)
    cap = _round_up(max(len(idx) for _, idx, _ in per_row), cap_multiple)
    x = np.zeros((g, cap, dpad), np.float32)
    s = np.full((g, cap, _MPAD), PAD_META, np.float32)
    gid = np.full((g, cap), -1, np.int32)
    seg_ids = np.zeros(g, np.int64)
    t_min = np.zeros(g, np.float64)
    t_max = np.zeros(g, np.float64)
    for row, (sid, idx, src) in enumerate(per_row):
        nn = len(idx)
        x[row, :nn, :d] = src.x[idx]
        s[row, :nn, :] = 0.0
        s[row, :nn, :m] = src.s[idx]
        gid[row, :nn] = src.gids[idx]
        seg_ids[row] = sid
        t_min[row], t_max[row] = src.t_min, src.t_max
    sharding = None
    if mesh is not None and g % mesh.devices.size == 0:
        sharding = NamedSharding(mesh, P("shard", None, None))
    flat_gid = gid.reshape(-1).astype(np.int64)
    live = np.nonzero(flat_gid >= 0)[0]
    order = np.argsort(flat_gid[live])
    pack = ShardPack(
        epoch=epoch, n_shards=n_shards, m=m, seg_ids=seg_ids,
        t_min=t_min, t_max=t_max,
        x=jnp.zeros(1), gids_dev=jnp.zeros(1),   # placed below
        _s_host=s, _sharding=sharding,
        _gid_sorted=flat_gid[live][order], _gid_flat_pos=live[order])
    pack.x = pack._put(x)
    gid_sharding = (NamedSharding(mesh, P("shard", None))
                    if sharding is not None else None)
    pack.gids_dev = (jax.device_put(gid, gid_sharding)
                     if gid_sharding is not None else jnp.asarray(gid))
    return pack


@partial(jax.jit, static_argnames=("k",))
def _merge_shard_topk(ids, dd, gid_stack, active, k):
    """Shard-local (ids, dists) [g, b, k'] -> exact global (gids, dists)
    [b, k].  Inactive rows and misses are masked to +inf before one
    ``top_k`` over the concatenated shard axis."""
    g = jax.vmap(lambda gr, im: gr[jnp.maximum(im, 0)])(gid_stack, ids)
    valid = (ids >= 0) & active[:, None, None]
    dd = jnp.where(valid, dd, jnp.inf)
    b = dd.shape[1]
    alld = dd.transpose(1, 0, 2).reshape(b, -1)
    allg = g.transpose(1, 0, 2).reshape(b, -1)
    neg, sel = jax.lax.top_k(-alld, k)
    out_d = -neg
    out_g = jnp.take_along_axis(allg, sel, axis=1)
    return jnp.where(jnp.isfinite(out_d), out_g, -1), out_d


def pack_search(pack: ShardPack, queries: np.ndarray, filt: Optional[Filter],
                k: int, t_lo: float = -np.inf, t_hi: float = np.inf,
                metric: str = "l2") -> Tuple[np.ndarray, np.ndarray]:
    """Fan one query batch out over every active shard of the pack and merge
    the shard-local top-k exactly.

    Temporal pruning happens via the ``active`` mask (host-computed from the
    per-row segment spans) rather than by reshaping the dispatch, so the jit
    cache sees one static shape per pack.  Returns ``(gids [b, k] int64,
    dists [b, k] fp32)`` with ``-1`` / ``+inf`` padding.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    b = queries.shape[0]
    kk = min(k, pack.cap)                 # per-shard list length
    # merged width: for k > cap the per-shard lists (= whole shards) still
    # hold up to n_rows * kk candidates, so the global top-k stays exact
    k_out = min(k, pack.n_rows * kk)
    ids, dd = sharded_filtered_topk(queries, pack.x, pack.s_dev, filt, kk,
                                    metric=metric, m=pack.m)
    active = jnp.asarray(pack.active_rows(t_lo, t_hi))
    out_g, out_d = _merge_shard_topk(ids, dd, pack.gids_dev, active, k_out)
    gids = np.full((b, k), -1, np.int64)
    dists = np.full((b, k), np.inf, np.float32)
    gids[:, :k_out] = np.asarray(out_g, np.int64)
    dists[:, :k_out] = np.asarray(out_d, np.float32)
    return gids, dists
