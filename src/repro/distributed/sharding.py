"""Per-architecture sharding rules (DP / TP / EP / SP) for the production
meshes.

The rule engine maps (param path, shape) -> PartitionSpec by pattern, only
sharding a dimension when the mesh axis size divides it (otherwise that
dimension stays replicated — correctness first, the §Perf loop then tightens
the rules per arch).

Conventions (DESIGN.md §3.1):
* batch-like leading dims     -> ('pod','data') [dp axes]
* vocab/embedding rows        -> 'model'
* attention q/kv projections  -> output (head) dim over 'model'
* attention/mlp output projs  -> input dim over 'model' (Megatron pairing)
* MoE expert stacks [L,E,D,F] -> E over dp axes when divisible (EP), F over
  'model' (TP-within-expert) — fits 235B-class experts in v5e HBM
* mamba channel dims (d_inner)-> 'model' (channel-parallel SSM)
* KV caches                   -> batch over dp; kv-heads over 'model' when
  divisible, else sequence over 'model' (SP, long-context decode)
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ArchConfig

Params = Any


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def dp_axes(mesh: Mesh):
    """The mesh's data-parallel axis names (with 'pod' when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis):
    """axis if it divides dim else None (replicate)."""
    return axis if _fits(dim, mesh, axis) else None


def _expert_axes(e: int, mesh: Mesh):
    """Largest dp-axis combination that divides the expert count."""
    cands = []
    if "pod" in mesh.axis_names:
        cands = [("pod", "data"), ("data",), ("pod",)]
    else:
        cands = [("data",)]
    for c in cands:
        if _fits(e, mesh, c):
            return c if len(c) > 1 else c[0]
    return None


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...],
                mesh: Mesh, cfg: ArchConfig) -> P:
    """Pattern-matched PartitionSpec for one parameter leaf."""
    name = path[-1]
    joined = "/".join(path)

    # ---- embeddings: vocab over model ------------------------------------
    if name in ("embedding", "unembed"):
        return P(_maybe(shape[0], mesh, "model"), None)

    # ---- MoE ---------------------------------------------------------------
    if "ffn" in path and name == "router":
        return P(*([None] * len(shape)))
    if "ffn" in path and name in ("w_gate", "w_up", "w_down") and len(shape) == 4:
        # [L, E, D, F] (w_down: [L, E, F, D])
        e_ax = _expert_axes(shape[1], mesh)
        if name == "w_down":
            return P(None, e_ax, _maybe(shape[2], mesh, "model"), None)
        return P(None, e_ax, None, _maybe(shape[3], mesh, "model"))

    # ---- attention: shard over WHOLE heads only (splitting a head across
    # devices makes the softmax contraction partial -> giant [B,H,S,S]
    # all-reduces; replicate instead when heads don't divide the axis) ------
    if name in ("wq", "wk", "wv"):
        heads = cfg.n_kv if name in ("wk", "wv") else cfg.n_heads
        ax = "model" if (heads % _axis_size(mesh, "model") == 0
                         and _fits(shape[-1], mesh, "model")) else None
        return P(*([None] * (len(shape) - 2)), None, ax)
    if name == "wo":
        ax = "model" if (cfg.n_heads % _axis_size(mesh, "model") == 0
                         and _fits(shape[-2], mesh, "model")) else None
        return P(*([None] * (len(shape) - 2)), ax, None)

    # ---- dense / shared-expert MLP -----------------------------------------
    if name in ("w_gate", "w_up"):
        return P(*([None] * (len(shape) - 2)),
                 None, _maybe(shape[-1], mesh, "model"))
    if name == "w_down":
        return P(*([None] * (len(shape) - 2)),
                 _maybe(shape[-2], mesh, "model"), None)

    # ---- SSM (channel-parallel over d_inner) --------------------------------
    if name in ("in_proj",):
        return P(*([None] * (len(shape) - 2)),
                 None, _maybe(shape[-1], mesh, "model"))
    if name in ("x_proj", "out_proj"):
        return P(*([None] * (len(shape) - 2)),
                 _maybe(shape[-2], mesh, "model"), None)
    if name in ("dt_proj",):
        return P(*([None] * (len(shape) - 2)),
                 None, _maybe(shape[-1], mesh, "model"))
    if name in ("conv_w",):
        return P(*([None] * (len(shape) - 2)),
                 None, _maybe(shape[-1], mesh, "model"))
    if name in ("conv_b", "dt_bias", "d_skip") and shape[-1] >= 128:
        return P(*([None] * (len(shape) - 1)),
                 _maybe(shape[-1], mesh, "model"))
    if name == "a_log" and len(shape) >= 2 and shape[-2] >= 128:
        return P(*([None] * (len(shape) - 2)),
                 _maybe(shape[-2], mesh, "model"), None)

    # ---- norms / scalars: replicated ----------------------------------------
    return P(*([None] * len(shape)))


def params_shardings(specs: Params, mesh: Mesh, cfg: ArchConfig) -> Params:
    """NamedShardings for a parameter pytree via ``param_pspec`` rules."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    out = []
    for path, leaf in flat:
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        out.append(NamedSharding(mesh, param_pspec(keys, leaf.shape, mesh, cfg)))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(param_shardings: Params, mesh: Mesh,
                        param_specs: Params) -> Params:
    """Adam m/v: parameter sharding + ZeRO-1 — additionally shard the
    largest still-replicated dimension over the data axes.  Optimizer state
    is only touched inside the update, so the extra partitioning costs one
    reduce-scatter/all-gather pair per step and cuts fp32 m/v memory by the
    dp degree (8x/16x) — without it 15B-class dense models cannot fit v5e."""
    dp = dp_axes(mesh)
    dp_name = dp if len(dp) > 1 else dp[0]
    dp_size = _axis_size(mesh, dp)

    def zero1(ns: NamedSharding, spec):
        shape = spec.shape
        pspec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
        # skip leaves that already consume a dp axis (e.g. expert-parallel
        # weights sharded E over ('pod','data')) — an axis may appear in a
        # PartitionSpec only once.
        used = set()
        for s in pspec:
            if s is None:
                continue
            used.update(s if isinstance(s, tuple) else (s,))
        if used & set(dp if isinstance(dp, tuple) else (dp,)):
            return ns
        cands = [i for i in range(len(shape))
                 if pspec[i] is None and shape[i] % dp_size == 0
                 and shape[i] >= dp_size]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            pspec[best] = dp_name
        return NamedSharding(mesh, P(*pspec))

    mv = jax.tree.map(zero1, param_shardings, param_specs)
    return {"m": mv, "v": mv, "step": NamedSharding(mesh, P())}


def batch_shardings(mesh: Mesh, batch_spec: Params) -> Params:
    """Batch pytree shardings: leading dim over the dp axes when it
    divides, replicated otherwise."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        if leaf.shape and leaf.shape[0] % _axis_size(
                mesh, dp if isinstance(dp, tuple) else (dp,)) == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree.map(one, batch_spec)


def cache_shardings(mesh: Mesh, cache_spec: Params, cfg: ArchConfig) -> Params:
    """KV / state caches: batch over dp, then heads or seq over model."""
    dp = dp_axes(mesh)
    dp_name = dp if len(dp) > 1 else dp[0]
    dp_size = _axis_size(mesh, dp if isinstance(dp, tuple) else (dp,))
    model = mesh.shape["model"]

    def one(leaf):
        shape = leaf.shape
        # locate the batch axis: first axis equal to a known batch size is
        # fragile; instead: kv caches are [L, B, S, KV, HD] (4/5-d),
        # hybrid conv/ssm are [G, AE, B, ...] or [L, B, ...].
        spec = [None] * len(shape)
        # batch axis = the axis right after leading stack axes whose size
        # matches none of (n_layers variants) — heuristics replaced by:
        # find first axis index i>=1 with shape[i] % dp_size == 0 and mark it.
        for i in range(1, len(shape)):
            if shape[i] % dp_size == 0 and shape[i] >= dp_size:
                spec[i] = dp_name
                batch_i = i
                break
        else:
            batch_i = None
        # shard kv-heads over model if divisible; else the longest remaining
        # axis (sequence / d_inner) over model.
        cand = [i for i in range(1, len(shape))
                if spec[i] is None and shape[i] % model == 0 and shape[i] >= model]
        if cand:
            if cfg.decode_shard == "heads" and len(shape) >= 4 \
                    and (len(shape) - 2) in cand:
                big = len(shape) - 2            # kv-heads axis of [.,B,S,KV,HD]
            else:                                # auto/seq: largest axis (seq)
                big = max(cand, key=lambda i: shape[i])
            spec[big] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_spec)


def replicated(mesh: Mesh, spec: Params) -> Params:
    """Fully-replicated NamedShardings for every leaf of ``spec``."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), spec)
