"""Sharding-constraint hints usable from model code without threading a mesh
through every call.

The launcher (dryrun/train/serve) registers the active mesh via
``use_mesh_hints(mesh)``; model code calls ``constrain(x, *spec)`` which
applies ``with_sharding_constraint`` only for axes that exist in the
registered mesh *and* divide the corresponding dimension — otherwise that
dimension is left unconstrained.  With no registered mesh (unit tests,
single-device smoke) it is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

_CURRENT: Optional[Mesh] = None


@contextlib.contextmanager
def use_mesh_hints(mesh: Mesh):
    """Register ``mesh`` as the active mesh for ``constrain`` hints
    (and enter it) for the duration of the with-block."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = mesh
    try:
        with mesh:
            yield
    finally:
        _CURRENT = prev


def mesh_axis_size(axis) -> int:
    """Product of the registered mesh's sizes for ``axis`` (a name or
    tuple of names); 1 when no mesh is registered."""
    if _CURRENT is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= _CURRENT.shape.get(a, 1)
        return out
    return _CURRENT.shape.get(axis, 1)


def has_axis(axis) -> bool:
    """Whether every name in ``axis`` exists on the registered mesh."""
    if _CURRENT is None:
        return False
    names = set(_CURRENT.axis_names)
    if isinstance(axis, tuple):
        return all(a in names for a in axis)
    return axis in names


def constrain(x: jax.Array, *spec):
    """Best-effort with_sharding_constraint; silently drops invalid axes."""
    if _CURRENT is None:
        return x
    clean = []
    for dim, s in zip(x.shape, spec):
        if s is None or not has_axis(s):
            clean.append(None)
        elif dim % mesh_axis_size(s) == 0 and dim >= mesh_axis_size(s):
            clean.append(s)
        else:
            clean.append(None)
    # pad remaining dims
    clean += [None] * (x.ndim - len(clean))
    if all(c is None for c in clean):
        return x
    return jax.lax.with_sharding_constraint(x, P(*clean))


def dp_axes():
    """The registered mesh's data-parallel axis name(s), or None."""
    if _CURRENT is None:
        return None
    return ("pod", "data") if "pod" in _CURRENT.axis_names else "data"
