import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): ``.lower().compile()`` every
(architecture x input-shape x mesh) cell on the production meshes —
(data=16, model=16) single pod and (pod=2, data=16, model=16) = 512 chips —
and record memory / cost / collective-schedule evidence for §Dry-run and
§Roofline.

The two lines above MUST precede every other import (jax locks the device
count at first init).

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all          # every cell, subprocess-per-cell
  python -m repro.launch.dryrun --all --filter train_4k
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, SHAPES, cell_supported, get_config
from ..configs.shapes import ShapeSpec
from ..distributed import hints
from ..distributed.hlo_analysis import (collective_bytes, depth_delta,
                                        flops_and_bytes, roofline_terms)
from ..distributed.sharding import (batch_shardings, cache_shardings,
                                    opt_state_shardings, params_shardings,
                                    replicated)
from ..models import abstract_params, build_model
from ..models.common import ArchConfig
from ..training.optimizer import OptConfig, abstract_opt_state
from ..training.train_step import make_train_step
from .mesh import HW, make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
def with_depth(cfg: ArchConfig, units: int) -> ArchConfig:
    """Same width, reduced depth (for the depth-delta roofline method)."""
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=units * cfg.attn_every)
    if cfg.family in ("encdec", "audio"):
        return dataclasses.replace(cfg, n_layers=units, n_enc_layers=units)
    return dataclasses.replace(cfg, n_layers=units)


def depth_units(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def input_specs(cfg: ArchConfig, shape: ShapeSpec, model) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        n_tok = s - (cfg.n_patches or 0)
        batch = {"tokens": jax.ShapeDtypeStruct((b, n_tok), i32),
                 "labels": jax.ShapeDtypeStruct((b, n_tok), i32)}
        if cfg.family in ("audio", "encdec"):
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frames, cfg.d_model), jnp.float32)
        if cfg.n_patches:
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        n_tok = s - (cfg.n_patches or 0)
        out = {"tokens": jax.ShapeDtypeStruct((b, n_tok), i32),
               "cache": model.cache_specs(b, s)}
        if cfg.family in ("audio", "encdec"):
            out["extra"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frames, cfg.d_model), jnp.float32)
        if cfg.n_patches:
            out["extra"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against a seq_len KV cache
    return {"token": jax.ShapeDtypeStruct((b, 1), i32),
            "cache": model.cache_specs(b, s),
            "pos": jax.ShapeDtypeStruct((b,), i32)}


# ---------------------------------------------------------------------------
def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, accum: int = 1):
    """Returns (fn, arg_specs tuple, in_shardings tuple, donate_argnums)."""
    model = build_model(cfg)
    pspecs = abstract_params(model.param_specs())
    pshard = params_shardings(pspecs, mesh, cfg)
    specs = input_specs(cfg, shape, model)

    if shape.kind == "train":
        opt_cfg = OptConfig(total_steps=1000)
        step = make_train_step(model, opt_cfg, accum_steps=accum)
        state = {"params": pspecs, "opt": abstract_opt_state(pspecs)}
        state_sh = {"params": pshard,
                    "opt": opt_state_shardings(pshard, mesh, pspecs)}
        bsh = batch_shardings(mesh, specs["batch"])
        return step, (state, specs["batch"]), (state_sh, bsh), (0,)

    if shape.kind == "prefill":
        csh = cache_shardings(mesh, specs["cache"], cfg)
        tsh = batch_shardings(mesh, {"t": specs["tokens"]})["t"]
        if "extra" in specs:
            esh = batch_shardings(mesh, {"e": specs["extra"]})["e"]

            def fn(params, tokens, cache, extra):
                return model.prefill(params, tokens, cache, extra)

            return fn, (pspecs, specs["tokens"], specs["cache"],
                        specs["extra"]), (pshard, tsh, csh, esh), (2,)

        def fn(params, tokens, cache):
            return model.prefill(params, tokens, cache)

        return fn, (pspecs, specs["tokens"], specs["cache"]), \
            (pshard, tsh, csh), (2,)

    # decode
    csh = cache_shardings(mesh, specs["cache"], cfg)
    tsh = batch_shardings(mesh, {"t": specs["token"]})["t"]
    psh = batch_shardings(mesh, {"p": specs["pos"]})["p"]

    def fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return fn, (pspecs, specs["token"], specs["cache"], specs["pos"]), \
        (pshard, tsh, csh, psh), (2,)


def compile_cell(cfg: ArchConfig, shape: ShapeSpec, mesh,
                 want_hlo: bool = False, accum: int = 1) -> Dict[str, Any]:
    fn, arg_specs, in_sh, donate = build_cell(cfg, shape, mesh, accum=accum)
    t0 = time.perf_counter()
    with hints.use_mesh_hints(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*arg_specs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
    t2 = time.perf_counter()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = {
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         + ma.output_size_in_bytes
                                         - ma.alias_size_in_bytes),
        },
        "cost": flops_and_bytes(ca),
        "collectives": coll,
    }
    rec["memory"]["fits_hbm"] = rec["memory"]["peak_per_device_bytes"] \
        <= HW.HBM_BYTES
    if want_hlo:
        rec["hlo_head"] = "\n".join(
            l for l in hlo.splitlines()
            if any(c in l for c in ("all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute")))[:20000]
    return rec


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch            # decode: one token


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             skip_delta: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if mesh_kind == "pod2" else 256
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "chips": chips}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    dp = 32 if mesh_kind == "pod2" else 16
    try:
        # auto-microbatching: escalate grad-accum until the step fits HBM
        # (production launcher behaviour; per-token costs are unchanged)
        accum_tried = []
        full = None
        accum = 1
        max_accum = 16
        while True:
            full = compile_cell(cfg, shape, mesh, accum=accum)
            accum_tried.append(
                {"accum": accum,
                 "temp_gb": round(full["memory"]["temp_bytes"] / 1e9, 2),
                 "fits": full["memory"]["fits_hbm"]})
            if shape.kind != "train" or full["memory"]["fits_hbm"]:
                break
            # jump straight to the overshoot-implied accumulation level
            over = full["memory"]["peak_per_device_bytes"] / HW.HBM_BYTES
            nxt = accum
            while nxt < over * accum and nxt < max_accum:
                nxt *= 2
            nxt = max(nxt, accum * 2)
            if nxt > max_accum or shape.global_batch % (nxt * dp) != 0:
                break
            accum = nxt
        rec["accum"] = accum_tried
        rec["full"] = full
        if not skip_delta:
            # depth-delta roofline correction: XLA cost_analysis counts scan
            # bodies ONCE regardless of trip count (verified: flops are
            # depth-invariant under scan), so the delta compiles UNROLL the
            # layer loop and collapse ssm chunk scans to one trip so every
            # instance is counted (see distributed/hlo_analysis.py).
            u = 1
            mk = lambda uu: dataclasses.replace(     # noqa: E731
                with_depth(cfg, uu), unroll=True, ssm_chunk=-1)
            c1 = compile_cell(mk(u), shape, mesh)
            c2 = compile_cell(mk(u + 1), shape, mesh)
            d = depth_delta(c1["cost"], c2["cost"], c1["collectives"],
                            c2["collectives"], u, depth_units(cfg))
            rec["delta"] = d
            terms = roofline_terms(d["flops"], d["bytes"],
                                   d["collective_bytes"], chips,
                                   HW.PEAK_BF16_FLOPS, HW.HBM_BW, HW.ICI_BW)
            mf = model_flops(cfg, shape)
            terms["model_flops"] = mf
            terms["hlo_flops_total"] = d["flops"] * chips
            terms["useful_ratio"] = (mf / (d["flops"] * chips)
                                     if d["flops"] else 0.0)
            rec["roofline"] = terms
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record compile failures as data
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    return rec


# ---------------------------------------------------------------------------
def cell_path(arch, shape, mesh_kind):
    os.makedirs(OUT_DIR, exist_ok=True)
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.join(OUT_DIR, f"{safe}__{shape}__{mesh_kind}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod1", "pod2"), default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--filter", default="",
                    help="substring filter on '<arch>__<shape>__<mesh>'")
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-skip-existing", dest="skip_existing",
                    action="store_false")
    ap.add_argument("--skip-delta", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, m) for a in ARCH_IDS for s in SHAPES
                 for m in ("pod1", "pod2")]
        cells = [c for c in cells
                 if args.filter in f"{c[0]}__{c[1]}__{c[2]}"]
        for arch, shape, mesh_kind in cells:
            path = cell_path(arch, shape, mesh_kind)
            if args.skip_existing and os.path.exists(path):
                print(f"[skip-existing] {path}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
            if args.skip_delta or mesh_kind == "pod2":
                # §Roofline is single-pod; pod2 cells only need the
                # compile + memory + collective-schedule proof.
                cmd.append("--skip-delta")
            print(">>", " ".join(cmd), flush=True)
            r = subprocess.run(cmd, cwd=os.getcwd())
            if r.returncode != 0:
                print(f"[subprocess failed] {arch} {shape} {mesh_kind}")
        return

    assert args.arch and args.shape
    rec = run_cell(args.arch, args.shape, args.mesh,
                   skip_delta=args.skip_delta)
    path = cell_path(args.arch, args.shape, args.mesh)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("full", "delta")}, indent=1))
    if rec["status"] == "ok":
        m = rec["full"]["memory"]
        print(f"memory/device: args={m['argument_bytes']/1e9:.2f}GB "
              f"temp={m['temp_bytes']/1e9:.2f}GB fits_hbm={m['fits_hbm']}")
        if "roofline" in rec:
            print("roofline:", json.dumps(rec["roofline"]))
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
