import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: compile a (arch x shape x mesh) cell under a named
optimization variant and report the roofline-term deltas vs baseline.

  PYTHONPATH=src python -m repro.launch.perf --arch gemma3-1b \
      --shape train_4k --mesh pod1 --variant sp_dots

Variants compose config-level levers (see models/common.py):
  baseline      paper-faithful defaults
  sp            sequence-parallel residual stream (Megatron-SP)
  dots          remat policy saving matmul outputs
  sp_dots       both
  qchunk512/qchunk2048   attention query-block size
  kv_heads      decode KV cache sharded over kv-heads instead of sequence
  cf10          MoE capacity factor 1.0 (tighter dispatch buffer)
  accumN        N-way gradient accumulation (train shapes)
"""
import argparse
import dataclasses
import json
import time

from ..configs import ARCH_IDS, SHAPES, get_config
from ..launch.dryrun import (cell_path, compile_cell, depth_units, model_flops,
                             with_depth)
from ..launch.mesh import HW, make_production_mesh
from ..distributed.hlo_analysis import depth_delta, roofline_terms

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "perf")

VARIANTS = {
    "baseline": {},
    "sp": dict(seq_parallel=True),
    "dots": dict(remat_policy="dots"),
    "sp_dots": dict(seq_parallel=True, remat_policy="dots"),
    "qchunk512": dict(attn_q_chunk=512),
    "qchunk2048": dict(attn_q_chunk=2048),
    "kv_heads": dict(decode_shard="heads"),
    "cf10": dict(capacity_factor=1.0),
    "ssmchunk256": dict(ssm_chunk=256),
    "localdisp": dict(moe_local_dispatch=True),
    "localdisp_cf10": dict(moe_local_dispatch=True, capacity_factor=1.0),
}


def run_variant(arch: str, shape_name: str, mesh_kind: str, variant: str,
                accum: int = 1, skip_delta: bool = False):
    overrides = VARIANTS[variant] if variant in VARIANTS else {}
    if variant.startswith("accum"):
        accum = int(variant[5:])
        overrides = {}
    cfg = dataclasses.replace(get_config(arch), **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    chips = 512 if mesh_kind == "pod2" else 256
    t0 = time.time()
    full = compile_cell(cfg, shape, mesh, accum=accum)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "accum": accum, "full": full}
    if not skip_delta:
        mk = lambda u: dataclasses.replace(   # noqa: E731
            with_depth(cfg, u), unroll=True, ssm_chunk=-1)
        c1 = compile_cell(mk(1), shape, mesh)
        c2 = compile_cell(mk(2), shape, mesh)
        d = depth_delta(c1["cost"], c2["cost"], c1["collectives"],
                        c2["collectives"], 1, depth_units(cfg))
        terms = roofline_terms(d["flops"], d["bytes"], d["collective_bytes"],
                               chips, HW.PEAK_BF16_FLOPS, HW.HBM_BW,
                               HW.ICI_BW)
        mf = model_flops(cfg, shape)
        terms["model_flops"] = mf
        terms["useful_ratio"] = mf / (d["flops"] * chips) if d["flops"] else 0
        rec["roofline"] = terms
    rec["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(PERF_DIR, exist_ok=True)
    safe = arch.replace(".", "_")
    path = os.path.join(PERF_DIR,
                        f"{safe}__{shape_name}__{mesh_kind}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=tuple(SHAPES), required=True)
    ap.add_argument("--mesh", choices=("pod1", "pod2"), default="pod1")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--skip-delta", action="store_true")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.mesh, args.variant,
                      args.accum, args.skip_delta)
    m = rec["full"]["memory"]
    line = {
        "variant": args.variant,
        "peak_gb": round(m["peak_per_device_bytes"] / 1e9, 2),
        "fits": m["fits_hbm"],
        "coll_gb_full": round(rec["full"]["collectives"]["total"] / 1e9, 3),
    }
    if "roofline" in rec:
        ro = rec["roofline"]
        line.update(compute_s=round(ro["compute_s"], 4),
                    memory_s=round(ro["memory_s"], 4),
                    collective_s=round(ro["collective_s"], 4),
                    bottleneck=ro["bottleneck"],
                    useful=round(ro["useful_ratio"], 3))
    print(json.dumps(line))


if __name__ == "__main__":
    main()
