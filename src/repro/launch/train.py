"""End-to-end training driver with fault tolerance.

Single-process usage (CPU container / smoke scale):
  PYTHONPATH=src python -m repro.launch.train --arch codeqwen1.5-7b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt

On a real cluster the same driver runs per host under
``jax.distributed.initialize()`` with the production mesh (``--mesh pod1``);
the data pipeline shards by host id and the checkpoint manager handles
elastic restarts (restore-with-reshard).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..data.pipeline import DataConfig, PrefetchingLoader, SyntheticTokenPipeline
from ..distributed import hints
from ..models import build_model, init_params
from ..training.checkpoint import CheckpointManager
from ..training.fault_tolerance import (FaultTolerantRunner, HeartbeatMonitor)
from ..training.optimizer import OptConfig
from ..training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd", "const"))
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    state = init_train_state(params)
    opt_cfg = OptConfig(lr=args.lr, schedule=args.schedule,
                        warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, args.accum))

    pipe = SyntheticTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0))

    start_step = 0
    runner = None
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir)
        runner = FaultTolerantRunner(cm, HeartbeatMonitor(hosts=[0]),
                                     ckpt_every=args.ckpt_every)
        restored, manifest = cm.restore(state)
        if restored is not None:
            state = jax.tree.map(jnp.asarray, restored)
            start_step = manifest["extra"]["data_step"]
            print(f"[resume] restored step {manifest['step']}, "
                  f"data cursor {start_step}")

    loader = PrefetchingLoader(pipe, start_step=start_step)
    t_start = time.time()
    for i in range(start_step, args.steps):
        step_i, batch = next(loader)
        t0 = time.time()
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch))
        dt = time.time() - t0
        if runner:
            runner.monitor.beat(0, step_time_s=dt)
            runner.maybe_checkpoint(i, state, data_step=step_i + 1)
        if i % args.log_every == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"{tok_s:,.0f} tok/s", flush=True)
    loader.close()
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
