"""Serving driver: continuous-batched generation, optionally RAG-augmented.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import build_model, init_params
from ..serving.batching import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    batcher = ContinuousBatcher(model, params, n_slots=args.slots,
                                max_len=args.max_len, eos_id=1)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        batcher.submit(Request(
            req_id=i, prompt=rng.integers(2, cfg.vocab, size=plen
                                          ).astype(np.int32),
            max_new=args.max_new))
    t0 = time.time()
    done = batcher.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, {batcher.steps} decode ticks)")
    for r in done[:3]:
        print(f"  req {r.req_id}: {len(r.output)} tokens -> {r.output[:8]}…")


if __name__ == "__main__":
    main()
