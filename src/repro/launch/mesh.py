"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
initialization (see launch/dryrun.py), and smoke tests must keep seeing one
device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_compat_kwargs", "HW"]


def mesh_compat_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; omit it elsewhere (the
    default is Auto on every version that has the argument)."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]       # single-pod uses 256 of the 512 hosts
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes,
        **mesh_compat_kwargs(len(axes)))


class HW:
    """TPU v5e hardware constants for the roofline (per chip)."""

    PEAK_BF16_FLOPS = 197e12       # FLOP/s
    HBM_BW = 819e9                 # bytes/s
    ICI_BW = 50e9                  # bytes/s per link
    HBM_BYTES = 16e9               # capacity
