"""Quantized read path: int8 segment codecs + exact fp32 rerank.

Sealed segments are immutable by construction, so per-dimension symmetric
int8 scales can be fit once — at seal or compaction-publish — and never
revisited (``codec``).  The sealed-segment scan then runs over int8 codes
with the scale folded into the fp32 query (asymmetric distance, see
``repro.kernels.quant_topk``), over-fetches a candidate set, and a final
exact fp32 rerank (``rerank``) restores full-precision ordering with the
same deterministic ``(dist, gid)`` tie-break the unquantized merge uses.

- ``codec``   fit / quantize / dequantize + the per-segment ``SegmentQuant``
              payload (codes, scales, dequantized squared norms)
- ``rerank``  exact fp32 top-k over a candidate gid set via the existing
              ``core.graph.topk_over_candidates`` primitive
"""
from .codec import (QUANT_KINDS, SegmentQuant, dequantize, encode_segment,
                    fit_scales, quantize)
from .rerank import rerank_exact

__all__ = ["QUANT_KINDS", "SegmentQuant", "dequantize", "encode_segment",
           "fit_scales", "quantize", "rerank_exact"]
