"""Exact fp32 rerank of a quantized scan's over-fetched candidates.

The quantized read path returns approximate ``(gid, dist)`` candidates;
this stage gathers the candidates' original fp32 vectors (from the
manager's point store — the ledger that already serves point lookups) and
re-scores them with the existing exact primitive
``repro.core.graph.topk_over_candidates``, then normalizes the result
through ``repro.distributed.segment_shards.host_topk`` so the output obeys
the same deterministic ``(dist, gid)`` tie-break as the unquantized
merge (``streaming.query.merge_topk``).  Downstream, the reranked block is
indistinguishable from an exact fp32 segment block.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["rerank_exact"]


def rerank_exact(queries: np.ndarray, cand_gids: np.ndarray, k: int,
                 lookup: Callable, metric: str = "l2"
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Candidate gids ``[b, s]`` (``-1`` padded) -> exact fp32
    ``(gids [b, k], dists [b, k])``.

    ``lookup(gids) -> (x, s, present)`` supplies the original fp32 vectors
    (``SegmentManager.get_points``); candidates whose row is gone
    (``present=False`` — only possible once every gid in their store chunk
    is dead) are dropped, which matches the downstream liveness filter.
    Per-row candidate lists are sorted by gid before the top-k so distance
    ties at the k-th boundary resolve to the smallest gid — the exact
    ordering contract of ``host_topk`` — and duplicated gids within a row
    (impossible from disjoint segment blocks, cheap to guard) are masked.
    """
    from ..core.graph import squared_norms, topk_over_candidates
    from ..distributed.segment_shards import host_topk

    queries = np.atleast_2d(np.asarray(queries, np.float32))
    cand = np.atleast_2d(np.asarray(cand_gids, np.int64))
    b = queries.shape[0]
    uniq = np.unique(cand[cand >= 0])
    if len(uniq) == 0:
        return (np.full((b, k), -1, np.int64),
                np.full((b, k), np.inf, np.float32))
    x, _, present = lookup(uniq)
    pos = np.searchsorted(uniq, np.maximum(cand, 0))
    local = np.where((cand >= 0) & present[pos], pos, len(uniq))
    local.sort(axis=1)                     # ascending local id == gid order
    if local.shape[1] > 1:                 # defensive within-row dedup
        dup = local[:, 1:] == local[:, :-1]
        local[:, 1:][dup] = len(uniq)
    local = np.where(local < len(uniq), local, -1).astype(np.int32)
    xj = jnp.asarray(np.asarray(x, np.float32))
    ids, dd = topk_over_candidates(queries, local, xj, squared_norms(xj),
                                   min(k, local.shape[1]), metric=metric)
    ids = np.asarray(ids)
    g = np.where(ids >= 0, uniq[np.maximum(ids, 0)], -1)
    return host_topk(g, np.asarray(dd, np.float32), k)
