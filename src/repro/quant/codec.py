"""Per-segment symmetric int8 scalar quantization.

One scale per vector dimension, fit over a sealed segment's rows:
``scale[j] = max_i |x[i, j]| / 127``.  Codes are round-to-nearest of
``x / scale`` clipped to ``[-127, 127]``, so every element satisfies the
codec contract

    |x[i, j] - scale[j] * code[i, j]|  <=  scale[j] / 2

(tested as a hypothesis property in ``tests/test_quant.py``).  Scales are
fit only when a segment's content is (re)written — seal and
compaction-publish — because sealed segments are immutable; restore loads
codes/scales from the segment artifact and never re-quantizes.

The dequantized squared norms (``xsq``) are precomputed here too: the
asymmetric-distance kernel needs ``||deq(x)||^2`` per point and the segment
is immutable, so paying O(n d) once at encode time keeps it off every
query.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["QUANT_KINDS", "SegmentQuant", "dequantize", "encode_segment",
           "fit_scales", "quantize"]

QUANT_KINDS = ("int8",)
_QMAX = 127.0                    # symmetric int8 code range [-127, 127]
_MIN_SCALE = 1e-12               # all-zero dimensions quantize to code 0


def fit_scales(x: np.ndarray) -> np.ndarray:
    """Per-dimension symmetric scales for one segment: ``[d]`` fp32 with
    ``scale[j] = max_i |x[i, j]| / 127`` (floored so an all-zero dimension
    stays finite and round-trips to exactly zero)."""
    x = np.atleast_2d(np.asarray(x, np.float32))
    amax = np.abs(x).max(axis=0) if len(x) else np.zeros(x.shape[1],
                                                         np.float32)
    return np.maximum(amax / _QMAX, _MIN_SCALE).astype(np.float32)


def quantize(x: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """``[n, d]`` fp32 -> int8 codes: round-to-nearest of ``x / scales``,
    clipped to the symmetric range."""
    x = np.atleast_2d(np.asarray(x, np.float32))
    q = np.rint(x / np.asarray(scales, np.float32)[None, :])
    return np.clip(q, -_QMAX, _QMAX).astype(np.int8)


def dequantize(codes: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """int8 codes -> fp32 reconstruction ``codes * scales``."""
    return (np.asarray(codes, np.float32)
            * np.asarray(scales, np.float32)[None, :])


@dataclasses.dataclass(frozen=True)
class SegmentQuant:
    """One sealed segment's quantized payload (rows parallel to the
    segment's ``index.x`` rows, so validity masks apply unchanged).

    ``xsq`` holds the *dequantized* squared norms — the asymmetric L2
    kernel computes ``||q - deq(x)||^2 = ||q||^2 - 2 (q*scale).codes +
    xsq`` and must use the reconstruction's norm, not the original's, for
    its candidate ranking to match the dequantized oracle exactly.
    """

    kind: str                    # codec name ("int8")
    codes: np.ndarray            # [n, d] int8
    scales: np.ndarray           # [d] fp32
    xsq: np.ndarray              # [n] fp32 dequantized squared norms

    @property
    def n(self) -> int:
        """Encoded rows."""
        return int(self.codes.shape[0])

    @property
    def d(self) -> int:
        """Vector dimension."""
        return int(self.codes.shape[1])

    @property
    def nbytes(self) -> int:
        """Host bytes of the payload (codes + scales + norms)."""
        return int(self.codes.nbytes + self.scales.nbytes + self.xsq.nbytes)

    def take(self, rows: np.ndarray) -> "SegmentQuant":
        """Row-subset view (e.g. the live rows) sharing this payload's
        scales — valid because per-dimension maxima only shrink under
        subsetting, so the scale bound still holds for every kept row."""
        rows = np.asarray(rows)
        return SegmentQuant(self.kind, self.codes[rows], self.scales,
                            self.xsq[rows])


def encode_segment(x: np.ndarray, kind: str = "int8") -> SegmentQuant:
    """Fit scales over ``x`` and encode it — the one entry point used at
    seal and compaction-publish time."""
    if kind not in QUANT_KINDS:
        raise ValueError(f"unknown quantization kind {kind!r}; "
                         f"supported: {QUANT_KINDS}")
    x = np.atleast_2d(np.asarray(x, np.float32))
    scales = fit_scales(x)
    codes = quantize(x, scales)
    deq = dequantize(codes, scales)
    xsq = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
    return SegmentQuant(kind, codes, scales, xsq)
