"""Training step factory: microbatched gradient accumulation + AdamW.

``make_train_step(model, opt_cfg, accum_steps)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
in/out shardings (see launch/train.py and launch/dryrun.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .optimizer import OptConfig, adamw_update, init_opt_state

TrainState = Dict[str, Any]        # {params, opt: {m, v, step}}


def init_train_state(params) -> TrainState:
    return {"params": params, "opt": init_opt_state(params)}


def make_train_step(model, opt_cfg: OptConfig, accum_steps: int = 1):
    """Build the train step.  With ``accum_steps > 1`` the global batch is
    split along axis 0 into microbatches processed under `lax.scan` (activation
    memory / throughput trade — a §Perf lever)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        params = state["params"]
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            from ..distributed.hints import constrain, dp_axes
            dp = dp_axes()
            # keep the BATCH dim sharded over dp after the reshape — without
            # the constraint XLA may shard the accum axis instead, silently
            # replicating each microbatch across the data axis.
            micro = jax.tree.map(
                lambda a: constrain(
                    a.reshape((accum_steps, a.shape[0] // accum_steps)
                              + a.shape[1:]), None, dp), batch)

            def mb(carry, mb_batch):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_batch)
                return (acc_loss + l,
                        jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     acc_g, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(mb, (jnp.float32(0.0), zeros),
                                            micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        new_params, opt, om = adamw_update(params, grads, state["opt"],
                                           opt_cfg)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": opt}, metrics

    return train_step
