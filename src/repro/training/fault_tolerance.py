"""Cluster-runtime fault handling: heartbeats, straggler detection, and the
elastic restart plan.

At 1000+ nodes the failure model is: (a) hard node loss — detected by missed
heartbeats, handled by checkpoint-restore onto the surviving mesh (elastic);
(b) stragglers — detected by per-step-time outliers, handled by excluding the
slow host from the next mesh or, within a step, by bounded collect timeouts.
On this single-process container the *policies* are fully implemented and
unit-tested against simulated timing traces; the transport (real heartbeat
RPCs) is the thin layer a deployment supplies.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HeartbeatConfig:
    interval_s: float = 10.0
    miss_threshold: int = 3            # missed beats => dead
    straggler_factor: float = 2.0      # step_time > f * median => straggler
    straggler_window: int = 20         # sliding window of step times


class HeartbeatMonitor:
    """Tracks liveness + per-host step times; pure logic (testable)."""

    def __init__(self, hosts: Sequence[int], cfg: HeartbeatConfig = HeartbeatConfig()):
        self.cfg = cfg
        self.last_beat: Dict[int, float] = {h: time.monotonic() for h in hosts}
        self.step_times: Dict[int, deque] = {
            h: deque(maxlen=cfg.straggler_window) for h in hosts}

    def beat(self, host: int, step_time_s: Optional[float] = None,
             now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.last_beat[host] = now
        if step_time_s is not None:
            self.step_times[host].append(step_time_s)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        limit = self.cfg.interval_s * self.cfg.miss_threshold
        return [h for h, t in self.last_beat.items() if now - t > limit]

    def stragglers(self) -> List[int]:
        medians = []
        for times in self.step_times.values():
            if times:
                medians.extend(times)
        if not medians:
            return []
        medians.sort()
        med = medians[len(medians) // 2]
        out = []
        for h, times in self.step_times.items():
            if times and (sum(times) / len(times)) > self.cfg.straggler_factor * med:
                out.append(h)
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Given surviving chips, the largest runnable production mesh and the
    batch re-sharding plan (global batch is preserved; per-replica batch
    grows as the data axis shrinks)."""

    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    data_parallel: int
    notes: str = ""


def plan_elastic_mesh(n_chips: int, model_parallel: int = 16,
                      pods: int = 1) -> ElasticPlan:
    """Shrink the data axis to the largest power-of-two that fits the
    surviving chip count, keeping TP (model axis) intact — TP must not change
    because parameter layouts are sharded along it."""
    per_pod = n_chips // max(pods, 1)
    data = 1
    while data * 2 * model_parallel <= per_pod:
        data *= 2
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"), data * pods,
                           notes=f"{n_chips} chips -> ({pods},{data},{model_parallel})")
    return ElasticPlan((data, model_parallel), ("data", "model"), data,
                       notes=f"{n_chips} chips -> ({data},{model_parallel})")


class FaultTolerantRunner:
    """Training-loop supervisor: periodic checkpoints, failure detection
    hooks, restore-and-reshard on simulated node loss.  See
    tests/test_fault_tolerance.py and launch/train.py."""

    def __init__(self, ckpt_manager, monitor: HeartbeatMonitor,
                 ckpt_every: int = 50):
        self.ckpt = ckpt_manager
        self.monitor = monitor
        self.ckpt_every = ckpt_every
        self.failures_handled = 0

    def maybe_checkpoint(self, step: int, state, data_step: int):
        if step % self.ckpt_every == 0 and step > 0:
            self.ckpt.save(step, state, extra={"data_step": data_step})

    def check_cluster(self, now: Optional[float] = None) -> Dict:
        dead = self.monitor.dead_hosts(now)
        strag = self.monitor.stragglers()
        return {"dead": dead, "stragglers": strag,
                "action": ("elastic_restart" if dead else
                           "exclude_stragglers" if strag else "none")}
