"""Fault-tolerant sharded checkpointing (no orbax dependency).

Guarantees:
* **Atomicity** — writes go to ``step_XXXX.tmp`` and are renamed only after
  every array and the manifest have been fsynced; a crash mid-save never
  corrupts the latest valid checkpoint.
* **Integrity** — the manifest stores per-leaf SHA-256 + shapes/dtypes;
  ``restore`` verifies before handing arrays back and falls back to the
  previous valid step on corruption.
* **Elasticity** — arrays are saved *unsharded* (gathered); restore takes an
  optional target sharding pytree, so a job may come back on a different
  mesh/device count (reshard-on-restore).
* **Data-order resume** — the data cursor (step) rides in the manifest; the
  stateless pipeline regenerates exactly the batches that would have followed.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

Params = Any


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Params, extra: Optional[Dict] = None):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten_with_paths(state)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
            path = os.path.join(tmp, fname)
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)                      # atomic publish
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def available_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    # ------------------------------------------------------------------
    def _verify_and_load(self, step: int, template: Params,
                         shardings: Optional[Params]):
        cdir = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = _flatten_with_paths(template)
        shard_leaves = None
        if shardings is not None:
            shard_leaves, _ = _flatten_with_paths(shardings)
        out = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(cdir, meta["file"]))
            if hashlib.sha256(arr.tobytes()).hexdigest() != meta["sha256"]:
                raise IOError(f"integrity failure in {key} @ step {step}")
            if shard_leaves is not None and key in shard_leaves:
                out[key] = jax.device_put(arr, shard_leaves[key])
            else:
                out[key] = arr
        ordered = [out[k] for k in leaves_t]
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest

    def restore(self, template: Params, shardings: Optional[Params] = None,
                step: Optional[int] = None):
        """Restore latest (or given) step; skip corrupt checkpoints.
        Returns (state, manifest) or (None, None) if nothing restorable."""
        steps = self.available_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            try:
                return self._verify_and_load(s, template, shardings)
            except (IOError, FileNotFoundError, json.JSONDecodeError):
                continue
        return None, None
