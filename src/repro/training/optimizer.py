"""Optimizer substrate: AdamW with cosine / WSD (warmup-stable-decay,
MiniCPM) / constant schedules, global-norm gradient clipping.

Pure JAX (no optax): state is a pytree {m, v} matching params, fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    wsd_decay_frac: float = 0.1       # MiniCPM: final 10% exponential decay
    min_lr_ratio: float = 0.1


def schedule_lr(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        post = jnp.float32(1.0)
    elif cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        post = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1 - cfg.wsd_decay_frac)
        frac = jnp.clip((s - decay_start)
                        / max(cfg.total_steps - decay_start, 1), 0.0, 1.0)
        post = jnp.exp(jnp.log(jnp.maximum(cfg.min_lr_ratio, 1e-6)) * frac)
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * post


def init_opt_state(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(param_specs: Params) -> Dict[str, Any]:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, param_specs),
            "v": jax.tree.map(zeros, param_specs),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params: Params, grads: Params, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = schedule_lr(step, cfg)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
