"""Gradient compression for the data-parallel all-reduce: int8 quantization
with error feedback (1-bit-Adam-family trick).

The compressed all-reduce runs inside ``shard_map`` over the data axis:
each replica quantizes its local gradient (per-tensor scale), all-reduces the
int8 payload (8x less ICI traffic — directly shrinks the collective roofline
term), dequantizes, and keeps the quantization residual in an error-feedback
buffer added to the *next* step's gradient, which preserves convergence.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_residual(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                               jnp.ndarray]:
    """Returns (q, scale, residual = g - dequant(q))."""
    q, scale = quantize_int8(g)
    return q, scale, g - dequantize_int8(q, scale)


def compressed_psum(grads: Params, errors: Params, axis_name: str
                    ) -> Tuple[Params, Params]:
    """Inside shard_map: error-feedback compressed mean over ``axis_name``.

    grads/errors: local fp32 pytrees.  Returns (averaged grads, new errors).
    """
    n = jax.lax.psum(jnp.ones(()), axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale, resid = compress_residual(g)
        # int8 payload summed across replicas (scales too — per-replica scale
        # rides along as one fp32 per tensor, negligible traffic)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(scale, axis_name)
        avg = qsum.astype(jnp.float32) * (ssum / n) / n
        return avg, resid

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_error_state(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
