"""Durable streaming snapshots: WAL + segment artifacts + atomic manifest.

Three cooperating pieces give :class:`~repro.streaming.manager.SegmentManager`
crash-consistent durability:

* **Write-ahead log** (:class:`WriteAheadLog`) — the hot path.  Every ingest
  batch, delete, and point-store GC appends one CRC-framed record
  (``[u32 length][u32 crc32][payload]``) to an append-only file; fsyncs are
  batched (``wal_fsync_every``).  Replay stops at the first torn or
  corrupt frame, so a crash mid-append loses only the unacknowledged record.

* **Segment artifacts** — immutable per-segment directories written once at
  seal / compaction-publish through the extended
  :func:`repro.core.cubegraph.save_index` (graphs + standalone ``x.npy`` /
  ``s.npy`` point arrays + gid map + time range; with the quantized read
  path on, also the int8 codec payload — codes, per-dimension scales,
  dequantized norms — so restore never re-quantizes).  Restore loads them
  with ``np.load(mmap_mode="r")`` for cheap replica warm-start.  Artifacts
  are staged in a ``*.tmp`` directory and published with one
  ``os.replace``.

* **Versioned manifest** (``MANIFEST.json``) — the commit point.  A
  checkpoint captures the mutable residue (liveness bitmap, delta buffer,
  point-store chunks) into a ``state-<version>.npz``, rotates the WAL, and
  swaps the manifest via write-temp-then-rename.  Every on-disk state is
  therefore self-consistent: restore reads the last published manifest and
  replays the (complete-by-construction) WAL tail after it.

Checkpoints happen only at segment-list transitions (seal, compaction
publish, expiry) and on explicit :meth:`SegmentManager.snapshot_to` — the
LSM discipline: sealed data is written once, the WAL covers everything
between checkpoints, and nothing on the ingest/delete hot path ever waits
on an index serialization.

Recovery sequence (:func:`restore_manager`)::

    MANIFEST.json -> verify state checksum -> load segment artifacts (mmap)
                  -> rebuild alive bitmap / delta buffer / point store
                  -> replay WAL tail (ingest / delete / gc records)
                  -> re-derive per-segment validity from the alive bitmap

The restored manager answers queries bit-for-bit identically to the
pre-snapshot one: sealed-segment arrays round-trip exactly, the delta
buffer preserves row order, and the shard-pack read path rebuilds from the
same live points in the same segment order (``tests/test_persistence.py``).
The size-bucketed device pack is *derived* state: it is never serialized —
restore cold-builds the buckets lazily on the first sharded query from the
restored segments' live points (the manifest's per-segment entries carry
``n_live`` and the projected ``bucket_cap``, and the cfg blob carries the
bucket geometry knobs, so a replica's device footprint is known up front).

Fault injection: every critical transition calls ``fault_hook(point)`` when
one is installed (``"wal.append"`` mid-frame, ``"segment.write"`` between
index arrays and the artifact's metadata, ``"manifest.rename"`` just before
the atomic swap).  The crash-recovery tests raise from these hooks and then
restore from disk — simulating a kill at the worst possible instant.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import shutil
import struct
import threading
import time
import zlib
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import CubeGraphConfig
from ..core.cubegraph import load_index, load_index_extras, save_index
from ..obs.metrics import NULL_REGISTRY
from .segments import SealedSegment

__all__ = ["RestoreError", "WriteAheadLog", "StreamPersistence",
           "load_manifest", "restore_manager", "write_segment_artifact",
           "load_segment_artifact"]

WAL_MAGIC = b"CGWAL001"
_FRAME = struct.Struct("<II")            # payload length, crc32(payload)
REC_INGEST, REC_DELETE, REC_GC = 1, 2, 3
MANIFEST_NAME = "MANIFEST.json"
MANIFEST_FORMAT = 1


class RestoreError(RuntimeError):
    """A snapshot directory failed a consistency check during restore."""


def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync so renames survive a power cut."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                      # pragma: no cover - platform quirk
        return
    try:
        os.fsync(fd)
    except OSError:                      # pragma: no cover - platform quirk
        pass
    finally:
        os.close(fd)


def _fsync_tree(directory: str) -> None:
    """fsync every file under ``directory`` — artifact data blocks must be
    durable before a manifest referencing the artifact commits."""
    for dirpath, _, files in os.walk(directory):
        for name in files:
            try:
                fd = os.open(os.path.join(dirpath, name), os.O_RDONLY)
            except OSError:              # pragma: no cover - platform quirk
                continue
            try:
                os.fsync(fd)
            except OSError:              # pragma: no cover - platform quirk
                pass
            finally:
                os.close(fd)


def _atomic_write(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` via temp file + fsync + atomic rename."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------
class WriteAheadLog:
    """Append-only CRC-framed record log (the ingest/delete hot path).

    Frame layout: ``[u32 length][u32 crc32][payload]`` after an 8-byte file
    magic.  Appends write the whole frame in one unbuffered write and fsync
    every ``fsync_every`` records (and on :meth:`sync`), trading a bounded
    tail-loss window for hot-path latency.  :meth:`replay` yields decoded
    records and stops cleanly at the first torn or corrupt frame.
    """

    def __init__(self, path: str, fsync_every: int = 32,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 metrics=None):
        self.path = path
        self.fsync_every = max(int(fsync_every), 1)
        self.fault_hook = fault_hook
        self.metrics = NULL_REGISTRY if metrics is None else metrics
        self._since_sync = 0
        self._f = open(path, "ab", buffering=0)
        # a new OR empty file always gets the magic — appends to a
        # magic-less log would be silently unreplayable
        if self._f.tell() == 0:
            self._f.write(WAL_MAGIC)
            self._f.flush()
            os.fsync(self._f.fileno())

    @property
    def offset(self) -> int:
        """Current end-of-log byte offset (== bytes durable once synced)."""
        return self._f.tell()

    def append(self, rec_type: int, payload: bytes) -> int:
        """Frame and append one record; returns the post-append offset.

        Failure-atomic for a *surviving* process: if any write raises
        (ENOSPC, a raising fault hook), the file is truncated back to the
        pre-append offset before the exception propagates, so the log never
        carries a torn frame that would hide later appends from replay.  A
        process killed mid-write does leave a torn frame — replay stops at
        it and a resuming replica truncates it (see
        ``restore_manager``).

        With a fault hook installed the frame is split in two writes around
        the hook call, emulating the kill-mid-write state at the hook.
        """
        body = bytes([rec_type]) + payload
        frame = _FRAME.pack(len(body), zlib.crc32(body)) + body
        start = self._f.tell()
        since0 = self._since_sync
        t0 = time.perf_counter()
        try:
            if self.fault_hook is not None:
                mid = len(frame) // 2
                self._f.write(frame[:mid])
                self.fault_hook("wal.append")
                self._f.write(frame[mid:])
            else:
                self._f.write(frame)
            self._since_sync += 1
            # the batched fsync is part of this append's failure atom: if
            # it raises (ENOSPC at sync time, a "wal.fsync" fault), the
            # un-acknowledged record is rolled back too — otherwise the
            # caller aborts its mutation while the record survives replay,
            # and the *next* logged ingest would no longer extend the
            # store (phantom-point RestoreError on recovery)
            if self._since_sync >= self.fsync_every:
                self.sync()
        except BaseException:
            self._since_sync = since0
            try:
                self._f.truncate(start)
                self._f.seek(start)
            except OSError:              # pragma: no cover - disk gone
                pass
            raise
        # the append histogram includes the batched fsync when this record
        # hit the batch boundary — that is the latency an acknowledged
        # ingest actually pays, which is what the histogram is for
        self.metrics.histogram("wal_append_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return self._f.tell()

    def sync(self) -> None:
        """fsync pending appends (batch boundary).  Named fault point
        ``wal.fsync`` fires just before the flush — a raise here, reached
        through :meth:`append`, rolls the triggering record back (see the
        failure-atomicity note there)."""
        t0 = time.perf_counter()
        if self.fault_hook is not None:
            self.fault_hook("wal.fsync")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._since_sync = 0
        self.metrics.histogram("wal_fsync_ms").observe(
            (time.perf_counter() - t0) * 1e3)

    def close(self) -> None:
        """Sync and release the file handle."""
        try:
            self.sync()
        finally:
            self._f.close()

    # -- record encodings ----------------------------------------------
    def log_ingest(self, gid0: int, x: np.ndarray, s: np.ndarray) -> int:
        """One ingest batch: first assigned gid + raw row bytes."""
        x = np.ascontiguousarray(x, np.float32)
        s = np.ascontiguousarray(s, np.float64)
        head = struct.pack("<QIII", int(gid0), x.shape[0], x.shape[1],
                           s.shape[1])
        return self.append(REC_INGEST, head + x.tobytes() + s.tobytes())

    def log_delete(self, gids: np.ndarray) -> int:
        """One delete batch by global id."""
        g = np.ascontiguousarray(gids, np.int64)
        return self.append(REC_DELETE, struct.pack("<I", len(g)) + g.tobytes())

    def log_gc(self, chunk_ids: Sequence[int]) -> int:
        """One point-store GC pass: the freed chunk indices."""
        c = np.ascontiguousarray(chunk_ids, np.int64)
        return self.append(REC_GC, struct.pack("<I", len(c)) + c.tobytes())

    @staticmethod
    def scan(path: str, offset: int = 0
             ) -> Tuple[List[Tuple[int, object]], int]:
        """Decode every intact record after ``offset`` (0 means the whole
        log), stopping at the first torn or CRC-failing frame — the durable
        prefix property.  Returns ``(records, durable_end)`` where
        ``durable_end`` is the byte offset just past the last intact frame:
        a resuming replica truncates the file there so fresh appends extend
        the durable prefix instead of hiding behind a torn frame."""
        records: List[Tuple[int, object]] = []
        end = max(offset, len(WAL_MAGIC))
        if not os.path.exists(path):
            return records, end
        with open(path, "rb") as f:
            if f.read(len(WAL_MAGIC)) != WAL_MAGIC:
                return records, len(WAL_MAGIC)
            if offset > len(WAL_MAGIC):
                f.seek(offset)
            while True:
                head = f.read(_FRAME.size)
                if len(head) < _FRAME.size:
                    return records, end
                length, crc = _FRAME.unpack(head)
                body = f.read(length)
                if len(body) < length or zlib.crc32(body) != crc:
                    return records, end
                rec_type = body[0]
                payload = body[1:]
                if rec_type == REC_INGEST:
                    gid0, n, d, m = struct.unpack_from("<QIII", payload)
                    off = struct.calcsize("<QIII")
                    x = np.frombuffer(payload, np.float32, n * d,
                                      off).reshape(n, d)
                    s = np.frombuffer(payload, np.float64, n * m,
                                      off + x.nbytes).reshape(n, m)
                    records.append((rec_type, (gid0, x, s)))
                elif rec_type in (REC_DELETE, REC_GC):
                    (n,) = struct.unpack_from("<I", payload)
                    records.append(
                        (rec_type, np.frombuffer(payload, np.int64, n, 4)))
                else:                     # unknown type: future format
                    return records, end
                end = f.tell()

    @staticmethod
    def replay(path: str, offset: int = 0):
        """Yield the intact records after ``offset`` (see :meth:`scan`)."""
        yield from WriteAheadLog.scan(path, offset)[0]


# ---------------------------------------------------------------------------
# Segment artifacts
# ---------------------------------------------------------------------------
def write_segment_artifact(seg: SealedSegment, directory: str,
                           fault_hook: Optional[Callable] = None) -> None:
    """Write one sealed segment as an immutable artifact directory.

    Staged under ``<directory>.tmp`` and published with one ``os.replace``,
    so a partially written artifact is never mistaken for a complete one —
    restore only trusts directories the manifest names, and the manifest is
    only swapped after every artifact it references has been renamed.
    """
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    extra_arrays = {"gids": seg.gids}
    extra_meta = {"seg_id": seg.seg_id, "time_dim": seg.time_dim,
                  "t_min": seg.t_min, "t_max": seg.t_max}
    if seg.quant is not None:
        # the codec payload is part of the immutable artifact: restore
        # attaches it as-is and never re-fits scales or re-encodes
        extra_arrays.update(qcodes=seg.quant.codes, qscales=seg.quant.scales,
                            qxsq=seg.quant.xsq)
        extra_meta["quant_kind"] = seg.quant.kind
    save_index(seg.index, tmp, extra_arrays=extra_arrays,
               extra_meta=extra_meta)
    if fault_hook is not None:
        fault_hook("segment.write")
    _fsync_tree(tmp)
    if os.path.exists(directory):        # pragma: no cover - re-publish
        shutil.rmtree(directory)
    os.replace(tmp, directory)
    _fsync_dir(os.path.dirname(directory) or ".")


def load_segment_artifact(directory: str,
                          mmap_mode: Optional[str] = "r") -> SealedSegment:
    """Artifact directory -> :class:`SealedSegment` (point arrays mmapped
    by default; validity is re-derived by the caller from the manager's
    restored liveness bitmap).  A quantized artifact's codec payload
    (codes / scales / norms) is attached verbatim — restore never
    re-quantizes, so a restored replica's int8 scan is bit-for-bit the
    writer's."""
    idx = load_index(directory, mmap_mode=mmap_mode)
    arrays, extra = load_index_extras(directory, ["gids"])
    quant = None
    if extra.get("quant_kind"):
        from ..quant import SegmentQuant
        qarr, _ = load_index_extras(directory,
                                    ["qcodes", "qscales", "qxsq"])
        quant = SegmentQuant(str(extra["quant_kind"]),
                             np.array(qarr["qcodes"]),
                             np.array(qarr["qscales"]),
                             np.array(qarr["qxsq"]))
    return SealedSegment(int(extra["seg_id"]), idx,
                         np.array(arrays["gids"]), int(extra["time_dim"]),
                         quant=quant)


# ---------------------------------------------------------------------------
# Manifest + checkpoint
# ---------------------------------------------------------------------------
def load_manifest(root: str) -> dict:
    """Parse ``<root>/MANIFEST.json`` (raises ``FileNotFoundError`` when the
    directory holds no published snapshot)."""
    with open(os.path.join(root, MANIFEST_NAME)) as f:
        return json.load(f)


class StreamPersistence:
    """One manager's durable home directory: WAL + artifacts + manifest.

    Attach with ``StreamConfig(persist_dir=...)`` (the manager then logs
    every ingest/delete/GC and checkpoints at each segment-list transition)
    or construct standalone for a one-shot export via
    :meth:`SegmentManager.snapshot_to`.  All mutation entry points are
    called with the manager lock held, so a checkpoint always captures a
    quiescent, self-consistent state.
    """

    _ART_RE = re.compile(r"^seg-\d+-[vn](\d+)(?:\.tmp)?$")

    def __init__(self, root: str, fsync_every: int = 32,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 metrics=None):
        self.root = root
        self.fsync_every = max(int(fsync_every), 1)
        self.fault_hook = fault_hook
        self.metrics = NULL_REGISTRY if metrics is None else metrics
        os.makedirs(root, exist_ok=True)
        self.version = 0
        self.wal: Optional[WriteAheadLog] = None
        # artifact-name allocation + in-flight staging registry (cleanup
        # must never rmtree a directory another thread is writing into)
        self._seq_lock = threading.Lock()
        self._staging: set = set()
        self._seq = max((int(m.group(1)) for m in
                         (self._ART_RE.match(n) for n in os.listdir(root))
                         if m), default=0)
        if os.path.exists(os.path.join(root, MANIFEST_NAME)):
            man = load_manifest(root)
            self.version = int(man["version"])
            self.wal = WriteAheadLog(os.path.join(root, man["wal_file"]),
                                     self.fsync_every, fault_hook,
                                     metrics=self.metrics)
        else:
            self.wal = WriteAheadLog(os.path.join(root, "wal-000000.log"),
                                     self.fsync_every, fault_hook,
                                     metrics=self.metrics)

    # -- hot path ------------------------------------------------------
    def log_ingest(self, gid0: int, x, s) -> None:
        """WAL-append one acknowledged ingest batch."""
        self.wal.log_ingest(gid0, x, s)

    def log_delete(self, gids) -> None:
        """WAL-append one acknowledged delete batch."""
        self.wal.log_delete(gids)

    def log_gc(self, chunk_ids) -> None:
        """WAL-append one point-store GC pass (freed chunk ids)."""
        if len(chunk_ids):
            self.wal.log_gc(chunk_ids)

    # -- artifacts -----------------------------------------------------
    def _next_artifact_name(self, seg_id: int) -> str:
        """Allocate a root-unique artifact directory name (thread-safe)."""
        with self._seq_lock:
            self._seq += 1
            return f"seg-{seg_id:05d}-n{self._seq:06d}"

    def stage_segment(self, seg: SealedSegment) -> str:
        """Write ``seg``'s artifact into this root (idempotent), safe to
        call WITHOUT the manager lock.  Compaction stages its replacement
        segments here during the lock-free execute phase, so the
        under-lock publish checkpoint finds the artifacts already on disk
        and only swaps state + manifest.  Validity is not a problem:
        restore derives per-segment validity from the liveness bitmap, so
        deletions racing the stage never make the artifact stale."""
        key = os.path.abspath(self.root)
        art = seg.artifacts.get(key)
        if art is not None and os.path.isdir(os.path.join(self.root, art)):
            return art
        art = self._next_artifact_name(seg.seg_id)
        with self._seq_lock:             # shield from a concurrent _cleanup
            self._staging.update((art, art + ".tmp"))
        try:
            write_segment_artifact(seg, os.path.join(self.root, art),
                                   self.fault_hook)
        finally:
            with self._seq_lock:
                self._staging.difference_update((art, art + ".tmp"))
        seg.artifacts[key] = art
        return art

    # -- checkpoint ----------------------------------------------------
    def checkpoint(self, manager) -> dict:
        """Capture ``manager`` (lock held by the caller) into a new manifest
        version: missing segment artifacts are written, the mutable residue
        goes into ``state-<v>.npz``, the WAL rotates, and ``MANIFEST.json``
        swaps last — the single commit point.  Returns the manifest dict."""
        from ..distributed.segment_shards import bucket_cap_for
        t_ckpt = time.perf_counter()
        v = self.version + 1
        seg_entries = []
        for seg in manager.segments:
            art = self.stage_segment(seg)     # no-op when already staged
            entry = {"seg_id": seg.seg_id, "dir": art,
                     "t_min": seg.t_min, "t_max": seg.t_max,
                     "n": seg.n, "n_live": seg.n_live,
                     # which codec (if any) the artifact's codes carry, so
                     # operators can audit a snapshot's quantization state
                     # without opening artifacts
                     "quant": None if seg.quant is None else seg.quant.kind}
            if manager.cfg.n_shards >= 1:
                # pack state is derived (restore cold-builds the buckets
                # lazily on the first sharded query), but the manifest
                # records each segment's capacity bucket so operators can
                # size a replica's device memory before restoring — the
                # cfg blob already carries n_shards / pack_cap_multiple /
                # incremental_pack, which is all the cold build needs
                entry["bucket_cap"] = bucket_cap_for(
                    seg.n_live, manager.cfg.n_shards,
                    manager.cfg.pack_cap_multiple)
            seg_entries.append(entry)

        state_name = f"state-{v:06d}.npz"
        state_bytes = _encode_state(manager)
        _atomic_write(os.path.join(self.root, state_name), state_bytes)

        wal_name = f"wal-{v:06d}.log"
        old_wal = self.wal
        old_wal.sync()
        new_wal = WriteAheadLog(os.path.join(self.root, wal_name),
                                self.fsync_every, self.fault_hook,
                                metrics=self.metrics)

        alive = np.ascontiguousarray(manager.alive)
        manifest = {
            "format": MANIFEST_FORMAT,
            "version": v,
            "epoch": manager.epoch,
            "next_seg_id": manager._next_seg_id,
            "n_total": manager.n_total,
            # strict JSON: non-finite floats have no standard encoding, so
            # the pre-first-ingest watermark (-inf) is stored as null
            "now": manager.now if math.isfinite(manager.now) else None,
            "d": manager.d,
            "m": manager.m,
            "cfg": _encode_cfg(manager.cfg),
            "counters": dict(manager.counters),
            "segments": seg_entries,
            "state_file": state_name,
            "state_crc": zlib.crc32(state_bytes),
            "alive_crc": zlib.crc32(np.packbits(alive).tobytes()),
            "wal_file": wal_name,
            "wal_offset": len(WAL_MAGIC),
        }
        data = json.dumps(manifest, indent=1, allow_nan=False).encode()
        tmp = os.path.join(self.root, MANIFEST_NAME + ".tmp")
        try:
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            if self.fault_hook is not None:
                self.fault_hook("manifest.rename")
            os.replace(tmp, os.path.join(self.root, MANIFEST_NAME))
        except BaseException:
            # failed commit: the old manifest + old WAL stay authoritative;
            # release the never-published WAL instead of leaking its fd on
            # every retried checkpoint
            try:
                new_wal.close()
                os.remove(new_wal.path)
            except OSError:              # pragma: no cover - disk gone
                pass
            raise
        _fsync_dir(self.root)

        self.version = v
        self.wal = new_wal
        old_wal.close()
        self._cleanup(manifest)
        self.metrics.counter("checkpoints_total").inc()
        self.metrics.histogram("checkpoint_ms").observe(
            (time.perf_counter() - t_ckpt) * 1e3)
        return manifest

    def _cleanup(self, manifest: dict) -> None:
        """Drop files the freshly published manifest no longer references
        (old WALs/state files, orphaned or staged artifacts).  Runs after
        the rename; a crash mid-cleanup only leaves harmless garbage.
        Names registered by an in-flight :meth:`stage_segment` are skipped
        rather than blocked on (the compactor's disk write must never
        stall a lock-holding checkpoint); a staged-but-unpublished
        artifact may still be removed once its staging finishes — the
        publish checkpoint then detects the missing directory and
        rewrites it."""
        keep = {manifest["wal_file"], manifest["state_file"], MANIFEST_NAME,
                *(e["dir"] for e in manifest["segments"])}
        for name in os.listdir(self.root):
            if name in keep:
                continue
            # re-check the staging registry immediately before each removal
            # (not once up front): a stage_segment may have registered this
            # name after a single earlier snapshot was taken
            with self._seq_lock:
                if name in self._staging:
                    continue
            path = os.path.join(self.root, name)
            try:
                if name.startswith(("wal-", "state-")) \
                        and os.path.isfile(path):
                    os.remove(path)
                elif name.startswith("seg-") and os.path.isdir(path):
                    shutil.rmtree(path)
            except OSError:              # pragma: no cover - races are fine
                pass

    def close(self) -> None:
        """Sync and close the active WAL."""
        if self.wal is not None:
            self.wal.close()


# ---------------------------------------------------------------------------
# State capture / restore helpers
# ---------------------------------------------------------------------------
_UNBOUNDED_CFG_FIELDS = ("ttl", "seal_max_age")    # inf <-> null in JSON


def _encode_cfg(cfg) -> dict:
    """StreamConfig -> strict-JSON-safe dict (``inf`` policy knobs become
    ``null``; nested index cfg expanded)."""
    out = dataclasses.asdict(cfg)
    out["index_cfg"] = dataclasses.asdict(cfg.index_cfg)
    for key in _UNBOUNDED_CFG_FIELDS:
        if not math.isfinite(out[key]):
            out[key] = None
    return out


def _decode_cfg(blob: dict, persist_dir: Optional[str]):
    """Inverse of :func:`_encode_cfg`; rebinds ``persist_dir``."""
    from .manager import StreamConfig
    kw = dict(blob)
    for key in _UNBOUNDED_CFG_FIELDS:
        if kw.get(key) is None:
            kw[key] = math.inf
    kw["index_cfg"] = CubeGraphConfig(**kw["index_cfg"])
    kw["persist_dir"] = persist_dir
    return StreamConfig(**kw)


def _encode_state(manager) -> bytes:
    """The mutable residue outside segment artifacts, as one npz blob:
    liveness bitmap (bit-packed), delta-buffer rows (including lazily
    deleted ones, preserving order), and resident point-store chunks."""
    import io
    delta = manager.delta
    store = manager.store
    chunk_ids = np.sort(np.fromiter(store._chunks, np.int64,
                                    len(store._chunks)))
    buf = io.BytesIO()
    np.savez(
        buf,
        alive=np.packbits(np.ascontiguousarray(manager.alive)),
        delta_x=delta.x[: delta.size], delta_s=delta.s[: delta.size],
        delta_gids=delta.gids[: delta.size],
        delta_valid=delta.valid[: delta.size],
        store_chunk_ids=chunk_ids,
        store_x=np.stack([store._chunks[int(c)][0] for c in chunk_ids])
        if len(chunk_ids) else np.zeros((0, store.chunk, store.d), np.float32),
        store_s=np.stack([store._chunks[int(c)][1] for c in chunk_ids])
        if len(chunk_ids) else np.zeros((0, store.chunk, store.m), np.float64),
    )
    return buf.getvalue()


def restore_manager(root: str, cfg=None, shard_mesh=None, resume: bool = True,
                    mmap_segments: Optional[bool] = None):
    """Rebuild a :class:`SegmentManager` from a snapshot directory.

    Loads the last published manifest (checksum-verified), mmaps segment
    artifacts, reconstructs the liveness bitmap / delta buffer / point
    store, replays the WAL tail, and re-derives per-segment validity from
    the final bitmap.  With ``resume`` (default) the manager re-attaches to
    ``root`` and keeps persisting; pass ``resume=False`` for a read-only
    clone (e.g. a serving replica warm-starting from a shared export).

    The restored manager honors ``StreamConfig.device_budget_bytes``
    (persisted, or overridden via ``cfg``): its first sharded query
    cold-builds the bucket blocks *host-side* from the mmapped artifacts
    and admits only the most-recent buckets that fit the budget
    (``SegmentManager._tier_warm_admit``), instead of staging the whole
    corpus on device before answering — the tiered-storage fix for
    exp11's restored-first-query cost on cold-heavy corpora.
    """
    import io

    from .manager import SegmentManager
    from .segments import grow_rows

    man = load_manifest(root)
    if man.get("format") != MANIFEST_FORMAT:
        raise RestoreError(f"unknown manifest format {man.get('format')!r}")
    state_path = os.path.join(root, man["state_file"])
    with open(state_path, "rb") as f:
        state_bytes = f.read()
    if zlib.crc32(state_bytes) != man["state_crc"]:
        raise RestoreError(f"checksum mismatch for {man['state_file']}")

    if cfg is None:
        cfg = _decode_cfg(man["cfg"],
                          os.path.abspath(root) if resume else None)
    else:
        # a cfg override may change policy (seal thresholds, n_shards,
        # ttl, index build params) but never the on-disk geometry the
        # snapshot was written with — silently re-keying the point store
        # or re-interpreting the time column would corrupt the state
        saved = man["cfg"]
        if cfg.store_chunk != saved["store_chunk"]:
            raise RestoreError(
                f"cfg.store_chunk={cfg.store_chunk} does not match the "
                f"snapshot's store_chunk={saved['store_chunk']}")
        if cfg.time_dim % man["m"] != saved["time_dim"] % man["m"]:
            raise RestoreError(
                f"cfg.time_dim={cfg.time_dim} does not match the "
                f"snapshot's time_dim={saved['time_dim']} (m={man['m']})")
    mgr = SegmentManager(man["d"], man["m"], cfg, shard_mesh=shard_mesh,
                         _restoring=True)

    with np.load(io.BytesIO(state_bytes)) as z:
        n_total = int(man["n_total"])
        alive = np.unpackbits(z["alive"], count=n_total).astype(bool) \
            if n_total else np.zeros(0, bool)
        cap = len(mgr._alive)
        while cap < n_total:
            cap *= 2
        mgr._alive = np.zeros(cap, bool)
        mgr._alive[:n_total] = alive
        # -- point store ----------------------------------------------
        mgr.store.n_total = n_total
        for i, ci in enumerate(z["store_chunk_ids"]):
            mgr.store._chunks[int(ci)] = (np.array(z["store_x"][i]),
                                          np.array(z["store_s"][i]))
        # -- delta buffer (row order preserved, invalid rows included) --
        dx, ds = np.array(z["delta_x"]), np.array(z["delta_s"])
        dg, dv = np.array(z["delta_gids"]), np.array(z["delta_valid"])
    size = len(dg)
    mgr.delta.x, mgr.delta.s, mgr.delta.gids, mgr.delta.valid = grow_rows(
        max(size, 16), (mgr.delta.x, 0.0), (mgr.delta.s, 0.0),
        (mgr.delta.gids, -1), (mgr.delta.valid, False))
    mgr.delta.x[:size] = dx
    mgr.delta.s[:size] = ds
    mgr.delta.gids[:size] = dg
    mgr.delta.valid[:size] = dv
    mgr.delta.size = size
    if size:
        t = ds[:, mgr.time_dim]
        mgr.delta.t_min, mgr.delta.t_max = float(t.min()), float(t.max())

    mmap = cfg.mmap_segments if mmap_segments is None else mmap_segments
    for entry in man["segments"]:
        seg = load_segment_artifact(os.path.join(root, entry["dir"]),
                                    mmap_mode="r" if mmap else None)
        seg.artifacts[os.path.abspath(root)] = entry["dir"]
        mgr.segments.append(seg)

    mgr.now = float(man["now"]) if man["now"] is not None else -math.inf
    mgr.epoch = int(man["epoch"])
    mgr._next_seg_id = int(man["next_seg_id"])
    mgr.counters.update(man["counters"])

    # -- WAL tail: every acknowledged op after the checkpoint ----------
    wal_path = os.path.join(root, man["wal_file"])
    records, wal_end = WriteAheadLog.scan(wal_path, man["wal_offset"])
    reg = mgr.obs.registry
    reg.counter("recovery_restores_total").inc()
    reg.counter("recovery_replayed_records_total").inc(len(records))
    _REC_NAMES = {REC_INGEST: "ingest", REC_DELETE: "delete", REC_GC: "gc"}
    for rec_type, rec in records:
        reg.counter('recovery_replayed_records_total'
                    f'{{type="{_REC_NAMES[rec_type]}"}}').inc()
        if rec_type == REC_INGEST:
            gid0, x, s = rec
            if gid0 != mgr.store.n_total:
                raise RestoreError(
                    f"WAL ingest at gid {gid0} does not extend the store "
                    f"(n_total={mgr.store.n_total})")
            mgr._apply_ingest(np.array(x), np.array(s))
        elif rec_type == REC_DELETE:
            mgr._apply_delete(np.array(rec))
        elif rec_type == REC_GC:
            freed = mgr.store.free_chunks(np.array(rec))
            mgr.counters["store_gc_points"] += freed

    # -- per-segment validity is derived state: alive[gids] -----------
    for seg in mgr.segments:
        seg.index.valid[:] = mgr.alive[seg.gids]

    crc = zlib.crc32(np.packbits(np.ascontiguousarray(mgr.alive)).tobytes())
    if not records and crc != man["alive_crc"]:
        raise RestoreError("liveness bitmap checksum mismatch")

    if resume:
        # drop any torn tail so fresh appends extend the durable prefix
        # (a record hiding behind a torn frame would never replay)
        try:
            if os.path.getsize(wal_path) > wal_end:
                with open(wal_path, "r+b") as f:
                    f.truncate(wal_end)
        except OSError:                  # pragma: no cover - platform quirk
            pass
        mgr.persist = StreamPersistence(root, cfg.wal_fsync_every,
                                        metrics=mgr.obs.registry)
    return mgr
