"""Streaming temporal index: LSM-style segment lifecycle for CubeGraph.

- ``segments``  delta buffer (exact kernel scan) + sealed ``CubeGraphIndex``
                time-range partitions, both speaking global point ids, plus
                the chunked GC-able ``PointStore`` ledger
- ``manager``   seal policy, off-path compaction (plan/execute/publish with
                an epoch guard), TTL expiry, point-store GC
- ``query``     temporal segment pruning + fan-out (per-segment graph search
                or mesh-sharded kernel scan; with ``quantize="int8"`` a
                two-stage int8 scan + exact fp32 rerank) + exact
                ``(gid, dist)`` merge
- ``persistence``  durability: CRC-framed write-ahead log, immutable
                per-segment artifacts, atomic versioned manifest, and the
                crash-consistent restore path (``SegmentManager.restore``)
- ``resilience``  fault injection (deterministic ``FaultInjector``),
                supervised background workers (``Supervisor`` with retry /
                backoff / error budget), and query deadlines (``Deadline``,
                ``QueryResult`` with explicit ``degraded`` marking)
"""
from .manager import CompactionPlan, SegmentManager, StreamConfig
from .persistence import (RestoreError, StreamPersistence, WriteAheadLog,
                          load_manifest, restore_manager)
from .query import (GroupQuery, merge_topk, query_segments,
                    query_segments_grouped, temporal_bounds)
from .resilience import (FAULT_POINTS, Deadline, FaultError, FaultInjector,
                         QueryResult, Supervisor)
from .segments import (DeltaBuffer, DeltaSnapshot, PointStore, SealedSegment,
                       SegmentQueryStats)

__all__ = [
    "CompactionPlan", "SegmentManager", "StreamConfig",
    "DeltaBuffer", "DeltaSnapshot", "PointStore", "SealedSegment",
    "SegmentQueryStats",
    "GroupQuery", "merge_topk", "query_segments",
    "query_segments_grouped", "temporal_bounds",
    "RestoreError", "StreamPersistence", "WriteAheadLog",
    "load_manifest", "restore_manager",
    "FAULT_POINTS", "Deadline", "FaultError", "FaultInjector",
    "QueryResult", "Supervisor",
]
