"""Streaming temporal index: LSM-style segment lifecycle for CubeGraph.

- ``segments``  delta buffer (exact kernel scan) + sealed ``CubeGraphIndex``
                time-range partitions, both speaking global point ids
- ``manager``   seal policy, compaction (merge + lazy-delete GC), TTL expiry
- ``query``     temporal segment pruning + fan-out + exact top-k merge
"""
from .manager import SegmentManager, StreamConfig
from .query import query_segments, temporal_bounds
from .segments import DeltaBuffer, SealedSegment, SegmentQueryStats

__all__ = [
    "SegmentManager", "StreamConfig",
    "DeltaBuffer", "SealedSegment", "SegmentQueryStats",
    "query_segments", "temporal_bounds",
]
