"""Cost-based read-path planner: scan vs. stitched graph traversal.

The sealed-segment read path has three per-bucket modes (ROADMAP item 1):

* **scan** — the fused (possibly int8) filtered top-k kernel over the whole
  device-resident bucket block: cost linear in ``active_rows * cap`` padded
  rows, fully regular, exact (quantized buckets rerank).
* **graph** — the stitched beam traversal (``kernels/graph_topk``) over the
  bucket's adjacency block: cost roughly ``hops * width * degree`` gathers,
  i.e. near-logarithmic in bucket points, but approximate and wasteful
  when the filter is so selective that routing mostly burns hops on
  φ-failing points.
* **host_scan** — the tiered-storage cold path
  (``streaming/tiering.py``): the bucket's block is host-resident (evicted
  under ``StreamConfig.device_budget_bytes``) and streams through the same
  fused kernel per dispatch — exact, but every dispatch pays the staging
  transfer.  The planner prices it against "admit the block first, then
  scan/traverse it resident" (``admit_cost_per_byte``), so a repeatedly-hit
  cold bucket is re-admitted instead of re-streamed.

This module picks the mode *per bucket per dispatch* from the rolling
:class:`~repro.obs.metrics.BucketStats` snapshot (the observation feed PR 6
added exactly for this) plus the bucket's geometry.  All constants live in
one :class:`PlannerCosts` dataclass so ROADMAP item 5's measured rooflines
can replace the guesses without touching the decision logic.

Contract with ``obs/metrics.py``: a per-bucket stats snapshot exposes at
least :data:`REQUIRED_STATS_KEYS` — pinned by ``tests/test_planner.py`` so
a metrics-side rename fails loudly instead of silently degrading planning.

The planner only *prices* the modes; it never changes answers on its own:
whenever it picks scan, the dispatch is byte-for-byte the forced-scan one
(the parity property in ``tests/test_planner.py``), and graph picks are
gated on the bucket actually carrying a graph block with live seeds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

__all__ = ["PlannerCosts", "PlanDecision", "READ_PATHS",
           "REQUIRED_STATS_KEYS", "decide_bucket", "plan_read_paths"]

READ_PATHS = ("auto", "scan", "graph")

# Snapshot keys the planner consumes — the BucketStats schema contract.
REQUIRED_STATS_KEYS = ("rows", "rows_scanned", "blocks_pruned",
                       "candidates", "candidate_slots", "dispatches",
                       "queries", "cache_hits", "cache_misses",
                       "pruning_rate", "selectivity")


@dataclasses.dataclass(frozen=True)
class PlannerCosts:
    """Planner constants, in one place (placeholder rooflines).

    Units are abstract "row-visit equivalents"; only ratios matter.  The
    defaults make graph win once a bucket's padded scan rows exceed a few
    thousand — conservative for interpret-mode CPU, and meant to be
    replaced by measured rooflines (ROADMAP item 5).
    """

    scan_cost_per_row: float = 1.0      # per padded scanned row
    hop_cost: float = 120.0             # per traversal hop (gather+kernel)
    base_hops: float = 12.0             # fixed hops (seed scoring etc.)
    hops_per_log2: float = 10.0         # extra hops per log2(bucket points)
    seed_cost: float = 0.5              # per stitched seed position
    min_selectivity: float = 0.02       # below this, φ starves routing:
                                        # force scan (traversal would burn
                                        # hops on φ-failing candidates)
    min_graph_rows: int = 512           # don't bother traversing tiny
                                        # buckets — scan is one cheap
                                        # dispatch there
    host_scan_multiplier: float = 4.0   # cold (host-streamed) scan penalty
                                        # per padded row vs. the resident
                                        # scan: the block crosses the host
                                        # link on every dispatch
    admit_cost_per_byte: float = 0.05   # one-shot staging cost of admitting
                                        # a cold bucket block, in
                                        # row-equivalents per byte uploaded
    cost_per_ms: float = 250_000.0      # row-equivalents the rig retires
                                        # per millisecond — converts a
                                        # query deadline's remaining ms
                                        # into a cost ceiling for the
                                        # deadline gate (placeholder like
                                        # everything above; ROADMAP item 5
                                        # calibrates it from rooflines)


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One bucket's planned mode plus the estimates behind it."""

    cap: int
    mode: str                           # "scan" | "graph" | "host_scan"
                                        # | "skip" (deadline refusal — the
                                        # bucket is not dispatched and the
                                        # query reports degraded=True)
    est_scan: float                     # resident-scan estimate (host_scan
                                        # decisions price est_scan *
                                        # host_scan_multiplier on top)
    est_graph: float
    reason: str


def estimate_scan_cost(cap: int, active_rows: int,
                       costs: PlannerCosts) -> float:
    """Padded-row scan cost: linear in the temporally unpruned rows."""
    return float(active_rows) * float(cap) * costs.scan_cost_per_row


def estimate_graph_cost(cap: int, active_rows: int, n_seeds: int,
                        costs: PlannerCosts,
                        n_points: Optional[float] = None) -> float:
    """Expected traversal cost: seeds plus hops ~ log2(live bucket points).

    ``n_points`` is the *live* point estimate (from the pack's per-row fill
    counts); without one the padded ``active_rows * cap`` upper bound is
    used, which inflates the hop estimate for partially-filled buckets and
    shifts the scan/graph crossover — callers with fill information should
    always pass it."""
    if n_points is None:
        n_points = float(active_rows) * float(cap)
    n_points = max(float(n_points), 2.0)
    hops = costs.base_hops + costs.hops_per_log2 * math.log2(n_points)
    return hops * costs.hop_cost + float(n_seeds) * costs.seed_cost


def _graph_guard(cap: int, active_rows: int, stats: Optional[Dict],
                 costs: PlannerCosts) -> Optional[str]:
    """Reason the auto policy must not traverse this bucket, else None."""
    if active_rows * cap < costs.min_graph_rows:
        return "small_bucket"
    if stats is not None:
        sel = stats["selectivity"]
        if sel is not None and sel < costs.min_selectivity:
            return "selective_filter"
    return None


def decide_bucket(cap: int, active_rows: int, n_seeds: int,
                  graph_ready: bool, stats: Optional[Dict],
                  costs: PlannerCosts, read_path: str = "auto",
                  resident: bool = True, stage_bytes: int = 0,
                  n_points: Optional[float] = None,
                  deadline_cost: Optional[float] = None) -> PlanDecision:
    """Pick scan vs. graph vs. host_scan for one bucket dispatch.

    ``stats`` is this bucket's entry from a ``BucketStats`` snapshot (or
    ``None`` before any observation); only :data:`REQUIRED_STATS_KEYS` are
    consulted.  ``graph_ready`` and ``n_seeds`` gate the graph mode: a
    bucket without a staged adjacency block or without live entry points
    never traverses regardless of cost (answers must never depend on a
    missing structure).  ``resident=False`` marks a bucket whose block the
    tier evicted to host memory: it either streams through the kernel cold
    (``host_scan`` — exact, pays ``host_scan_multiplier`` per dispatch) or,
    when the one-shot staging cost prices lower, is admitted first and
    dispatched resident (mode ``scan``/``graph`` with reason
    ``admit_cheaper`` — the query path performs the admission).
    ``n_points`` is the live-fill estimate forwarded to
    :func:`estimate_graph_cost`.

    ``deadline_cost`` (remaining query-deadline ms converted to cost
    units via ``PlannerCosts.cost_per_ms``) gates the *cold* modes: the
    planner refuses ``host_scan`` / ``admit_cheaper`` whose priced cost
    the remaining deadline cannot cover, picking whichever cold route
    still fits, or mode ``"skip"`` (reason ``"deadline"``) when neither
    does — the query then omits the bucket and reports an explicitly
    degraded result instead of blowing the budget on a host stream.
    Resident buckets are never skipped here; the query path's
    between-dispatch deadline checks bound those.
    """
    est_scan = estimate_scan_cost(cap, active_rows, costs)
    est_graph = estimate_graph_cost(cap, active_rows, n_seeds, costs,
                                    n_points=n_points)
    can_graph = graph_ready and n_seeds > 0

    def _fits(cost: float) -> bool:
        return deadline_cost is None or cost <= deadline_cost

    if not resident:
        est_host = est_scan * costs.host_scan_multiplier
        stage = float(stage_bytes) * costs.admit_cost_per_byte
        if read_path == "graph" and can_graph:
            return PlanDecision(cap, "graph", est_scan, est_graph, "forced")
        if read_path == "scan":
            if not _fits(est_host):
                return PlanDecision(cap, "skip", est_scan, est_graph,
                                    "deadline")
            return PlanDecision(cap, "host_scan", est_scan, est_graph,
                                "forced")
        best, mode = est_scan, "scan"
        if can_graph and _graph_guard(cap, active_rows, stats, costs) \
                is None and est_graph < est_scan:
            best, mode = est_graph, "graph"
        if stage + best < est_host and _fits(stage + best):
            return PlanDecision(cap, mode, est_scan, est_graph,
                                "admit_cheaper")
        if _fits(est_host):
            return PlanDecision(cap, "host_scan", est_scan, est_graph,
                                "cold_scan_cheaper")
        if _fits(stage + best):
            # the stream is too slow for what's left of the deadline but
            # a one-shot admission still fits — admit and run resident
            return PlanDecision(cap, mode, est_scan, est_graph,
                                "admit_cheaper")
        return PlanDecision(cap, "skip", est_scan, est_graph, "deadline")
    if not can_graph:
        return PlanDecision(cap, "scan", est_scan, est_graph, "graph_unready")
    if read_path == "scan":
        return PlanDecision(cap, "scan", est_scan, est_graph, "forced")
    if read_path == "graph":
        return PlanDecision(cap, "graph", est_scan, est_graph, "forced")
    guard = _graph_guard(cap, active_rows, stats, costs)
    if guard is not None:
        return PlanDecision(cap, "scan", est_scan, est_graph, guard)
    if est_graph < est_scan:
        return PlanDecision(cap, "graph", est_scan, est_graph,
                            "graph_cheaper")
    return PlanDecision(cap, "scan", est_scan, est_graph, "scan_cheaper")


def plan_read_paths(view, read_path: str, stats_snapshot: Dict,
                    costs: PlannerCosts, t_lo: float, t_hi: float,
                    graph_allowed: bool = True,
                    deadline_cost: Optional[float] = None
                    ) -> Dict[int, PlanDecision]:
    """Plan every bucket of a :class:`~..distributed.segment_shards.PackView`.

    ``stats_snapshot`` is ``BucketStats.snapshot()`` (keys are ``str(cap)``);
    ``graph_allowed=False`` (e.g. the filter has no kernel encoding, so the
    traversal kernel cannot evaluate φ) forces scan everywhere.  Buckets
    whose rows are all temporally pruned are skipped — no dispatch happens
    for them in either mode.  ``deadline_cost`` threads the query's
    remaining deadline (in cost units) into every
    :func:`decide_bucket` call — see the deadline gate there.
    """
    from ..distributed.segment_shards import bucket_graph_seeds
    plan: Dict[int, PlanDecision] = {}
    for bv in view.buckets:
        active = bv.active_rows(t_lo, t_hi)
        n_active = int(np.count_nonzero(active))
        if n_active == 0:
            continue
        resident = getattr(bv, "resident", True)
        fill = getattr(bv, "fill", None)
        n_points = None if fill is None else float(fill[active].sum())
        if not graph_allowed:
            est = estimate_scan_cost(bv.cap, n_active, costs)
            if resident:
                mode = "scan"
            elif deadline_cost is not None \
                    and est * costs.host_scan_multiplier > deadline_cost:
                mode = "skip"             # deadline gate, forced-scan cold
            else:
                mode = "host_scan"
            plan[bv.cap] = PlanDecision(
                bv.cap, mode, est, float("inf"),
                "deadline" if mode == "skip" else "filter_not_encodable")
            continue
        seeds = bucket_graph_seeds(bv, t_lo, t_hi)
        plan[bv.cap] = decide_bucket(bv.cap, n_active, len(seeds),
                                     bv.graph_ready,
                                     stats_snapshot.get(str(bv.cap)),
                                     costs, read_path, resident=resident,
                                     stage_bytes=getattr(bv, "stage_bytes",
                                                         0),
                                     n_points=n_points,
                                     deadline_cost=deadline_cost)
    return plan
