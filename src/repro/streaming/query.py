"""Unified query path over the delta buffer and sealed segments.

Planning prunes segments whose ``[t_min, t_max]`` span misses the filter's
temporal bounds (extracted from its bounding box — half-open
``IntervalFilter`` windows work directly).  The query then fans out to the
delta buffer (exact fused-kernel scan) and each surviving sealed segment
(stitched-graph beam search), and the per-segment top-k candidate lists are
merged with an exact re-rank through ``topk_over_candidates`` against the
manager's global point store — so merged distances are consistent no matter
which segment a candidate came from.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import Filter
from ..core.graph import squared_norms, topk_over_candidates
from .segments import SegmentQueryStats

__all__ = ["temporal_bounds", "query_segments"]


def temporal_bounds(filt: Optional[Filter], time_dim: int
                    ) -> Tuple[float, float]:
    """Filter -> (t_lo, t_hi) constraint on the time dim; ±inf if none."""
    if filt is None:
        return -np.inf, np.inf
    lo, hi = filt.bounding_box()
    if time_dim >= len(lo):
        return -np.inf, np.inf
    return float(lo[time_dim]), float(hi[time_dim])


def _store_arrays(manager):
    """Cached jnp views of the global point store (re-cut when it grows)."""
    cache = getattr(manager, "_store_cache", None)
    if cache is not None and cache[0] == manager.n_total:
        return cache[1], cache[2]
    x = jnp.asarray(manager.store_x)
    norms = squared_norms(x)
    manager._store_cache = (manager.n_total, x, norms)
    return x, norms


def query_segments(manager, queries: np.ndarray, filt: Optional[Filter],
                   k: int = 10, ef: int = 64, return_stats: bool = False,
                   **search_kw):
    """Fan out one query batch across all live segments and merge top-k.

    Returns ``(gids [b, k], dists [b, k])`` — plus a list of per-segment
    ``SegmentQueryStats`` when ``return_stats`` is set (pruned segments
    appear with ``pruned=True`` and zero search time).
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    b = queries.shape[0]
    t_lo, t_hi = temporal_bounds(filt, manager.time_dim)
    metric = manager.cfg.index_cfg.metric

    blocks_i: List[np.ndarray] = []
    stats: List[SegmentQueryStats] = []

    if manager.delta.n_live > 0:
        st = manager.delta.stats()
        if manager.delta.t_max >= t_lo and manager.delta.t_min <= t_hi:
            t0 = time.perf_counter()
            ids, _ = manager.delta.query(queries, filt, k, metric=metric)
            st.search_ms = (time.perf_counter() - t0) * 1e3
            blocks_i.append(ids)
        else:
            st.pruned = True
        stats.append(st)

    for seg in manager.segments:
        st = seg.stats()
        if seg.n_live == 0 or not seg.overlaps(t_lo, t_hi):
            st.pruned = True
            stats.append(st)
            continue
        t0 = time.perf_counter()
        ids, _ = seg.query(queries, filt, k=k, ef=ef, **search_kw)
        st.search_ms = (time.perf_counter() - t0) * 1e3
        blocks_i.append(ids)
        stats.append(st)

    if not blocks_i:
        out_i = np.full((b, k), -1, np.int64)
        out_d = np.full((b, k), np.inf, np.float32)
        return (out_i, out_d, stats) if return_stats else (out_i, out_d)

    # Exact merge: global ids are disjoint across segments, so concatenate
    # the candidate lists and re-rank against the global store.
    cand = np.concatenate(blocks_i, axis=1)
    x_all, norms = _store_arrays(manager)
    ids, dd = topk_over_candidates(queries, cand.astype(np.int32), x_all,
                                   norms, min(k, cand.shape[1]),
                                   metric=metric)
    ids = np.asarray(ids)
    dd = np.asarray(dd, np.float32)
    out_i = np.full((b, k), -1, np.int64)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i[:, : ids.shape[1]] = ids
    out_d[:, : ids.shape[1]] = dd
    return (out_i, out_d, stats) if return_stats else (out_i, out_d)
