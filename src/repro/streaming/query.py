"""Unified query path over the delta buffer and sealed segments.

Planning prunes segments whose ``[t_min, t_max]`` span misses the filter's
temporal bounds (extracted from its bounding box — half-open
``IntervalFilter`` windows work directly).  The query then fans out to the
delta buffer (exact fused-kernel scan) and the sealed segments — either one
stitched-graph beam search per segment (default) or, with
``StreamConfig.n_shards >= 1``, one jitted dispatch of the fused kernel
per non-empty, temporally unpruned capacity *bucket* of the manager's
size-bucketed shard pack (temporal pruning skips whole device blocks),
distributed across a device mesh when one is attached.

Merging is a direct exact merge of the per-segment ``(gid, dist)`` pairs:
every path reports the same fp32 distance for the same point and global ids
are disjoint across the delta buffer and segments, so concatenating the
candidate lists and taking the global top-k needs no re-rank — the global
point store stays off the hot path entirely.  The merged result is finally
filtered through the manager's liveness bitmap, which is what makes query
results immune to racing deletions/compactions (see the epoch guarantee in
``repro.streaming.manager``).

With ``StreamConfig(quantize="int8")`` the sealed-pack scan becomes
two-stage: the per-bucket dispatches run the fused asymmetric-distance
kernel over int8 codes and over-fetch ``rerank_multiple * k`` candidates,
which are reranked exactly at fp32 (``repro.quant.rerank``) before
entering the same merge — so the merged block is exact again and the
delta buffer / liveness semantics are untouched.

With ``StreamConfig(read_path="auto"|"graph")`` each sealed-pack dispatch
first runs the cost planner (``repro.streaming.planner``) over the pack's
buckets: buckets planned ``scan`` go through the exact same fused-kernel
calls as above (byte-for-byte — the planner never changes scan answers),
while buckets planned ``graph`` run the stitched beam traversal
(``repro.kernels.graph_topk``) seeded with the entry points of every
temporally unpruned segment resident in the bucket.  fp32 graph blocks
carry exact distances and join the merge directly; quantized graph blocks
are candidate sets that go through the same exact fp32 rerank as the scan
path.  Traversal results are approximate (recall target, not parity), so
``auto`` only picks graph where the planner prices it cheaper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from ..core import Filter
from ..obs.metrics import NULL_REGISTRY
from ..obs.trace import NULL_TRACE, block_ready
from .resilience import Deadline, QueryResult
from .segments import SegmentQueryStats

__all__ = ["GroupQuery", "merge_topk", "temporal_bounds", "query_segments",
           "query_segments_grouped"]


def temporal_bounds(filt: Optional[Filter], time_dim: int
                    ) -> Tuple[float, float]:
    """Filter -> (t_lo, t_hi) constraint on the time dim; ±inf if none."""
    if filt is None:
        return -np.inf, np.inf
    lo, hi = filt.bounding_box()
    if time_dim >= len(lo):
        return -np.inf, np.inf
    return float(lo[time_dim]), float(hi[time_dim])


def merge_topk(blocks_g: List[np.ndarray], blocks_d: List[np.ndarray],
               k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Exact top-k merge of per-segment/per-bucket ``(gid, dist)`` blocks.

    Blocks are ``[b, k_i]`` with ``-1`` id padding; distances are
    comparable across blocks (same metric over the same vectors), and gids
    are disjoint across blocks, so the top-k of the concatenation is the
    exact global answer.  ``np.argpartition`` narrows each row to ``k``
    candidates before sorting only that slice — O(total + k log k) per row
    instead of a full O(total log total) argsort — and the sort tie-breaks
    equal distances on gid, keeping results deterministic regardless of
    block order.  Returns ``(gids [b, k], dists [b, k])``.
    """
    from ..distributed.segment_shards import host_topk
    return host_topk(np.concatenate(blocks_g, axis=1),
                     np.concatenate(blocks_d, axis=1), k)


def _alive_filter(manager, gids: np.ndarray, dists: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Drop merged candidates whose gid has since been deleted/expired,
    keeping each row's order and -1/inf padding."""
    ok = gids >= 0
    ok[ok] = manager.alive[gids[ok]]
    if ok.all():
        return gids, dists
    order = np.argsort(~ok, axis=1, kind="stable")
    gids = np.take_along_axis(np.where(ok, gids, -1), order, axis=1)
    dists = np.take_along_axis(np.where(ok, dists, np.inf), order, axis=1)
    return gids, dists


def _plan_pack(manager, pack, filt, rp, t_lo, t_hi, obs, registry,
               deadline=None):
    """Run the cost planner over one ``PackView`` dispatch.

    Returns ``(plan, graph_caps)`` where ``graph_caps`` is the set of bucket
    capacities routed to the stitched traversal this dispatch.  Also records
    the plan on ``manager.last_plan`` and bumps the
    ``planner_decision_total{mode=...}`` counters — one increment per bucket
    decision, labelled like the pack gauges in ``obs/metrics.py``.

    With a running ``deadline`` the remaining budget (converted to cost
    units via ``PlannerCosts.cost_per_ms``) gates the cold modes: the
    planner refuses ``host_scan``/``admit_cheaper`` decisions the budget
    can't cover (mode ``"skip"`` — the caller omits those buckets and
    marks the result degraded).
    """
    from ..kernels.ops import encode_filter
    from .planner import PlannerCosts, plan_read_paths
    costs = manager.cfg.planner_costs or PlannerCosts()
    snap = (obs.bucket_stats.snapshot()
            if obs is not None and obs.bucket_stats is not None else {})
    # a filter the kernels cannot encode falls back to the host scan path
    # everywhere; the traversal kernel shares the same φ encoding, so it
    # is equally unavailable — force scan across the whole pack
    graph_ok = encode_filter(filt, pack.m) is not None
    deadline_cost = (None if deadline is None else
                     max(deadline.remaining_ms(), 0.0) * costs.cost_per_ms)
    plan = plan_read_paths(pack, rp, snap, costs, t_lo, t_hi,
                           graph_allowed=graph_ok,
                           deadline_cost=deadline_cost)
    manager.last_plan = plan
    for dec in plan.values():
        registry.counter(
            f'planner_decision_total{{mode="{dec.mode}"}}').inc()
    graph_caps = frozenset(c for c, dec in plan.items()
                           if dec.mode == "graph")
    return plan, graph_caps


def _graph_search_blocks(manager, pack, buckets, queries, filt, k,
                         t_lo, t_hi, metric, trace, registry,
                         observe=None, on_cold=None, deadline=None,
                         degrade=None):
    """Stitched-traversal dispatch for the buckets the planner sent to
    ``graph`` mode.

    fp32 buckets yield exact ``(gid, dist)`` blocks; quantized buckets
    yield over-fetched candidate blocks that are reranked exactly at fp32
    (union across graph buckets — gids are disjoint) before joining the
    merge.  A bucket whose traversal is unavailable after all (filter not
    encodable, no live seeds — the planner should have gated these) falls
    back to the ordinary scan for that bucket alone; the fallback threads
    the same ``observe`` / ``on_cold`` hooks as the main scan path, so a
    fallback dispatch still feeds ``BucketStats`` (and therefore the
    planner) instead of silently starving it.  Returns
    ``(blocks_g, blocks_d)`` lists.

    With a running ``deadline``, the remaining budget is checked before
    each bucket's traversal; once spent, the remaining buckets are
    skipped and reported through ``degrade("deadline_graph", n)`` — the
    caller marks the result degraded.
    """
    import dataclasses as _dc

    from ..distributed.segment_shards import (bucket_graph_seeds,
                                              pack_search, pack_search_blocks)
    from ..kernels.graph_topk import bucket_graph_topk
    cfg = manager.cfg
    quantized = pack.quantize is not None
    kk = max(k, cfg.rerank_multiple * k if quantized else k)
    blocks_g: List[np.ndarray] = []
    blocks_d: List[np.ndarray] = []
    cand_g: List[np.ndarray] = []
    for i, bv in enumerate(buckets):
        if deadline is not None and deadline.expired():
            if degrade is not None:
                degrade("deadline_graph", len(buckets) - i)
            break
        seeds = bucket_graph_seeds(bv, t_lo, t_hi)
        with trace.span("bucket_graph", cap=bv.cap, seeds=int(len(seeds))):
            out = bucket_graph_topk(
                queries, bv, seeds, filt, kk, m=pack.m, metric=metric,
                ef=max(cfg.graph_ef, kk), width=cfg.graph_width,
                max_iters=cfg.graph_max_iters)
            if out is not None:
                block_ready(out[:2])
        if out is None:                       # planner gate raced/failed
            sub = _dc.replace(pack, buckets=(bv,))
            if quantized:
                gg, dd = pack_search(
                    sub, queries, filt, k, t_lo=t_lo, t_hi=t_hi,
                    metric=metric, lookup=manager.get_points,
                    rerank_multiple=cfg.rerank_multiple, trace=trace,
                    observe=observe, on_cold=on_cold)
                blocks_g.append(gg)
                blocks_d.append(dd)
            else:
                for gg, dd in pack_search_blocks(
                        sub, queries, filt, k, t_lo=t_lo, t_hi=t_hi,
                        metric=metric, trace=trace, observe=observe,
                        on_cold=on_cold):
                    blocks_g.append(gg)
                    blocks_d.append(dd)
            continue
        gg, dd, hops = out
        registry.histogram("graph_hops").observe(float(hops))
        if quantized:
            cand_g.append(np.asarray(gg))
        else:
            blocks_g.append(np.asarray(gg))
            blocks_d.append(np.asarray(dd))
    if cand_g:
        from ..quant.rerank import rerank_exact
        with trace.span("graph_rerank",
                        candidates=int(sum(g.shape[1] for g in cand_g))):
            gg, dd = rerank_exact(queries, np.concatenate(cand_g, axis=1),
                                  k, manager.get_points, metric=metric)
        blocks_g.append(gg)
        blocks_d.append(dd)
    return blocks_g, blocks_d


def query_segments(manager, queries: np.ndarray, filt: Optional[Filter],
                   k: int = 10, ef: int = 64, return_stats: bool = False,
                   use_shards: Optional[bool] = None, trace=None,
                   read_path: Optional[str] = None,
                   deadline_ms: Optional[float] = None,
                   **search_kw):
    """Fan out one query batch across all live segments and merge top-k.

    Runs against a snapshot — ``(epoch, segment list, frozen delta copy)``
    — taken under the manager lock at entry, so concurrent compaction
    publishes never tear the segment list mid-query and concurrent
    ingests/seals never mutate the delta rows being scanned.  Returns
    ``(gids [b, k], dists [b, k])`` — plus a list of per-segment
    ``SegmentQueryStats`` when ``return_stats`` is set (pruned segments
    appear with ``pruned=True`` and zero search time; under the sharded
    path every searched segment reports the shared dispatch time).

    ``use_shards`` overrides ``StreamConfig.n_shards`` per call (True
    forces the sharded kernel scan, False the per-segment graph search).
    ``read_path`` overrides ``StreamConfig.read_path`` per call
    (``"scan"`` | ``"graph"`` | ``"auto"``): anything but ``"scan"`` runs
    the cost planner over the sealed pack and routes each bucket to the
    fused scan or the stitched graph traversal; the chosen plan is left on
    ``manager.last_plan`` for inspection.

    All reported timings (``search_ms``, trace spans) stop their clocks
    only after ``jax.block_until_ready`` on the dispatch results, so they
    measure device work rather than JAX's async enqueue.  ``trace``
    (``repro.obs.trace.QueryTrace``, or None for the shared no-op) opens
    one span per phase — delta scan, per-bucket dispatch, rerank, merge —
    and the manager's :class:`~repro.obs.metrics.BucketStats` accumulator
    receives one per-bucket observation per sharded query.

    ``deadline_ms`` (default ``StreamConfig.query_deadline_ms``; None =
    unbounded) starts a :class:`~.resilience.Deadline` for this call.
    The remaining budget is checked *between* bucket dispatches — sealed
    scans (resident and cold host streams alike), graph traversals, and
    the per-segment fan-out — never mid-kernel; once spent, the
    remaining buckets are skipped and the merged partial result is
    returned as a :class:`~.resilience.QueryResult` with
    ``degraded=True`` and per-reason skip counts (also counted in
    ``query_degraded_total{reason=...}``).  The delta buffer is always
    scanned (freshest data, one cheap exact dispatch), and the planner
    refuses cold decisions the budget can't cover (see
    ``streaming/planner.py``).  Without a deadline the path is
    unchanged: results are exact and ``degraded`` is always False.
    """
    t_all = time.perf_counter()
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    b = queries.shape[0]
    trace = NULL_TRACE if trace is None else trace
    obs = getattr(manager, "obs", None)
    registry = obs.registry if obs is not None else NULL_REGISTRY
    if deadline_ms is None:
        deadline_ms = manager.cfg.query_deadline_ms
    deadline = Deadline.start(deadline_ms)
    reasons: dict = {}

    def _degrade(reason: str, n: int = 1) -> None:
        reasons[reason] = reasons.get(reason, 0) + int(n)
        registry.counter(
            f'query_degraded_total{{reason="{reason}"}}').inc(n)
    observe = (obs.bucket_stats.observe
               if obs is not None and obs.bucket_stats is not None else None)
    t_lo, t_hi = temporal_bounds(filt, manager.time_dim)
    metric = manager.cfg.index_cfg.metric
    # one lock hold captures the whole consistent view: the segment list
    # (epoch guard) AND a frozen copy of the delta's live rows, so a racing
    # ingest/seal can never resize or reset the buffer mid-scan
    with trace.span("snapshot"):
        epoch, segments, delta = manager.snapshot()

    blocks_g: List[np.ndarray] = []
    blocks_d: List[np.ndarray] = []
    stats: List[SegmentQueryStats] = []

    if delta.n_live > 0:
        st = delta.stats()
        if delta.t_max >= t_lo and delta.t_min <= t_hi:
            with trace.span("delta_scan", rows=delta.n_live):
                t0 = time.perf_counter()
                ids, dd = delta.query(queries, filt, k, metric=metric)
                block_ready((ids, dd))
                st.search_ms = (time.perf_counter() - t0) * 1e3
            blocks_g.append(ids)
            blocks_d.append(dd)
        else:
            st.pruned = True
        stats.append(st)

    sharded = (manager.cfg.n_shards >= 1 if use_shards is None
               else bool(use_shards))
    live_segs = [g for g in segments if g.n_live > 0]
    if sharded and live_segs:
        from ..distributed.segment_shards import (PackView, pack_search,
                                                  pack_search_blocks)
        # None when every snapshot segment lost its last live point to a
        # racing delete — nothing sealed to search, fall through.
        pack = manager.shard_pack(epoch, live_segs)
        dt_ms = 0.0
        tier = getattr(manager, "tier", None)
        on_cold = None
        if pack is not None:
            # cost-based routing: with read_path != "scan" the planner
            # splits the pack's buckets into a scan subset (dispatched
            # through the exact same calls below — byte-for-byte the
            # forced-scan answer) and a graph subset (stitched traversal)
            rp = (manager.cfg.read_path if read_path is None
                  else str(read_path))
            scan_pack = pack
            graph_bvs: tuple = ()
            if tier is not None and isinstance(pack, PackView):
                # feed the query window's drift to the prefetch predictor
                # and count cold (streamed) dispatches as tier misses
                tier.note_window(t_lo, t_hi)

                def on_cold(cap, stage_bytes, _reg=registry):
                    _reg.counter("tier_miss_total").inc()
            if isinstance(pack, PackView) and rp != "scan":
                import dataclasses as _dc
                plan, graph_caps = _plan_pack(manager, pack, filt, rp,
                                              t_lo, t_hi, obs, registry,
                                              deadline=deadline)
                # deadline-refused buckets (mode "skip"): the planner
                # priced every cold route above the remaining budget —
                # omit them and answer degraded instead of stalling
                skip_caps = frozenset(c for c, dec in plan.items()
                                      if dec.mode == "skip")
                if skip_caps:
                    _degrade("deadline_planner", len(skip_caps))
                if tier is not None:
                    # the planner priced re-admission below streaming for
                    # these cold buckets: admit them now and dispatch the
                    # resident block this very query.  tier_admit refuses
                    # (returns None — keep the exact cold view) when the
                    # block no longer fits or the pack has moved past this
                    # query's snapshot epoch.
                    admitted = {}
                    for cap, dec in plan.items():
                        if dec.reason == "admit_cheaper":
                            nbv = manager.tier_admit(cap,
                                                     expect_epoch=epoch)
                            if nbv is not None:
                                admitted[cap] = nbv
                    if admitted:
                        pack = _dc.replace(
                            pack, buckets=tuple(admitted.get(bv.cap, bv)
                                                for bv in pack.buckets))
                        scan_pack = pack
                drop = graph_caps | skip_caps
                if drop:
                    graph_bvs = tuple(bv for bv in pack.buckets
                                      if bv.cap in graph_caps)
                    scan_pack = _dc.replace(
                        pack, buckets=tuple(bv for bv in pack.buckets
                                            if bv.cap not in drop))
            with trace.span("sealed_scan",
                            quantized=getattr(pack, "quantize", None)
                            is not None):
                t0 = time.perf_counter()
                if isinstance(pack, PackView) and deadline is not None:
                    # deadline-aware dispatch: one sub-view per bucket so
                    # the remaining budget is re-checked between bucket
                    # dispatches.  Per-bucket rerank-to-k blocks merge to
                    # the same exact (dist, gid) answer as the bulk union
                    # rerank — top-k of a union equals the merge of exact
                    # per-part top-ks under the shared tiebreak — so a
                    # query that finishes in time is bit-for-bit the
                    # no-deadline answer.
                    import dataclasses as _dc
                    bvs = scan_pack.buckets
                    for i, bv in enumerate(bvs):
                        if deadline.expired():
                            _degrade("deadline_sealed_scan", len(bvs) - i)
                            break
                        manager._fault("query.bucket")
                        sub = _dc.replace(scan_pack, buckets=(bv,))
                        if scan_pack.quantize is not None:
                            gg, dd = pack_search(
                                sub, queries, filt, k, t_lo=t_lo,
                                t_hi=t_hi, metric=metric,
                                lookup=manager.get_points,
                                rerank_multiple=manager.cfg.rerank_multiple,
                                trace=trace, observe=observe,
                                on_cold=on_cold)
                            blocks_g.append(gg)
                            blocks_d.append(dd)
                        else:
                            for gg, dd in pack_search_blocks(
                                    sub, queries, filt, k, t_lo=t_lo,
                                    t_hi=t_hi, metric=metric, trace=trace,
                                    observe=observe, on_cold=on_cold):
                                blocks_g.append(gg)
                                blocks_d.append(dd)
                elif isinstance(pack, PackView) and pack.quantize is not None:
                    # two-stage quantized read path: pack_search
                    # over-fetches rerank_multiple * k candidates from
                    # each unpruned bucket's int8 asymmetric-distance
                    # dispatch and reranks the union exactly at fp32
                    # (original vectors from the point store) — one exact
                    # (gid, dist) block for the merge
                    if scan_pack.buckets:
                        gg, dd = pack_search(
                            scan_pack, queries, filt, k, t_lo=t_lo,
                            t_hi=t_hi, metric=metric,
                            lookup=manager.get_points,
                            rerank_multiple=manager.cfg.rerank_multiple,
                            trace=trace, observe=observe, on_cold=on_cold)
                        blocks_g.append(gg)
                        blocks_d.append(dd)
                elif isinstance(pack, PackView):
                    # one fused dispatch per unpruned capacity bucket;
                    # every bucket block joins the same exact (gid, dist)
                    # merge as the delta block below
                    if scan_pack.buckets:
                        for gg, dd in pack_search_blocks(
                                scan_pack, queries, filt, k, t_lo=t_lo,
                                t_hi=t_hi, metric=metric, trace=trace,
                                observe=observe, on_cold=on_cold):
                            blocks_g.append(gg)
                            blocks_d.append(dd)
                else:                     # legacy monolithic pack
                    gg, dd = pack_search(pack, queries, filt, k, t_lo=t_lo,
                                         t_hi=t_hi, metric=metric,
                                         trace=trace)
                    blocks_g.append(gg)
                    blocks_d.append(dd)
                if graph_bvs:
                    gb_g, gb_d = _graph_search_blocks(
                        manager, pack, graph_bvs, queries, filt, k,
                        t_lo, t_hi, metric, trace, registry,
                        observe=observe, on_cold=on_cold,
                        deadline=deadline, degrade=_degrade)
                    blocks_g.extend(gb_g)
                    blocks_d.extend(gb_d)
                # the per-bucket spans above already blocked on their own
                # results; this keeps the shared dispatch time honest even
                # if a future path returns device arrays here
                block_ready((blocks_g[-1] if blocks_g else None,
                             blocks_d[-1] if blocks_d else None))
                dt_ms = (time.perf_counter() - t0) * 1e3
            if tier is not None:
                # stage buckets the workload's window drift is about to
                # touch, off the query path (daemon thread, at most one)
                manager.maybe_prefetch()
        for seg in segments:
            st = seg.stats()
            if pack is None or seg.n_live == 0 \
                    or not seg.overlaps(t_lo, t_hi):
                st.pruned = True
            else:
                st.search_ms = dt_ms
            stats.append(st)
    else:
        for seg in segments:
            st = seg.stats()
            if seg.n_live == 0 or not seg.overlaps(t_lo, t_hi):
                st.pruned = True
                stats.append(st)
                continue
            if deadline is not None and deadline.expired():
                # budget spent: report the segment unsearched (pruned
                # with zero search time) and mark the answer degraded
                _degrade("deadline_segment")
                st.pruned = True
                stats.append(st)
                continue
            with trace.span("segment_scan", seg_id=seg.seg_id,
                            rows=seg.n_live):
                t0 = time.perf_counter()
                ids, dd = seg.query(queries, filt, k=k, ef=ef, **search_kw)
                block_ready((ids, dd))
                st.search_ms = (time.perf_counter() - t0) * 1e3
            blocks_g.append(ids)
            blocks_d.append(np.asarray(dd))
            stats.append(st)

    registry.counter("query_batches_total").inc()
    registry.counter("query_rows_total").inc(b)
    if reasons:
        registry.counter("query_degraded_queries_total").inc()
    if not blocks_g:
        out_g = np.full((b, k), -1, np.int64)
        out_d = np.full((b, k), np.inf, np.float32)
        registry.histogram("query_ms").observe(
            (time.perf_counter() - t_all) * 1e3)
        out = (out_g, out_d, stats) if return_stats else (out_g, out_d)
        return QueryResult(out, degraded=bool(reasons), reasons=reasons)

    with trace.span("merge", blocks=len(blocks_g)):
        out_g, out_d = merge_topk(blocks_g, blocks_d, k)
        out_g, out_d = _alive_filter(manager, out_g, out_d)
    registry.histogram("query_ms").observe(
        (time.perf_counter() - t_all) * 1e3)
    out = (out_g, out_d, stats) if return_stats else (out_g, out_d)
    return QueryResult(out, degraded=bool(reasons), reasons=reasons)


@dataclasses.dataclass
class GroupQuery:
    """One request group of a heterogeneous batched query: its own query
    rows, filter, ``k``, and per-call overrides (deadline, read path) —
    the unit :func:`query_segments_grouped` batches into shared per-bucket
    dispatches."""

    queries: np.ndarray
    filt: Optional[Filter] = None
    k: int = 10
    ef: int = 64
    deadline_ms: Optional[float] = None
    read_path: Optional[str] = None


def query_segments_grouped(manager, groups, trace=None, observe_group=None):
    """Continuous filtered batching: answer several heterogeneous
    :class:`GroupQuery` request groups in ONE pass over the manager's
    state — one snapshot, one delta scan per group, and one shared
    per-bucket sealed-pack dispatch where every group active in a bucket
    rides the same device-block read
    (:func:`repro.distributed.segment_shards.pack_search_blocks_grouped`).

    Answers are **bit-for-bit** what per-group :func:`query_segments`
    calls would return: the grouped kernel dispatch is a ``vmap`` of the
    solo dispatch over the group axis, the bucket skip set per group
    matches its solo temporal pruning, and each group merges with its own
    ``k`` and temporal mask through the same exact ``(dist, gid)`` merge.

    The shared fast path requires a batchable configuration — bucketed
    sealed pack (``n_shards >= 1``, ``incremental_pack``), fp32 blocks
    (``quantize=None``), and every group on the ``"scan"`` read path;
    anything else (quantized packs, planner/graph routing, legacy
    monolithic packs, unsharded managers) falls back to per-group
    :func:`query_segments` calls — same answers, no block sharing.

    Per-group deadlines (``GroupQuery.deadline_ms``, defaulting to
    ``StreamConfig.query_deadline_ms``) drop only the *lagging group*
    from remaining buckets — other groups keep scanning — and mark that
    group's :class:`~.resilience.QueryResult` degraded with
    ``deadline_sealed_scan`` skip counts, exactly like the solo path.

    ``observe_group(group_idx, cap, rows=, active_rows=, candidates=,
    candidate_slots=, cache_hit=)`` attributes each shared bucket
    dispatch back to the groups that rode it — the hook the serving tier
    uses for per-tenant ``BucketStats``.  Returns one
    ``QueryResult((gids [b_i, k_i], dists [b_i, k_i]))`` per group, in
    input order.
    """
    trace = NULL_TRACE if trace is None else trace
    obs = getattr(manager, "obs", None)
    registry = obs.registry if obs is not None else NULL_REGISTRY
    cfg = manager.cfg
    groups = list(groups)
    if not groups:
        return []
    rps = [g.read_path if g.read_path is not None else cfg.read_path
           for g in groups]
    shared_ok = (cfg.n_shards >= 1 and cfg.incremental_pack
                 and cfg.quantize is None
                 and all(rp == "scan" for rp in rps))
    if not shared_ok:
        return [query_segments(manager, g.queries, g.filt, k=g.k, ef=g.ef,
                               trace=trace, read_path=g.read_path,
                               deadline_ms=g.deadline_ms)
                for g in groups]

    t_all = time.perf_counter()
    qs = [np.atleast_2d(np.asarray(g.queries, np.float32)) for g in groups]
    bounds = [temporal_bounds(g.filt, manager.time_dim) for g in groups]
    deadlines = [Deadline.start(g.deadline_ms if g.deadline_ms is not None
                                else cfg.query_deadline_ms) for g in groups]
    reasons: List[dict] = [{} for _ in groups]

    def _degrade(gi: int, reason: str, n: int = 1) -> None:
        reasons[gi][reason] = reasons[gi].get(reason, 0) + int(n)
        registry.counter(
            f'query_degraded_total{{reason="{reason}"}}').inc(n)

    observe = (obs.bucket_stats.observe
               if obs is not None and obs.bucket_stats is not None else None)
    metric = cfg.index_cfg.metric
    with trace.span("snapshot"):
        epoch, segments, delta = manager.snapshot()

    blocks_g: List[List[np.ndarray]] = [[] for _ in groups]
    blocks_d: List[List[np.ndarray]] = [[] for _ in groups]

    if delta.n_live > 0:
        for gi, (q, (t_lo, t_hi)) in enumerate(zip(qs, bounds)):
            if delta.t_max >= t_lo and delta.t_min <= t_hi:
                with trace.span("delta_scan", rows=delta.n_live,
                                group=gi):
                    ids, dd = delta.query(q, groups[gi].filt,
                                          groups[gi].k, metric=metric)
                    block_ready((ids, dd))
                blocks_g[gi].append(ids)
                blocks_d[gi].append(dd)

    live_segs = [s for s in segments if s.n_live > 0]
    if live_segs:
        from ..distributed.segment_shards import (
            PackView, pack_search_blocks_grouped)
        # None when every snapshot segment lost its last live point to a
        # racing delete — nothing sealed to search, fall through.
        pack = manager.shard_pack(epoch, live_segs)
        if isinstance(pack, PackView):
            tier = getattr(manager, "tier", None)
            on_cold = None
            if tier is not None:
                for t_lo, t_hi in bounds:
                    tier.note_window(t_lo, t_hi)

                def on_cold(cap, stage_bytes, _reg=registry):
                    _reg.counter("tier_miss_total").inc()
            pk_groups = [(qs[gi], groups[gi].filt, groups[gi].k,
                          bounds[gi][0], bounds[gi][1])
                         for gi in range(len(groups))]
            with trace.span("sealed_scan_grouped", groups=len(groups)):
                per = pack_search_blocks_grouped(
                    pack, pk_groups, metric=metric, trace=trace,
                    observe=observe, on_cold=on_cold,
                    deadlines=deadlines,
                    on_expired=lambda gi, n:
                        _degrade(gi, "deadline_sealed_scan", n),
                    fault=lambda: manager._fault("query.bucket"),
                    observe_group=observe_group)
            for gi, bl in enumerate(per):
                for gg, dd in bl:
                    blocks_g[gi].append(gg)
                    blocks_d[gi].append(dd)
            if tier is not None:
                manager.maybe_prefetch()

    out: List[QueryResult] = []
    for gi, g in enumerate(groups):
        b = qs[gi].shape[0]
        registry.counter("query_batches_total").inc()
        registry.counter("query_rows_total").inc(b)
        if reasons[gi]:
            registry.counter("query_degraded_queries_total").inc()
        if not blocks_g[gi]:
            og = np.full((b, g.k), -1, np.int64)
            od = np.full((b, g.k), np.inf, np.float32)
        else:
            with trace.span("merge", blocks=len(blocks_g[gi]), group=gi):
                og, od = merge_topk(blocks_g[gi], blocks_d[gi], g.k)
                og, od = _alive_filter(manager, og, od)
        out.append(QueryResult((og, od), degraded=bool(reasons[gi]),
                               reasons=reasons[gi]))
    registry.histogram("query_ms").observe(
        (time.perf_counter() - t_all) * 1e3)
    return out
