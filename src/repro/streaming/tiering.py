"""Tiered bucket storage: HBM as a budgeted cache over the bucketed pack.

ROADMAP item 1 ("Beyond-HBM corpus").  The resident corpus used to be
capped by device memory — every sealed segment's block lived in the
:class:`~repro.distributed.segment_shards.BucketedShardPack` forever.
This module makes residency a *policy*: under
``StreamConfig(device_budget_bytes=...)`` the pack keeps at most
``budget`` device bytes of bucket blocks resident and demotes the rest to
host ``np`` arrays (``BucketedShardPack.evict_bucket``).  Three pieces:

* **Exactness for cold reads** — an evicted bucket's host block holds
  byte-identical content to the device block it replaced, and the sharded
  kernels (``kernels/ops.py``) accept host arrays (``jnp.asarray`` at
  entry), so a cold bucket simply *streams through the same fused kernel*
  per dispatch.  Same kernel + same bytes ⇒ the ``(dist, gid)`` results
  are bit-for-bit the all-resident ones — the property
  ``tests/test_tiering.py`` pins across lifecycle interleavings.
  :func:`host_reference_topk` is the independent numpy oracle for that
  contract (same ``(dist, gid)`` ordering as
  :func:`~repro.distributed.segment_shards.host_topk`).

* **Admission/eviction policy** — :class:`TierState` ranks buckets by
  *heat*: the rolling ``BucketStats`` dispatch history (buckets the
  planner keeps dispatching are hot) plus overlap with the recent query
  windows (buckets the workload's time range touches are hot even before
  their first dispatch).  ``pick_victims`` evicts coldest-first until the
  budget holds; the manager re-enforces after every pack delta
  (seal/publish/expire) and every admission.

* **Time-window prefetch** — :meth:`TierState.note_window` records each
  query's ``[t_lo, t_hi]``; :meth:`TierState.predicted_window` linearly
  extrapolates the windows' drift (mean successive center delta), and
  ``prefetch_targets`` names the cold buckets the *next* window will
  touch so ``SegmentManager.maybe_prefetch`` can stage them off the query
  path (same lock/epoch discipline as ``compact_async``) before queries
  land on them.

The planner's third mode (``host_scan`` in ``streaming/planner.py``)
prices cold dispatches against "admit first, then run resident"; this
module never decides *plans*, only *residency*.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..distributed.segment_shards import PAD_META, host_topk

__all__ = ["TierState", "host_reference_topk"]

# Heat bonus for a bucket whose time span overlaps the recent/predicted
# query windows: dominates any realistic dispatch count so temporal
# relevance outranks stale popularity when picking eviction victims.
_WINDOW_BONUS = 1e9


class TierState:
    """Residency policy state for one :class:`SegmentManager` (thread-safe).

    Owns nothing but the budget number and the rolling query-window
    history; the pack holds the actual blocks and the manager serializes
    evict/admit calls under its lock.  ``registry`` is the obs metrics
    registry the tier gauges/counters go to (``NULL_REGISTRY`` when
    observability is off).
    """

    def __init__(self, budget_bytes: int, registry=None,
                 window_history: int = 12):
        from ..obs.metrics import NULL_REGISTRY
        self.budget_bytes = int(budget_bytes)
        self.registry = NULL_REGISTRY if registry is None else registry
        self._lock = threading.Lock()
        self._windows: collections.deque = collections.deque(
            maxlen=max(int(window_history), 2))

    # ------------------------------------------------------------------
    # query-window drift tracking

    def note_window(self, t_lo: float, t_hi: float) -> None:
        """Record one query's time window (ignored unless both ends are
        finite — unbounded scans say nothing about drift)."""
        if np.isfinite(t_lo) and np.isfinite(t_hi) and t_lo <= t_hi:
            with self._lock:
                self._windows.append((float(t_lo), float(t_hi)))

    def recent_window(self) -> Optional[Tuple[float, float]]:
        """The last finite query window, or None before any."""
        with self._lock:
            return self._windows[-1] if self._windows else None

    def predicted_window(self) -> Optional[Tuple[float, float]]:
        """Extrapolate where the workload's window lands next: the last
        window shifted by the mean successive center delta.  With fewer
        than two recorded windows the last one is returned unshifted
        (stationary workloads prefetch what they already touch)."""
        with self._lock:
            wins = list(self._windows)
        if not wins:
            return None
        lo, hi = wins[-1]
        if len(wins) == 1:
            return (lo, hi)
        centers = [(a + b) / 2.0 for a, b in wins]
        drift = float(np.mean(np.diff(centers)))
        return (lo + drift, hi + drift)

    # ------------------------------------------------------------------
    # heat + policy

    @staticmethod
    def _overlaps(t_min: float, t_max: float,
                  win: Optional[Tuple[float, float]]) -> bool:
        if win is None:
            return False
        return t_max >= win[0] and t_min <= win[1]

    def heat(self, meta: Dict) -> float:
        """One bucket's heat: rolling dispatch count plus a dominating
        bonus when its time span overlaps the recent or predicted query
        window.  ``meta`` is one row from ``SegmentManager._bucket_meta``
        (keys ``cap``/``resident``/``nbytes``/``t_min``/``t_max``/
        ``stats``)."""
        stats = meta.get("stats")
        h = float(stats["dispatches"]) if stats else 0.0
        recent = self.recent_window()
        predicted = self.predicted_window()
        if self._overlaps(meta["t_min"], meta["t_max"], recent) or \
                self._overlaps(meta["t_min"], meta["t_max"], predicted):
            h += _WINDOW_BONUS
        return h

    def pick_victims(self, meta: Sequence[Dict],
                     need_bytes: int) -> List[int]:
        """Capacities to evict, coldest-first, until ``need_bytes`` of
        device memory frees up.  Ties (no observations, no window
        overlap) break toward evicting the bucket with the *oldest*
        ``t_max`` (furthest from the workload's drift) and, below that,
        the largest block (fewest evictions)."""
        resident = [m for m in meta if m["resident"] and m["nbytes"] > 0]
        resident.sort(key=lambda m: (self.heat(m), m["t_max"],
                                     -m["nbytes"]))
        victims, freed = [], 0
        for m in resident:
            if freed >= need_bytes:
                break
            victims.append(m["cap"])
            freed += m["nbytes"]
        return victims

    def prefetch_targets(self, meta: Sequence[Dict]) -> List[int]:
        """Cold buckets whose time span overlaps the predicted next
        window, hottest-first — what the prefetcher should stage before
        queries land on them.  Empty before any finite window."""
        win = self.predicted_window()
        if win is None:
            return []
        cold = [m for m in meta
                if not m["resident"]
                and self._overlaps(m["t_min"], m["t_max"], win)]
        cold.sort(key=lambda m: -self.heat(m))
        return [m["cap"] for m in cold]


def host_reference_topk(bv, queries: np.ndarray, filt, k: int,
                        t_lo: float, t_hi: float, metric: str = "l2",
                        m: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Independent pure-numpy oracle for one fp32 bucket's filtered top-k.

    Documents (and lets tests pin) the cold-read exactness contract: the
    same validity rules as the fused kernel (pad rows rejected via the
    ``PAD_META`` sentinel, temporally pruned rows dropped, φ evaluated on
    the first ``m`` metadata dims) and the same ``(dist, gid)`` total
    order (delegates the final merge to
    :func:`~repro.distributed.segment_shards.host_topk`).  Distances are
    numerically — not bitwise — the kernel's (different accumulation
    order), so comparisons use ``allclose`` on distances and exact
    equality on gids away from ties.  Quantized buckets have no single
    host-side distance (asymmetric + rerank), so this oracle rejects
    them.
    """
    if bv.quantized:
        raise ValueError("host_reference_topk covers fp32 buckets only")
    q = np.asarray(queries, np.float32)
    x = np.asarray(bv.x)                      # [rows, cap, dpad]
    s = np.asarray(bv.s)                      # [rows, cap, mpad]
    g = np.asarray(bv.gids).astype(np.int64)  # [rows, cap]
    rows, cap, dpad = x.shape
    if q.shape[1] < dpad:                     # packed vectors are padded;
        q = np.pad(q, ((0, 0), (0, dpad - q.shape[1])))  # pad cols are 0
    xf = x.reshape(rows * cap, dpad)
    sf = s.reshape(rows * cap, -1)
    gf = g.reshape(rows * cap)
    active = bv.active_rows(t_lo, t_hi)
    valid = (gf >= 0) & np.repeat(active, cap) & (sf[:, 0] < PAD_META / 2)
    if filt is not None:
        mm = sf.shape[1] if m is None else int(m)
        valid = valid & np.asarray(filt.contains(sf[:, :mm]), bool)
    if metric == "l2":
        qq = (q ** 2).sum(-1, dtype=np.float32)
        xx = (xf ** 2).sum(-1, dtype=np.float32)
        d = qq[:, None] - 2.0 * (q @ xf.T) + xx[None, :]
    elif metric == "ip":
        d = -(q @ xf.T)
    else:
        raise ValueError(f"unknown metric: {metric!r}")
    gmat = np.broadcast_to(gf, (q.shape[0], gf.size)).copy()
    gmat[:, ~valid] = -1
    return host_topk(gmat, d.astype(np.float32), k)
