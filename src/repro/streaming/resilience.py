"""Resilience substrate: fault injection, supervised workers, deadlines.

Three facilities the rest of the streaming runtime builds on:

* :class:`FaultInjector` — a deterministic, seed-driven generalization of
  the one-off fault hooks that grew inside ``tests/test_persistence.py``.
  It *is* a valid ``fault_hook(point)`` callable (the convention
  ``streaming/persistence.py`` already threads through WAL appends,
  artifact writes, and the manifest rename), so one injector instance
  plugs into every named fault point in the system — see
  :data:`FAULT_POINTS` for the catalog.  Faults fire either from an
  explicit per-point hit schedule or pseudo-randomly at a configured rate,
  derived by hashing ``(seed, point, hit_index)`` so a given seed produces
  the same fault sequence regardless of thread interleaving or wall
  clock.  Injected stalls (``delays=``) model slow I/O (a cold-tier
  stream that hangs) rather than crashes.

* :class:`Supervisor` — owns the manager's background workers
  (``compact_async``, ``maybe_prefetch``, deferred checkpoints).  A
  supervised run retries a failing worker with bounded exponential
  backoff; a worker that keeps failing past its error budget trips a
  sticky per-worker ``degraded`` flag.  Every error lands in the obs
  registry (``worker_errors_total{worker=...}`` et al.) and in the
  :meth:`Supervisor.health` snapshot that ``SegmentManager.stats()``
  surfaces under ``"health"`` — a daemon thread can no longer die
  silently.

* :class:`Deadline` / :class:`QueryResult` — per-query time budgets.
  The query path checks :meth:`Deadline.expired` between bucket
  dispatches (cold-tier host streams and graph traversals included) and,
  on overrun, returns the partial result from the buckets it already
  answered, explicitly marked ``degraded=True`` with per-reason skip
  counts.  ``QueryResult`` subclasses ``tuple`` so every existing
  ``g, d = manager.query(...)`` call site keeps working unchanged.

The invariant all of this serves (pinned by ``tests/test_resilience.py``):
**no fault schedule ever yields a silently wrong answer** — every query
outcome is either bit-for-bit what the fault-free run produces after
recovery, or an explicit error / explicitly ``degraded`` result.
"""
from __future__ import annotations

import threading
import time
import traceback
import zlib
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

__all__ = ["FAULT_POINTS", "FaultError", "FaultInjector", "Supervisor",
           "Deadline", "QueryResult"]

# Catalog of named fault points threaded through the runtime.  An
# injector may name any subset (unknown names are allowed for forward
# compatibility — they simply never fire until a call site exists).
FAULT_POINTS = (
    "wal.append",          # mid-frame during a WAL append (persistence.py)
    "wal.fsync",           # before the batched fsync (persistence.py)
    "segment.write",       # artifact staged, before fsync+rename
    "manifest.rename",     # state written, before the atomic swap
    "pack.delta",          # before an incremental pack delta applies
    "admission.stage",     # tier admission: host-side stage (under lock)
    "admission.upload",    # tier admission: lock-free device upload
    "admission.install",   # tier admission: epoch/gen-checked install
    "prefetch.round",      # top of one background prefetch round
    "compaction.execute",  # start of a compaction execute phase
    "query.bucket",        # before one per-bucket dispatch (deadline path)
)


class FaultError(RuntimeError):
    """The exception every injected crash raises.

    A distinct type so harnesses can tell an injected fault from a real
    bug: chaos tests catch ``FaultError`` (and recover), while any other
    exception escaping the same code path fails the test.
    """


class FaultInjector:
    """Deterministic, seed-driven fault-point hook (thread-safe).

    Callable as ``injector(point)`` — the ``fault_hook`` convention — so
    one instance threads through the WAL, the persistence checkpoint, the
    pack's admission trio, and the manager's lifecycle points alike.

    Firing is decided per ``(point, hit_index)``:

    * ``schedule={"wal.append": (2,)}`` crashes the 2nd ``wal.append``
      hit (1-based) — the exact-placement mode the persistence crash
      tests use;
    * ``rate=0.1, seed=s`` crashes ~10% of hits at points in ``points``
      (default: all), chosen by hashing ``(seed, point, hit)`` — the
      same seed replays the same fault sequence bit-for-bit, regardless
      of thread interleaving, which is what makes chaos runs
      reproducible from a single echoed seed;
    * ``delays={"query.bucket": 0.05}`` sleeps instead of raising —
      stall injection for deadline/degraded-mode tests.

    ``max_faults`` bounds total injected crashes (stalls don't count);
    ``disarm()`` turns the injector into a pure hit counter.
    """

    def __init__(self, schedule: Optional[Dict[str, Iterable[int]]] = None,
                 seed: int = 0, rate: float = 0.0,
                 points: Optional[Sequence[str]] = None,
                 delays: Optional[Dict[str, float]] = None,
                 max_faults: Optional[int] = None):
        self.schedule = {p: frozenset(int(i) for i in hits)
                         for p, hits in (schedule or {}).items()}
        self.seed = int(seed)
        self.rate = float(rate)
        self.points = None if points is None else frozenset(points)
        self.delays = dict(delays or {})
        self.max_faults = max_faults
        self.armed = True
        self.hits: Dict[str, int] = {}
        self.fired: list = []            # (point, hit_index) per crash
        self._lock = threading.Lock()

    def _chance(self, point: str, n: int) -> bool:
        """Deterministic pseudo-random draw for hit ``n`` of ``point``."""
        if self.rate <= 0.0:
            return False
        if self.points is not None and point not in self.points:
            return False
        h = zlib.crc32(f"{self.seed}|{point}|{n}".encode())
        return (h / 2.0 ** 32) < self.rate

    def __call__(self, point: str) -> None:
        """Count one hit of ``point``; stall or raise if scheduled."""
        with self._lock:
            n = self.hits.get(point, 0) + 1
            self.hits[point] = n
            if not self.armed:
                return
            crash = (n in self.schedule.get(point, ())
                     or self._chance(point, n))
            if crash and (self.max_faults is None
                          or len(self.fired) < self.max_faults):
                self.fired.append((point, n))
            else:
                crash = False
            delay = self.delays.get(point) if not crash else None
        if delay:
            time.sleep(delay)
        if crash:
            raise FaultError(f"injected fault at {point} (hit {n})")

    def disarm(self) -> None:
        """Stop injecting (hit counting continues)."""
        self.armed = False

    def arm(self) -> None:
        """Resume injecting after :meth:`disarm`."""
        self.armed = True


class _WorkerState:
    """Mutable per-worker bookkeeping inside a :class:`Supervisor`."""

    __slots__ = ("runs", "errors", "retries", "restarts",
                 "consecutive_failures", "degraded", "last_error")

    def __init__(self):
        self.runs = 0                 # completed successful runs
        self.errors = 0               # failed attempts (incl. retried)
        self.retries = 0              # in-run retry attempts
        self.restarts = 0             # fresh runs after a failed run
        self.consecutive_failures = 0  # whole runs failed in a row
        self.degraded = False         # error budget tripped (sticky until
        self.last_error = None        # a run succeeds)


class Supervisor:
    """Bounded-retry supervisor for the manager's background workers.

    :meth:`run` executes a worker function with up to ``max_retries``
    retries under exponential backoff (``backoff_base_s * 2**attempt``,
    capped at ``backoff_max_s``).  A whole run that still fails counts
    against the worker's error budget; ``error_budget`` consecutive
    failed runs trip the worker's ``degraded`` flag, cleared by the next
    successful run.  Every failure records the traceback tail and bumps
    the registry counters — nothing a daemon thread does can vanish
    silently anymore:

    * ``worker_errors_total{worker=w}`` — failed attempts;
    * ``worker_retries_total{worker=w}`` — backoff retries;
    * ``worker_restarts_total{worker=w}`` — fresh runs after a failure;
    * ``worker_degraded{worker=w}`` (gauge) — 1 while degraded.

    :meth:`health` returns the JSON-safe snapshot ``stats()["health"]``
    exposes; ``tools/obs_dump.py`` renders the counters/gauges above in
    Prometheus text format like every other metric.
    """

    def __init__(self, registry=None, max_retries: int = 2,
                 backoff_base_s: float = 0.02, backoff_max_s: float = 1.0,
                 error_budget: int = 3,
                 sleep: Callable[[float], None] = time.sleep):
        from ..obs.metrics import NULL_REGISTRY
        self.registry = NULL_REGISTRY if registry is None else registry
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        self.error_budget = int(error_budget)
        self._sleep = sleep
        self._lock = threading.Lock()
        self._workers: Dict[str, _WorkerState] = {}
        self._threads: Dict[str, threading.Thread] = {}

    def _state(self, name: str) -> _WorkerState:
        st = self._workers.get(name)
        if st is None:
            st = self._workers[name] = _WorkerState()
        return st

    def _record_failure(self, name: str, st: _WorkerState) -> None:
        st.errors += 1
        st.last_error = traceback.format_exc(limit=8)
        self.registry.counter(
            f'worker_errors_total{{worker="{name}"}}').inc()

    def note_error(self, name: str, exc: BaseException) -> None:
        """Record an inline (non-retried) worker failure — used by call
        sites that must fall back immediately (e.g. a pack-delta failure
        invalidates the pack rather than retrying under the lock) but
        must never drop the error on the floor."""
        with self._lock:
            st = self._state(name)
            st.errors += 1
            st.consecutive_failures += 1
            st.last_error = "".join(traceback.format_exception(
                type(exc), exc, exc.__traceback__, limit=8))
            if st.consecutive_failures >= self.error_budget:
                st.degraded = True
            self.registry.counter(
                f'worker_errors_total{{worker="{name}"}}').inc()
            self.registry.gauge(
                f'worker_degraded{{worker="{name}"}}').set(
                    1.0 if st.degraded else 0.0)

    def run(self, name: str, fn: Callable[[], object]):
        """Run ``fn`` as worker ``name`` with bounded retry + backoff.

        Returns ``fn``'s result on (eventual) success.  After exhausting
        retries the run counts one consecutive failure (possibly tripping
        ``degraded``) and returns None — the error itself lives on in
        ``health()`` and the registry, never re-raised into the daemon
        thread where it would vanish.
        """
        with self._lock:
            st = self._state(name)
            if st.consecutive_failures > 0:
                st.restarts += 1
                self.registry.counter(
                    f'worker_restarts_total{{worker="{name}"}}').inc()
        for attempt in range(self.max_retries + 1):
            try:
                result = fn()
            except Exception:
                with self._lock:
                    self._record_failure(name, st)
                    final = attempt >= self.max_retries
                    if final:
                        st.consecutive_failures += 1
                        if st.consecutive_failures >= self.error_budget:
                            st.degraded = True
                    else:
                        st.retries += 1
                        self.registry.counter(
                            f'worker_retries_total{{worker="{name}"}}').inc()
                    self.registry.gauge(
                        f'worker_degraded{{worker="{name}"}}').set(
                            1.0 if st.degraded else 0.0)
                if final:
                    return None
                self._sleep(min(self.backoff_base_s * (2.0 ** attempt),
                                self.backoff_max_s))
            else:
                with self._lock:
                    st.runs += 1
                    st.consecutive_failures = 0
                    st.degraded = False
                    self.registry.gauge(
                        f'worker_degraded{{worker="{name}"}}').set(0.0)
                return result
        return None                      # pragma: no cover - unreachable

    def spawn(self, name: str, fn: Callable[[], object]
              ) -> threading.Thread:
        """Run ``fn`` supervised on a daemon thread (at most one alive
        per worker name — the ``compact_async`` discipline).  Returns the
        (possibly already running) thread."""
        with self._lock:
            t = self._threads.get(name)
            if t is not None and t.is_alive():
                return t
            t = threading.Thread(target=lambda: self.run(name, fn),
                                 daemon=True, name=f"cubegraph-{name}")
            self._threads[name] = t
        t.start()
        return t

    def degraded(self, name: str) -> bool:
        """Whether worker ``name`` has tripped its error budget."""
        with self._lock:
            st = self._workers.get(name)
            return bool(st is not None and st.degraded)

    def health(self) -> Dict[str, dict]:
        """JSON-safe per-worker snapshot for ``stats()["health"]``."""
        with self._lock:
            return {
                name: {
                    "runs": st.runs,
                    "errors": st.errors,
                    "retries": st.retries,
                    "restarts": st.restarts,
                    "consecutive_failures": st.consecutive_failures,
                    "degraded": st.degraded,
                    "last_error": st.last_error,
                }
                for name, st in self._workers.items()
            }


class Deadline:
    """Monotonic per-query time budget.

    Created at query entry from ``StreamConfig(query_deadline_ms=)`` or
    the per-call ``query(deadline_ms=)`` override; the query path asks
    :meth:`expired` between bucket dispatches and the planner prices
    decisions against :meth:`remaining_ms`.  ``Deadline.start(None)``
    returns None — the no-deadline hot path stays a single ``is None``
    check with zero clock reads.
    """

    __slots__ = ("budget_ms", "_t0")

    def __init__(self, budget_ms: float):
        self.budget_ms = float(budget_ms)
        self._t0 = time.perf_counter()

    @classmethod
    def start(cls, budget_ms: Optional[float]) -> Optional["Deadline"]:
        """A running deadline, or None when no budget is set."""
        return None if budget_ms is None else cls(budget_ms)

    def remaining_ms(self) -> float:
        """Milliseconds left (negative once overrun)."""
        return self.budget_ms - (time.perf_counter() - self._t0) * 1e3

    def expired(self) -> bool:
        """Whether the budget is spent."""
        return self.remaining_ms() <= 0.0


class QueryResult(tuple):
    """A query's result tuple, annotated with degraded-mode metadata.

    Subclasses ``tuple`` so ``g, d = manager.query(...)`` (and the
    ``return_stats`` / ``return_trace`` arities) unpack exactly as
    before.  ``degraded`` is True when any bucket was skipped to honor a
    deadline — the partial answer covers only the buckets dispatched
    before the budget ran out; ``reasons`` maps each skip reason (e.g.
    ``"deadline_sealed_scan"``, ``"deadline_graph"``,
    ``"deadline_planner"``) to the number of buckets skipped for it.
    Without a deadline (the default), ``degraded`` is always False and
    results carry the usual exactness guarantees.
    """

    degraded: bool
    reasons: Dict[str, int]

    def __new__(cls, items: Tuple, degraded: bool = False,
                reasons: Optional[Dict[str, int]] = None) -> "QueryResult":
        """Wrap an ordinary result tuple with degraded-mode metadata."""
        self = super().__new__(cls, items)
        self.degraded = bool(degraded)
        self.reasons = dict(reasons or {})
        return self
