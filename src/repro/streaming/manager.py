"""Segment lifecycle: seal policy, compaction, TTL/retention expiry.

``SegmentManager`` owns the delta buffer, the ordered list of sealed
segments, and a global append-only point store (vectors + metadata by global
id) that the unified query path uses to re-rank merged candidates exactly.

Lifecycle (all event-time — "now" is the max timestamp ingested so far,
so replayed histories behave identically to live streams):

  ingest -> delta buffer -> [seal policy] -> sealed CubeGraphIndex segment
         -> [compaction]  -> merged/GC'd segments
         -> [retention]   -> whole-segment O(1) drop

Compaction runs synchronously from ``maintenance()`` in this reproduction;
an async compaction thread is a ROADMAP follow-up.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from ..core import CubeGraphConfig, Filter
from .segments import DeltaBuffer, SealedSegment, grow_rows

__all__ = ["StreamConfig", "SegmentManager"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Policy knobs for the streaming lifecycle."""

    time_dim: int = -1                    # metadata column holding time
    seal_max_points: int = 2048           # seal delta at this many live points
    seal_max_age: float = math.inf        # ... or when its span exceeds this
    # Retention is segment-granular for sealed data: a segment drops (O(1))
    # only once its *entire* span [t_min, t_max] is older than now - ttl, so
    # a straddling segment retains its older points until it ages out or is
    # compacted.  Delta-buffer stragglers are masked point-wise.
    ttl: float = math.inf
    compact_max_segments: int = 8         # merge adjacent pairs above this
    compact_deleted_fraction: float = 0.3  # GC a segment above this
    index_cfg: CubeGraphConfig = dataclasses.field(
        default_factory=CubeGraphConfig)


class SegmentManager:
    """LSM-style lifecycle manager over DeltaBuffer + SealedSegments."""

    def __init__(self, d: int, m: int, cfg: StreamConfig = StreamConfig()):
        self.d = int(d)
        self.m = int(m)
        self.cfg = cfg
        self.time_dim = cfg.time_dim % m
        self.delta = DeltaBuffer(d, m, self.time_dim,
                                 capacity=min(cfg.seal_max_points, 4096))
        self.segments: List[SealedSegment] = []     # ordered by t_min
        self._next_seg_id = 0
        # global append-only store (doubling growth), indexed by global id
        self._x = np.zeros((1024, d), np.float32)
        self._s = np.zeros((1024, m), np.float64)
        self._alive = np.zeros(1024, bool)
        self.n_total = 0                            # ids handed out so far
        self.now = -math.inf                        # event-time watermark
        self.counters = {"sealed": 0, "compactions": 0, "expired_segments": 0,
                         "expired_points": 0, "deleted": 0}

    # ------------------------------------------------------------------
    # Global point store
    # ------------------------------------------------------------------
    def _store_grow(self, need: int) -> None:
        self._x, self._s, self._alive = grow_rows(
            need, (self._x, 0.0), (self._s, 0.0), (self._alive, False))

    @property
    def store_x(self) -> np.ndarray:
        """Vectors of every id ever ingested — [n_total, d] view."""
        return self._x[: self.n_total]

    @property
    def store_s(self) -> np.ndarray:
        return self._s[: self.n_total]

    @property
    def alive(self) -> np.ndarray:
        """Liveness per global id (False once deleted or expired)."""
        return self._alive[: self.n_total]

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    # ------------------------------------------------------------------
    # Write path
    # ------------------------------------------------------------------
    def ingest(self, x: np.ndarray, s: np.ndarray) -> np.ndarray:
        """Append a batch; returns assigned global ids.  The batch is fed to
        the delta buffer in seal-policy-sized chunks, so a bulk load larger
        than ``seal_max_points`` seals into several time-ordered segments
        instead of one oversized one."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        s = np.atleast_2d(np.asarray(s, np.float64))
        n_add = x.shape[0]
        gids = np.arange(self.n_total, self.n_total + n_add, dtype=np.int64)
        self._store_grow(self.n_total + n_add)
        self._x[gids] = x
        self._s[gids] = s
        self._alive[gids] = True
        self.n_total += n_add
        self.now = max(self.now, float(s[:, self.time_dim].max()))
        lo = 0
        while lo < n_add:
            room = max(self.cfg.seal_max_points - self.delta.n_live, 1)
            take = min(room, n_add - lo)
            self.delta.append(x[lo:lo + take], s[lo:lo + take],
                              gids[lo:lo + take])
            lo += take
            self.maybe_seal()
        return gids

    def delete(self, gids: Sequence[int]) -> int:
        """Lazy delete by global id, wherever each point lives."""
        gids = np.asarray(gids, np.int64)
        live = gids[self._alive[gids]]
        if len(live) == 0:
            return 0
        self._alive[live] = False
        hits = self.delta.delete(live)
        for seg in self.segments:
            hits += seg.delete(live)
        self.counters["deleted"] += hits
        return hits

    # ------------------------------------------------------------------
    # Seal policy
    # ------------------------------------------------------------------
    def should_seal(self) -> bool:
        if self.delta.n_live >= self.cfg.seal_max_points:
            return True
        return (self.delta.n_live > 0
                and self.now - self.delta.t_min > self.cfg.seal_max_age)

    def maybe_seal(self) -> Optional[SealedSegment]:
        return self.seal() if self.should_seal() else None

    def seal(self) -> Optional[SealedSegment]:
        """Freeze the delta's live points into an immutable indexed segment."""
        xl, sl, gl = self.delta.live_points()
        self.delta.reset()
        if len(gl) == 0:
            return None
        seg = SealedSegment.from_points(self._next_seg_id, xl, sl, gl,
                                        self.time_dim, self.cfg.index_cfg)
        self._next_seg_id += 1
        self.segments.append(seg)
        self.segments.sort(key=lambda g: g.t_min)
        self.counters["sealed"] += 1
        return seg

    # ------------------------------------------------------------------
    # Retention / TTL
    # ------------------------------------------------------------------
    def expire(self, now: Optional[float] = None) -> int:
        """Drop whole segments past retention — O(1) per segment (the index
        is released, not edited).  Straggler delta points expire via mask."""
        if not math.isfinite(self.cfg.ttl):
            return 0
        cutoff = (self.now if now is None else float(now)) - self.cfg.ttl
        dropped = 0
        kept: List[SealedSegment] = []
        for seg in self.segments:
            if seg.t_max < cutoff:
                self._alive[seg.gids] = False
                dropped += seg.n_live
                self.counters["expired_segments"] += 1
            else:
                kept.append(seg)
        self.segments = kept
        n_delta = self.delta.expire_before(cutoff)
        if n_delta:
            sel = self.delta.gids[: self.delta.size]
            t = self._s[sel][:, self.time_dim]
            self._alive[sel[t < cutoff]] = False
        self.counters["expired_points"] += dropped + n_delta
        return dropped + n_delta

    # ------------------------------------------------------------------
    # Compaction
    # ------------------------------------------------------------------
    def compact(self) -> int:
        """GC heavily-deleted segments and merge adjacent ones; returns the
        number of rewrite operations performed."""
        ops = 0
        # (1) per-segment garbage collection of lazy deletions
        for i, seg in enumerate(self.segments):
            if (seg.deleted_fraction() > self.cfg.compact_deleted_fraction
                    and seg.n_live > 0):
                self.segments[i] = seg.compacted()
                ops += 1
        self.segments = [g for g in self.segments if g.n_live > 0]
        # (2) merge the adjacent pair with the fewest combined live points
        #     until the segment count is back under the policy bound
        while len(self.segments) > self.cfg.compact_max_segments:
            sizes = [g.n_live for g in self.segments]
            pair = min(range(len(sizes) - 1),
                       key=lambda i: sizes[i] + sizes[i + 1])
            a, b = self.segments[pair], self.segments[pair + 1]
            merged = self._merge(a, b)
            self.segments[pair:pair + 2] = [merged] if merged else []
            ops += 1
        if ops:
            self.counters["compactions"] += 1
        return ops

    def _merge(self, a: SealedSegment, b: SealedSegment
               ) -> Optional[SealedSegment]:
        keep_a = np.nonzero(a.index.valid)[0]
        keep_b = np.nonzero(b.index.valid)[0]
        gids = np.concatenate([a.gids[keep_a], b.gids[keep_b]])
        if len(gids) == 0:
            return None
        x = np.concatenate([np.asarray(a.index.x)[keep_a],
                            np.asarray(b.index.x)[keep_b]])
        s = np.concatenate([a.index.s_np[keep_a], b.index.s_np[keep_b]])
        seg = SealedSegment.from_points(self._next_seg_id, x, s, gids,
                                        self.time_dim, self.cfg.index_cfg)
        self._next_seg_id += 1
        return seg

    def maintenance(self) -> dict:
        """One synchronous lifecycle tick: seal (if due) + expire + compact."""
        sealed = self.maybe_seal() is not None
        expired = self.expire()
        compactions = self.compact()
        return {"sealed": sealed, "expired_points": expired,
                "compaction_ops": compactions}

    # ------------------------------------------------------------------
    # Read path (fan-out lives in streaming/query.py)
    # ------------------------------------------------------------------
    def query(self, queries: np.ndarray, filt: Optional[Filter], k: int = 10,
              ef: int = 64, return_stats: bool = False, **kw):
        from .query import query_segments
        return query_segments(self, queries, filt, k=k, ef=ef,
                              return_stats=return_stats, **kw)

    def stats(self) -> dict:
        return {
            "n_total": self.n_total,
            "n_live": self.n_live,
            "delta_live": self.delta.n_live,
            "n_segments": len(self.segments),
            "segment_live": [g.n_live for g in self.segments],
            "segment_spans": [(g.t_min, g.t_max) for g in self.segments],
            "now": self.now,
            **self.counters,
        }
