"""Segment lifecycle: seal policy, off-path compaction, TTL/retention expiry.

``SegmentManager`` owns the delta buffer, the ordered list of sealed
segments, a per-gid liveness bitmap, and a chunked :class:`PointStore`
ledger (off the query hot path since PR 2 — the unified query merges
per-segment ``(gid, dist)`` pairs directly, and the ledger is
garbage-collected chunk-wise as points retire).

Lifecycle (all event-time — "now" is the max timestamp ingested so far,
so replayed histories behave identically to live streams)::

  ingest -> delta buffer -> [seal policy] -> sealed CubeGraphIndex segment
         -> [compaction]  -> merged/GC'd segments
         -> [retention]   -> whole-segment O(1) drop

Compaction consistency (the epoch guarantee)
--------------------------------------------
Compaction is split into ``plan`` (cheap, under the manager lock) /
``execute`` (expensive index rebuilds, lock-free, off-thread via
:meth:`SegmentManager.compact_async`) / ``publish`` (atomic swap under the
lock).  Every mutation of the segment *list* bumps ``epoch``; queries take
a snapshot ``(epoch, segments)`` under the lock and run entirely against
it, so an in-flight query never observes a half-merged list.  At publish
time, deletions that landed while a replacement segment was being built
are re-applied to it before the swap, and the query path additionally
filters its merged result through the liveness bitmap — so a point deleted
before a query began is never returned, no matter how the query interleaves
with a concurrent compaction.

The sharded read path's device-resident pack rides the same guarantee:
every epoch bump applies an O(changed-segments) *delta* to the cached
size-bucketed pack under the lock (``_apply_pack_delta``), and queries
read immutable per-epoch ``PackView`` snapshots — see
``repro.distributed.segment_shards``.
"""
from __future__ import annotations

import dataclasses
import math
import os
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core import CubeGraphConfig, Filter
from .segments import DeltaBuffer, PointStore, SealedSegment, grow_rows

__all__ = ["CompactionPlan", "StreamConfig", "SegmentManager"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Policy knobs for the streaming lifecycle."""

    time_dim: int = -1                    # metadata column holding time
    seal_max_points: int = 2048           # seal delta at this many live points
    seal_max_age: float = math.inf        # ... or when its span exceeds this
    # Retention is segment-granular for sealed data: a segment drops (O(1))
    # only once its *entire* span [t_min, t_max] is older than now - ttl, so
    # a straddling segment retains its older points until it ages out or is
    # compacted.  Delta-buffer stragglers are masked point-wise.
    ttl: float = math.inf
    compact_max_segments: int = 8         # merge adjacent pairs above this
    compact_deleted_fraction: float = 0.3  # GC a segment above this
    # Sealed-segment read path: 0 = per-segment stitched-graph beam search;
    # >= 1 = partition each sealed segment into this many shards and scan
    # them with the fused kernel in one dispatch (exact; distributes across
    # a device mesh when one is attached).
    n_shards: int = 0
    # Pack maintenance for the sealed read path: with ``incremental_pack``
    # the device-resident pack is size-bucketed and updated by
    # O(changed-segment) deltas at each seal/publish/expire; ``False``
    # restores the legacy monolithic pack that rebuilds wholesale on every
    # epoch bump (kept for A/B benchmarking — see exp12).
    incremental_pack: bool = True
    pack_cap_multiple: int = 256          # bucket row-capacity quantum
    # Quantized read path (requires n_shards >= 1 and incremental_pack):
    # ``quantize="int8"`` fits per-dimension symmetric scales for every
    # sealed segment at seal/compaction-publish, stores int8 codes instead
    # of fp32 blocks on device (~4x more resident corpus per HBM byte),
    # scans with the fused asymmetric-distance kernel over-fetching
    # ``rerank_multiple * k`` candidates, and reranks them exactly at fp32
    # before the standard merge.  ``None`` (default) keeps the fp32 path
    # bit-for-bit unchanged — the A/B baseline for exp13.
    quantize: Optional[str] = None
    rerank_multiple: int = 4              # quantized over-fetch factor
    # Cost-based sealed read path (requires n_shards >= 1 and
    # incremental_pack).  "scan" (default) always dispatches the fused
    # (quantized) kernel scan — byte-for-byte the pre-planner behavior.
    # "graph" / "auto" additionally stage each sealed segment's coarsest
    # CubeGraph layer (adjacency + entry points) into the bucketed pack at
    # seal/compaction-publish and traverse it with the stitched Pallas beam
    # search (kernels/graph_topk): "graph" forces traversal wherever a
    # bucket carries a usable graph, "auto" lets streaming.planner pick
    # scan vs. traversal per bucket per dispatch from BucketStats + cost
    # estimates (PlannerCosts).  The planner never changes scan answers —
    # see tests/test_planner.py's parity property.
    read_path: str = "scan"
    planner_costs: Optional[object] = None  # PlannerCosts override (None =
                                            # defaults; replaced by measured
                                            # rooflines in ROADMAP item 5)
    graph_ef: int = 128                   # traversal beam width
    graph_width: int = 8                  # expansions per traversal hop
    graph_max_iters: int = 256            # traversal hop budget
    # Pre-trace the per-bucket kernel dispatch when a bucket block is
    # created or doubles, at seal/publish time (off the query path), so
    # the first query after a growth pays no trace (exp12's residual
    # spikes).
    pack_warm_compile: bool = True
    # Tiered storage (streaming/tiering.py; requires n_shards >= 1 and
    # incremental_pack): with a byte budget set, HBM becomes a cache —
    # the bucketed pack keeps at most this many device bytes of bucket
    # blocks resident, demoting the coldest (by BucketStats dispatch
    # history + query-window overlap) to host arrays.  Cold buckets
    # stream through the same fused kernels per dispatch, so answers
    # stay bit-for-bit the all-resident ones; the planner prices the
    # cold dispatch ("host_scan") against re-admission.  ``None``
    # (default) keeps every block resident forever — the pre-tiering
    # behavior, byte-for-byte.
    device_budget_bytes: Optional[int] = None
    tier_window_history: int = 12         # query windows kept for drift
    # Stage cold buckets whose time span overlaps the *predicted* next
    # query window (the recent windows' drift, extrapolated) on a daemon
    # thread after each sharded query — same at-most-one / lock+epoch
    # discipline as compact_async.
    tier_prefetch: bool = True
    # Observability (repro.obs): lifecycle/query counters, latency
    # histograms, and the rolling per-bucket BucketStats accumulator that
    # feeds the cost-based planner.  Off -> every instrumented call site
    # hits shared no-op singletons (no allocations, no locks).  Per-query
    # tracing is separately opt-in via query(..., return_trace=True).
    obs_enabled: bool = True
    # Query deadline (streaming/resilience.py): with a budget set, every
    # query checks remaining time between bucket dispatches (cold-tier
    # host streams and graph traversals included) and on overrun returns
    # the partial result from already-answered buckets explicitly marked
    # ``degraded=True`` (per-reason skip counters in
    # ``query_degraded_total{reason=...}``); the planner additionally
    # refuses host_scan/admit_cheaper decisions the remaining budget
    # can't cover.  ``None`` (default) keeps the unbounded exact path —
    # zero clock reads added.  Per-call override: query(deadline_ms=).
    query_deadline_ms: Optional[float] = None
    store_chunk: int = 4096               # PointStore GC granularity (rows)
    # Durability (repro.streaming.persistence): with ``persist_dir`` set the
    # manager WAL-logs every ingest/delete/GC and checkpoints (segment
    # artifacts + manifest swap) at each segment-list transition, so a
    # crashed replica restores via ``SegmentManager.restore(persist_dir)``.
    persist_dir: Optional[str] = None
    wal_fsync_every: int = 32             # WAL appends between fsyncs
    mmap_segments: bool = True            # restore x/s via np.load(mmap_mode)
    index_cfg: CubeGraphConfig = dataclasses.field(
        default_factory=CubeGraphConfig)


@dataclasses.dataclass
class CompactionPlan:
    """One compaction round, planned against a segment-list snapshot.

    ``gc`` segments are rewritten in place (lazy-deletion reclamation);
    each ``merges`` group of adjacent segments collapses into one.  The
    plan pins the ``epoch`` it was made at; ``publish`` drops any operation
    whose victims have left the list since (expired or already replaced).
    """

    epoch: int
    gc: List[SealedSegment]
    merges: List[List[SealedSegment]]
    drop_empty: bool = False

    @property
    def n_ops(self) -> int:
        """Rewrite operations this plan will perform if fully applied."""
        return len(self.gc) + sum(len(g) - 1 for g in self.merges)


class SegmentManager:
    """LSM-style lifecycle manager over DeltaBuffer + SealedSegments.

    Thread-safety: all list/ledger mutations take ``_lock``; reads snapshot
    under the lock and run lock-free (see the module docstring for the
    compaction epoch guarantee).  ``shard_mesh`` (optional) places the
    sharded read path's stacked segment shards across a device mesh built
    by ``repro.distributed.segment_shards.make_shard_mesh``.
    """

    def __init__(self, d: int, m: int, cfg: StreamConfig = StreamConfig(),
                 shard_mesh=None, _restoring: bool = False):
        self.d = int(d)
        self.m = int(m)
        self.cfg = cfg
        if cfg.quantize is not None:
            from ..quant import QUANT_KINDS
            if cfg.quantize not in QUANT_KINDS:
                raise ValueError(f"unknown quantize kind {cfg.quantize!r}; "
                                 f"supported: {QUANT_KINDS}")
            if cfg.n_shards < 1:
                raise ValueError("quantize requires the sharded read path "
                                 "(StreamConfig.n_shards >= 1)")
            if not cfg.incremental_pack:
                raise ValueError("quantize requires incremental_pack=True "
                                 "(the legacy monolithic pack is fp32-only)")
        if cfg.read_path not in ("scan", "graph", "auto"):
            raise ValueError(f"unknown read_path {cfg.read_path!r}; "
                             "supported: 'scan' | 'graph' | 'auto'")
        if cfg.read_path != "scan":
            if cfg.n_shards < 1:
                raise ValueError("read_path='graph'/'auto' requires the "
                                 "sharded read path (n_shards >= 1)")
            if not cfg.incremental_pack:
                raise ValueError("read_path='graph'/'auto' requires "
                                 "incremental_pack=True (graph blocks ride "
                                 "the bucketed pack)")
        if cfg.device_budget_bytes is not None:
            if cfg.device_budget_bytes < 0:
                raise ValueError("device_budget_bytes must be >= 0")
            if cfg.n_shards < 1 or not cfg.incremental_pack:
                raise ValueError("device_budget_bytes requires the sharded "
                                 "incremental pack (n_shards >= 1, "
                                 "incremental_pack=True) — residency is a "
                                 "bucketed-pack concept")
        self.time_dim = cfg.time_dim % m
        self.delta = DeltaBuffer(d, m, self.time_dim,
                                 capacity=min(cfg.seal_max_points, 4096))
        self.segments: List[SealedSegment] = []     # ordered by t_min
        self.shard_mesh = shard_mesh
        self.epoch = 0                              # segment-list generation
        self._lock = threading.RLock()
        self._next_seg_id = 0
        self._compact_thread: Optional[threading.Thread] = None
        # Cached device pack for the sharded read path: a BucketedShardPack
        # kept in sync by _apply_pack_delta at every segment-list
        # transition (or a legacy ShardPack rebuilt per epoch when
        # cfg.incremental_pack is off).  None until the first sharded
        # query cold-builds it — including after restore().
        self._pack = None
        # Most recent {cap: PlanDecision} from the cost-based planner
        # (read_path != "scan" only) — exposed for tests/observability.
        self.last_plan = None
        self.store = PointStore(d, m, chunk=cfg.store_chunk)
        self._alive = np.zeros(1024, bool)
        self.now = -math.inf                        # event-time watermark
        self.counters = {"sealed": 0, "compactions": 0, "expired_segments": 0,
                         "expired_points": 0, "deleted": 0,
                         "store_gc_points": 0}
        from ..obs import StreamObs
        self.obs = StreamObs(enabled=cfg.obs_enabled)
        # Resilience (streaming/resilience.py): the Supervisor owns every
        # background worker (compactor / prefetcher / checkpointer) with
        # bounded retry + error budget; fault_injector is None in
        # production and a FaultInjector under test/chaos harnesses —
        # install_fault_injector threads it through the WAL, checkpoint,
        # pack-admission, and lifecycle fault points.
        from .resilience import Supervisor
        self.supervisor = Supervisor(registry=self.obs.registry)
        self.fault_injector = None
        # Tiered storage: TierState owns the budget + query-window drift
        # history; the manager serializes every evict/admit under _lock.
        self.tier = None
        self._prefetch_thread: Optional[threading.Thread] = None
        if cfg.device_budget_bytes is not None:
            from .tiering import TierState
            self.tier = TierState(cfg.device_budget_bytes,
                                  registry=self.obs.registry,
                                  window_history=cfg.tier_window_history)
        self.persist = None                         # StreamPersistence
        self._suspend_ckpt = False                  # batched seals in ingest
        if cfg.persist_dir and not _restoring:
            from .persistence import MANIFEST_NAME, StreamPersistence
            if os.path.exists(os.path.join(cfg.persist_dir, MANIFEST_NAME)):
                raise ValueError(
                    f"{cfg.persist_dir!r} already holds a snapshot — use "
                    "SegmentManager.restore(...) to resume it")
            self.persist = StreamPersistence(cfg.persist_dir,
                                             cfg.wal_fsync_every,
                                             metrics=self.obs.registry)
            # publish an (empty) manifest immediately so the directory is
            # restorable even if we crash before the first seal
            self.persist.checkpoint(self)

    # ------------------------------------------------------------------
    # Resilience: fault points + supervised workers
    # ------------------------------------------------------------------
    def _fault(self, point: str) -> None:
        """Fire one named fault point when an injector is installed (the
        production path is a single None check)."""
        inj = self.fault_injector
        if inj is not None:
            inj(point)

    def install_fault_injector(self, inj) -> None:
        """Thread a :class:`~.resilience.FaultInjector` (or None to
        uninstall) through every fault point this manager owns: the WAL
        (``wal.append`` / ``wal.fsync``), checkpoint artifacts
        (``segment.write`` / ``manifest.rename``), the pack's admission
        trio, and the lifecycle points (``pack.delta`` /
        ``prefetch.round`` / ``compaction.execute`` / ``query.bucket``).
        One injector instance sees every point, so a seed-driven schedule
        interleaves faults across subsystems deterministically."""
        with self._lock:
            self.fault_injector = inj
            if self._pack is not None:
                self._pack.fault_hook = inj
            if self.persist is not None:
                self.persist.fault_hook = inj
                if self.persist.wal is not None:
                    self.persist.wal.fault_hook = inj

    def checkpoint_async(self) -> Optional[threading.Thread]:
        """Run a durable checkpoint on the supervised ``checkpointer``
        daemon worker (at most one alive) — the deferred-checkpoint path
        for callers that want durability without blocking the write path.
        A failing checkpoint is retried with backoff and lands in
        ``stats()["health"]`` instead of dying with the thread.  Returns
        the thread, or None without persistence attached."""
        if self.persist is None:
            return None

        def _ckpt():
            with self._lock:
                self.persist.checkpoint(self)
        return self.supervisor.spawn("checkpointer", _ckpt)

    # ------------------------------------------------------------------
    # Liveness ledger / point store
    # ------------------------------------------------------------------
    @property
    def n_total(self) -> int:
        """Global ids handed out so far (monotone)."""
        return self.store.n_total

    @property
    def alive(self) -> np.ndarray:
        """Liveness per global id (False once deleted or expired)."""
        return self._alive[: self.n_total]

    @property
    def n_live(self) -> int:
        """Number of live points across the delta buffer and all segments."""
        return int(self.alive.sum())

    def get_points(self, gids: Sequence[int]):
        """(x, s, present) rows from the ledger — ``present`` is False for
        ids whose store chunk was garbage-collected."""
        return self.store.get(gids)

    def gc_store(self) -> int:
        """Free point-store chunks with no live id left; returns #rows.
        WAL-logged (when persistence is attached) so restore replays the
        same chunk frees instead of resurrecting retired rows."""
        with self._lock:
            dead = self.store.dead_chunks(self.alive)
            if self.persist is not None and len(dead):
                self.persist.log_gc(dead)         # log-before-mutate
            freed = self.store.free_chunks(dead)
            self.counters["store_gc_points"] += freed
        return freed

    # ------------------------------------------------------------------
    # Write path
    # ------------------------------------------------------------------
    def ingest(self, x: np.ndarray, s: np.ndarray) -> np.ndarray:
        """Append a batch; returns assigned global ids.  The batch is fed to
        the delta buffer in seal-policy-sized chunks, so a bulk load larger
        than ``seal_max_points`` seals into several time-ordered segments
        instead of one oversized one."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        s = np.atleast_2d(np.asarray(s, np.float64))
        n_add = x.shape[0]
        with self._lock:
            epoch0 = self.epoch
            # log-before-mutate: if the WAL append fails (disk full), the
            # append is rolled back in the log and nothing in memory has
            # changed — the manager never holds phantom alive points
            if self.persist is not None and n_add:
                self.persist.log_ingest(self.store.n_total, x, s)
            gids = self.store.append(x, s)
            self._alive = grow_rows(self.n_total, (self._alive, False))[0]
            self._alive[gids] = True
            self.now = max(self.now, float(s[:, self.time_dim].max()))
            self.obs.registry.counter(
                "lifecycle_ingested_points_total").inc(n_add)
            # checkpoints are deferred to the end of the batch so a seal
            # mid-loop never captures a half-appended delta buffer
            self._suspend_ckpt = True
            try:
                lo = 0
                while lo < n_add:
                    room = max(self.cfg.seal_max_points - self.delta.n_live,
                               1)
                    take = min(room, n_add - lo)
                    self.delta.append(x[lo:lo + take], s[lo:lo + take],
                                      gids[lo:lo + take])
                    lo += take
                    self.maybe_seal()
            finally:
                self._suspend_ckpt = False
            if self.persist is not None and self.epoch != epoch0:
                self.persist.checkpoint(self)
        return gids

    def _apply_ingest(self, x: np.ndarray, s: np.ndarray) -> np.ndarray:
        """WAL-replay ingest: store/liveness/delta updates with no logging
        and no sealing (restore reproduces the last manifest's segmentation
        exactly; an over-full delta seals on the next live ingest)."""
        gids = self.store.append(x, s)
        self._alive = grow_rows(self.n_total, (self._alive, False))[0]
        self._alive[gids] = True
        self.now = max(self.now, float(s[:, self.time_dim].max()))
        self.delta.append(x, s, gids)
        return gids

    def delete(self, gids: Sequence[int]) -> int:
        """Lazy delete by global id, wherever each point lives."""
        gids = np.asarray(gids, np.int64)
        with self._lock:
            live = gids[self._alive[gids]]
            if len(live) == 0:
                return 0
            if self.persist is not None:     # log-before-mutate
                self.persist.log_delete(live)
            hits = self._apply_delete(live)
            if self._pack is not None:
                self._pack.mark_dead(live)
        return hits

    def _apply_delete(self, live: np.ndarray) -> int:
        """Shared core of :meth:`delete` and WAL replay: flip liveness and
        lazily delete from the delta buffer and every sealed segment."""
        live = live[self._alive[live]]
        if len(live) == 0:
            return 0
        self._alive[live] = False
        hits = self.delta.delete(live)
        for seg in self.segments:
            hits += seg.delete(live)
        self.counters["deleted"] += hits
        self.obs.registry.counter("lifecycle_deleted_points_total").inc(
            len(live))
        return hits

    # ------------------------------------------------------------------
    # Seal policy
    # ------------------------------------------------------------------
    def should_seal(self) -> bool:
        """Whether the delta buffer is due to freeze into a segment."""
        if self.delta.n_live >= self.cfg.seal_max_points:
            return True
        return (self.delta.n_live > 0
                and self.now - self.delta.t_min > self.cfg.seal_max_age)

    def maybe_seal(self) -> Optional[SealedSegment]:
        """Seal if the policy says so; returns the new segment or None."""
        return self.seal() if self.should_seal() else None

    def seal(self) -> Optional[SealedSegment]:
        """Freeze the delta's live points into an immutable indexed segment
        (with ``cfg.quantize``, also fit its scales and int8 codes here —
        the segment is immutable from now on, so the codec payload is
        final)."""
        with self._lock:
            xl, sl, gl = self.delta.live_points()
            self.delta.reset()
            if len(gl) == 0:
                return None
            seg = SealedSegment.from_points(self._next_seg_id, xl, sl, gl,
                                            self.time_dim, self.cfg.index_cfg,
                                            quantize=self.cfg.quantize)
            self._next_seg_id += 1
            self.segments.append(seg)
            self.segments.sort(key=lambda g: g.t_min)
            self.epoch += 1
            self.counters["sealed"] += 1
            self.obs.registry.counter("lifecycle_sealed_total").inc()
            self.obs.registry.counter("lifecycle_sealed_points_total").inc(
                len(gl))
            self._apply_pack_delta((), (seg,))
            self._checkpoint_if_attached()
        self._warm_pack()
        return seg

    def _shard_source(self, seg: SealedSegment):
        """One segment's live points (plus its codec payload when the
        quantized read path is on) as a pack delta input.  Built from the
        segment's single-snapshot :meth:`~SealedSegment.live_snapshot`, so
        the lock-free cold pack build can never see vectors and codec rows
        of different lengths when a delete races it (the row set itself is
        reconciled later by ``sync_alive``, as for the fp32 path)."""
        from ..distributed.segment_shards import SegmentShardSource
        nbrs = entries = None
        if self.cfg.read_path != "scan":
            xl, sl, gl, quant, graph = seg.live_snapshot(with_graph=True)
            nbrs, entries = graph.nbrs, graph.entries
        else:
            xl, sl, gl, quant = seg.live_snapshot()
        codes = scales = xsq = None
        if self.cfg.quantize is not None and quant is not None:
            codes, scales, xsq = quant.codes, quant.scales, quant.xsq
        return SegmentShardSource(seg.seg_id, xl, sl, gl, seg.t_min,
                                  seg.t_max, codes=codes, scales=scales,
                                  xsq=xsq, nbrs=nbrs, entries=entries)

    @property
    def graph_degree(self) -> Optional[int]:
        """Adjacency width staged into pack graph blocks (None = scan-only
        pack).  Segments flatten their hierarchical index into the union
        of every layer's edges (``SealedSegment._live_graph``), so the
        bound is ``n_layers`` times one layer's ``all_nbrs`` width (intra
        degree + cross-edge budget), capped at 64: after per-point dedupe
        the real unique degree sits well below the bound, and every padded
        ``-1`` lane is wasted gather/score work in each traversal hop, so
        the cap trims tail edges of the few highest-degree points instead
        of paying for them on every hop."""
        if self.cfg.read_path == "scan":
            return None
        ic = self.cfg.index_cfg
        return min(64, int(ic.n_layers
                           * (ic.m_intra + 2 * self.m * ic.m_cross)))

    def _warm_pack(self) -> int:
        """Pre-trace the kernel dispatch for bucket blocks the last pack
        delta created or doubled — called at the end of a seal / publish
        transition, so the trace cost lands on the (already index-building)
        write path instead of the next query (exp12's residual spikes).
        Returns the number of dispatches warmed."""
        if not self.cfg.pack_warm_compile:
            return 0
        with self._lock:
            pack = self._pack
            shapes = (pack.drain_warm_shapes()
                      if hasattr(pack, "drain_warm_shapes") else [])
        if not shapes:
            return 0
        from ..kernels import warm_sharded_shapes
        return warm_sharded_shapes(shapes)

    def _apply_pack_delta(self, removed, added) -> None:
        """Keep the cached bucketed pack in sync with one segment-list
        transition (called under the lock, after the epoch bump): victims
        tombstone their bucket slots, each added segment's live points
        append into their capacity bucket — O(changed segments), never a
        re-stack of the rest of the pack.  With ``incremental_pack`` off
        (or a legacy pack cached) this degrades to the old behavior:
        invalidate and cold-rebuild on the next sharded query.  Any delta
        failure also falls back to invalidation, so queries stay correct.
        """
        pack = self._pack
        if pack is None:
            return
        from ..distributed.segment_shards import BucketedShardPack
        if (self.cfg.n_shards < 1 or not self.cfg.incremental_pack
                or not isinstance(pack, BucketedShardPack)
                or pack.quantize != self.cfg.quantize
                or getattr(pack, "graph_degree", None) != self.graph_degree):
            self._pack = None
            return
        try:
            pack.metrics = self.obs.registry
            pack.fault_hook = self.fault_injector
            self._fault("pack.delta")
            for seg in removed:
                pack.remove_segment(seg.seg_id)
            for seg in added:
                src = self._shard_source(seg)
                if len(src.gids):
                    pack.add_segment(src)
            pack.epoch = self.epoch
            self._update_pack_gauges(pack)
            self._tier_enforce(pack)
        except Exception as exc:
            # correctness first: invalidate so the next sharded query
            # cold-builds an exact pack — but never silently (this was
            # a bare swallow before PR 9)
            self.supervisor.note_error("pack_delta", exc)
            self._pack = None

    def _update_pack_gauges(self, pack) -> None:
        """Refresh the device-pack occupancy gauges after a transition
        (caller holds the lock).  Gauges for released capacity classes are
        dropped rather than left frozen at their last value."""
        reg = self.obs.registry
        if not reg.enabled or not hasattr(pack, "bucket_stats"):
            return
        reg.drop_prefix("pack_bucket_")
        reg.gauge("pack_nbytes").set(pack.nbytes)
        reg.gauge("pack_segments").set(pack.n_segments)
        for cap, row in pack.bucket_stats().items():
            for key in ("rows", "live_rows", "segments", "resident"):
                reg.gauge(f'pack_bucket_{key}{{cap="{cap}"}}').set(row[key])

    # ------------------------------------------------------------------
    # Tiered storage (streaming/tiering.py): HBM as a budgeted cache
    # ------------------------------------------------------------------
    def _bucket_meta(self, pack) -> List[dict]:
        """Per-bucket policy inputs for the tier (caller holds the lock):
        capacity, residency, full block bytes, the bucket's packed time
        span, and its rolling BucketStats entry (None before any
        observation)."""
        snap = (self.obs.bucket_stats.snapshot()
                if self.obs.bucket_stats is not None else {})
        meta = []
        for cap, b in pack.buckets.items():
            alloc = b.seg_ids >= 0
            if not alloc.any():
                continue
            meta.append({"cap": cap, "resident": b.resident,
                         "nbytes": b.full_nbytes,
                         "t_min": float(b.t_min[alloc].min()),
                         "t_max": float(b.t_max[alloc].max()),
                         "stats": snap.get(str(cap))})
        return meta

    def _tier_enforce(self, pack, protect: Tuple[int, ...] = ()) -> int:
        """Evict coldest-first until the pack's resident bytes fit the
        budget (caller holds the lock; no-op without a tier or with a
        legacy pack).  ``protect`` names capacities a caller just admitted
        — never the immediate eviction victim (admission thrash).
        Returns device bytes freed."""
        if self.tier is None or not hasattr(pack, "evict_bucket"):
            return 0
        freed = 0
        need = pack.nbytes - self.tier.budget_bytes
        if need > 0:
            meta = [m for m in self._bucket_meta(pack)
                    if m["cap"] not in protect]
            for cap in self.tier.pick_victims(meta, need):
                freed += pack.evict_bucket(cap)
                self.obs.registry.counter("tier_evictions_total").inc()
                if pack.nbytes <= self.tier.budget_bytes:
                    break
        self._update_tier_gauges(pack)
        return freed

    def _update_tier_gauges(self, pack) -> None:
        """Refresh the tier occupancy gauges (caller holds the lock)."""
        if self.tier is None:
            return
        reg = self.obs.registry
        reg.gauge("tier_budget_bytes").set(self.tier.budget_bytes)
        reg.gauge("tier_resident_bytes").set(pack.nbytes)
        reg.gauge("tier_host_bytes").set(getattr(pack, "host_nbytes", 0))

    def tier_admit(self, cap: int, prefetch: bool = False,
                   expect_epoch: Optional[int] = None):
        """Admit one cold bucket's block back to the device (the query
        path calls this when the planner prices ``admit_cheaper``), then
        re-enforce the budget with the admitted bucket protected.  Returns
        the refreshed :class:`~..distributed.segment_shards.BucketView`
        (resident), or None when there is nothing to admit or the block
        alone exceeds the budget (it stays cold and streams per
        dispatch).  ``expect_epoch`` guards an in-flight query's snapshot:
        when the pack has moved past it the admission still happens (it
        helps the next query) but None is returned, so the caller keeps
        dispatching its epoch-consistent cold view."""
        with self._lock:
            pack = self._pack
            if (self.tier is None or pack is None
                    or not hasattr(pack, "admit_bucket")):
                return None
            b = pack.buckets.get(cap)
            if b is None:
                return None
            stale = (expect_epoch is not None
                     and pack.epoch != expect_epoch)
            if not b.resident:
                if b.full_nbytes > self.tier.budget_bytes:
                    return None
                if not pack.admit_bucket(cap):
                    return None         # pragma: no cover - defensive
                reg = self.obs.registry
                reg.counter("tier_admissions_total").inc()
                if prefetch:
                    reg.counter("tier_prefetch_admissions_total").inc()
                # the dispatch that triggered this admission compiles the
                # resident signature during the same query — drop the
                # warm-shape note instead of re-tracing it later
                pack.drain_warm_shapes()
            self._tier_enforce(pack, protect=(cap,))
            return None if stale else pack.bucket_view(cap)

    def _tier_warm_admit(self, pack) -> None:
        """Budget-bounded warm-up of a cold-built pack (restore / first
        sharded query; caller holds the lock): admit buckets
        most-recent-span-first while they fit, then flip
        ``resident_default`` so buckets created by later deltas start on
        the device (enforcement keeps the budget).  This is what replaces
        exp11's restore-time full resident build — under a budget the
        cold build uploads only what fits, not the whole corpus."""
        for m in sorted(self._bucket_meta(pack), key=lambda m: -m["t_max"]):
            if (not m["resident"]
                    and pack.nbytes + m["nbytes"] <= self.tier.budget_bytes):
                pack.admit_bucket(m["cap"])
                self.obs.registry.counter("tier_admissions_total").inc()
        pack.resident_default = True
        # the first query against this pack compiles its dispatches anyway
        pack.drain_warm_shapes()
        self._update_tier_gauges(pack)

    def maybe_prefetch(self) -> Optional[threading.Thread]:
        """Stage cold buckets the predicted next query window will touch,
        on a supervised daemon thread (at most one alive — the
        compact_async discipline; failures are retried and recorded in
        ``stats()["health"]``).  The query path calls this after each
        sharded dispatch; returns the thread, or None when there is
        nothing to prefetch."""
        if self.tier is None or not self.cfg.tier_prefetch:
            return None
        with self._lock:
            pack = self._pack
            if pack is None or not hasattr(pack, "stage_admission"):
                return None
            if not self.tier.prefetch_targets(self._bucket_meta(pack)):
                return None
        # supervised: a crashing prefetch round is retried with backoff
        # and recorded in stats()["health"] — never a silent daemon death
        t = self.supervisor.spawn("prefetcher", self._prefetch_once)
        self._prefetch_thread = t
        return t

    def _prefetch_once(self) -> int:
        """One prefetch round: snapshot the cold targets under the lock,
        upload their host blocks lock-free, and install each upload under
        the lock only if the pack and the bucket's mutation generation
        are unchanged (a delta that landed mid-upload silently discards
        the stale upload — the bucket stays cold and correct).  Returns
        buckets admitted.  Fault point ``prefetch.round`` fires at entry
        (the supervised worker retries a crashed round; prefetch is
        residency-only, so a crash at any stage changes no answers)."""
        self._fault("prefetch.round")
        with self._lock:
            pack = self._pack
            if (self.tier is None or pack is None
                    or not hasattr(pack, "stage_admission")):
                return 0
            staged = []
            budget = self.tier.budget_bytes
            for cap in self.tier.prefetch_targets(self._bucket_meta(pack)):
                b = pack.buckets.get(cap)
                if b is None or b.resident or b.full_nbytes > budget:
                    continue
                st = pack.stage_admission(cap)
                if st is not None:
                    staged.append((cap, st))
        if not staged:
            return 0
        ups = [(cap, pack.upload_admission(st)) for cap, st in staged]
        admitted = 0
        with self._lock:
            if self._pack is not pack:
                return 0
            reg = self.obs.registry
            for cap, (gen, dev) in ups:
                if pack.install_admission(cap, gen, dev):
                    admitted += 1
                    reg.counter("tier_admissions_total").inc()
                    reg.counter("tier_prefetch_admissions_total").inc()
            if admitted:
                self._tier_enforce(pack)
        if admitted:
            self._warm_pack()
        return admitted

    def _checkpoint_if_attached(self) -> None:
        """Durably checkpoint after a segment-list transition (no-op without
        persistence; deferred during a bulk ingest, which checkpoints once
        at the batch boundary)."""
        if self.persist is not None and not self._suspend_ckpt:
            self.persist.checkpoint(self)

    # ------------------------------------------------------------------
    # Retention / TTL
    # ------------------------------------------------------------------
    def expire(self, now: Optional[float] = None) -> int:
        """Drop whole segments past retention — O(1) per segment (the index
        is released, not edited).  Straggler delta points expire via mask."""
        if not math.isfinite(self.cfg.ttl):
            return 0
        with self._lock:
            cutoff = (self.now if now is None else float(now)) - self.cfg.ttl
            dropped = 0
            kept: List[SealedSegment] = []
            expired: List[SealedSegment] = []
            for seg in self.segments:
                if seg.t_max < cutoff:
                    self._alive[seg.gids] = False
                    dropped += seg.n_live
                    self.counters["expired_segments"] += 1
                    expired.append(seg)
                else:
                    kept.append(seg)
            list_changed = len(kept) != len(self.segments)
            if list_changed:
                self.segments = kept
                self.epoch += 1
                self._apply_pack_delta(expired, ())
            gl = self.delta.expire_before(cutoff)
            self._alive[gl] = False
            self.counters["expired_points"] += dropped + len(gl)
            reg = self.obs.registry
            reg.counter("lifecycle_expired_segments_total").inc(len(expired))
            reg.counter("lifecycle_expired_points_total").inc(
                dropped + len(gl))
            # list_changed matters on its own: dropping an all-dead segment
            # flips no liveness bit but still bumps the epoch and must reach
            # the manifest, or restore resurrects the segment
            if list_changed or dropped or len(gl):
                self._checkpoint_if_attached()
        return dropped + len(gl)

    # ------------------------------------------------------------------
    # Compaction (plan under lock / execute lock-free / publish atomically)
    # ------------------------------------------------------------------
    def plan_compaction(self) -> Optional[CompactionPlan]:
        """Pick this round's rewrites against the current segment list.

        Merging simulates the greedy smallest-adjacent-pair policy on live
        counts, so one plan carries the full set of merge *groups* needed to
        get the list back under ``compact_max_segments``.  Returns None when
        there is nothing to do.
        """
        with self._lock:
            segs = [g for g in self.segments if g.n_live > 0]
            drop_empty = len(segs) != len(self.segments)
            groups = [[g] for g in segs]
            while len(groups) > self.cfg.compact_max_segments:
                sizes = [sum(x.n_live for x in grp) for grp in groups]
                i = min(range(len(sizes) - 1),
                        key=lambda j: sizes[j] + sizes[j + 1])
                groups[i:i + 2] = [groups[i] + groups[i + 1]]
            merges = [grp for grp in groups if len(grp) > 1]
            merged = {id(g) for grp in merges for g in grp}
            gc = [g for g in segs if id(g) not in merged
                  and g.deleted_fraction() > self.cfg.compact_deleted_fraction]
            if not gc and not merges and not drop_empty:
                return None
            plan = CompactionPlan(self.epoch, gc, merges, drop_empty)
            self.obs.registry.counter("compaction_plans_total").inc()
            self.obs.registry.counter("compaction_planned_ops_total").inc(
                plan.n_ops)
            return plan

    def execute_compaction(self, plan: CompactionPlan
                           ) -> List[Tuple[List[SealedSegment],
                                           Optional[SealedSegment]]]:
        """Build every replacement segment in the plan — the expensive part,
        run without the lock (this is what ``compact_async`` moves off the
        ingest/query path).  With persistence attached the replacements'
        durable artifacts are also staged here, lock-free, so the publish
        checkpoint under the lock only swaps state + manifest.  Returns
        ``(victims, replacement)`` pairs.  Fault point
        ``compaction.execute`` fires before any rebuild — a crash here
        mutates nothing (the plan is re-derivable from unchanged
        state)."""
        self._fault("compaction.execute")
        t0 = time.perf_counter()
        built: List[Tuple[List[SealedSegment], Optional[SealedSegment]]] = []
        for seg in plan.gc:
            built.append(([seg], seg.compacted(quantize=self.cfg.quantize)))
        for grp in plan.merges:
            built.append((grp, self._merge_group(grp)))
        if self.persist is not None:
            for _, new_seg in built:
                if new_seg is not None:
                    self.persist.stage_segment(new_seg)
        self.obs.registry.counter("compaction_executed_ops_total").inc(
            plan.n_ops)
        self.obs.registry.histogram("compaction_execute_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return built

    def publish_compaction(self, plan: CompactionPlan,
                           built) -> int:
        """Atomically swap replacements into the segment list.

        Operations whose victims already left the list (expired or replaced
        by a racing round) are dropped; deletions that landed during the
        build are re-applied to each replacement before it becomes visible.
        Bumps ``epoch`` so cached read structures (shard packs, query
        snapshots) refresh.  Returns the number of applied rewrite ops.
        """
        ops = 0
        with self._lock:
            current = {id(g) for g in self.segments}
            out = list(self.segments)
            for victims, new_seg in built:
                if any(id(v) not in current for v in victims):
                    continue
                if new_seg is not None:
                    dead = new_seg.gids[~self._alive[new_seg.gids]]
                    if len(dead):
                        new_seg.delete(dead)
                victim_ids = {id(v) for v in victims}
                out = [g for g in out if id(g) not in victim_ids]
                if new_seg is not None and new_seg.n_live > 0:
                    out.append(new_seg)
                ops += 1 if len(victims) == 1 else len(victims) - 1
            out = [g for g in out if g.n_live > 0]
            changed = ops > 0 or len(out) != len(self.segments)
            if changed:
                pre_ids = {id(g): g for g in self.segments}
                post_ids = {id(g) for g in out}
                out.sort(key=lambda g: g.t_min)
                self.segments = out
                self.epoch += 1
                # pack delta = the object-identity diff of the swap (covers
                # merge victims, GC rewrites reusing a seg_id, and all-dead
                # segments silently dropped from the list)
                self._apply_pack_delta(
                    [g for oid, g in pre_ids.items() if oid not in post_ids],
                    [g for g in out if id(g) not in pre_ids])
            if ops:
                self.counters["compactions"] += 1
                self.obs.registry.counter(
                    "compaction_published_ops_total").inc(ops)
            if changed:
                self._checkpoint_if_attached()
        self._warm_pack()
        return ops

    def compact(self) -> int:
        """One full synchronous compaction: plan/execute/publish rounds
        until a plan comes back empty; returns total rewrite operations.
        (Call :meth:`compact_async` to run this off the hot path.)"""
        total = 0
        for _ in range(8):          # one round in the uncontended case
            plan = self.plan_compaction()
            if plan is None:
                break
            built = self.execute_compaction(plan)
            applied = self.publish_compaction(plan, built)
            total += applied
            if applied < plan.n_ops:
                break               # racing mutations; let the next tick retry
        return total

    def compact_async(self) -> threading.Thread:
        """Run :meth:`compact` on a supervised daemon thread (at most one
        at a time); returns the thread.  Queries and ingest proceed
        concurrently — the publish step is the only part that takes the
        lock.  A compaction that raises is retried with bounded backoff
        by the :class:`~.resilience.Supervisor`; persistent failure trips
        the ``compactor`` worker's degraded flag in ``stats()["health"]``
        (the work is deferred, never silently lost — the next tick
        re-plans from unchanged state)."""
        t = self.supervisor.spawn("compactor", self.compact)
        self._compact_thread = t
        return t

    def wait_for_compaction(self, timeout: Optional[float] = None) -> None:
        """Block until the background compaction (if any) finishes."""
        t = self._compact_thread
        if t is not None:
            t.join(timeout)

    def _merge_group(self, segs: Sequence[SealedSegment]
                     ) -> Optional[SealedSegment]:
        """Rebuild one segment from the live points of ``segs``."""
        xs, ss, gs = [], [], []
        for g in segs:
            xl, sl, gl = g.live_points()
            xs.append(xl)
            ss.append(sl)
            gs.append(gl)
        gids = np.concatenate(gs)
        if len(gids) == 0:
            return None
        with self._lock:
            sid = self._next_seg_id
            self._next_seg_id += 1
        return SealedSegment.from_points(sid, np.concatenate(xs),
                                         np.concatenate(ss), gids,
                                         self.time_dim, self.cfg.index_cfg,
                                         quantize=self.cfg.quantize)

    def maintenance(self, async_compaction: bool = False) -> dict:
        """One lifecycle tick: seal (if due) + expire + compact + store GC.

        With ``async_compaction`` the compaction rounds run on the
        background thread and this tick returns immediately (the dict then
        reports ``compaction_ops=None``)."""
        sealed = self.maybe_seal() is not None
        expired = self.expire()
        if async_compaction:
            self.compact_async()
            compactions = None
        else:
            compactions = self.compact()
        freed = self.gc_store()
        return {"sealed": sealed, "expired_points": expired,
                "compaction_ops": compactions, "store_gc_points": freed}

    # ------------------------------------------------------------------
    # Durability (WAL + manifest snapshots live in streaming/persistence.py)
    # ------------------------------------------------------------------
    def snapshot_to(self, directory: str) -> dict:
        """Write a complete, self-consistent snapshot of this manager to
        ``directory`` (segment artifacts + state + atomic manifest) and
        return the manifest dict.

        Segment artifacts are immutable content, so they are staged
        *without* the lock first; only the state + manifest capture runs
        under the manager lock, which is what serializes it against
        ingest, deletes, and — crucially — a racing ``compact_async``
        publish: the captured state is always entirely pre- or entirely
        post-publish.  When ``directory`` is this manager's own
        ``persist_dir`` the attached persistence simply checkpoints; any
        other directory gets a standalone export (existing artifacts are
        rewritten there once and reused by later exports to the same
        place).
        """
        from .persistence import StreamPersistence
        if self.persist is not None and os.path.abspath(directory) \
                == os.path.abspath(self.persist.root):
            p, owned = self.persist, False
        else:
            p = StreamPersistence(directory, self.cfg.wal_fsync_every)
            owned = True
        with self._lock:
            segments = list(self.segments)
        for seg in segments:         # lock-free: artifact content is frozen
            p.stage_segment(seg)
        try:
            with self._lock:
                return p.checkpoint(self)
        finally:
            if owned:
                p.close()

    @classmethod
    def restore(cls, directory: str, cfg: Optional[StreamConfig] = None,
                shard_mesh=None, resume: bool = True) -> "SegmentManager":
        """Rebuild a manager from a snapshot directory: last published
        manifest + mmapped segment artifacts + WAL-tail replay.  The result
        answers queries bit-for-bit identically to the snapshotted manager
        (see ``repro.streaming.persistence.restore_manager``).  ``resume``
        re-attaches persistence to ``directory`` so the restored manager
        keeps journaling; pass ``cfg`` to override the persisted config
        (e.g. a different ``n_shards`` for the read path)."""
        from .persistence import restore_manager
        return restore_manager(directory, cfg=cfg, shard_mesh=shard_mesh,
                               resume=resume)

    # ------------------------------------------------------------------
    # Read path (fan-out lives in streaming/query.py)
    # ------------------------------------------------------------------
    def snapshot(self):
        """(epoch, segment-list copy, frozen delta rows) — the consistent
        view a query runs against while ingest/seal/compaction publish
        concurrently.  All three are captured in one lock hold: a list
        copy alone would let a racing seal move points from the delta into
        a segment between two reads, duplicating them across blocks."""
        with self._lock:
            return self.epoch, list(self.segments), self.delta.freeze()

    def shard_pack(self, epoch: int, segments: List[SealedSegment]):
        """The consistent shard-pack read state for ``(epoch, segments)``:
        an immutable ``PackView`` of the delta-maintained bucketed pack
        (or the legacy monolithic ``ShardPack`` with ``incremental_pack``
        off), cold-building when no cached pack matches the epoch — first
        sharded query, after ``restore()``, or after a delta fallback.

        The cold build runs outside the lock (it copies live points and
        uploads device arrays); installation re-checks the epoch and syncs
        the pack against deletions that landed mid-build.  The view (or
        legacy pack) itself is captured under the lock, so it can never
        interleave with a concurrent delta application.
        """
        from ..distributed.segment_shards import (BucketedShardPack,
                                                  build_bucketed_pack,
                                                  build_shard_pack)

        def _read_state(pack):
            return (pack.view() if isinstance(pack, BucketedShardPack)
                    else pack)

        with self._lock:
            pack = self._pack
            if pack is not None and pack.epoch == epoch:
                return _read_state(pack)
        sources = []
        for seg in segments:
            src = self._shard_source(seg)
            if len(src.gids):
                sources.append(src)
        if not sources:
            return None
        if self.cfg.incremental_pack:
            # under a tier budget the cold build stays host-side
            # (resident_default=False — no device upload of blocks the
            # budget would immediately evict); _tier_warm_admit then
            # uploads only what fits, most-recent-span first
            pack = build_bucketed_pack(
                sources, self.cfg.n_shards, epoch, mesh=self.shard_mesh,
                cap_multiple=self.cfg.pack_cap_multiple,
                quantize=self.cfg.quantize, metrics=self.obs.registry,
                graph_degree=self.graph_degree,
                resident_default=self.tier is None)
            # a cold build's dispatches compile during this same query
            # anyway — drop its warm-shape backlog instead of re-tracing
            pack.drain_warm_shapes()
        else:
            pack = build_shard_pack(sources, self.cfg.n_shards, epoch,
                                    mesh=self.shard_mesh)
        with self._lock:
            pack.sync_alive(self.alive)
            pack.fault_hook = self.fault_injector
            if self.epoch == epoch:
                self._pack = pack
                if self.tier is not None and hasattr(pack, "admit_bucket"):
                    self._tier_warm_admit(pack)
                self._update_pack_gauges(pack)
            return _read_state(pack)

    def query(self, queries: np.ndarray, filt: Optional[Filter], k: int = 10,
              ef: int = 64, return_stats: bool = False,
              return_trace: bool = False, **kw):
        """Unified fan-out query over the delta buffer + sealed segments;
        see :func:`repro.streaming.query.query_segments`.

        ``return_trace`` appends a finished
        :class:`~repro.obs.trace.QueryTrace` to the result tuple — a span
        tree decomposing this call's latency (delta scan, per-bucket
        dispatch, rerank, merge) with every timer stopped only after
        ``jax.block_until_ready``.  Tracing never changes results (see
        ``tests/test_obs.py``).

        ``deadline_ms`` (forwarded via ``**kw``, default
        ``StreamConfig.query_deadline_ms``) bounds this call's time
        budget; on overrun the returned
        :class:`~.resilience.QueryResult` carries ``degraded=True`` with
        the partial answer from already-dispatched buckets — see
        ``streaming/resilience.py``."""
        from .query import query_segments
        if not return_trace:
            return query_segments(self, queries, filt, k=k, ef=ef,
                                  return_stats=return_stats, **kw)
        from ..obs.trace import QueryTrace
        from .resilience import QueryResult
        trace = QueryTrace("query")
        out = query_segments(self, queries, filt, k=k, ef=ef,
                             return_stats=return_stats, trace=trace, **kw)
        res = out + (trace.finish(),)
        if isinstance(out, QueryResult):     # keep degraded metadata:
            res = QueryResult(res, degraded=out.degraded,   # tuple concat
                              reasons=out.reasons)          # strips it
        return res

    def query_grouped(self, groups, trace=None, observe_group=None):
        """Continuous filtered batching entry point: answer several
        heterogeneous :class:`~repro.streaming.query.GroupQuery` request
        groups in one pass, sharing each sealed bucket's device-block
        read across every group active there; see
        :func:`repro.streaming.query.query_segments_grouped` (answers
        are bit-for-bit the per-group :meth:`query` answers)."""
        from .query import query_segments_grouped
        return query_segments_grouped(self, groups, trace=trace,
                                      observe_group=observe_group)

    def stats(self) -> dict:
        """Lifecycle counters, per-segment occupancy, and the ``obs``
        metrics block for dashboards.  Strict-JSON safe end-to-end:
        ``json.dumps(stats, allow_nan=False)`` always succeeds — non-finite
        values (the pre-first-ingest ``now`` watermark, unbounded segment
        spans) follow the persistence layer's inf→null convention."""
        from ..obs.metrics import json_sanitize
        with self._lock:
            pack = self._pack
            return json_sanitize({
                "pack_nbytes": 0 if pack is None else int(pack.nbytes),
                "pack_buckets": (pack.bucket_stats()
                                 if hasattr(pack, "bucket_stats") else {}),
                "n_total": self.n_total,
                "n_live": self.n_live,
                "delta_live": self.delta.n_live,
                "n_segments": len(self.segments),
                "segment_live": [g.n_live for g in self.segments],
                "segment_spans": [(g.t_min, g.t_max) for g in self.segments],
                "now": self.now,
                "epoch": self.epoch,
                "n_shards": self.cfg.n_shards,
                "quantize": self.cfg.quantize,
                "tier": (None if self.tier is None else {
                    "budget_bytes": self.tier.budget_bytes,
                    "resident_bytes": 0 if pack is None else int(pack.nbytes),
                    "host_bytes": (0 if pack is None else
                                   int(getattr(pack, "host_nbytes", 0))),
                }),
                "store_resident_points": self.store.resident_points,
                "store_nbytes": self.store.nbytes,
                # per-worker supervisor snapshot (runs / errors / retries /
                # restarts / degraded / last_error) — the machine-readable
                # twin of worker_errors_total{worker=} and friends
                "health": self.supervisor.health(),
                "obs": self.obs.snapshot(),
                **self.counters,
            })
