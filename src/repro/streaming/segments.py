"""Streaming segments: mutable delta buffer + immutable sealed segments.

LSM-style write path for the temporal workload (ROADMAP: continuous
ingestion).  Fresh points land in an append-only in-memory ``DeltaBuffer``
answered by brute-force fused filtered top-k (the Pallas kernel — exact, and
fast while the buffer is small).  When the buffer hits the seal policy it
freezes into a ``SealedSegment``: a time-range-partitioned ``CubeGraphIndex``
answered by the stitched-graph beam search.  Both speak *global* point ids so
results from any mix of segments merge directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core import CubeGraphConfig, CubeGraphIndex, Filter
from ..kernels import filtered_topk

__all__ = ["DeltaBuffer", "DeltaSnapshot", "PointStore", "SealedSegment",
           "SegmentGraph", "SegmentQueryStats", "scan_filtered_topk"]


# Per-segment seed budget for the stitched traversal (see _live_graph):
# dense all-layer cube entries below this, an even-stride subsample above.
_MAX_SEED_ENTRIES = 256


@dataclasses.dataclass(frozen=True)
class SegmentGraph:
    """Live-row adjacency + entry points of a sealed segment's CubeGraph
    index (the union of every layer's edges), re-indexed to the live-row
    subset that :meth:`SealedSegment.live_snapshot` returns.

    ``nbrs`` is ``[n_live, deg] int32`` (-1 padded; neighbors pointing at
    deleted rows are dropped — dead rows stay routable inside the segment's
    own index but a packed graph block only carries live rows, whose meta
    the traversal kernel's predicate sees).  ``entries`` is ``[e] int32``
    live-local entry ids — the per-cube entry points of the index's layers
    (capped at ``_MAX_SEED_ENTRIES``), i.e. the seeds the stitched
    cross-segment traversal starts this segment's component from.
    """

    nbrs: np.ndarray
    entries: np.ndarray


def grow_rows(need: int, *pairs):
    """Amortized-doubling row growth for parallel arrays.

    ``pairs`` are ``(array, fill_value)``; all arrays share axis-0 length.
    Returns the grown arrays (unchanged objects if capacity suffices).
    """
    cap = len(pairs[0][0])
    if need <= cap:
        return tuple(a for a, _ in pairs)
    while cap < need:
        cap *= 2
    return tuple(
        np.concatenate([a, np.full((cap - len(a),) + a.shape[1:], fill,
                                   a.dtype)])
        for a, fill in pairs)


class PointStore:
    """Chunked append-only (vector, metadata) ledger keyed by global id.

    Since PR 2 the unified query path merges per-segment ``(gid, dist)``
    pairs directly, so this ledger is *off* the query hot path: it only
    serves point lookups (debugging, serving-side hydration) and is
    garbage-collectable.  Rows live in fixed-size chunks; :meth:`gc` frees
    every chunk whose ids are all dead (deleted or expired), which is the
    common case because gids are ingestion-ordered and retention drops
    whole time ranges.
    """

    def __init__(self, d: int, m: int, chunk: int = 4096):
        self.d = int(d)
        self.m = int(m)
        self.chunk = max(int(chunk), 16)
        self._chunks: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.n_total = 0                 # ids handed out so far

    def append(self, x: np.ndarray, s: np.ndarray) -> np.ndarray:
        """Append a batch of rows; returns their (sequential) global ids."""
        x = np.atleast_2d(np.asarray(x, np.float32))
        s = np.atleast_2d(np.asarray(s, np.float64))
        n_add = x.shape[0]
        gids = np.arange(self.n_total, self.n_total + n_add, dtype=np.int64)
        lo = 0
        while lo < n_add:
            gid = int(gids[lo])
            ci, off = divmod(gid, self.chunk)
            if ci not in self._chunks:
                self._chunks[ci] = (np.zeros((self.chunk, self.d), np.float32),
                                    np.zeros((self.chunk, self.m), np.float64))
            take = min(self.chunk - off, n_add - lo)
            cx, cs = self._chunks[ci]
            cx[off:off + take] = x[lo:lo + take]
            cs[off:off + take] = s[lo:lo + take]
            lo += take
        self.n_total += n_add
        return gids

    def get(self, gids: Sequence[int]
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Rows by global id -> ``(x, s, present)``; ``present`` is False
        (and the row zero) for ids whose chunk has been freed."""
        g = np.asarray(gids, np.int64)
        x = np.zeros((len(g), self.d), np.float32)
        s = np.zeros((len(g), self.m), np.float64)
        present = np.zeros(len(g), bool)
        ci_of = g // self.chunk
        for ci in np.unique(ci_of):
            if int(ci) not in self._chunks:
                continue
            sel = np.nonzero(ci_of == ci)[0]
            cx, cs = self._chunks[int(ci)]
            off = g[sel] - ci * self.chunk
            x[sel] = cx[off]
            s[sel] = cs[off]
            present[sel] = True
        return x, s, present

    def dead_chunks(self, alive: np.ndarray) -> np.ndarray:
        """Resident chunk indices with no live id left (GC candidates).

        ``alive`` is the manager's per-gid liveness mask (length
        ``n_total``).  Split out from :meth:`gc` so the persistence layer
        can WAL-log exactly which chunks a GC pass freed and replay the
        same frees deterministically at restore.
        """
        out = []
        for ci in sorted(self._chunks):
            lo = ci * self.chunk
            hi = min(lo + self.chunk, self.n_total)
            if hi <= lo or not alive[lo:hi].any():
                out.append(ci)
        return np.asarray(out, np.int64)

    def free_chunks(self, chunk_ids: Sequence[int]) -> int:
        """Release the given resident chunks (O(1) each, no copying);
        returns #rows freed.  Unknown / already-freed ids are ignored."""
        freed = 0
        for ci in np.asarray(chunk_ids, np.int64):
            ci = int(ci)
            if ci not in self._chunks:
                continue
            lo = ci * self.chunk
            hi = min(lo + self.chunk, self.n_total)
            freed += max(hi - lo, 0)
            del self._chunks[ci]
        return freed

    def gc(self, alive: np.ndarray) -> int:
        """Free every chunk with no live id left; returns #rows freed.

        Whole-chunk freeing mirrors the segment-granular retention design:
        gids are ingestion-ordered, so retention retires contiguous id
        ranges and their chunks empty out together.
        """
        return self.free_chunks(self.dead_chunks(alive))

    @property
    def resident_points(self) -> int:
        """Rows currently backed by an allocated chunk."""
        out = 0
        for ci in self._chunks:
            out += min(self.chunk, self.n_total - ci * self.chunk)
        return out

    @property
    def nbytes(self) -> int:
        """Host bytes held by resident chunks."""
        return sum(cx.nbytes + cs.nbytes for cx, cs in self._chunks.values())


@dataclasses.dataclass
class SegmentQueryStats:
    """Per-segment accounting for one fan-out query (returned to callers)."""

    segment_id: int
    kind: str                   # "delta" | "sealed"
    n_live: int
    t_min: float
    t_max: float
    pruned: bool = False        # skipped by temporal range pruning
    search_ms: float = 0.0


def scan_filtered_topk(queries: np.ndarray, xl: np.ndarray, sl: np.ndarray,
                       gl: np.ndarray, filt: Optional[Filter], k: int,
                       metric: str = "l2") -> Tuple[np.ndarray, np.ndarray]:
    """Exact filtered top-k over copied live rows -> padded global-id
    blocks ``(gids [b, k], dists [b, k])`` — the shared scan behind both
    the mutable :class:`DeltaBuffer` and its frozen :class:`DeltaSnapshot`.
    """
    b = np.atleast_2d(queries).shape[0]
    if len(gl) == 0:
        return (np.full((b, k), -1, np.int64),
                np.full((b, k), np.inf, np.float32))
    ids, dd = filtered_topk(np.atleast_2d(queries), xl, sl, filt,
                            min(k, len(gl)), metric=metric)
    ids = np.asarray(ids)
    dd = np.asarray(dd, np.float32)
    out_i = np.full((b, k), -1, np.int64)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i[:, : ids.shape[1]] = np.where(ids >= 0, gl[np.maximum(ids, 0)], -1)
    out_d[:, : ids.shape[1]] = np.where(ids >= 0, dd, np.inf)
    return out_i, out_d


@dataclasses.dataclass
class DeltaSnapshot:
    """Frozen copy of a delta buffer's live rows.

    Taken under the manager lock (:meth:`DeltaBuffer.freeze`) and scanned
    lock-free afterwards, so a query never observes a concurrent append
    resizing the buffer's arrays or a seal resetting them mid-scan.  Time
    bounds cover the *live* rows only (lazily deleted stragglers cannot be
    returned, so they need not widen the pruning window).
    """

    x: np.ndarray                # [n_live, d] copied live vectors
    s: np.ndarray                # [n_live, m] copied live metadata
    gids: np.ndarray             # [n_live] global ids
    t_min: float
    t_max: float

    @property
    def n_live(self) -> int:
        """Live rows captured by this snapshot."""
        return len(self.gids)

    def query(self, queries: np.ndarray, filt: Optional[Filter], k: int,
              metric: str = "l2") -> Tuple[np.ndarray, np.ndarray]:
        """Exact filtered top-k over the frozen rows (global ids)."""
        return scan_filtered_topk(queries, self.x, self.s, self.gids, filt,
                                  k, metric=metric)

    def stats(self, segment_id: int = -1) -> SegmentQueryStats:
        """Fresh per-query accounting row for this snapshot."""
        return SegmentQueryStats(segment_id=segment_id, kind="delta",
                                 n_live=self.n_live, t_min=self.t_min,
                                 t_max=self.t_max)


class DeltaBuffer:
    """Append-only write buffer with lazy deletion and exact filtered top-k.

    Arrays grow amortized-doubling; deletes flip a validity mask.  Queries
    scan only live rows through ``filtered_topk`` (kernel path when the
    filter encodes, jnp fallback otherwise), so delta answers are exact.
    Concurrent readers must go through :meth:`freeze` (under the owner's
    lock) — the buffer itself is not safe to scan while appends run.
    """

    def __init__(self, d: int, m: int, time_dim: int, capacity: int = 1024):
        self.d = int(d)
        self.m = int(m)
        self.time_dim = int(time_dim)
        cap = max(int(capacity), 16)
        self.x = np.zeros((cap, d), np.float32)
        self.s = np.zeros((cap, m), np.float64)
        self.gids = np.full(cap, -1, np.int64)
        self.valid = np.zeros(cap, bool)
        self.size = 0
        self.t_min = np.inf
        self.t_max = -np.inf

    def __len__(self) -> int:
        return self.size

    @property
    def n_live(self) -> int:
        """Rows appended and not yet deleted/expired."""
        return int(self.valid[: self.size].sum())

    def append(self, x: np.ndarray, s: np.ndarray, gids: np.ndarray) -> None:
        """Append rows (vectors, metadata, their global ids) to the tail."""
        x = np.asarray(x, np.float32)
        s = np.asarray(s, np.float64)
        n_add = x.shape[0]
        self.x, self.s, self.gids, self.valid = grow_rows(
            self.size + n_add, (self.x, 0.0), (self.s, 0.0),
            (self.gids, -1), (self.valid, False))
        lo = self.size
        self.x[lo:lo + n_add] = x
        self.s[lo:lo + n_add] = s
        self.gids[lo:lo + n_add] = np.asarray(gids, np.int64)
        self.valid[lo:lo + n_add] = True
        self.size += n_add
        t = s[:, self.time_dim]
        self.t_min = min(self.t_min, float(t.min()))
        self.t_max = max(self.t_max, float(t.max()))

    def delete(self, gids: Sequence[int]) -> int:
        """Flip validity for any of ``gids`` present here; returns #hits."""
        if self.size == 0:
            return 0
        hit = np.isin(self.gids[: self.size], np.asarray(gids, np.int64))
        hit &= self.valid[: self.size]
        self.valid[: self.size][hit] = False
        return int(hit.sum())

    def expire_before(self, cutoff: float) -> np.ndarray:
        """Invalidate live rows with timestamp < cutoff; returns their
        global ids (so the caller can retire them in its liveness ledger)."""
        if self.size == 0:
            return np.empty(0, np.int64)
        old = self.valid[: self.size] & (self.s[: self.size, self.time_dim]
                                         < cutoff)
        self.valid[: self.size][old] = False
        return self.gids[: self.size][old].copy()

    def live_points(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(x, s, gids) of live rows — copied, safe to hand to a builder."""
        keep = np.nonzero(self.valid[: self.size])[0]
        return (self.x[keep].copy(), self.s[keep].copy(),
                self.gids[keep].copy())

    def freeze(self) -> DeltaSnapshot:
        """Copy the live rows into an immutable :class:`DeltaSnapshot`
        (call under the owning manager's lock)."""
        xl, sl, gl = self.live_points()
        t = sl[:, self.time_dim]
        return DeltaSnapshot(xl, sl, gl,
                             float(t.min()) if len(gl) else np.inf,
                             float(t.max()) if len(gl) else -np.inf)

    def reset(self) -> None:
        """Empty the buffer (after its live points were sealed away)."""
        self.valid[: self.size] = False
        self.size = 0
        self.t_min = np.inf
        self.t_max = -np.inf

    def query(self, queries: np.ndarray, filt: Optional[Filter], k: int,
              metric: str = "l2") -> Tuple[np.ndarray, np.ndarray]:
        """Exact filtered top-k over live rows -> (global ids, dists)."""
        xl, sl, gl = self.live_points()
        return scan_filtered_topk(queries, xl, sl, gl, filt, k,
                                  metric=metric)

    def stats(self, segment_id: int = -1) -> SegmentQueryStats:
        """Fresh per-query accounting row for this buffer."""
        return SegmentQueryStats(segment_id=segment_id, kind="delta",
                                 n_live=self.n_live, t_min=self.t_min,
                                 t_max=self.t_max)


class SealedSegment:
    """Immutable time-range partition backed by a ``CubeGraphIndex``.

    The index speaks segment-local ids; ``gids`` maps them back to global
    ids.  Deletion is the index's lazy validity mask; the segment itself is
    never restructured in place — compaction replaces it wholesale.
    """

    def __init__(self, seg_id: int, index: CubeGraphIndex, gids: np.ndarray,
                 time_dim: int, quant=None):
        self.seg_id = int(seg_id)
        self.index = index
        self.gids = np.asarray(gids, np.int64)
        self.time_dim = int(time_dim)
        # int8 codec payload (repro.quant.SegmentQuant, rows parallel to
        # index.x) — fit exactly once, at seal or compaction-publish, and
        # round-tripped through segment artifacts so restore never
        # re-quantizes
        self.quant = quant
        # durable-artifact bookkeeping: persistence root -> artifact dir
        # name, filled in by repro.streaming.persistence when this segment
        # is written to (or restored from) a snapshot directory
        self.artifacts: Dict[str, str] = {}
        t = self.index.s_np[:, time_dim]
        self.t_min = float(t.min()) if len(t) else np.inf
        self.t_max = float(t.max()) if len(t) else -np.inf
        # sorted view for O(log n) global -> local id translation
        self._order = np.argsort(self.gids)
        self._sorted_gids = self.gids[self._order]

    @classmethod
    def from_points(cls, seg_id: int, x: np.ndarray, s: np.ndarray,
                    gids: np.ndarray, time_dim: int,
                    cfg: CubeGraphConfig,
                    quantize: Optional[str] = None) -> "SealedSegment":
        """Build the segment's CubeGraphIndex over the given points; with
        ``quantize`` set, also fit the per-dimension scales and encode the
        int8 codec payload (this is a seal / compaction-publish — the only
        times a segment's content is written, hence the only times scales
        are fit)."""
        index = CubeGraphIndex.build(np.asarray(x, np.float32),
                                     np.asarray(s, np.float64), cfg)
        quant = None
        if quantize is not None:
            from ..quant import encode_segment
            quant = encode_segment(np.asarray(x, np.float32), quantize)
        return cls(seg_id, index, gids, time_dim, quant=quant)

    @property
    def n(self) -> int:
        """Total rows in the segment (live + lazily deleted)."""
        return self.index.n

    @property
    def n_live(self) -> int:
        """Rows not yet deleted."""
        return int(self.index.valid.sum())

    def deleted_fraction(self) -> float:
        """Fraction of this segment's rows lazily deleted so far."""
        return self.index.deleted_fraction()

    def overlaps(self, t_lo: float, t_hi: float) -> bool:
        """Whether this segment's time span intersects ``[t_lo, t_hi]``."""
        return self.t_max >= t_lo and self.t_min <= t_hi

    def live_points(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(x, s, gids) of live rows — inputs for sharding or a merge
        rebuild.  ``x``/``s`` are fresh host copies; safe to hand off."""
        keep = np.nonzero(self.index.valid)[0]
        return (np.asarray(self.index.x)[keep], self.index.s_np[keep],
                self.gids[keep])

    def locate(self, gids: Sequence[int]) -> np.ndarray:
        """Global ids -> local ids (-1 where not in this segment)."""
        g = np.asarray(gids, np.int64)
        pos = np.searchsorted(self._sorted_gids, g)
        pos_c = np.clip(pos, 0, len(self._sorted_gids) - 1)
        ok = (len(self._sorted_gids) > 0) & (self._sorted_gids[pos_c] == g)
        return np.where(ok, self._order[pos_c], -1)

    def delete(self, gids: Sequence[int]) -> int:
        """Lazy-delete by global id; returns the number present here."""
        local = self.locate(gids)
        local = local[local >= 0]
        if len(local):
            self.index.delete(local)
        return len(local)

    def live_snapshot(self, with_graph: bool = False):
        """``(x, s, gids, quant)`` of the live rows, all derived from ONE
        read of the validity mask — the input a lock-free reader (the cold
        shard-pack build) must use, so a delete racing it can never yield
        vectors and codec rows of different lengths.  ``quant`` is the
        row-subset :class:`~repro.quant.codec.SegmentQuant` payload, or
        ``None`` when the segment carries no codec.

        With ``with_graph=True`` a fifth element is appended: the
        :class:`SegmentGraph` (coarsest-layer adjacency + entry points,
        re-indexed to the same live-row subset) that the graph read path
        stages into the bucketed pack.  The default 4-tuple shape is pinned
        by callers and tests — never change it."""
        keep = np.nonzero(self.index.valid)[0]
        quant = self.quant.take(keep) if self.quant is not None else None
        out = (np.asarray(self.index.x)[keep], self.index.s_np[keep],
               self.gids[keep].copy(), quant)
        if with_graph:
            out = out + (self._live_graph(keep),)
        return out

    def _live_graph(self, keep: np.ndarray) -> SegmentGraph:
        # Flatten the hierarchical index into one navigable adjacency: the
        # union, per point, of every layer's edges (intra + cross, already
        # concatenated in all_nbrs) — coarse layers contribute the
        # long-range links greedy routing needs to cross clusters, fine
        # layers the local links that make the last hops exact.  Edges are
        # re-indexed to live-local ids; edges into deleted rows are dropped
        # (they are not packed — compaction restores their connectivity).
        inv = np.full(self.index.n, -1, np.int32)
        inv[keep] = np.arange(len(keep), dtype=np.int32)
        nb = np.concatenate([np.asarray(lg.all_nbrs)[keep]
                             for lg in self.index.layers], axis=1)
        nb = np.where(nb >= 0, inv[np.maximum(nb, 0)], -1).astype(np.int32)
        # per-row dedupe, valid edges first: sort descending so duplicates
        # are adjacent and -1 padding sinks to the tail
        nb = -np.sort(-nb, axis=1)
        dup = np.zeros_like(nb, dtype=bool)
        dup[:, 1:] = nb[:, 1:] == nb[:, :-1]
        nb = np.where(dup, -1, nb)
        nbrs = -np.sort(-nb, axis=1)
        # Entry points: the per-cube entries of EVERY layer.  Each sealed
        # segment is its own connected component inside a shared bucket
        # (edges never cross segments), and the stitched beam is shared
        # across components — sparse seeding starves all but the closest
        # component.  Dense per-cube seeds start every component's search
        # next to the query, which is what keeps stitched recall high as
        # buckets accumulate segments (one extra scored candidate per
        # nonempty cube — the planner's seed_cost term prices this).
        ents = []
        for lg in self.index.layers:
            e = np.asarray(lg.cubes.entry).reshape(-1)
            e = e[e >= 0]
            if len(e):
                ents.append(inv[e])
        entries = (np.unique(np.concatenate(ents)) if ents
                   else np.empty(0, np.int32))
        entries = entries[entries >= 0].astype(np.int32)
        if len(entries) > _MAX_SEED_ENTRIES:
            # Big (compacted) segments would otherwise contribute O(n)
            # seeds — the traversal's seed-init cost must stay bounded for
            # its latency to scale sub-linearly.  An even-stride subsample
            # keeps seeds spread across the segment; large segments mean
            # few components per bucket, so within-component navigation
            # (not seed density) carries recall there.
            idx = np.linspace(0, len(entries) - 1, _MAX_SEED_ENTRIES)
            entries = entries[idx.astype(np.int64)]
        if len(entries) == 0 and len(keep):
            # all designated entries were deleted: fall back to the first
            # few live rows so the segment stays reachable until compaction
            entries = np.arange(min(len(keep), 4), dtype=np.int32)
        return SegmentGraph(nbrs=nbrs, entries=entries)

    def compacted(self, quantize: Optional[str] = None) -> "SealedSegment":
        """GC lazy deletions: rebuild over live points (same seg id/gids).
        A quantized segment re-fits its scales over the surviving rows —
        this is a compaction publish, i.e. a content rewrite, exactly when
        the codec contract allows re-encoding.  ``quantize`` (the owner's
        configured codec) also lets a segment restored from a
        pre-quantization snapshot gain its codec at this rewrite.

        Index, gid map, and codec payload all derive from ONE
        :meth:`live_snapshot` of the validity mask: this method runs on
        the lock-free compaction execute phase, so a racing delete may
        shrink or keep the row set but can never misalign the rebuilt
        index's rows with the gids/codes (the racing delete itself is
        re-applied to the replacement at publish time)."""
        x, s, gids, _ = self.live_snapshot()
        kind = quantize if quantize is not None else \
            (self.quant.kind if self.quant is not None else None)
        quant = None
        if kind is not None:
            from ..quant import encode_segment
            quant = encode_segment(x, kind)
        index = CubeGraphIndex.build(x, s, self.index.cfg)
        return SealedSegment(self.seg_id, index, gids, self.time_dim,
                             quant=quant)

    def query(self, queries: np.ndarray, filt: Optional[Filter], k: int,
              ef: int = 64, **kw) -> Tuple[np.ndarray, np.ndarray]:
        """Graph search -> (global ids [b, k], dists [b, k]).  ``filt=None``
        becomes a pass-all box over this segment's grid bounds (the core
        index requires a predicate for planning)."""
        if filt is None:
            from ..core import BoxFilter
            g = self.index.grid
            filt = BoxFilter(lo=np.asarray(g.lo, np.float32),
                             hi=np.asarray(g.hi, np.float32))
        kw.setdefault("tie_gids", self.gids)   # stable (dist, gid) ordering
        ids, dd = self.index.query(np.atleast_2d(queries), filt, k=k, ef=ef,
                                   **kw)
        ids = np.asarray(ids)
        gids = np.where(ids >= 0, self.gids[np.maximum(ids, 0)], -1)
        return gids, np.asarray(dd, np.float32)

    def stats(self) -> SegmentQueryStats:
        """Fresh per-query accounting row for this segment."""
        return SegmentQueryStats(segment_id=self.seg_id, kind="sealed",
                                 n_live=self.n_live, t_min=self.t_min,
                                 t_max=self.t_max)
