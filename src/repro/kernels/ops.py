"""Jit'd public wrappers around the Pallas kernels (padding, filter encoding,
kernel/reference dispatch).

On this CPU container the kernels execute with ``interpret=True``; on a real
TPU set ``interpret=False`` (the kernels are written with static-shape
compare/exchange networks and 128-aligned tiles so they lower via Mosaic).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.filters import BallFilter, BoxFilter, ComposeFilter, Filter
from . import ref
from .distance import pairwise_dist_kernel_call
from .filtered_topk import filtered_topk_kernel_call

__all__ = ["pairwise_dist", "filtered_topk", "encode_filter",
           "exact_filtered_search"]

_POS = 1e30
_PAD_META = 2e30


def _pad_to(a, axis, mult, value):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


def pairwise_dist(q, x, metric: str = "l2", use_kernel: bool = True,
                  tq: int = 128, tn: int = 512, interpret: bool = True):
    """[bq, d] x [n, d] -> [bq, n] distance matrix."""
    if not use_kernel:
        return (ref.pairwise_sq_l2(q, x) if metric == "l2"
                else ref.pairwise_neg_ip(q, x))
    bq, n = q.shape[0], x.shape[0]
    q = _pad_to(_pad_to(jnp.asarray(q), 1, 128, 0.0), 0, tq, 0.0)
    x = _pad_to(_pad_to(jnp.asarray(x), 1, 128, 0.0), 0, tn, 0.0)
    out = pairwise_dist_kernel_call(q, x, metric=metric, tq=tq, tn=tn,
                                    interpret=interpret)
    return out[:bq, :n]


def encode_filter(filt: Optional[Filter], m: int,
                  mpad: int = 128) -> Optional[Tuple[str, np.ndarray]]:
    """Filter object -> (kind, packed [4, mpad] params) or None if the filter
    has no kernel encoding (the caller falls back to the jnp path)."""
    params = np.zeros((4, mpad), np.float32)
    params[0, :] = -_POS
    params[1, :] = _POS
    params[3, 0] = _POS          # ball r^2 (pass-all by default)
    params[3, 1] = 0             # ball ndim

    def put_box(lo, hi):
        params[0, :m] = np.maximum(params[0, :m], np.asarray(lo, np.float32))
        params[1, :m] = np.minimum(params[1, :m], np.asarray(hi, np.float32))

    if filt is None:
        return "none", params
    if isinstance(filt, BoxFilter):
        put_box(filt.lo, filt.hi)
        return "box", params
    if isinstance(filt, BallFilter):
        c = np.asarray(filt.center, np.float32)
        params[2, : len(c)] = c
        params[3, 0] = float(np.asarray(filt.radius)) ** 2
        params[3, 1] = len(c)
        return "ball", params
    if isinstance(filt, ComposeFilter):
        a, b, op = filt.a, filt.b, filt.op
        if (op == "andnot" and isinstance(a, BoxFilter)
                and isinstance(b, BallFilter)):
            put_box(a.lo, a.hi)
            c = np.asarray(b.center, np.float32)
            params[2, : len(c)] = c
            params[3, 0] = float(np.asarray(b.radius)) ** 2
            params[3, 1] = len(c)
            return "box_not_ball", params
        if op == "and" and isinstance(a, BallFilter) and isinstance(b, BoxFilter):
            # ball ∧ box: box goes to rows 0/1, ball to rows 2/3 with kind
            # needing both => encode as box_not_ball with inverted ball? No —
            # use a dedicated 'ball' + box composite: box rows apply in every
            # kind except 'none'/'ball'; keep jnp fallback for this one.
            return None
    return None


def filtered_topk(q, x, s, filt: Optional[Filter], k: int,
                  metric: str = "l2", use_kernel: bool = True,
                  tq: int = 64, tn: int = 256, interpret: bool = True):
    """Fused brute-force filtered top-k (exact): returns (ids [bq, k] int32
    with -1 misses, dists [bq, k] ascending)."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    bq, n = q.shape[0], x.shape[0]
    enc = encode_filter(filt, s.shape[1]) if use_kernel else None
    if enc is None:
        # jnp fallback (arbitrary Filter objects, incl. polygons)
        d = (ref.pairwise_sq_l2(q, x) if metric == "l2"
             else ref.pairwise_neg_ip(q, x))
        if filt is not None:
            ok = filt.contains(s)
            d = jnp.where(ok[None, :], d, jnp.inf)
        neg, ids = jax.lax.top_k(-d, k)
        dd = -neg
        return jnp.where(jnp.isfinite(dd), ids, -1), dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qp = _pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0)
    xp = _pad_to(_pad_to(x, 1, 128, 0.0), 0, tn, 0.0)
    sp = _pad_to(_pad_to(s, 1, 128, 0.0), 0, tn, _PAD_META)
    dd, ids = filtered_topk_kernel_call(
        qp, xp, sp, jnp.asarray(params), kind=kind, kpad=kpad, metric=metric,
        tq=tq, tn=tn, interpret=interpret)
    return ids[:bq, :k], dd[:bq, :k]


def exact_filtered_search(q, x, s, filt: Optional[Filter], k: int,
                          metric: str = "l2", **kw):
    """Ground-truth generator: exact filtered top-k at kernel speed."""
    return filtered_topk(q, x, s, filt, k, metric=metric, **kw)
