"""Jit'd public wrappers around the Pallas kernels (padding, filter encoding,
kernel/reference dispatch).

On this CPU container the kernels execute with ``interpret=True``; on a real
TPU set ``interpret=False`` (the kernels are written with static-shape
compare/exchange networks and 128-aligned tiles so they lower via Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.filters import (BallFilter, BoxFilter, ComposeFilter, Filter,
                            IntervalFilter)
from . import ref
from .distance import pairwise_dist_kernel_call
from .filtered_topk import filtered_topk_kernel_call

__all__ = ["pairwise_dist", "filtered_topk", "next_pow2",
           "sharded_filtered_topk", "encode_filter", "exact_filtered_search",
           "PAD_META"]

_POS = 1e30
_PAD_META = 2e30
# Metadata sentinel for padding / dead rows: every filter kind (including
# "none") rejects rows whose metadata carries this value, so consumers that
# stack ragged shards can mask rows by overwriting their metadata.
PAD_META = _PAD_META


def _pad_to(a, axis, mult, value):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def next_pow2(v: int) -> int:
    """Smallest power of two >= v — the shared rounding rule behind the
    kernel's kpad padding and the shard packs' bucket-capacity classes
    (one definition, so the two shape families can't drift apart)."""
    p = 1
    while p < v:
        p *= 2
    return p


_next_pow2 = next_pow2


def pairwise_dist(q, x, metric: str = "l2", use_kernel: bool = True,
                  tq: int = 128, tn: int = 512, interpret: bool = True):
    """[bq, d] x [n, d] -> [bq, n] distance matrix."""
    if not use_kernel:
        return (ref.pairwise_sq_l2(q, x) if metric == "l2"
                else ref.pairwise_neg_ip(q, x))
    bq, n = q.shape[0], x.shape[0]
    q = _pad_to(_pad_to(jnp.asarray(q), 1, 128, 0.0), 0, tq, 0.0)
    x = _pad_to(_pad_to(jnp.asarray(x), 1, 128, 0.0), 0, tn, 0.0)
    out = pairwise_dist_kernel_call(q, x, metric=metric, tq=tq, tn=tn,
                                    interpret=interpret)
    return out[:bq, :n]


def _flatten_and(filt: Filter):
    """Flatten nested 'and' compositions into a list of leaf filters."""
    if isinstance(filt, ComposeFilter) and filt.op == "and":
        return _flatten_and(filt.a) + _flatten_and(filt.b)
    return [filt]


def encode_filter(filt: Optional[Filter], m: int,
                  mpad: int = 128) -> Optional[Tuple[str, np.ndarray]]:
    """Filter object -> (kind, packed [4, mpad] params) or None if the filter
    has no kernel encoding (the caller falls back to the jnp path).

    Box rows default to (-1e30, +1e30) per dim, so half-open intervals
    (``IntervalFilter`` with an open end) encode without a synthetic bound:
    metadata padding rows carry +2e30 and still fail every box test.
    Conjunctions of boxes/intervals fold into one box; one ball plus any
    boxes/intervals encodes as the fused ``box_ball`` kind.
    """
    params = np.zeros((4, mpad), np.float32)
    params[0, :] = -_POS
    params[1, :] = _POS
    params[3, 0] = _POS          # ball r^2 (pass-all by default)
    params[3, 1] = 0             # ball ndim

    def put_box(lo, hi):
        params[0, :m] = np.maximum(params[0, :m], np.asarray(lo, np.float32))
        params[1, :m] = np.minimum(params[1, :m], np.asarray(hi, np.float32))

    def put_interval(f: IntervalFilter) -> bool:
        if f.dim >= m:
            return False
        if f.lo is not None:
            params[0, f.dim] = max(params[0, f.dim],
                                   float(np.asarray(f.lo)))
        if f.hi is not None:
            params[1, f.dim] = min(params[1, f.dim],
                                   float(np.asarray(f.hi)))
        return True

    def put_ball(f: BallFilter):
        c = np.asarray(f.center, np.float32)
        params[2, : len(c)] = c
        params[3, 0] = float(np.asarray(f.radius)) ** 2
        params[3, 1] = len(c)

    if filt is None:
        return "none", params
    if isinstance(filt, BoxFilter):
        put_box(filt.lo, filt.hi)
        return "box", params
    if isinstance(filt, IntervalFilter):
        return ("box", params) if put_interval(filt) else None
    if isinstance(filt, BallFilter):
        put_ball(filt)
        return "ball", params
    if isinstance(filt, ComposeFilter):
        if filt.op == "andnot":
            # (boxes/intervals) \ ball
            b = filt.b
            parts = _flatten_and(filt.a)
            if isinstance(b, BallFilter) and all(
                    isinstance(p, (BoxFilter, IntervalFilter)) for p in parts):
                for p in parts:
                    if isinstance(p, BoxFilter):
                        put_box(p.lo, p.hi)
                    elif not put_interval(p):
                        return None
                put_ball(b)
                return "box_not_ball", params
            return None
        if filt.op == "and":
            parts = _flatten_and(filt)
            balls = [p for p in parts if isinstance(p, BallFilter)]
            rest = [p for p in parts if not isinstance(p, BallFilter)]
            if len(balls) > 1 or not all(
                    isinstance(p, (BoxFilter, IntervalFilter)) for p in rest):
                return None
            for p in rest:
                if isinstance(p, BoxFilter):
                    put_box(p.lo, p.hi)
                elif not put_interval(p):
                    return None
            if not balls:
                return "box", params
            put_ball(balls[0])
            return "box_ball", params
    return None


def filtered_topk(q, x, s, filt: Optional[Filter], k: int,
                  metric: str = "l2", use_kernel: bool = True,
                  tq: int = 64, tn: int = 256, interpret: bool = True):
    """Fused brute-force filtered top-k (exact): returns (ids [bq, k] int32
    with -1 misses, dists [bq, k] ascending)."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    bq, n = q.shape[0], x.shape[0]
    enc = encode_filter(filt, s.shape[1]) if use_kernel else None
    if enc is None:
        # jnp fallback (arbitrary Filter objects, incl. polygons)
        d = (ref.pairwise_sq_l2(q, x) if metric == "l2"
             else ref.pairwise_neg_ip(q, x))
        if filt is not None:
            ok = filt.contains(s)
            d = jnp.where(ok[None, :], d, jnp.inf)
        neg, ids = jax.lax.top_k(-d, k)
        dd = -neg
        return jnp.where(jnp.isfinite(dd), ids, -1), dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qp = _pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0)
    xp = _pad_to(_pad_to(x, 1, 128, 0.0), 0, tn, 0.0)
    sp = _pad_to(_pad_to(s, 1, 128, 0.0), 0, tn, _PAD_META)
    dd, ids = filtered_topk_kernel_call(
        qp, xp, sp, jnp.asarray(params), kind=kind, kpad=kpad, metric=metric,
        tq=tq, tn=tn, interpret=interpret)
    return ids[:bq, :k], dd[:bq, :k]


@functools.lru_cache(maxsize=None)
def _sharded_kernel_dispatch(kind: str, kpad: int, metric: str, tq: int,
                             tn: int, interpret: bool):
    """One jitted shard-stack dispatch per (filter kind, k, tile) config.

    The bucketed pack calls :func:`sharded_filtered_topk` once per
    capacity bucket, so the dispatch must not re-trace per call: this
    returns a single ``jax.jit``-wrapped callable whose internal cache is
    keyed on the stack *shape* — each bucket geometry compiles exactly
    once and every later call (any bucket, any epoch) reuses its
    executable.
    """
    def call(qp, xp, sp, pj):
        def one(x, s):
            return filtered_topk_kernel_call(qp, x, s, pj, kind=kind,
                                             kpad=kpad, metric=metric,
                                             tq=tq, tn=tn,
                                             interpret=interpret)
        return jax.vmap(one)(xp, sp)
    return jax.jit(call)


def sharded_filtered_topk(q, xs, ss, filt: Optional[Filter], k: int,
                          metric: str = "l2", use_kernel: bool = True,
                          tq: int = 64, tn: int = 256, interpret: bool = True,
                          m: Optional[int] = None):
    """Shard-parallel fused filtered top-k: one dispatch over a stacked shard
    axis.

    ``q`` is ``[bq, d]``; ``xs`` / ``ss`` are ``[g, n, d]`` / ``[g, n, m]``
    stacks of ``g`` equal-capacity shards (pad ragged shards with
    ``PAD_META`` metadata rows — they fail every predicate, including
    ``filt=None``).  The fused kernel is ``vmap``-ed over the shard axis, so
    the whole stack is a single jitted dispatch; placed on a mesh with a
    ``"shard"`` axis, XLA partitions that axis across devices and each
    device scans only its resident shards.

    Returns ``(ids [g, bq, k], dists [g, bq, k])`` with *shard-local* ids
    (-1 for misses) and ascending exact distances — shard results merge
    exactly because every shard computes the same per-point distance the
    monolithic kernel would.

    ``m`` is the real metadata dimension when ``ss`` arrives pre-padded to
    the 128-lane layout (filter encoding and the jnp fallback must see only
    the live columns).
    """
    q = jnp.asarray(q, jnp.float32)
    xs = jnp.asarray(xs, jnp.float32)
    ss = jnp.asarray(ss, jnp.float32)
    bq, n = q.shape[0], xs.shape[1]
    m = ss.shape[2] if m is None else int(m)
    enc = encode_filter(filt, m) if use_kernel else None
    if enc is None:
        # jnp fallback mirroring filtered_topk's (arbitrary Filter objects);
        # zero-pad q to the (possibly pre-padded) stack width — padding
        # lanes are zero in xs, so they contribute nothing to distances
        qf = _pad_to(q, 1, xs.shape[2], 0.0)

        def one(x, s):
            d = (ref.pairwise_sq_l2(qf, x) if metric == "l2"
                 else ref.pairwise_neg_ip(qf, x))
            ok = (s[:, 0] < _POS)
            if filt is not None:
                ok &= filt.contains(s[:, :m])
            d = jnp.where(ok[None, :], d, jnp.inf)
            neg, ids = jax.lax.top_k(-d, min(k, n))
            dd = -neg
            return jnp.where(jnp.isfinite(dd), ids, -1), dd
        ids, dd = jax.vmap(one)(xs, ss)
        return ids, dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qp = _pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0)
    xp = _pad_to(_pad_to(xs, 2, 128, 0.0), 1, tn, 0.0)
    sp = _pad_to(_pad_to(ss, 2, 128, 0.0), 1, tn, _PAD_META)
    pj = jnp.asarray(params)
    dd, ids = _sharded_kernel_dispatch(kind, kpad, metric, tq, tn,
                                       interpret)(qp, xp, sp, pj)
    return ids[:, :bq, :k], dd[:, :bq, :k]


def exact_filtered_search(q, x, s, filt: Optional[Filter], k: int,
                          metric: str = "l2", **kw):
    """Ground-truth generator: exact filtered top-k at kernel speed."""
    return filtered_topk(q, x, s, filt, k, metric=metric, **kw)
