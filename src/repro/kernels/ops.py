"""Jit'd public wrappers around the Pallas kernels (padding, filter encoding,
kernel/reference dispatch).

On this CPU container the kernels execute with ``interpret=True``; on a real
TPU set ``interpret=False`` (the kernels are written with static-shape
compare/exchange networks and 128-aligned tiles so they lower via Mosaic).
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.filters import (BallFilter, BoxFilter, ComposeFilter, Filter,
                            IntervalFilter)
from . import ref
from .distance import pairwise_dist_kernel_call
from .filtered_topk import filtered_topk_kernel_call
from .quant_topk import quant_filtered_topk_kernel_call

__all__ = ["pairwise_dist", "filtered_topk", "next_pow2", "round_up",
           "sharded_filtered_topk", "sharded_filtered_topk_grouped",
           "sharded_quant_filtered_topk",
           "quant_meta_rows", "warm_sharded_shapes", "dispatch_trace_count",
           "encode_filter", "exact_filtered_search", "PAD_META"]

_POS = 1e30
_PAD_META = 2e30
# Metadata sentinel for padding / dead rows: every filter kind (including
# "none") rejects rows whose metadata carries this value, so consumers that
# stack ragged shards can mask rows by overwriting their metadata.
PAD_META = _PAD_META


def _pad_to(a, axis, mult, value):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def next_pow2(v: int) -> int:
    """Smallest power of two >= v — the shared rounding rule behind the
    kernel's kpad padding and the shard packs' bucket-capacity classes
    (one definition, so the two shape families can't drift apart)."""
    p = 1
    while p < v:
        p *= 2
    return p


def round_up(v: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= max(v, 1) — the shared
    round-up-to-tile rule for lane/sublane padding, bucket row capacities,
    and warm-compile block shapes (one definition across kernels and the
    shard packs)."""
    return ((max(v, 1) + mult - 1) // mult) * mult


_next_pow2 = next_pow2


def pairwise_dist(q, x, metric: str = "l2", use_kernel: bool = True,
                  tq: int = 128, tn: int = 512, interpret: bool = True):
    """[bq, d] x [n, d] -> [bq, n] distance matrix."""
    if not use_kernel:
        return (ref.pairwise_sq_l2(q, x) if metric == "l2"
                else ref.pairwise_neg_ip(q, x))
    bq, n = q.shape[0], x.shape[0]
    q = _pad_to(_pad_to(jnp.asarray(q), 1, 128, 0.0), 0, tq, 0.0)
    x = _pad_to(_pad_to(jnp.asarray(x), 1, 128, 0.0), 0, tn, 0.0)
    out = pairwise_dist_kernel_call(q, x, metric=metric, tq=tq, tn=tn,
                                    interpret=interpret)
    return out[:bq, :n]


def _flatten_and(filt: Filter):
    """Flatten nested 'and' compositions into a list of leaf filters."""
    if isinstance(filt, ComposeFilter) and filt.op == "and":
        return _flatten_and(filt.a) + _flatten_and(filt.b)
    return [filt]


def encode_filter(filt: Optional[Filter], m: int,
                  mpad: int = 128) -> Optional[Tuple[str, np.ndarray]]:
    """Filter object -> (kind, packed [4, mpad] params) or None if the filter
    has no kernel encoding (the caller falls back to the jnp path).

    Box rows default to (-1e30, +1e30) per dim, so half-open intervals
    (``IntervalFilter`` with an open end) encode without a synthetic bound:
    metadata padding rows carry +2e30 and still fail every box test.
    Conjunctions of boxes/intervals fold into one box; one ball plus any
    boxes/intervals encodes as the fused ``box_ball`` kind.
    """
    params = np.zeros((4, mpad), np.float32)
    params[0, :] = -_POS
    params[1, :] = _POS
    params[3, 0] = _POS          # ball r^2 (pass-all by default)
    params[3, 1] = 0             # ball ndim

    def put_box(lo, hi):
        params[0, :m] = np.maximum(params[0, :m], np.asarray(lo, np.float32))
        params[1, :m] = np.minimum(params[1, :m], np.asarray(hi, np.float32))

    def put_interval(f: IntervalFilter) -> bool:
        if f.dim >= m:
            return False
        if f.lo is not None:
            params[0, f.dim] = max(params[0, f.dim],
                                   float(np.asarray(f.lo)))
        if f.hi is not None:
            params[1, f.dim] = min(params[1, f.dim],
                                   float(np.asarray(f.hi)))
        return True

    def put_ball(f: BallFilter):
        c = np.asarray(f.center, np.float32)
        params[2, : len(c)] = c
        params[3, 0] = float(np.asarray(f.radius)) ** 2
        params[3, 1] = len(c)

    if filt is None:
        return "none", params
    if isinstance(filt, BoxFilter):
        put_box(filt.lo, filt.hi)
        return "box", params
    if isinstance(filt, IntervalFilter):
        return ("box", params) if put_interval(filt) else None
    if isinstance(filt, BallFilter):
        put_ball(filt)
        return "ball", params
    if isinstance(filt, ComposeFilter):
        if filt.op == "andnot":
            # (boxes/intervals) \ ball
            b = filt.b
            parts = _flatten_and(filt.a)
            if isinstance(b, BallFilter) and all(
                    isinstance(p, (BoxFilter, IntervalFilter)) for p in parts):
                for p in parts:
                    if isinstance(p, BoxFilter):
                        put_box(p.lo, p.hi)
                    elif not put_interval(p):
                        return None
                put_ball(b)
                return "box_not_ball", params
            return None
        if filt.op == "and":
            parts = _flatten_and(filt)
            balls = [p for p in parts if isinstance(p, BallFilter)]
            rest = [p for p in parts if not isinstance(p, BallFilter)]
            if len(balls) > 1 or not all(
                    isinstance(p, (BoxFilter, IntervalFilter)) for p in rest):
                return None
            for p in rest:
                if isinstance(p, BoxFilter):
                    put_box(p.lo, p.hi)
                elif not put_interval(p):
                    return None
            if not balls:
                return "box", params
            put_ball(balls[0])
            return "box_ball", params
    return None


def filtered_topk(q, x, s, filt: Optional[Filter], k: int,
                  metric: str = "l2", use_kernel: bool = True,
                  tq: int = 64, tn: int = 256, interpret: bool = True):
    """Fused brute-force filtered top-k (exact): returns (ids [bq, k] int32
    with -1 misses, dists [bq, k] ascending)."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    bq, n = q.shape[0], x.shape[0]
    enc = encode_filter(filt, s.shape[1]) if use_kernel else None
    if enc is None:
        # jnp fallback (arbitrary Filter objects, incl. polygons)
        d = (ref.pairwise_sq_l2(q, x) if metric == "l2"
             else ref.pairwise_neg_ip(q, x))
        if filt is not None:
            ok = filt.contains(s)
            d = jnp.where(ok[None, :], d, jnp.inf)
        neg, ids = jax.lax.top_k(-d, k)
        dd = -neg
        return jnp.where(jnp.isfinite(dd), ids, -1), dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qp = _pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0)
    xp = _pad_to(_pad_to(x, 1, 128, 0.0), 0, tn, 0.0)
    sp = _pad_to(_pad_to(s, 1, 128, 0.0), 0, tn, _PAD_META)
    dd, ids = filtered_topk_kernel_call(
        qp, xp, sp, jnp.asarray(params), kind=kind, kpad=kpad, metric=metric,
        tq=tq, tn=tn, interpret=interpret)
    return ids[:bq, :k], dd[:bq, :k]


# ---------------------------------------------------------------------------
# Shard-stack dispatch: jit caches, trace accounting, compile warming
# ---------------------------------------------------------------------------
_TRACE_COUNT = [0]               # bumped at *trace* time of any dispatch
_WARM_SIGS: "OrderedDict[tuple, None]" = OrderedDict()
_WARM_SIGS_MAX = 16
_WARM_LOCK = threading.Lock()


def dispatch_trace_count() -> int:
    """How many shard-stack dispatch traces have run in this process —
    a test/benchmark observable for the compile-warming path (a warmed
    shape must not trace again when the first real query hits it)."""
    return _TRACE_COUNT[0]


def _note_warm_sig(key: tuple) -> None:
    """Remember a dispatch signature (filter kind, k, tiles, padded query
    block, stack geometry) so :func:`warm_sharded_shapes` can replay it
    against a freshly grown bucket shape.  Bounded LRU: only the most
    recent signatures matter — they are what the next query will use."""
    with _WARM_LOCK:
        _WARM_SIGS[key] = None
        _WARM_SIGS.move_to_end(key)
        while len(_WARM_SIGS) > _WARM_SIGS_MAX:
            _WARM_SIGS.popitem(last=False)


def _mesh_placed(arr, mesh):
    """Pin ``arr`` with the shard-axis sharding the bucketed pack's
    ``_place`` uses for its device blocks (mirrored here because jit
    caches per input *sharding*: warming with unsharded zeros would
    compile an executable a mesh-placed query never hits)."""
    if mesh is not None and int(arr.shape[0]) % mesh.devices.size == 0:
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P("shard", *([None] * (arr.ndim - 1)))
        return jax.device_put(arr, NamedSharding(mesh, spec))
    return arr


def warm_sharded_shapes(specs) -> int:
    """Pre-trace the per-bucket kernel dispatch for freshly allocated /
    doubled bucket-block shapes, off the query path.

    ``specs`` is an iterable of dicts describing device blocks the pack
    just created: ``{"mode": "fp32", "rows", "cap", "dpad", "mesh"}`` or
    ``{"mode": "int8", "rows", "cap", "dq", "mq", "mesh"}``.  For every
    recorded dispatch signature (captured from real queries) whose
    geometry matches, the jitted dispatch is invoked once on zero arrays
    of the new shape, built and placed exactly the way the real wrappers
    build theirs (same padding helpers, same mesh sharding) so the jit
    cache entry is the one the first post-growth query will hit (the
    exp12 residual-spike fix).  Returns the number of dispatches warmed.
    """
    with _WARM_LOCK:
        sigs = list(_WARM_SIGS)
    warmed = 0
    for spec in specs:
        rows, cap = int(spec["rows"]), int(spec["cap"])
        mesh = spec.get("mesh")
        for sig in sigs:
            mode, kind, kpad, metric, tq, tn, interpret, bq_pad = sig[:8]
            if mode != spec.get("mode", "fp32"):
                continue
            if mode == "fp32":
                dpad = sig[8]
                if dpad != int(spec["dpad"]):
                    continue
                qp = jnp.zeros((bq_pad, dpad), jnp.float32)
                x0 = _mesh_placed(jnp.zeros((rows, cap, dpad), jnp.float32),
                                  mesh)
                s0 = _mesh_placed(jnp.full((rows, cap, 128), _PAD_META,
                                           jnp.float32), mesh)
                xp = _pad_to(x0, 1, tn, 0.0)
                sp = _pad_to(s0, 1, tn, _PAD_META)
                pj = jnp.zeros((4, 128), jnp.float32)
                _sharded_kernel_dispatch(kind, kpad, metric, tq, tn,
                                         interpret)(qp, xp, sp, pj)
            else:
                dq, mq = sig[8], sig[9]
                if dq != int(spec["dq"]) or mq != int(spec["mq"]):
                    continue
                sc = _mesh_placed(jnp.zeros((rows, dq), jnp.float32), mesh)
                # reproduce the wrapper's scale-fold so the product array
                # carries the same (propagated) sharding as a real query's
                qs = _pad_to(jnp.zeros((bq_pad, dq), jnp.float32)[None]
                             * sc[:, None, :], 1, tq, 0.0)
                c0 = _mesh_placed(jnp.zeros((rows, dq, cap), jnp.int8),
                                  mesh)
                st0 = _mesh_placed(jnp.full((rows, mq, cap), _PAD_META,
                                            jnp.float32), mesh)
                cp = _pad_to(c0, 2, tn, 0)
                stp = _pad_to(st0, 2, tn, _PAD_META)
                pt = jnp.zeros((4, mq), jnp.float32)
                qn = jnp.zeros((bq_pad,), jnp.float32)
                _sharded_quant_dispatch(kind, kpad, metric, tq, tn,
                                        interpret)(qs, cp, stp, pt, qn)
            warmed += 1
    return warmed


@functools.lru_cache(maxsize=None)
def _sharded_kernel_dispatch(kind: str, kpad: int, metric: str, tq: int,
                             tn: int, interpret: bool):
    """One jitted shard-stack dispatch per (filter kind, k, tile) config.

    The bucketed pack calls :func:`sharded_filtered_topk` once per
    capacity bucket, so the dispatch must not re-trace per call: this
    returns a single ``jax.jit``-wrapped callable whose internal cache is
    keyed on the stack *shape* — each bucket geometry compiles exactly
    once and every later call (any bucket, any epoch) reuses its
    executable.
    """
    def call(qp, xp, sp, pj):
        _TRACE_COUNT[0] += 1             # python side-effect: trace time only
        def one(x, s):
            return filtered_topk_kernel_call(qp, x, s, pj, kind=kind,
                                             kpad=kpad, metric=metric,
                                             tq=tq, tn=tn,
                                             interpret=interpret)
        return jax.vmap(one)(xp, sp)
    return jax.jit(call)


def sharded_filtered_topk(q, xs, ss, filt: Optional[Filter], k: int,
                          metric: str = "l2", use_kernel: bool = True,
                          tq: int = 64, tn: int = 256, interpret: bool = True,
                          m: Optional[int] = None):
    """Shard-parallel fused filtered top-k: one dispatch over a stacked shard
    axis.

    ``q`` is ``[bq, d]``; ``xs`` / ``ss`` are ``[g, n, d]`` / ``[g, n, m]``
    stacks of ``g`` equal-capacity shards (pad ragged shards with
    ``PAD_META`` metadata rows — they fail every predicate, including
    ``filt=None``).  The fused kernel is ``vmap``-ed over the shard axis, so
    the whole stack is a single jitted dispatch; placed on a mesh with a
    ``"shard"`` axis, XLA partitions that axis across devices and each
    device scans only its resident shards.

    Returns ``(ids [g, bq, k], dists [g, bq, k])`` with *shard-local* ids
    (-1 for misses) and ascending exact distances — shard results merge
    exactly because every shard computes the same per-point distance the
    monolithic kernel would.

    ``m`` is the real metadata dimension when ``ss`` arrives pre-padded to
    the 128-lane layout (filter encoding and the jnp fallback must see only
    the live columns).
    """
    q = jnp.asarray(q, jnp.float32)
    xs = jnp.asarray(xs, jnp.float32)
    ss = jnp.asarray(ss, jnp.float32)
    bq, n = q.shape[0], xs.shape[1]
    m = ss.shape[2] if m is None else int(m)
    enc = encode_filter(filt, m) if use_kernel else None
    if enc is None:
        # jnp fallback mirroring filtered_topk's (arbitrary Filter objects);
        # zero-pad q to the (possibly pre-padded) stack width — padding
        # lanes are zero in xs, so they contribute nothing to distances
        qf = _pad_to(q, 1, xs.shape[2], 0.0)

        def one(x, s):
            d = (ref.pairwise_sq_l2(qf, x) if metric == "l2"
                 else ref.pairwise_neg_ip(qf, x))
            ok = (s[:, 0] < _POS)
            if filt is not None:
                ok &= filt.contains(s[:, :m])
            d = jnp.where(ok[None, :], d, jnp.inf)
            neg, ids = jax.lax.top_k(-d, min(k, n))
            dd = -neg
            return jnp.where(jnp.isfinite(dd), ids, -1), dd
        ids, dd = jax.vmap(one)(xs, ss)
        return ids, dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qp = _pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0)
    xp = _pad_to(_pad_to(xs, 2, 128, 0.0), 1, tn, 0.0)
    sp = _pad_to(_pad_to(ss, 2, 128, 0.0), 1, tn, _PAD_META)
    pj = jnp.asarray(params)
    _note_warm_sig(("fp32", kind, kpad, metric, tq, tn, interpret,
                    int(qp.shape[0]), int(qp.shape[1])))
    dd, ids = _sharded_kernel_dispatch(kind, kpad, metric, tq, tn,
                                       interpret)(qp, xp, sp, pj)
    return ids[:, :bq, :k], dd[:, :bq, :k]


@functools.lru_cache(maxsize=None)
def _grouped_kernel_dispatch(kind: str, kpad: int, metric: str, tq: int,
                             tn: int, interpret: bool):
    """Multi-group sibling of :func:`_sharded_kernel_dispatch`: one jitted
    dispatch that vmaps the fused kernel over a *group* axis of
    ``(queries, filter params)`` pairs on top of the usual shard axis, so a
    heterogeneous-filter batch scans a bucket's device block once instead
    of once per distinct filter.  Groups sharing a dispatch must share the
    static config (filter kind, kpad, tiles) — the wrappers class groups by
    exactly that key."""
    def call(qps, xp, sp, pjs):
        _TRACE_COUNT[0] += 1             # python side-effect: trace time only
        def per_group(qp, pj):
            def one(x, s):
                return filtered_topk_kernel_call(qp, x, s, pj, kind=kind,
                                                 kpad=kpad, metric=metric,
                                                 tq=tq, tn=tn,
                                                 interpret=interpret)
            return jax.vmap(one)(xp, sp)
        return jax.vmap(per_group)(qps, pjs)
    return jax.jit(call)


def sharded_filtered_topk_grouped(groups, xs, ss, metric: str = "l2",
                                  use_kernel: bool = True, tq: int = 64,
                                  tn: int = 256, interpret: bool = True,
                                  m: Optional[int] = None):
    """Heterogeneous-filter shard-stack scan: several ``(q, filt, k)``
    request groups against ONE ``[g, n, d]`` / ``[g, n, m]`` shard stack.

    ``groups`` is a sequence of ``(q [bq_i, d], filt_i, k_i)`` tuples.
    Groups whose filters share a kernel encoding class — same filter
    ``kind`` and same ``kpad = next_pow2(max(k, 8))`` — are stacked on a
    *group* axis (queries padded to the widest group's padded row count,
    one packed ``[4, 128]`` parameter block per group) and dispatched as a
    single vmapped kernel call per class, so the stack's device blocks are
    read once per class instead of once per request group.  Singleton
    classes and groups whose filters have no kernel encoding go through
    :func:`sharded_filtered_topk` unchanged.

    Returns a list of ``(ids [g, bq_i, k_i], dists [g, bq_i, k_i])``
    aligned with ``groups``.  Each entry is **bit-for-bit** what
    ``sharded_filtered_topk(q_i, xs, ss, filt_i, k_i)`` returns alone: the
    kernel computes every query row independently (zero-padded rows and
    sibling groups cannot perturb a row's distances), and a class shares
    the per-group static config with the solo dispatch, so the vmapped
    call runs the identical computation per group.
    """
    groups = list(groups)
    xs = jnp.asarray(xs, jnp.float32)
    ss = jnp.asarray(ss, jnp.float32)
    m = ss.shape[2] if m is None else int(m)
    out: list = [None] * len(groups)
    classes: "OrderedDict[tuple, list]" = OrderedDict()
    for i, (q, filt, k) in enumerate(groups):
        enc = encode_filter(filt, m) if use_kernel else None
        if enc is None:
            out[i] = sharded_filtered_topk(
                q, xs, ss, filt, int(k), metric=metric,
                use_kernel=use_kernel, tq=tq, tn=tn, interpret=interpret,
                m=m)
            continue
        kind, params = enc
        kpad = _next_pow2(max(int(k), 8))
        classes.setdefault((kind, kpad), []).append((i, q, params, int(k)))
    for (kind, kpad), members in classes.items():
        if len(members) == 1:
            i, q, _, k = members[0]
            out[i] = sharded_filtered_topk(
                q, xs, ss, groups[i][1], k, metric=metric, tq=tq, tn=tn,
                interpret=interpret, m=m)
            continue
        tnk = max(tn, kpad)
        qps, bqs = [], []
        for _, q, _, _ in members:
            q = jnp.asarray(q, jnp.float32)
            bqs.append(q.shape[0])
            qps.append(_pad_to(_pad_to(q, 1, 128, 0.0), 0, tq, 0.0))
        bq_pad = max(qp.shape[0] for qp in qps)
        qps = jnp.stack([qp if qp.shape[0] == bq_pad
                         else jnp.pad(qp, ((0, bq_pad - qp.shape[0]),
                                           (0, 0)))
                         for qp in qps])
        pjs = jnp.stack([jnp.asarray(p) for _, _, p, _ in members])
        xp = _pad_to(_pad_to(xs, 2, 128, 0.0), 1, tnk, 0.0)
        sp = _pad_to(_pad_to(ss, 2, 128, 0.0), 1, tnk, _PAD_META)
        dd, ids = _grouped_kernel_dispatch(kind, kpad, metric, tq, tnk,
                                           interpret)(qps, xp, sp, pjs)
        for gi, (i, _, _, k) in enumerate(members):
            out[i] = (ids[gi, :, :bqs[gi], :k], dd[gi, :, :bqs[gi], :k])
    return out


def quant_meta_rows(m: int) -> int:
    """Transposed-metadata sublane count for ``m`` real metadata dims:
    ``m`` dims plus one sublane for the dequantized squared norm, rounded
    up to the fp32 sublane tile (8) — the shared rule between the quant
    kernel layout and the bucketed pack's quantized blocks."""
    return round_up(int(m) + 1, 8)


@functools.lru_cache(maxsize=None)
def _sharded_quant_dispatch(kind: str, kpad: int, metric: str, tq: int,
                            tn: int, interpret: bool):
    """Quantized sibling of :func:`_sharded_kernel_dispatch`: one jitted
    int8 shard-stack dispatch per (filter kind, k, tile) config, vmapped
    over the shard axis, with the per-query ``||q||^2`` term folded back
    into the L2 distances so they are comparable with exact fp32 blocks
    (up to quantization error)."""
    def call(qs, cs, sts, pt, qn):
        _TRACE_COUNT[0] += 1             # python side-effect: trace time only
        def one(q1, c1, s1):
            return quant_filtered_topk_kernel_call(
                q1, c1, s1, pt, kind=kind, kpad=kpad, metric=metric,
                tq=tq, tn=tn, interpret=interpret)
        dd, ids = jax.vmap(one)(qs, cs, sts)
        if metric == "l2":
            dd = jnp.where(jnp.isfinite(dd), dd + qn[None, :, None], dd)
        return dd, ids
    return jax.jit(call)


def sharded_quant_filtered_topk(q, codes, st, scales, filt: Optional[Filter],
                                k: int, metric: str = "l2",
                                use_kernel: bool = True, tq: int = 64,
                                tn: int = 256, interpret: bool = True,
                                m: Optional[int] = None):
    """Shard-parallel fused *asymmetric-distance* filtered top-k over int8
    segment codes.

    ``q`` is ``[bq, d]`` fp32; ``codes`` / ``st`` / ``scales`` are
    ``[g, dq, n]`` int8 / ``[g, mq, n]`` fp32 / ``[g, dq]`` fp32 stacks of
    ``g`` equal-capacity shards in the transposed quant layout
    (``dq = ceil(d / 32) * 32`` code sublanes, ``mq = quant_meta_rows(m)``
    metadata sublanes whose last row carries the dequantized squared
    norms; padding columns hold ``PAD_META`` metadata and fail every
    predicate).  Per shard the scale vector is folded into the query
    (``(q * scale) . codes == q . dequantize(codes)``) so the database is
    only ever touched at int8 — 4x fewer HBM bytes on the scan.

    Returns ``(ids [g, bq, k], dists [g, bq, k])`` with shard-local column
    ids (-1 for misses) and ascending distances equal to the exact fp32
    distance against the *dequantized* vectors — an over-fetched candidate
    list for the downstream exact rerank (``repro.quant.rerank``), merged
    exactly like the fp32 shard lists.

    ``m`` is the real metadata dimension and is required (``st`` is always
    padded, so it cannot be inferred): filter encoding and the jnp
    fallback must see only the live sublanes.
    """
    if m is None:
        # st always arrives padded to quant_meta_rows(m) sublanes, so the
        # real metadata dimension cannot be inferred from its shape (the
        # fp32 sibling's ss may be unpadded, hence its optional m)
        raise ValueError("sharded_quant_filtered_topk requires m= (the "
                         "real metadata dimension)")
    q = jnp.asarray(q, jnp.float32)
    codes = jnp.asarray(codes, jnp.int8)
    st = jnp.asarray(st, jnp.float32)
    scales = jnp.asarray(scales, jnp.float32)
    bq, d = q.shape
    g, dq, n = codes.shape
    mq = st.shape[1]
    m = int(m)
    qd = jnp.pad(q, ((0, 0), (0, dq - d))) if dq > d else q[:, :dq]
    qn = jnp.sum(q * q, axis=1)
    qs = qd[None, :, :] * scales[:, None, :]        # scale-folded queries
    enc = encode_filter(filt, m) if use_kernel else None
    if enc is None:
        # jnp fallback mirroring sharded_filtered_topk's (arbitrary Filter
        # objects, incl. polygons) over dequantized distances
        def one(qs_g, c_g, st_g):
            cf = c_g.astype(jnp.float32)
            ip = qs_g @ cf
            if metric == "l2":
                dmat = st_g[-1, :][None, :] - 2.0 * ip + qn[:, None]
            else:
                dmat = -ip
            ok = st_g[0, :] < _POS
            if filt is not None:
                ok &= filt.contains(st_g[:m, :].T)
            dmat = jnp.where(ok[None, :], dmat, jnp.inf)
            neg, ids = jax.lax.top_k(-dmat, min(k, n))
            dd = -neg
            return jnp.where(jnp.isfinite(dd), ids, -1), dd
        ids, dd = jax.vmap(one)(qs, codes, st)
        return ids, dd
    kind, params = enc
    kpad = _next_pow2(max(k, 8))
    tn = max(tn, kpad)
    qsp = _pad_to(qs, 1, tq, 0.0)
    cp = _pad_to(codes, 2, tn, 0)
    stp = _pad_to(st, 2, tn, _PAD_META)
    qnp = _pad_to(qn, 0, tq, 0.0)
    pt = jnp.asarray(params[:, :mq])
    _note_warm_sig(("int8", kind, kpad, metric, tq, tn, interpret,
                    int(qsp.shape[1]), dq, mq))
    dd, ids = _sharded_quant_dispatch(kind, kpad, metric, tq, tn,
                                      interpret)(qsp, cp, stp, pt, qnp)
    return ids[:, :bq, :k], dd[:, :bq, :k]


def exact_filtered_search(q, x, s, filt: Optional[Filter], k: int,
                          metric: str = "l2", **kw):
    """Ground-truth generator: exact filtered top-k at kernel speed."""
    return filtered_topk(q, x, s, filt, k, metric=metric, **kw)
