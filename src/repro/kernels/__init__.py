"""Pallas TPU kernels for CubeGraph's compute hot-spots (validated in
interpret mode on CPU; see DESIGN.md §2.2).

- ``distance``      tiled pairwise distance matrix (MXU contraction)
- ``filtered_topk`` fused distance + spatio-temporal predicate + streaming
                    top-k (the paper's Fig. 3 aligned-traversal loop)
- ``quant_topk``    fused *asymmetric-distance* filtered top-k over int8
                    segment codes (scale-folded fp32 query × int8 codes)
- ``ref``           pure-jnp oracles
- ``ops``           jit'd wrappers with padding + filter encoding, plus
                    the dispatch compile-warming registry
"""
from .ops import (PAD_META, dispatch_trace_count, exact_filtered_search,
                  filtered_topk, next_pow2, pairwise_dist, quant_meta_rows,
                  round_up, sharded_filtered_topk,
                  sharded_filtered_topk_grouped,
                  sharded_quant_filtered_topk, warm_sharded_shapes)

__all__ = ["PAD_META", "dispatch_trace_count", "exact_filtered_search",
           "filtered_topk", "next_pow2", "pairwise_dist", "quant_meta_rows",
           "round_up", "sharded_filtered_topk",
           "sharded_filtered_topk_grouped",
           "sharded_quant_filtered_topk", "warm_sharded_shapes"]
