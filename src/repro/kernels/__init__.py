"""Pallas TPU kernels for CubeGraph's compute hot-spots (validated in
interpret mode on CPU; see DESIGN.md §2.2).

- ``distance``      tiled pairwise distance matrix (MXU contraction)
- ``filtered_topk`` fused distance + spatio-temporal predicate + streaming
                    top-k (the paper's Fig. 3 aligned-traversal loop)
- ``ref``           pure-jnp oracles
- ``ops``           jit'd wrappers with padding + filter encoding
"""
from .ops import (PAD_META, exact_filtered_search, filtered_topk, next_pow2,
                  pairwise_dist, sharded_filtered_topk)

__all__ = ["PAD_META", "exact_filtered_search", "filtered_topk", "next_pow2",
           "pairwise_dist", "sharded_filtered_topk"]
