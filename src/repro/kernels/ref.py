"""Pure-jnp oracles for the Pallas kernels (the correctness references).

Every kernel in this package is validated against these functions across
shape/dtype sweeps in ``tests/test_kernels.py`` (interpret=True on CPU).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["pairwise_sq_l2", "pairwise_neg_ip", "filter_mask_ref",
           "filtered_topk_ref"]


def pairwise_sq_l2(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """[bq, d] x [n, d] -> squared L2 distances [bq, n] (fp32 accumulation)."""
    q = jnp.asarray(q)
    x = jnp.asarray(x)
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    ip = jnp.matmul(q, x.T, preferred_element_type=jnp.float32)
    return qn[:, None] - 2.0 * ip + xn[None, :]


def pairwise_neg_ip(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Negated inner product (so smaller = more similar), fp32 accumulation."""
    return -jnp.matmul(q, x.T, preferred_element_type=jnp.float32)


def filter_mask_ref(s: jnp.ndarray, kind: str, params: jnp.ndarray) -> jnp.ndarray:
    """Evaluate the packed filter encoding used by the fused kernel.

    ``params`` layout (rows of a [4, m] fp32 array):
      row 0: box lo       row 1: box hi
      row 2: ball center  row 3: [radius^2, ball_ndim, 0, ...]
    kinds: 'none' | 'box' | 'ball' | 'box_not_ball' | 'box_ball'
    """
    s = jnp.asarray(s, jnp.float32)
    m = s.shape[-1]
    in_box = jnp.all((s >= params[0, :m]) & (s <= params[1, :m]), axis=-1)
    mc = params[3, 1].astype(jnp.int32)
    dim_mask = jnp.arange(m) < mc
    d2 = jnp.sum(jnp.where(dim_mask, (s - params[2, :m]) ** 2, 0.0), axis=-1)
    in_ball = d2 <= params[3, 0]
    if kind == "none":
        return jnp.ones(s.shape[:-1], bool)
    if kind == "box":
        return in_box
    if kind == "ball":
        return in_ball
    if kind == "box_not_ball":
        return in_box & ~in_ball
    if kind == "box_ball":
        return in_box & in_ball
    raise ValueError(kind)


def filtered_topk_ref(q, x, s, kind: str, params, k: int, metric: str = "l2"):
    """Fused filtered exact top-k oracle.

    Returns (dists [bq, k] ascending, ids [bq, k]); failing candidates get
    +inf / -1.
    """
    d = pairwise_sq_l2(q, x) if metric == "l2" else pairwise_neg_ip(q, x)
    ok = filter_mask_ref(s, kind, jnp.asarray(params, jnp.float32))
    d = jnp.where(ok[None, :], d, jnp.inf)
    import jax
    neg, ids = jax.lax.top_k(-d, k)
    dd = -neg
    return dd, jnp.where(jnp.isfinite(dd), ids, -1)


def flash_decode_ref(q, k, v, lengths):
    """Oracle for the fused decode-attention kernel.
    q [bkv, g, hd], k/v [bkv, smax, hd], lengths [bkv] (inclusive prefix)."""
    qf = jnp.asarray(q, jnp.float32)
    kf = jnp.asarray(k, jnp.float32)
    vf = jnp.asarray(v, jnp.float32)
    hd = q.shape[-1]
    scores = jnp.einsum("bgd,bsd->bgs", qf, kf) / jnp.sqrt(hd)
    col = jnp.arange(k.shape[1])[None, None, :]
    scores = jnp.where(col <= lengths[:, None, None], scores, -1e30)
    import jax
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgs,bsd->bgd", attn, vf).astype(q.dtype)
