"""Pallas beam-step kernel + stitched per-bucket graph traversal.

The graph read path for sealed segments (ROADMAP item 1, paper §4.3): a
bucketed shard pack can carry, next to its fp32 or int8 scan blocks, a
``[rows, cap, degp]`` adjacency block of *flattened bucket positions*
(``row * cap + col``) staged from each sealed segment's coarsest CubeGraph
layer.  This module traverses that block with a batched best-first beam
search whose hot step — neighbor-candidate distance + fused predicate mask
— is a Pallas kernel in the spirit of ``kernels/filtered_topk.py``:

  1. the traced outer loop (``lax.while_loop``, fixed-shape state exactly
     like ``core/search.py``) gathers the top-W frontier's neighbor
     positions and their vectors/metadata from the bucket block;
  2. the kernel scores the gathered ``[b, c, d]`` candidate tile on the
     MXU and evaluates the packed filter predicate on the VPU, emitting
     raw distances (for routing) and the predicate mask (for collection)
     in one pass;
  3. beam and result merges are masked top-k over fixed shapes.

Stitching rule: the beam is seeded with the union of entry points of every
temporally active segment in the bucket (``bucket_graph_seeds``), so a
bucket holding many segments is traversed in ONE pass — routing is
"all"-style inside the bucket (dead points were dropped at pack staging;
edges never cross segment boundaries, seeds are what stitch components),
while collection applies the predicate φ.

Quantized buckets traverse the same way: candidates are dequantized on
gather (``codes * scales``) and the kernel recomputes their norms, so one
kernel serves both layouts; the caller reranks quantized results exactly
at fp32, exactly as on the scan path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .filtered_topk import _filter_mask
from .ops import encode_filter, next_pow2

__all__ = ["beam_step_scores", "bucket_graph_topk"]

_MPAD = 128                      # metadata lane padding (kernel layout)
_TQ = 8                          # query-tile rows per kernel program
INF = jnp.float32(np.inf)


def _beam_step_kernel(q_ref, cx_ref, cm_ref, p_ref, od_ref, ok_ref,
                      *, metric, kind):
    """One beam step's fused score: q [tq, dp], candidates cx [tq, c, dp]
    with metadata cm [tq, c, mpad] and packed filter p [4, mpad] ->
    raw distances od [tq, c] + predicate mask ok [tq, c] (int32 0/1).
    Distances are *unmasked* (routing ignores φ); the caller combines both
    outputs for collection."""
    q = q_ref[...]
    cx = cx_ref[...]
    tq, c, _ = cx.shape
    ip = jax.lax.dot_general(cx, q, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)  # [tq, c]
    if metric == "l2":
        qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=1)
        xn = jnp.sum(cx.astype(jnp.float32) ** 2, axis=2)
        d = xn - 2.0 * ip + qn[:, None]
    else:
        d = -ip
    cm = cm_ref[...].reshape(tq * c, -1)
    ok = _filter_mask(cm, p_ref[...], kind).reshape(tq, c)
    od_ref[...] = d
    ok_ref[...] = ok.astype(jnp.int32)


def beam_step_scores(q, cand_x, cand_meta, params, *, kind: str,
                     metric: str = "l2", interpret: bool = True):
    """Score one gathered candidate tile.  ``q [b, dp]`` (b % 8 == 0),
    ``cand_x [b, c, dp]``, ``cand_meta [b, c, mpad]``, ``params [4, mpad]``
    -> ``(dists [b, c] fp32 raw, ok [b, c] int32 predicate mask)``.
    Traced — safe to call from inside a ``lax.while_loop`` body."""
    from jax.experimental import pallas as pl
    b, c, dp = cand_x.shape
    mpad = cand_meta.shape[-1]
    grid = (b // _TQ,)
    kern = functools.partial(_beam_step_kernel, metric=metric, kind=kind)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_TQ, dp), lambda i: (i, 0)),
            pl.BlockSpec((_TQ, c, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((_TQ, c, mpad), lambda i: (i, 0, 0)),
            pl.BlockSpec((4, mpad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((_TQ, c), lambda i: (i, 0)),
            pl.BlockSpec((_TQ, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c), jnp.float32),
            jax.ShapeDtypeStruct((b, c), jnp.int32),
        ],
        interpret=interpret,
    )(q, cand_x, cand_meta, params)


def _score_candidates_jnp(q, cx, cm, params, *, kind: str, metric: str):
    """Pure-jnp twin of :func:`beam_step_scores` — the same dot_general /
    norm / ``_filter_mask`` math, inlined into the traced traversal loop.

    On CPU the Pallas kernel only runs in interpret mode, and a traversal
    makes one kernel call *per hop* (30-50 sequential calls), so interpret
    overhead dominates end-to-end latency by orders of magnitude; this
    twin compiles into the ``while_loop`` body as ordinary XLA.  Real
    accelerator backends keep the fused kernel (``use_pallas``)."""
    b, c, _ = cx.shape
    ip = jax.lax.dot_general(cx, q, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    if metric == "l2":
        qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=1)
        xn = jnp.sum(cx.astype(jnp.float32) ** 2, axis=2)
        d = xn - 2.0 * ip + qn[:, None]
    else:
        d = -ip
    ok = _filter_mask(cm.reshape(b * c, -1), params, kind).reshape(b, c)
    return d, ok.astype(jnp.int32)


def _unique_mask(ids):
    # first occurrence of each id per row (candidate dedupe), [b, c] bool
    order = jnp.argsort(ids, axis=1)
    sorted_ids = jnp.take_along_axis(ids, order, axis=1)
    first = jnp.concatenate(
        [jnp.ones_like(sorted_ids[:, :1], bool),
         sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=1)
    out = jnp.zeros_like(first)
    b = ids.shape[0]
    return out.at[jnp.arange(b)[:, None], order].set(first)


def _merge_topk(ids_a, d_a, ids_b, d_b, k):
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    nd, sel = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(ids, sel, axis=1), -nd


@functools.partial(jax.jit, static_argnames=(
    "k", "ef", "width", "max_iters", "kind", "metric", "m", "quantized",
    "interpret", "use_pallas"))
def _traverse(q, gids, nbrs, x, s, codes, st, scales, params, seeds,
              k, ef, width, max_iters, kind, metric, m, quantized,
              interpret, use_pallas):
    """Stitched best-first traversal over one bucket block.  All shapes are
    static per (bucket geometry, seed pad, k/ef/width) so repeat dispatches
    hit the jit cache.  Returns (positions [b, k], dists [b, k], hops)."""
    rows, cap = gids.shape
    b = q.shape[0]
    npos = rows * cap
    # ef-wide internal result list (classic ef-search): terminating against
    # the k-th result alone is too greedy and costs recall; the caller gets
    # the top-k slice of the ef-wide list
    kc = max(k, ef)

    def gather_score(pos):                 # pos [b, c] flattened positions
        safe = jnp.maximum(pos, 0)
        rv, cv = safe // cap, safe % cap
        gid = gids[rv, cv]                             # [b, c]
        if quantized:
            cx = codes[rv, :, cv].astype(jnp.float32) * scales[rv]
            meta = st[rv, :, cv]                       # [b, c, mq]
            mq = meta.shape[-1]
            cm = jnp.zeros(meta.shape[:2] + (_MPAD,), jnp.float32)
            cm = cm.at[..., :mq].set(meta)
        else:
            cx = x[rv, cv]                             # [b, c, dp]
            cm = s[rv, cv]                             # [b, c, mpad]
        if use_pallas:
            d, ok = beam_step_scores(q, cx, cm, params, kind=kind,
                                     metric=metric, interpret=interpret)
        else:
            d, ok = _score_candidates_jnp(q, cx, cm, params, kind=kind,
                                          metric=metric)
        return gid, d, ok.astype(bool)

    # ---- init from the stitched seed set (shared across the batch) -------
    S = seeds.shape[0]
    seed_b = jnp.broadcast_to(seeds[None, :], (b, S))
    gid0, d0, ok0 = gather_score(seed_b)
    valid0 = (seed_b >= 0) & (gid0 >= 0) & _unique_mask(seed_b)
    droute0 = jnp.where(valid0, d0, INF)
    dres0 = jnp.where(valid0 & ok0, d0, INF)

    visited = jnp.zeros((b, npos), bool)
    visited = visited.at[:, jnp.maximum(seeds, 0)].max(
        jnp.broadcast_to(seeds >= 0, (b, S)))

    pad_i = jnp.full((b, ef), -1, jnp.int32)
    pad_d = jnp.full((b, ef), INF)
    beam_pos, beam_d = _merge_topk(
        pad_i, pad_d, jnp.where(valid0, seed_b, -1), droute0, ef)
    beam_exp = jnp.zeros((b, ef), bool)
    res_pos, res_d = _merge_topk(
        jnp.full((b, kc), -1, jnp.int32), jnp.full((b, kc), INF),
        jnp.where(jnp.isfinite(dres0), seed_b, -1), dres0, kc)

    state = (beam_pos, beam_d, beam_exp, res_pos, res_d, visited,
             jnp.int32(0))

    def cond(st_):
        beam_pos, beam_d, beam_exp, _, res_d, _, it = st_
        frontier = jnp.where(beam_exp | (beam_pos < 0), INF, beam_d)
        best = jnp.min(frontier, axis=1)
        return (it < max_iters) & jnp.any(best < res_d[:, kc - 1])

    def body(st_):
        beam_pos, beam_d, beam_exp, res_pos, res_d, visited, it = st_
        frontier = jnp.where(beam_exp | (beam_pos < 0), INF, beam_d)
        kth = res_d[:, kc - 1]
        negd, sel = jax.lax.top_k(-frontier, width)
        exp_ok = (-negd) < kth[:, None]                # only expand improving
        exp_pos = jnp.where(
            exp_ok, jnp.take_along_axis(beam_pos, sel, axis=1), -1)
        beam_exp = beam_exp.at[jnp.arange(b)[:, None], sel].set(True)

        safe = jnp.maximum(exp_pos, 0)
        nb = nbrs[safe // cap, safe % cap]             # [b, w, degp]
        nb = jnp.where(exp_pos[:, :, None] >= 0, nb, -1)
        cand = nb.reshape(b, -1)

        gid, d, ok = gather_score(cand)
        fresh = (cand >= 0) & (gid >= 0)
        fresh &= ~jnp.take_along_axis(visited, jnp.maximum(cand, 0), axis=1)
        fresh &= _unique_mask(cand)
        droute = jnp.where(fresh, d, INF)
        dres = jnp.where(fresh & ok, d, INF)
        visited = visited.at[
            jnp.arange(b)[:, None], jnp.maximum(cand, 0)].max(fresh)

        ids2 = jnp.concatenate([beam_pos, jnp.where(fresh, cand, -1)],
                               axis=1)
        dd2 = jnp.concatenate([beam_d, droute], axis=1)
        ee2 = jnp.concatenate([beam_exp, jnp.zeros_like(cand, bool)], axis=1)
        ndd, sel2 = jax.lax.top_k(-dd2, ef)
        take = lambda a: jnp.take_along_axis(a, sel2, axis=1)
        beam_pos, beam_d, beam_exp = take(ids2), -ndd, take(ee2)

        res_pos, res_d = _merge_topk(
            res_pos, res_d, jnp.where(jnp.isfinite(dres), cand, -1), dres,
            kc)
        return (beam_pos, beam_d, beam_exp, res_pos, res_d, visited, it + 1)

    final = jax.lax.while_loop(cond, body, state)
    res_pos, res_d, hops = final[3], final[4], final[6]
    res_pos = jnp.where(jnp.isfinite(res_d), res_pos, -1)
    # deterministic (dist, gid) output ordering — same invariant as the
    # scan path's host_topk merge
    safe = jnp.maximum(res_pos, 0)
    g = jnp.where(res_pos >= 0, gids[safe // cap, safe % cap], -1)
    key = jnp.where(g >= 0, g, jnp.iinfo(jnp.int32).max)
    order = jnp.lexsort((key, res_d), axis=-1)
    g = jnp.take_along_axis(g, order, axis=1)[:, :k]
    res_d = jnp.take_along_axis(res_d, order, axis=1)[:, :k]
    return g, res_d, hops


def bucket_graph_topk(queries, bv, seeds, filt, k: int, *, m: int,
                      metric: str = "l2", ef: int = 64, width: int = 4,
                      max_iters: int = 128, interpret: bool = True,
                      use_pallas: Optional[bool] = None
                      ) -> Optional[Tuple[np.ndarray, np.ndarray, int]]:
    """Traverse one bucket's stitched graph block.

    ``queries [b, d]``; ``bv`` a ``BucketView`` carrying ``nbrs``;
    ``seeds`` the flattened positions from ``bucket_graph_seeds``; ``m``
    the true metadata width.  Returns ``(gids [b, k] int64 with -1
    misses, dists [b, k] fp32 ascending, hops)`` — fp32 buckets emit exact
    distances, quantized buckets emit asymmetric-distance candidates the
    caller must rerank.  Returns ``None`` when the filter has no kernel
    encoding or the bucket has no usable graph/seeds (caller falls back to
    the scan path).  ``use_pallas`` (default: only on real accelerator
    backends) picks the fused kernel vs. its pure-jnp twin for hop
    scoring — interpret-mode Pallas pays per-call overhead once per hop,
    which dominates traversal latency on CPU."""
    if bv.nbrs is None or len(seeds) == 0:
        return None
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    enc = encode_filter(filt, m)
    if enc is None:
        return None
    kind, params = enc
    q = np.atleast_2d(np.asarray(queries, np.float32))
    b, d = q.shape
    quantized = bv.quantized
    dp = int(bv.codes.shape[1]) if quantized else int(bv.x.shape[2])
    qp = np.zeros((-(-b // _TQ) * _TQ, dp), np.float32)
    qp[:b, :d] = q
    sp = np.full(next_pow2(max(len(seeds), 4)), -1, np.int64)
    sp[: len(seeds)] = seeds
    k = int(k)
    ef = max(int(ef), k)
    g, dd, hops = _traverse(
        jnp.asarray(qp), bv.gids, bv.nbrs,
        bv.x, bv.s, bv.codes, bv.st, bv.scales,
        jnp.asarray(params), jnp.asarray(sp, jnp.int32),
        k, ef, int(width), int(max_iters), kind, metric, int(m),
        quantized, bool(interpret), bool(use_pallas))
    return (np.asarray(g[:b], np.int64), np.asarray(dd[:b], np.float32),
            int(hops))
