"""Pallas TPU kernel: tiled pairwise distance matrix (MXU contraction).

Computes squared-L2 (or negated inner-product) distances between a query
block and the candidate set, tiled so each grid step's working set
(``[tq, d] + [tn, d] + [tq, tn]``) stays in VMEM with 128-aligned matmul
dims.  Used by graph construction (exact kNN candidate generation) and by
the brute-force scan path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_dist_kernel_call"]


def _dist_kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...]
    x = x_ref[...]
    ip = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if metric == "l2":
        qf = q.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        qn = jnp.sum(qf * qf, axis=1)
        xn = jnp.sum(xf * xf, axis=1)
        o_ref[...] = qn[:, None] - 2.0 * ip + xn[None, :]
    else:
        o_ref[...] = -ip


@functools.partial(jax.jit, static_argnames=("metric", "tq", "tn", "interpret"))
def pairwise_dist_kernel_call(q, x, metric: str = "l2", tq: int = 128,
                              tn: int = 512, interpret: bool = True):
    """[bq, d] x [n, d] -> [bq, n] distances via a (bq/tq, n/tn) Pallas grid.

    Inputs must be pre-padded: bq % tq == 0, n % tn == 0, d % 128 == 0
    (see ``ops.pairwise_dist`` for the padding wrapper).
    """
    bq, d = q.shape
    n = x.shape[0]
    grid = (bq // tq, n // tn)
    return pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, n), jnp.float32),
        interpret=interpret,
    )(q, x)
