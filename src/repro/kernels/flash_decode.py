"""Pallas TPU kernel: fused single-token decode attention ("flash-decode").

Hillclimb iteration L8 (EXPERIMENTS.md §Perf) showed decode_32k cells are
bound by KV-cache streaming; the unfused XLA path makes three HBM passes over
the cache slice (scores, softmax, weighted sum) plus fp32 score
materialization.  This kernel makes ONE pass: per grid step a ``[tS, hd]``
K/V tile is resident in VMEM and the running (max, sum-exp, weighted-V)
triple is updated online (streaming softmax), so the cache is read exactly
once at bf16 width.

Layout: one query vector per (batch, kv-head) pair against its cache rows —
GQA handled by evaluating the ``g`` query heads of a kv-head together
(``q [g, hd]`` block, MXU-friendly ``g x tS`` score tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode_kernel_call"]


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
            m_scr, s_scr, acc, *, ts, n_tiles, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -1e30, jnp.float32)
        s_scr[...] = jnp.zeros(s_scr.shape, jnp.float32)
        acc[...] = jnp.zeros(acc.shape, jnp.float32)

    q = q_ref[0]                                     # [g, hd]
    k = k_ref[0]                                     # [ts, hd]
    v = v_ref[0]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [g, ts]
    # mask positions beyond the filled prefix
    limit = len_ref[0, 0, 0]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + j * ts
    scores = jnp.where(col <= limit, scores, -1e30)

    m_prev = m_scr[...]                              # [g, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                  # [g, 1]
    p = jnp.exp(scores - m_new)                      # [g, ts]
    s_scr[...] = s_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_tiles - 1)
    def _emit():
        o_ref[0] = (acc[...] / s_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ts", "interpret"))
def flash_decode_kernel_call(q, k, v, lengths, *, ts: int = 512,
                             interpret: bool = True):
    """q [bkv, g, hd] (one row per (batch, kv-head); g = GQA group),
    k/v [bkv, smax, hd] cache slices, lengths [bkv] filled prefix (inclusive).
    smax % ts == 0, hd % 128 == 0, g a multiple of 8 (pad in the wrapper).
    Returns o [bkv, g, hd]."""
    bkv, g, hd = q.shape
    smax = k.shape[1]
    n_tiles = smax // ts
    scale = 1.0 / (hd ** 0.5)
    lens = lengths.reshape(bkv, 1, 1).astype(jnp.int32)
    kern = functools.partial(_kernel, ts=ts, n_tiles=n_tiles, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bkv, n_tiles),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, ts, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ts, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(bkv, g, hd), k, v, lens)
