"""Pallas TPU kernel: fused asymmetric-distance filtered top-k over int8
segment codes.

The quantized sibling of ``filtered_topk.py``: per grid step an int8
``[dq, tn]`` code tile and its fp32 ``[mq, tn]`` *transposed* metadata tile
are resident in VMEM; the kernel

  1. contracts the scale-folded fp32 query block against the raw int8
     codes on the MXU (``(q * scale) . codes == q . dequantize(codes)`` —
     the asymmetric-distance identity: the database stays int8, only the
     tiny query is touched at fp32),
  2. evaluates the same packed filter predicate as the fp32 kernel on the
     VPU (identical semantics over the transposed tile) and masks failures
     to +inf,
  3. folds the tile into a running top-k in VMEM scratch via the shared
     argmin-extraction + bitonic-merge networks of ``filtered_topk``.

Layout notes (why transposed): with points on the *lane* axis the code
tile is ``[dq, tn]`` (``dq`` = dim padded to the int8 sublane tile of 32)
and the metadata tile is ``[mq, tn]`` (``mq`` = meta dims + 1 padded to
the fp32 sublane tile of 8) — so a d=32, m=3 point costs 32 B of codes and
32 B of metadata on device instead of the fp32 layout's 512 B + 512 B.
The last metadata sublane carries the point's precomputed dequantized
squared norm (``xsq``); filter params never constrain sublanes >= m, so it
rides the predicate tile for free.  For L2 the kernel emits the partial
distance ``xsq - 2 * ip`` — the per-query constant ``||q||^2`` never
changes a row's ranking, so the wrapper adds it after the kernel to make
distances comparable with exact fp32 blocks.

Returns an *over-fetched* candidate list (the caller sizes ``kpad`` by its
rerank multiple); the exact fp32 rerank happens downstream
(``repro.quant.rerank``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .filtered_topk import _merge_sorted

__all__ = ["quant_filtered_topk_kernel_call"]

_POS = 1e30


def _filter_mask_t(meta_t, params_t, kind):
    """Transposed predicate: meta_t [mq, tn], params_t [4, mq] -> bool [tn].

    Same semantics as ``filtered_topk._filter_mask`` with points on the
    lane axis; the xsq sublane (mq - 1) passes every test because the
    packed params never constrain dims >= m (box bounds default to
    +/-1e30, the ball's ``ndim`` mask stops at the center's length).
    """
    mq = meta_t.shape[0]
    in_box = jnp.all((meta_t >= params_t[0][:, None])
                     & (meta_t <= params_t[1][:, None]), axis=0)
    mc = params_t[3, 1].astype(jnp.int32)
    dim_mask = jax.lax.broadcasted_iota(jnp.int32, (mq,), 0) < mc
    diff = meta_t - params_t[2][:, None]
    d2 = jnp.sum(jnp.where(dim_mask[:, None], diff * diff, 0.0), axis=0)
    in_ball = d2 <= params_t[3, 0]
    if kind == "none":
        # padding / dead columns carry meta = +2e30 and must still fail:
        return meta_t[0, :] < _POS
    if kind == "box":
        return in_box
    if kind == "ball":
        return in_ball
    if kind == "box_ball":
        return in_box & in_ball
    return in_box & ~in_ball                       # box_not_ball


def _quant_fused_kernel(q_ref, c_ref, st_ref, p_ref, od_ref, oi_ref,
                        run_d, run_i, *, metric, kind, kpad, tn, n_ctiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, jnp.float32)
        run_i[...] = jnp.full(run_i.shape, -1, jnp.int32)

    qs = q_ref[...]                                 # [tq, dq] scale-folded
    c = c_ref[...].astype(jnp.float32)              # [dq, tn] int8 -> f32
    st = st_ref[...]                                # [mq, tn] meta + xsq
    ip = jax.lax.dot_general(qs, c, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if metric == "l2":
        # partial asymmetric L2: ||q||^2 is added by the wrapper (a
        # per-query constant never reorders a query row's top-k)
        d = st[-1, :][None, :] - 2.0 * ip
    else:
        d = -ip

    ok = _filter_mask_t(st, p_ref[...], kind)
    d = jnp.where(ok[None, :], d, jnp.inf)

    # --- tile top-k: kpad rounds of argmin + one-hot mask (no scatter) -----
    tq = d.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (tq, tn), 1)
    base = j * tn
    tds, tis = [], []
    for _ in range(kpad):
        mn = jnp.min(d, axis=1)
        am = jnp.argmin(d, axis=1).astype(jnp.int32)
        tds.append(mn)
        tis.append(jnp.where(jnp.isfinite(mn), base + am, -1))
        d = jnp.where(col == am[:, None], jnp.inf, d)
    tile_d = jnp.stack(tds, axis=1)                 # ascending
    tile_i = jnp.stack(tis, axis=1)

    nd, ni = _merge_sorted(run_d[...], run_i[...], tile_d, tile_i)
    run_d[...] = nd
    run_i[...] = ni

    @pl.when(j == n_ctiles - 1)
    def _emit():
        od_ref[...] = run_d[...]
        oi_ref[...] = run_i[...]


@functools.partial(jax.jit, static_argnames=("metric", "kind", "kpad", "tq",
                                             "tn", "interpret"))
def quant_filtered_topk_kernel_call(qs, codes_t, st, params_t, *, kind: str,
                                    kpad: int, metric: str = "l2",
                                    tq: int = 64, tn: int = 256,
                                    interpret: bool = True):
    """Fused asymmetric-distance filtered top-k.  Pre-padded inputs:
    qs [bq, dq] fp32 scale-folded queries (bq % tq == 0), codes_t [dq, n]
    int8 (n % tn == 0), st [mq, n] transposed fp32 metadata whose last
    sublane is the dequantized squared norm (+2e30 in padding columns so
    they fail every predicate), params_t [4, mq] packed filter.  kpad
    power of two <= tn.  Returns (dists [bq, kpad] ascending — for L2
    *without* the ||q||^2 term, ids [bq, kpad], -1 for misses).
    """
    assert kpad & (kpad - 1) == 0 and kpad <= tn
    bq, dq = qs.shape
    mq, n = st.shape
    grid = (bq // tq, n // tn)
    kern = functools.partial(_quant_fused_kernel, metric=metric, kind=kind,
                             kpad=kpad, tn=tn, n_ctiles=grid[1])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, dq), lambda i, j: (i, 0)),
            pl.BlockSpec((dq, tn), lambda i, j: (0, j)),
            pl.BlockSpec((mq, tn), lambda i, j: (0, j)),
            pl.BlockSpec((4, mq), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kpad), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, kpad), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, kpad), jnp.float32),
            jax.ShapeDtypeStruct((bq, kpad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, kpad), jnp.float32),
            pltpu.VMEM((tq, kpad), jnp.int32),
        ],
        interpret=interpret,
    )(qs, codes_t, st, params_t)
