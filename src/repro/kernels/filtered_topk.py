"""Pallas TPU kernel: fused distance + spatio-temporal filter + streaming
top-k — the paper's hot loop (Fig. 3: metadata aligned with the node block so
the predicate is evaluated during traversal, not post-hoc).

Per grid step, a ``[tn, d]`` candidate-vector tile and its ``[tn, mpad]``
metadata tile are resident in VMEM; the kernel

  1. computes the query-block distances on the MXU,
  2. evaluates the packed filter predicate on the VPU and masks failures to
     +inf,
  3. folds the tile into a running top-k kept in VMEM scratch via a
     K-step argmin extraction (one-hot masking, no scatter) followed by a
     bitonic merge of two sorted-K lists — all static-shape compare/exchange
     networks, i.e. Mosaic-friendly (no data-dependent control flow).

Grid order is (query tile, candidate tile) with the candidate axis innermost:
scratch initializes at j == 0 and the result is emitted at the last j
(flash-attention-style streaming reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["filtered_topk_kernel_call", "FILTER_KINDS"]

FILTER_KINDS = ("none", "box", "ball", "box_not_ball", "box_ball")
_NEG = -1e30
_POS = 1e30


def _filter_mask(meta, params, kind):
    """meta [tn, mpad], params [4, mpad] -> bool [tn]."""
    mpad = meta.shape[-1]
    in_box = jnp.all((meta >= params[0]) & (meta <= params[1]), axis=-1)
    mc = params[3, 1].astype(jnp.int32)
    dim_mask = jax.lax.broadcasted_iota(jnp.int32, (mpad,), 0) < mc
    diff = meta - params[2]
    d2 = jnp.sum(jnp.where(dim_mask, diff * diff, 0.0), axis=-1)
    in_ball = d2 <= params[3, 0]
    if kind == "none":
        # padding rows carry meta = +2e30 and must still fail:
        return meta[:, 0] < _POS
    if kind == "box":
        return in_box
    if kind == "ball":
        return in_ball
    if kind == "box_ball":
        return in_box & in_ball
    return in_box & ~in_ball                       # box_not_ball


def _merge_sorted(run_d, run_i, tile_d, tile_i):
    """Bitonic merge of two ascending [tq, kpad] lists -> ascending top-kpad."""
    kpad = run_d.shape[1]
    comb_d = jnp.concatenate([run_d, jnp.flip(tile_d, axis=1)], axis=1)
    comb_i = jnp.concatenate([run_i, jnp.flip(tile_i, axis=1)], axis=1)
    stride = kpad
    while stride >= 1:
        tq = comb_d.shape[0]
        nb = comb_d.shape[1] // (2 * stride)
        d4 = comb_d.reshape(tq, nb, 2, stride)
        i4 = comb_i.reshape(tq, nb, 2, stride)
        a_d, b_d = d4[:, :, 0, :], d4[:, :, 1, :]
        a_i, b_i = i4[:, :, 0, :], i4[:, :, 1, :]
        swap = a_d > b_d
        lo_d = jnp.where(swap, b_d, a_d)
        hi_d = jnp.where(swap, a_d, b_d)
        lo_i = jnp.where(swap, b_i, a_i)
        hi_i = jnp.where(swap, a_i, b_i)
        comb_d = jnp.stack([lo_d, hi_d], axis=2).reshape(tq, -1)
        comb_i = jnp.stack([lo_i, hi_i], axis=2).reshape(tq, -1)
        stride //= 2
    return comb_d[:, :kpad], comb_i[:, :kpad]


def _fused_kernel(q_ref, x_ref, s_ref, p_ref, od_ref, oi_ref,
                  run_d, run_i, *, metric, kind, kpad, tn, n_ctiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, jnp.float32)
        run_i[...] = jnp.full(run_i.shape, -1, jnp.int32)

    q = q_ref[...]
    x = x_ref[...]
    ip = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if metric == "l2":
        qf = q.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        d = (jnp.sum(qf * qf, axis=1)[:, None] - 2.0 * ip
             + jnp.sum(xf * xf, axis=1)[None, :])
    else:
        d = -ip

    ok = _filter_mask(s_ref[...], p_ref[...], kind)
    d = jnp.where(ok[None, :], d, jnp.inf)

    # --- tile top-k: kpad rounds of argmin + one-hot mask (no scatter) -----
    tq = d.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (tq, tn), 1)
    base = j * tn
    tds, tis = [], []
    for _ in range(kpad):
        mn = jnp.min(d, axis=1)
        am = jnp.argmin(d, axis=1).astype(jnp.int32)
        tds.append(mn)
        tis.append(jnp.where(jnp.isfinite(mn), base + am, -1))
        d = jnp.where(col == am[:, None], jnp.inf, d)
    tile_d = jnp.stack(tds, axis=1)                       # ascending
    tile_i = jnp.stack(tis, axis=1)

    nd, ni = _merge_sorted(run_d[...], run_i[...], tile_d, tile_i)
    run_d[...] = nd
    run_i[...] = ni

    @pl.when(j == n_ctiles - 1)
    def _emit():
        od_ref[...] = run_d[...]
        oi_ref[...] = run_i[...]


@functools.partial(jax.jit, static_argnames=("metric", "kind", "kpad", "tq",
                                             "tn", "interpret"))
def filtered_topk_kernel_call(q, x, s_pad, params, *, kind: str, kpad: int,
                              metric: str = "l2", tq: int = 64, tn: int = 256,
                              interpret: bool = True):
    """Fused filtered top-k.  Pre-padded inputs:
    q [bq, d] (bq % tq == 0, d % 128 == 0), x [n, d] (n % tn == 0),
    s_pad [n, mpad] metadata padded to 128 lanes (+2e30 in padding rows so
    they fail every predicate), params [4, mpad] packed filter
    (box lo/hi, ball center, [r^2, ball_ndim]).  kpad power of two <= tn.
    Returns (dists [bq, kpad] ascending, ids [bq, kpad], -1 for misses).
    """
    assert kpad & (kpad - 1) == 0 and kpad <= tn
    bq, d = q.shape
    n, mpad = s_pad.shape
    grid = (bq // tq, n // tn)
    kern = functools.partial(_fused_kernel, metric=metric, kind=kind,
                             kpad=kpad, tn=tn, n_ctiles=grid[1])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tn, mpad), lambda i, j: (j, 0)),
            pl.BlockSpec((4, mpad), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kpad), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, kpad), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, kpad), jnp.float32),
            jax.ShapeDtypeStruct((bq, kpad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, kpad), jnp.float32),
            pltpu.VMEM((tq, kpad), jnp.int32),
        ],
        interpret=interpret,
    )(q, x, s_pad, params)
