"""Serving steps: prefill + decode with sampling, built on the model API's
KV/state caches.  ``make_serve_fns`` returns jitted callables shared by the
RAG pipeline, the continuous-batching scheduler, and the dry-run."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def sample_logits(logits: jnp.ndarray, key: jax.Array,
                  temperature: float = 0.0, top_k: int = 0) -> jnp.ndarray:
    """logits [b, 1, v] -> tokens [b, 1]."""
    lg = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    lg = lg / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1)[:, None].astype(jnp.int32)


def make_serve_fns(model, temperature: float = 0.0, top_k: int = 0):
    """Returns (prefill_fn, decode_fn):
    prefill_fn(params, tokens, cache, extra=None) -> (next_token, cache)
    decode_fn(params, token, cache, pos, key) -> (next_token, logits, cache)
    """

    @jax.jit
    def prefill_fn(params, tokens, cache, extra=None):
        if extra is not None:
            logits, cache = model.prefill(params, tokens, cache, extra)
        else:
            logits, cache = model.prefill(params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1, :].astype(jnp.float32),
                         axis=-1)[:, None].astype(jnp.int32)
        return nxt, cache

    @jax.jit
    def decode_fn(params, token, cache, pos, key):
        logits, cache = model.decode_step(params, token, cache, pos)
        nxt = sample_logits(logits, key, temperature, top_k)
        return nxt, logits, cache

    return prefill_fn, decode_fn


def generate(model, params, prompt_tokens: jnp.ndarray, max_new: int,
             max_len: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, extra=None) -> jnp.ndarray:
    """Greedy/temperature generation loop (host-driven)."""
    b, s = prompt_tokens.shape
    max_len = max_len or (s + max_new)
    cache = model.init_cache(b, max_len)
    prefill_fn, decode_fn = make_serve_fns(model, temperature)
    tok, cache = prefill_fn(params, prompt_tokens, cache, extra)
    out = [tok]
    pos = jnp.full((b,), s, jnp.int32)
    key = jax.random.key(seed)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        tok, _, cache = decode_fn(params, tok, cache, pos, sub)
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
