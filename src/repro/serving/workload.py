"""Geo-temporal traffic harness for the multi-tenant serving tier.

Drives :class:`~repro.serving.service.CubeGraphService` with the traffic
shape the paper's serving scenario describes, and measures what a real
deployment would watch:

* **moving time windows** — every step advances the stream clock; queries
  filter ``[now - window, now]``, so temporal pruning and the tiered
  prefetch predictor see a drifting window, not a static corpus;
* **skewed hot regions** — queries pick one of a few spatial hot spots
  with a Zipf-like weight (region 1 dominates), composed as a
  ``(spatial box ∧ time window)`` filter per request;
* **ingest bursts mid-query** — every ``burst_every`` steps each tenant
  ingests a burst *between* query flushes, so answers race seals and
  delta growth exactly as they would in production; a trickle of deletes
  rides along;
* **per-request SLOs** — a configurable fraction of requests carry
  ``deadline_ms``; the report separates SLO violations (answer later
  than ``slo_ms``) from degraded answers (deadline machinery skipped
  buckets).

Every answer is scored against a **numpy brute-force oracle** over the
tenant's live documents (recall@k on non-degraded answers — the exact
scan path must hold recall 1.0), and each step runs a **bit-for-bit
isolation probe**: one no-deadline request per tenant whose documents
and distances must exactly equal a dedicated single-tenant oracle
``DocumentStore`` that replayed only that tenant's writes.

``python -m repro.serving.workload --smoke`` runs a tiny configuration
and asserts the report schema (:data:`SLO_REPORT_KEYS`) — the CI hook
that keeps ``benchmarks/bench_serving.py`` (exp18) from bit-rotting.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import BoxFilter
from ..core.cubegraph import CubeGraphConfig
from ..streaming import StreamConfig
from .batching import RetrievalFailure
from .rag import Document, DocumentStore
from .service import AdmissionController, CubeGraphService, ServeRequest
from .tenancy import MultiTenantStore

__all__ = ["GeoTemporalWorkload", "SLO_REPORT_KEYS", "WorkloadConfig"]

# The report schema contract: every run() report carries exactly these
# top-level keys (plus "latency_samples" rows for the bench digest).
SLO_REPORT_KEYS = (
    "n_tenants", "n_requests", "n_answered", "recall_at_10",
    "latency_ms_p50", "latency_ms_p99", "slo_violation_fraction",
    "degraded_fraction", "rejected_fraction", "isolation_checks",
    "isolation_ok",
)

_HOT_REGIONS = ((2.0, 2.0), (7.0, 6.0), (4.5, 8.0))
_REGION_WEIGHTS = (0.65, 0.25, 0.10)        # Zipf-ish skew: one hot spot


@dataclasses.dataclass
class WorkloadConfig:
    """Knobs for one harness run (defaults: a small but non-trivial
    2-tenant run; the bench scales it up, the CI smoke scales it down)."""

    n_tenants: int = 2
    d_emb: int = 16
    m: int = 3                       # (lon, lat, t)
    n_initial: int = 300             # per-tenant corpus before traffic
    n_steps: int = 6
    queries_per_step: int = 10       # per tenant per step
    k: int = 10
    window: float = 120.0            # moving time window width
    step_dt: float = 40.0            # stream-clock advance per step
    region_half_width: float = 2.5   # spatial box half-width
    burst_every: int = 2
    burst_points: int = 48           # per tenant per burst
    deletes_per_step: int = 2        # per tenant
    deadline_ms: Optional[float] = 250.0
    deadline_fraction: float = 0.5   # fraction of requests with an SLO
    slo_ms: float = 250.0
    warmup_steps: int = 0            # steps excluded from the report
    # (first dispatches pay jit compiles; the bench warms up, the CI
    # smoke keeps 0 so the schema path is exercised end-to-end)
    seal_max_points: int = 128
    n_shards: int = 2
    seed: int = 0


class GeoTemporalWorkload:
    """Runs the configured traffic against one shared
    :class:`MultiTenantStore` + per-tenant single-tenant oracles, and
    reports recall / latency percentiles / SLO + degraded fractions /
    isolation."""

    def __init__(self, cfg: WorkloadConfig = WorkloadConfig()):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        idx_cfg = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=4)
        self._scfg = StreamConfig(time_dim=cfg.m - 1,
                                  seal_max_points=cfg.seal_max_points,
                                  n_shards=cfg.n_shards, index_cfg=idx_cfg)
        self.store = MultiTenantStore(cfg.d_emb, cfg.m,
                                      stream_cfg=self._scfg)
        self.service = CubeGraphService(
            self.store,
            AdmissionController(max_queue_per_tenant=10_000))
        self.tenants = [f"tenant{i}" for i in range(cfg.n_tenants)]
        self.oracles: Dict[str, DocumentStore] = {}
        # per tenant: ingestion-ordered (mt gid, oracle position) pairs
        self._order: Dict[str, List[int]] = {}
        self._next_doc_id = 0
        self.now = 0.0

    # -- corpus / traffic generation -----------------------------------

    def _make_docs(self, n: int) -> List[Document]:
        cfg = self.cfg
        region = self.rng.choice(len(_HOT_REGIONS), size=n,
                                 p=_REGION_WEIGHTS)
        centers = np.asarray(_HOT_REGIONS)[region]
        lonlat = centers + self.rng.normal(scale=1.5, size=(n, 2))
        ts = self.now + self.rng.uniform(0, cfg.step_dt, size=n)
        docs = []
        for i in range(n):
            docs.append(Document(
                doc_id=self._next_doc_id,
                tokens=np.arange(4, dtype=np.int32),
                embedding=self.rng.standard_normal(cfg.d_emb)
                .astype(np.float32),
                metadata=np.array([lonlat[i, 0], lonlat[i, 1],
                                   float(ts[i])])))
            self._next_doc_id += 1
        return docs

    def _ingest(self, tenant: str, docs: List[Document]) -> None:
        gids = self.store.insert(tenant, docs)
        self.oracles[tenant].insert(docs)
        self._order[tenant].extend(int(g) for g in gids)

    def _delete_some(self, tenant: str, n: int) -> None:
        coll = self.store.collection(tenant)
        live = [g for g in self._order[tenant] if g in coll.docs_by_gid]
        if len(live) <= n:
            return
        victims = list(self.rng.choice(live, size=n, replace=False))
        self.store.delete(tenant, victims)
        # oracle positions == per-tenant ingestion order
        pos = [self._order[tenant].index(g) for g in victims]
        self.oracles[tenant].delete(pos)

    def _query_filter(self) -> Tuple[BoxFilter, np.ndarray, np.ndarray]:
        cfg = self.cfg
        region = _HOT_REGIONS[self.rng.choice(len(_HOT_REGIONS),
                                              p=_REGION_WEIGHTS)]
        w = cfg.region_half_width
        lo = np.array([region[0] - w, region[1] - w,
                       self.now - cfg.window], np.float32)
        hi = np.array([region[0] + w, region[1] + w, self.now],
                      np.float32)
        return BoxFilter(lo=lo, hi=hi), lo.astype(np.float64), \
            hi.astype(np.float64)

    # -- scoring -------------------------------------------------------

    def _brute_ids(self, tenant: str, q: np.ndarray, lo, hi,
                   k: int) -> set:
        """Exact numpy oracle: doc_ids of the tenant's best-k live
        matches under the box filter (ties broken like the kernels:
        distance then insertion order)."""
        coll = self.store.collection(tenant)
        gids = sorted(coll.docs_by_gid)        # == ingestion order
        if not gids:
            return set()
        emb = np.stack([coll.docs_by_gid[g].embedding for g in gids])
        meta = np.stack([coll.docs_by_gid[g].metadata for g in gids])
        ok = np.all((meta >= lo) & (meta <= hi), axis=1)
        if not ok.any():
            return set()
        d2 = ((emb[ok].astype(np.float32) - q.astype(np.float32)) ** 2
              ).sum(axis=1)
        ids = np.asarray([coll.docs_by_gid[g].doc_id
                          for g in np.asarray(gids)[ok]])
        order = np.lexsort((ids, d2))[:k]
        return set(int(i) for i in ids[order])

    def _isolation_probe(self, tenant: str) -> bool:
        """One no-deadline request answered by the shared service must be
        bit-for-bit the single-tenant oracle store's answer."""
        cfg = self.cfg
        q = self.rng.standard_normal(cfg.d_emb).astype(np.float32)
        filt, _, _ = self._query_filter()
        ans = self.store.retrieve(tenant, q, filt, k=cfg.k)
        og, od = self.oracles[tenant].manager.query(q, filt, k=cfg.k)
        o_docs = [self.oracles[tenant].docs[i].doc_id
                  for i in np.asarray(og)[0] if i >= 0]
        m_docs = [d.doc_id for d in ans.docs[0]]
        return bool(m_docs == o_docs
                    and np.array_equal(ans.dists[0],
                                       np.asarray(od, np.float32)[0]))

    # -- the run -------------------------------------------------------

    def run(self) -> dict:
        """Execute the workload; returns the :data:`SLO_REPORT_KEYS`
        report (plus ``latency_samples`` rows for the bench digest)."""
        import time as _time
        cfg = self.cfg
        for t in self.tenants:
            self.oracles[t] = DocumentStore(
                self._make_docs(1), streaming=True,
                stream_cfg=dataclasses.replace(self._scfg))
            # DocumentStore() ingests its seed doc on construction; mirror
            # it into the shared store so both sides saw identical writes
            seed_doc = self.oracles[t].docs
            self.store.create_collection(t)
            self._order[t] = []
            gids = self.store.insert(t, seed_doc)
            self._order[t].extend(int(g) for g in gids)
            self._ingest(t, self._make_docs(cfg.n_initial - 1))

        latencies: List[float] = []
        recalls: List[float] = []
        lat_samples: List[dict] = []
        n_requests = n_rejected = n_degraded = n_violation = 0
        iso_checks, iso_ok = 0, True
        rid = 0
        pending: Dict[int, tuple] = {}

        for step in range(cfg.warmup_steps + cfg.n_steps):
            measuring = step >= cfg.warmup_steps
            self.now += cfg.step_dt
            if cfg.burst_every and step % cfg.burst_every == 1:
                for t in self.tenants:      # ingest burst mid-traffic
                    self._ingest(t, self._make_docs(cfg.burst_points))
                    self._delete_some(t, cfg.deletes_per_step)
            pending.clear()
            for t in self.tenants:
                for _ in range(cfg.queries_per_step):
                    q = self.rng.standard_normal(cfg.d_emb) \
                        .astype(np.float32)
                    filt, lo, hi = self._query_filter()
                    dl = (cfg.deadline_ms
                          if self.rng.uniform() < cfg.deadline_fraction
                          else None)
                    req = ServeRequest(req_id=rid, tenant=t, query_emb=q,
                                       filt=filt, k=cfg.k, deadline_ms=dl)
                    rid += 1
                    if measuring:
                        n_requests += 1
                    if isinstance(self.service.submit(req),
                                  RetrievalFailure):
                        n_rejected += measuring
                    else:
                        pending[req.req_id] = (t, q, lo, hi)
            t0 = _time.perf_counter()
            answers = self.service.flush()
            flush_s = _time.perf_counter() - t0
            if measuring:
                if pending:
                    lat_samples.append(
                        {"us_per_query":
                         round(flush_s / len(pending) * 1e6, 1)})
                for req_id, (t, q, lo, hi) in pending.items():
                    res = answers[req_id]
                    if isinstance(res, RetrievalFailure):
                        n_violation += 1
                        continue
                    latencies.append(res.latency_ms)
                    if res.latency_ms > cfg.slo_ms:
                        n_violation += 1
                    if res.degraded:
                        n_degraded += 1
                        continue             # recall on non-degraded only
                    want = self._brute_ids(t, q, lo, hi, cfg.k)
                    got = set(d.doc_id for d in res.docs)
                    if want:
                        recalls.append(len(got & want) / len(want))
            for t in self.tenants:           # per-step isolation probes
                iso_checks += 1
                iso_ok = self._isolation_probe(t) and iso_ok
            self.store.maintenance()
            for t in self.tenants:
                self.oracles[t].maintenance()

        lat = np.asarray(latencies if latencies else [0.0])
        return {
            "n_tenants": cfg.n_tenants,
            "n_requests": n_requests,
            "n_answered": len(latencies),
            "recall_at_10": round(float(np.mean(recalls)), 4)
            if recalls else None,
            "latency_ms_p50": round(float(np.percentile(lat, 50)), 3),
            "latency_ms_p99": round(float(np.percentile(lat, 99)), 3),
            "slo_violation_fraction": round(
                n_violation / max(n_requests, 1), 4),
            "degraded_fraction": round(
                n_degraded / max(n_requests, 1), 4),
            "rejected_fraction": round(
                n_rejected / max(n_requests, 1), 4),
            "isolation_checks": iso_checks,
            "isolation_ok": bool(iso_ok),
            "latency_samples": lat_samples,
        }


def _smoke() -> dict:
    """Tiny run asserting the report schema — the CI hook for exp18."""
    report = GeoTemporalWorkload(WorkloadConfig(
        n_initial=80, n_steps=2, queries_per_step=3, burst_points=16,
        seal_max_points=64, window=200.0)).run()
    missing = [key for key in SLO_REPORT_KEYS if key not in report]
    assert not missing, f"SLO report missing keys: {missing}"
    assert report["isolation_ok"], "tenant isolation probe failed"
    assert report["n_requests"] > 0
    return report


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        print(json.dumps(_smoke(), indent=1))
    else:
        print(json.dumps(GeoTemporalWorkload().run(), indent=1))
