"""Spatio-temporal RAG pipeline — CubeGraph's application layer (the paper's
title use case): embed query -> filtered top-k retrieval (CubeGraph) ->
context assembly -> generation on any assigned backbone.

The document store holds (embedding, metadata, token span) triples; the
query embedder is a learned linear projection stub (a real deployment plugs
in its encoder — orthogonal to the paper's contribution).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CubeGraphConfig, CubeGraphIndex, Filter
from ..obs import StreamObs, json_sanitize
from ..streaming import SegmentManager, StreamConfig
from .serve_step import generate


@dataclasses.dataclass
class Document:
    doc_id: int
    tokens: np.ndarray              # [t] int32 token span
    embedding: np.ndarray           # [d_emb]
    metadata: np.ndarray            # [m] (lon, lat, t, ...)


class RetrievedDocs(list):
    """One query's retrieved document row, carrying the streaming
    :class:`~repro.streaming.resilience.QueryResult` markers.

    Behaves exactly like the plain ``List[Document]`` it used to be
    (iteration, indexing, truthiness), plus ``degraded`` / ``reasons``:
    a deadline-bounded retrieve that ran out of budget returns its
    partial answer with ``degraded=True`` and per-reason skip counts
    instead of silently dropping the marker.  Static-index stores never
    degrade (no deadline machinery), so there ``degraded`` is always
    False.
    """

    def __init__(self, docs=(), degraded: bool = False,
                 reasons: Optional[dict] = None):
        super().__init__(docs)
        self.degraded = bool(degraded)
        self.reasons = dict(reasons or {})


class DocumentStore:
    """Filtered-retrieval store with two backends:

    * static (default): one monolithic ``CubeGraphIndex`` built up front,
      grown via incremental ``insert_batch``;
    * streaming (``streaming=True``): the LSM-style ``SegmentManager`` —
      continuous ingest, seal/compaction/TTL lifecycle, segment fan-out
      queries.  Document list positions double as global point ids.

    With ``stream_cfg.n_shards >= 1`` sealed segments are answered by the
    mesh-sharded kernel scan; pass ``shard_mesh``
    (``repro.distributed.segment_shards.make_shard_mesh()``) to spread the
    shards across a device mesh in a serving replica.  ``quantize="int8"``
    turns on the quantized read path for a streaming store (int8 sealed
    segments + exact fp32 rerank — ~4x more resident corpus per device
    byte): it overlays ``stream_cfg.quantize`` and forces the sharded read
    path on, since the quantized scan rides the bucketed shard pack.
    ``read_path="auto"|"graph"`` overlays ``stream_cfg.read_path`` the same
    way, turning on the cost-based sealed read path (scan vs. stitched
    graph traversal per bucket — ``repro.streaming.planner``), which also
    rides the bucketed pack and so forces sharding on.
    ``device_budget_bytes`` overlays ``stream_cfg.device_budget_bytes``
    (also forcing sharding on): the store's device memory becomes a
    budgeted cache over the sealed corpus — cold buckets demote to host
    arrays and stream through the same kernels exactly
    (``repro.streaming.tiering``).
    """

    def __init__(self, docs: Sequence[Document],
                 index_cfg: CubeGraphConfig = CubeGraphConfig(),
                 streaming: bool = False,
                 stream_cfg: Optional[StreamConfig] = None,
                 shard_mesh=None, quantize: Optional[str] = None,
                 read_path: Optional[str] = None,
                 device_budget_bytes: Optional[int] = None):
        self.docs = list(docs)
        self.streaming = bool(streaming)
        x = np.stack([d.embedding for d in self.docs]).astype(np.float32)
        s = np.stack([d.metadata for d in self.docs]).astype(np.float64)
        if self.streaming:
            if stream_cfg is None:
                stream_cfg = StreamConfig(index_cfg=index_cfg)
            if quantize is not None:
                stream_cfg = dataclasses.replace(
                    stream_cfg, quantize=quantize,
                    n_shards=max(stream_cfg.n_shards, 1))
            if read_path is not None:
                stream_cfg = dataclasses.replace(
                    stream_cfg, read_path=read_path,
                    n_shards=max(stream_cfg.n_shards, 1))
            if device_budget_bytes is not None:
                stream_cfg = dataclasses.replace(
                    stream_cfg, device_budget_bytes=device_budget_bytes,
                    n_shards=max(stream_cfg.n_shards, 1))
            self.manager = SegmentManager(x.shape[1], s.shape[1], stream_cfg,
                                          shard_mesh=shard_mesh)
            self.manager.ingest(x, s)
            self.index = None
        else:
            if quantize is not None:
                raise ValueError("quantize requires a streaming store "
                                 "(DocumentStore(streaming=True))")
            if read_path is not None and read_path != "scan":
                raise ValueError("read_path requires a streaming store "
                                 "(DocumentStore(streaming=True))")
            if device_budget_bytes is not None:
                raise ValueError("device_budget_bytes requires a streaming "
                                 "store (DocumentStore(streaming=True))")
            self.manager = None
            self.index = CubeGraphIndex.build(x, s, index_cfg)
        self._init_obs()

    def _init_obs(self) -> None:
        """Bind the store's metrics to its backend: a streaming store
        shares the manager's registry (serving-level request latencies land
        next to the index-level lifecycle/query metrics in one snapshot);
        a static store gets its own."""
        self.obs = self.manager.obs if self.streaming else StreamObs()
        self.metrics = self.obs.registry

    @classmethod
    def restore(cls, docs: Sequence[Document], directory: str,
                stream_cfg: Optional[StreamConfig] = None,
                shard_mesh=None, resume: bool = True) -> "DocumentStore":
        """Warm-start a streaming store from a snapshot directory instead of
        re-ingesting: the manager restores via
        ``SegmentManager.restore`` (mmapped segment artifacts + WAL-tail
        replay) and answers queries bit-for-bit identically to the replica
        that wrote the snapshot.  ``docs`` must be the same document list,
        in the same order, as when the snapshot was taken — store positions
        double as global point ids."""
        obj = cls.__new__(cls)
        obj.docs = list(docs)
        obj.streaming = True
        obj.index = None
        obj.manager = SegmentManager.restore(directory, cfg=stream_cfg,
                                             shard_mesh=shard_mesh,
                                             resume=resume)
        obj._init_obs()
        if obj.manager.n_total != len(obj.docs):
            raise ValueError(
                f"snapshot knows {obj.manager.n_total} points but "
                f"{len(obj.docs)} documents were provided — pass exactly "
                "the snapshot-time document list (insert new documents "
                "through store.insert after restoring)")
        return obj

    def snapshot_to(self, directory: str) -> dict:
        """Durably snapshot the streaming backend (see
        ``SegmentManager.snapshot_to``); static stores have nothing
        incremental to persist and should use ``core.cubegraph.save_index``
        directly."""
        if not self.streaming:
            raise ValueError("snapshot_to requires a streaming store")
        return self.manager.snapshot_to(directory)

    def retrieve(self, query_emb: np.ndarray, filt: Filter, k: int,
                 ef: int = 64, trace=None,
                 deadline_ms: Optional[float] = None
                 ) -> List[RetrievedDocs]:
        """Filtered top-k document retrieval for a query-embedding batch.

        The per-request end-to-end latency (index query + document
        materialization) lands in the ``retrieve_ms`` histogram; pass a
        ``repro.obs.trace.QueryTrace`` to additionally capture the span
        tree of the underlying streaming query.

        ``deadline_ms`` bounds the streaming query's time budget
        (see ``streaming/resilience.py``); on overrun each returned
        :class:`RetrievedDocs` row carries the partial answer with
        ``degraded=True`` and per-reason skip counts.  Static stores
        ignore the deadline (one bounded beam search; nothing to skip)."""
        t0 = time.perf_counter()
        q = np.atleast_2d(query_emb)
        degraded, reasons = False, {}
        if self.streaming:
            res = self.manager.query(q, filt, k=k, ef=ef, trace=trace,
                                     deadline_ms=deadline_ms)
            ids, _ = res
            degraded = bool(getattr(res, "degraded", False))
            reasons = dict(getattr(res, "reasons", {}) or {})
        else:
            ids, _ = self.index.query(q, filt, k=k, ef=ef)
        out = [RetrievedDocs((self.docs[i] for i in row if i >= 0),
                             degraded=degraded, reasons=reasons)
               for row in np.asarray(ids)]
        self.metrics.counter("retrieve_requests_total").inc(q.shape[0])
        self.metrics.histogram("retrieve_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return out

    def retrieve_grouped(self, requests) -> dict:
        """Continuous filtered batching over heterogeneous requests:
        answer a batch of :class:`~repro.serving.batching
        .RetrievalRequest` with *different* filters / ``k`` / deadlines
        in shared dispatches — a streaming store reads each sealed
        bucket's device block once for the whole batch
        (``SegmentManager.query_grouped``) instead of once per distinct
        filter.  Answers are bit-for-bit the per-request
        :meth:`retrieve` answers.  Returns ``{req_id: RetrievedDocs}``
        (one row per request)."""
        from .batching import _filter_key
        requests = list(requests)
        out: dict = {}
        if not requests:
            return out
        t0 = time.perf_counter()
        groups: dict = {}
        for r in requests:
            groups.setdefault(
                (_filter_key(r.filt, r.k), r.deadline_ms),
                []).append(r)
        members = list(groups.values())
        if self.streaming:
            from ..streaming import GroupQuery
            gqs = [GroupQuery(
                np.stack([r.query_emb for r in reqs]).astype(np.float32),
                reqs[0].filt, k=reqs[0].k,
                deadline_ms=reqs[0].deadline_ms) for reqs in members]
            for reqs, res in zip(members,
                                 self.manager.query_grouped(gqs)):
                ids = np.asarray(res[0])
                degraded = bool(getattr(res, "degraded", False))
                reasons = dict(getattr(res, "reasons", {}) or {})
                for r, row in zip(reqs, ids):
                    out[r.req_id] = RetrievedDocs(
                        (self.docs[i] for i in row if i >= 0),
                        degraded=degraded, reasons=reasons)
        else:
            for reqs in members:
                q = np.stack([r.query_emb
                              for r in reqs]).astype(np.float32)
                ids, _ = self.index.query(q, reqs[0].filt, k=reqs[0].k)
                for r, row in zip(reqs, np.asarray(ids)):
                    out[r.req_id] = RetrievedDocs(
                        self.docs[i] for i in row if i >= 0)
        self.metrics.counter("retrieve_requests_total").inc(len(requests))
        self.metrics.histogram("retrieve_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return out

    def metrics_snapshot(self) -> dict:
        """Strict-JSON-safe export of every metric this store touches.

        For a streaming store this is the manager's full observability
        block (lifecycle counters, per-bucket :class:`BucketStats`, WAL /
        checkpoint histograms) plus the serving-level request metrics that
        share the same registry; ``tools/obs_dump.py`` renders it as
        Prometheus text."""
        return json_sanitize(self.obs.snapshot())

    def insert(self, docs: Sequence[Document]):
        """Static: incremental graph insertion.  Streaming: delta-buffer
        ingest (seal policy may cut a new segment)."""
        x = np.stack([d.embedding for d in docs]).astype(np.float32)
        s = np.stack([d.metadata for d in docs]).astype(np.float64)
        if self.streaming:
            self.manager.ingest(x, s)
        else:
            self.index.insert_batch(x, s)
        self.docs.extend(docs)

    def delete(self, positions: Sequence[int]) -> None:
        """Lazy-delete documents by store position (== global id)."""
        if self.streaming:
            self.manager.delete(np.asarray(positions, np.int64))
        else:
            self.index.delete(positions)

    def maintenance(self, async_compaction: bool = False) -> dict:
        """Streaming lifecycle tick (seal + TTL expiry + compaction + store
        GC).  ``async_compaction`` runs the compaction rounds on the
        manager's background thread so the serving loop never blocks on an
        index rebuild."""
        if not self.streaming:
            return {}
        return self.manager.maintenance(async_compaction=async_compaction)


class RAGPipeline:
    """retrieve -> assemble -> generate."""

    SEP = 0                          # separator token id (synthetic vocab)

    def __init__(self, store: DocumentStore, model, params,
                 query_proj: Optional[np.ndarray] = None,
                 max_context: int = 512):
        self.store = store
        self.model = model
        self.params = params
        self.max_context = max_context
        d_emb = store.docs[0].embedding.shape[0]
        if query_proj is None:
            rng = np.random.default_rng(0)
            query_proj = (rng.normal(size=(model.cfg.d_model, d_emb))
                          / np.sqrt(model.cfg.d_model)).astype(np.float32)
        self.query_proj = query_proj

    def embed_query(self, query_tokens: np.ndarray) -> np.ndarray:
        """Stub encoder: mean-pooled token embeddings projected to doc space."""
        emb_table = np.asarray(
            jax.device_get(self.params["embed"]["embedding"]),
            np.float32)
        pooled = emb_table[query_tokens].mean(axis=-2)       # [.., d_model]
        return pooled @ self.query_proj                       # [.., d_emb]

    def assemble(self, docs: List[Document],
                 query_tokens: np.ndarray) -> np.ndarray:
        ctx: List[int] = []
        for d in docs:
            remaining = self.max_context - len(ctx) - len(query_tokens) - 1
            if remaining <= 0:
                break
            ctx.extend(d.tokens[:remaining].tolist())
            ctx.append(self.SEP)
        prompt = np.asarray(ctx + query_tokens.tolist(), np.int32)
        return prompt

    def answer(self, query_tokens: np.ndarray, filt: Filter, k: int = 4,
               max_new: int = 16, ef: int = 64) -> Tuple[np.ndarray, List[Document]]:
        q_emb = self.embed_query(query_tokens)
        docs = self.store.retrieve(q_emb, filt, k, ef=ef)[0]
        prompt = self.assemble(docs, query_tokens)
        out = generate(self.model, self.params, prompt[None, :],
                       max_new=max_new)
        return np.asarray(out)[0], docs
