"""Multi-tenant serving front end: admission control + continuous
filtered batching over one shared streaming substrate.

:class:`CubeGraphService` is the request loop the ROADMAP's "heavy
traffic" north-star asks for, layered over
:class:`~repro.serving.tenancy.MultiTenantStore`:

* **admission control** — :class:`AdmissionController` enforces
  per-tenant queue-depth quotas and a global in-flight cap at ``submit``
  time.  A rejected request gets an explicit
  :class:`~repro.serving.batching.RetrievalFailure` with
  ``reason="over_quota"`` (backpressure the client can see and retry on)
  and bumps ``tenant_rejected_total{tenant=...}`` — it is never silently
  dropped and never poisons the queue;

* **continuous filtered batching** — ``flush()`` drains the queue and
  generalizes :class:`~repro.serving.batching.RetrievalBatcher`: instead
  of requiring identical filter keys, heterogeneous ``(tenant, filter,
  k, deadline)`` requests become one
  :class:`~repro.streaming.GroupQuery` list answered by
  ``SegmentManager.query_grouped`` — every sealed bucket's device block
  is read ONCE for all tenants/filters active in it, and each group's
  answer is **bit-for-bit** what a solo
  ``MultiTenantStore.retrieve`` would have returned.  Per-group bucket
  observations feed each tenant's own
  :class:`~repro.obs.metrics.BucketStats`, so the cost planner's inputs
  stay tenant-attributed;

* **per-request SLOs** — each request may carry ``deadline_ms`` (PR 9's
  :class:`~repro.streaming.resilience.Deadline` machinery); an overrun
  group is dropped from *remaining* buckets only — other tenants keep
  scanning — and its answers come back with ``degraded=True`` plus
  per-reason skip counts;

* **async loop** — ``start()`` runs ``flush()`` on a supervised daemon
  thread (the manager's :class:`~repro.streaming.resilience.Supervisor`,
  so loop crashes are retried, counted, and surfaced in ``health()``
  instead of vanishing).

Failure isolation mirrors ``RetrievalBatcher``: if the shared grouped
dispatch raises, ``flush()`` falls back to per-group solo queries, each
in its own try — one poisoned filter cannot black-hole the whole flush.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from ..core import Filter
from ..streaming import GroupQuery
from .batching import RetrievalFailure, _filter_key
from .rag import Document
from .tenancy import MultiTenantStore

__all__ = ["AdmissionController", "CubeGraphService", "ServeRequest",
           "ServeResult"]


@dataclasses.dataclass
class ServeRequest:
    """One tenant retrieval request: a single query embedding plus its
    filter, fan-out, and optional per-request SLO budget."""

    req_id: int
    tenant: str
    query_emb: np.ndarray            # [d_emb]
    filt: Optional[Filter] = None
    k: int = 10
    deadline_ms: Optional[float] = None
    enqueued_at: float = 0.0         # stamped by CubeGraphService.submit


@dataclasses.dataclass
class ServeResult:
    """One answered request: materialized documents, the raw ``(gid,
    dist)`` row, degraded markers, and the measured queue-to-answer
    latency."""

    req_id: int
    tenant: str
    docs: List[Document]
    gids: np.ndarray                 # [k] int64, -1 padded
    dists: np.ndarray                # [k] fp32, +inf padded
    degraded: bool = False
    reasons: Optional[dict] = None
    latency_ms: float = 0.0


class AdmissionController:
    """Queue-depth admission: per-tenant quotas + a global cap.

    ``max_queue_per_tenant`` bounds how many requests one tenant may have
    queued (overridable per tenant via ``tenant_quotas``);
    ``max_queue_total`` bounds the whole queue.  :meth:`try_admit`
    returns ``None`` to admit or a stable rejection reason string —
    the service turns that into
    ``RetrievalFailure(reason="over_quota")`` backpressure.
    """

    def __init__(self, max_queue_per_tenant: int = 64,
                 max_queue_total: Optional[int] = None,
                 tenant_quotas: Optional[Dict[str, int]] = None):
        self.max_queue_per_tenant = int(max_queue_per_tenant)
        self.max_queue_total = (None if max_queue_total is None
                                else int(max_queue_total))
        self.tenant_quotas = dict(tenant_quotas or {})

    def try_admit(self, tenant: str, tenant_depth: int,
                  total_depth: int) -> Optional[str]:
        """``None`` = admit; otherwise the rejection reason."""
        if self.max_queue_total is not None \
                and total_depth >= self.max_queue_total:
            return "over_quota"
        quota = self.tenant_quotas.get(tenant, self.max_queue_per_tenant)
        if tenant_depth >= quota:
            return "over_quota"
        return None


class CubeGraphService:
    """The serving front end: submit -> (admission) -> queue ->
    continuous filtered batching -> per-tenant answers.

    ``flush()`` is synchronous (drain everything queued now); ``start()``
    runs it continuously on a supervised daemon thread.  Results are
    returned from ``flush()`` *and* retained in :attr:`results` keyed by
    ``req_id`` (popped by :meth:`take_result`) so async-loop clients can
    poll.  ``maintenance_every > 0`` triggers one substrate lifecycle
    tick (async compaction) every that-many flushes, exactly like
    ``RetrievalBatcher``.
    """

    def __init__(self, store: MultiTenantStore,
                 admission: Optional[AdmissionController] = None,
                 ef: int = 64, max_batch: int = 64,
                 maintenance_every: int = 0):
        self.store = store
        self.admission = admission or AdmissionController()
        self.ef = int(ef)
        self.max_batch = int(max_batch)
        self.maintenance_every = int(maintenance_every)
        self._flushes = 0
        self.queue: deque = deque()
        self.results: Dict[int, object] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.metrics = store.metrics

    # -- submission / admission ----------------------------------------

    def _depths(self) -> Dict[str, int]:
        depths: Dict[str, int] = {}
        for r in self.queue:
            depths[r.tenant] = depths.get(r.tenant, 0) + 1
        return depths

    def submit(self, req: ServeRequest) -> Optional[RetrievalFailure]:
        """Admit a request into the queue, or reject it with explicit
        backpressure: returns ``None`` when admitted, else a
        :class:`RetrievalFailure` with ``reason="over_quota"`` (also
        recorded in :attr:`results` so pollers see it)."""
        if req.tenant not in self.store.collections:
            raise KeyError(f"unknown collection {req.tenant!r}")
        with self._lock:
            depths = self._depths()
            reason = self.admission.try_admit(
                req.tenant, depths.get(req.tenant, 0), len(self.queue))
            if reason is None:
                if not req.enqueued_at:
                    req.enqueued_at = time.perf_counter()
                self.queue.append(req)
                return None
        self.metrics.counter(
            f'tenant_rejected_total{{tenant="{req.tenant}"}}').inc()
        failure = RetrievalFailure(
            req.req_id, f"tenant {req.tenant!r} queue depth exceeded",
            reason=reason)
        with self._lock:
            self.results[req.req_id] = failure
        return failure

    def __len__(self) -> int:
        return len(self.queue)

    def take_result(self, req_id: int):
        """Pop one finished request's :class:`ServeResult` /
        :class:`RetrievalFailure` (None if not finished yet)."""
        with self._lock:
            return self.results.pop(req_id, None)

    # -- the batched dispatch ------------------------------------------

    def flush(self) -> Dict[int, object]:
        """Drain the queue through ONE continuous filtered batch.

        Queued requests group by ``(tenant, filter value, k, deadline)``
        — chunked at ``max_batch`` — and every group becomes one
        tenant-scoped :class:`GroupQuery`; the whole heterogeneous batch
        then shares per-bucket device reads in a single
        ``query_grouped`` pass.  Returns (and retains in
        :attr:`results`) ``{req_id: ServeResult | RetrievalFailure}``.
        """
        with self._lock:
            drained: List[ServeRequest] = list(self.queue)
            self.queue.clear()
        out: Dict[int, object] = {}
        if drained:
            grouped: Dict[object, List[ServeRequest]] = {}
            for r in drained:
                grouped.setdefault(
                    (r.tenant, _filter_key(r.filt, r.k), r.deadline_ms),
                    []).append(r)
            chunks: List[List[ServeRequest]] = []
            for reqs in grouped.values():
                for lo in range(0, len(reqs), self.max_batch):
                    chunks.append(reqs[lo:lo + self.max_batch])
            t_flush = time.perf_counter()
            wait_hist = self.metrics.histogram("retrieval_queue_wait_ms")
            occ_hist = self.metrics.histogram("retrieval_batch_occupancy")
            for chunk in chunks:
                occ_hist.observe(len(chunk) / self.max_batch)
                for r in chunk:
                    if r.enqueued_at:
                        wait_hist.observe((t_flush - r.enqueued_at) * 1e3)
            gqs = [GroupQuery(
                np.stack([r.query_emb for r in chunk]).astype(np.float32),
                self.store.scoped_filter(chunk[0].tenant, chunk[0].filt),
                k=chunk[0].k, ef=self.ef,
                deadline_ms=chunk[0].deadline_ms) for chunk in chunks]
            stats_of = [self.store.collections[c[0].tenant].bucket_stats
                        for c in chunks]

            def observe_group(gi, cap, **kw):
                stats_of[gi].observe(cap, **kw)

            try:
                answers = self.store.manager.query_grouped(
                    gqs, observe_group=observe_group)
                for chunk, res in zip(chunks, answers):
                    self._finish_chunk(out, chunk, res, t_flush)
            except Exception:  # noqa: BLE001 — isolate per group instead
                for chunk, gq in zip(chunks, gqs):
                    try:
                        res = self.store.manager.query(
                            gq.queries, gq.filt, k=gq.k, ef=gq.ef,
                            deadline_ms=gq.deadline_ms)
                        self._finish_chunk(out, chunk, res, t_flush)
                    except Exception as exc:  # noqa: BLE001
                        self.metrics.counter(
                            "retrieval_failed_total").inc(len(chunk))
                        for r in chunk:
                            out[r.req_id] = RetrievalFailure(
                                r.req_id,
                                f"{type(exc).__name__}: {exc}")
        with self._lock:
            self.results.update(out)
        self._flushes += 1
        if (self.maintenance_every > 0
                and self._flushes % self.maintenance_every == 0):
            self.store.maintenance(async_compaction=True)
        return out

    def _finish_chunk(self, out: Dict[int, object],
                      chunk: List[ServeRequest], res, t_flush: float
                      ) -> None:
        """Split one answered group back into per-request results."""
        tenant = chunk[0].tenant
        gids = np.asarray(res[0], np.int64)
        dists = np.asarray(res[1], np.float32)
        degraded = bool(getattr(res, "degraded", False))
        reasons = dict(getattr(res, "reasons", {}) or {})
        docs = self.store.materialize(tenant, gids)
        now = time.perf_counter()
        lat_hist = self.metrics.histogram(
            f'tenant_request_ms{{tenant="{tenant}"}}')
        self.metrics.counter(
            f'tenant_requests_total{{tenant="{tenant}"}}').inc(len(chunk))
        if degraded:
            self.metrics.counter(
                f'tenant_degraded_total{{tenant="{tenant}"}}').inc(
                    len(chunk))
        for i, r in enumerate(chunk):
            lat = (now - (r.enqueued_at or t_flush)) * 1e3
            lat_hist.observe(lat)
            out[r.req_id] = ServeResult(
                req_id=r.req_id, tenant=tenant, docs=docs[i],
                gids=gids[i], dists=dists[i], degraded=degraded,
                reasons=reasons, latency_ms=lat)

    # -- async loop ----------------------------------------------------

    def start(self, interval_ms: float = 5.0) -> None:
        """Run the request loop on a supervised daemon thread: flush
        whenever work is queued, sleeping ``interval_ms`` between polls.
        Idempotent (at most one loop thread per service)."""
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                if self.queue:
                    self.flush()
                else:
                    self._stop.wait(interval_ms / 1e3)

        self.store.manager.supervisor.spawn("serving.loop", _loop)

    def stop(self) -> None:
        """Signal the async loop to exit (it drains nothing further)."""
        self._stop.set()
