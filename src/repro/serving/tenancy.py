"""Per-tenant collections over ONE shared streaming substrate.

A :class:`MultiTenantStore` multiplexes many tenant **collections** onto a
single :class:`~repro.streaming.SegmentManager` — one device pack, one
mesh, one HBM budget, one WAL — while keeping the tenants logically
isolated:

* **gid-spaces** — every point belongs to exactly one collection (the
  store records the owner of each gid it hands out); cross-tenant
  ``delete`` or document materialization raises
  :class:`TenantIsolationError` instead of silently touching another
  tenant's data;
* **metadata tagging** — the store appends one hidden metadata column
  (``tenant_dim == m_user``) holding the collection's numeric tenant id,
  and every query is automatically scoped with an
  ``IntervalFilter(dim=tenant_dim, lo=tid-0.5, hi=tid+0.5)`` conjunction.
  The scoped filter stays kernel-encodable for box/interval/ball user
  filters, so tenant isolation costs nothing on the fused scan path;
* **per-tenant accounting** — each collection carries its own
  :class:`~repro.obs.metrics.BucketStats` accumulator (fed by the serving
  tier's grouped dispatches) and its ingest/delete/live counters land in
  the shared registry under ``{tenant="<name>"}`` labels;
* **per-tenant snapshot layout** — :meth:`MultiTenantStore.snapshot_to`
  writes the shared substrate once (``<root>/substrate/``) plus one
  catalog directory per tenant (``<root>/tenants/<name>/``) holding that
  collection's document payloads, so a restore rebuilds both the index
  state and every tenant's document mapping.

**Isolation = correctness, bit-for-bit.**  Because the kernel computes
every ``(query, point)`` distance with the same fp32 arithmetic no matter
which other rows share the device block, and gid order *within* a tenant
equals its ingestion order in a single-tenant store, a collection's
answers are bit-for-bit the answers of a dedicated single-tenant store
holding only its documents — regardless of what other tenants ingest,
delete, or query concurrently.  ``tests/test_service.py`` asserts exactly
this against racing writers.

Quotas here bound **stored live points per tenant** (admission control
for *requests* lives in ``serving/service.py``): an ``insert`` that would
exceed ``quota_points`` raises :class:`TenantQuotaError` before touching
the substrate.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import (BoxFilter, ComposeFilter, CubeGraphConfig, Filter,
                    IntervalFilter, PolygonFilter)
from ..obs import json_sanitize
from ..obs.metrics import BucketStats
from ..streaming import SegmentManager, StreamConfig
from .rag import Document

__all__ = ["Collection", "MultiTenantStore", "TenantIsolationError",
           "TenantQuotaError", "TenantAnswer"]


class TenantQuotaError(RuntimeError):
    """An insert would push a collection past its ``quota_points``."""


class TenantIsolationError(RuntimeError):
    """A tenant operation referenced a gid owned by another collection."""


@dataclasses.dataclass
class TenantAnswer:
    """One tenant's retrieval answer: materialized documents plus the raw
    ``(gid, dist)`` rows and the degraded-result marker carried over from
    the streaming :class:`~repro.streaming.resilience.QueryResult`."""

    docs: List[List[Document]]
    gids: np.ndarray                 # [b, k] int64, -1 padded
    dists: np.ndarray                # [b, k] fp32, +inf padded
    degraded: bool = False
    reasons: Optional[dict] = None


@dataclasses.dataclass
class Collection:
    """One tenant's namespace: its numeric id, live-document mapping,
    point quota, and per-tenant bucket accounting."""

    name: str
    tid: int
    quota_points: Optional[int] = None
    docs_by_gid: Dict[int, Document] = dataclasses.field(
        default_factory=dict)
    bucket_stats: BucketStats = dataclasses.field(
        default_factory=BucketStats)

    @property
    def n_live(self) -> int:
        """Live (inserted minus deleted) points in this collection."""
        return len(self.docs_by_gid)


class MultiTenantStore:
    """Many tenant collections sharing one streaming substrate.

    ``d_emb`` / ``m`` describe the *user-visible* schema (embedding dims,
    metadata dims); the underlying manager runs with ``m + 1`` metadata
    dims — the hidden trailing column holds the tenant id.  The manager's
    temporal column is resolved against the user schema **before** the
    tenant column is appended, so ``StreamConfig(time_dim=-1)`` keeps
    meaning "last user metadata dim", never the tenant tag.

    The sharded read path is forced on (``n_shards >= 1``) — the
    serving tier's continuous filtered batching
    (:meth:`~repro.streaming.SegmentManager.query_grouped`) shares
    per-bucket device reads across tenants, which needs the bucketed
    pack.
    """

    def __init__(self, d_emb: int, m: int,
                 stream_cfg: Optional[StreamConfig] = None,
                 index_cfg: Optional[CubeGraphConfig] = None,
                 shard_mesh=None):
        if stream_cfg is None:
            stream_cfg = StreamConfig(
                index_cfg=index_cfg or CubeGraphConfig())
        elif index_cfg is not None:
            stream_cfg = dataclasses.replace(stream_cfg,
                                             index_cfg=index_cfg)
        self.m_user = int(m)
        self.tenant_dim = int(m)
        # resolve time_dim in USER coordinates before widening the schema:
        # the manager would otherwise resolve the default -1 to the
        # appended tenant column and temporally prune on tenant ids
        stream_cfg = dataclasses.replace(
            stream_cfg, time_dim=stream_cfg.time_dim % self.m_user,
            n_shards=max(stream_cfg.n_shards, 1))
        self.manager = SegmentManager(d_emb, self.m_user + 1, stream_cfg,
                                      shard_mesh=shard_mesh)
        self.obs = self.manager.obs
        self.metrics = self.obs.registry
        self.collections: Dict[str, Collection] = {}
        self._lock = threading.Lock()
        self._next_tid = 1

    # -- collection lifecycle ------------------------------------------

    def create_collection(self, name: str,
                          quota_points: Optional[int] = None) -> Collection:
        """Register a new tenant namespace (its numeric id is assigned
        here and never reused)."""
        with self._lock:
            if name in self.collections:
                raise ValueError(f"collection {name!r} already exists")
            coll = Collection(name=name, tid=self._next_tid,
                              quota_points=quota_points)
            self._next_tid += 1
            self.collections[name] = coll
        return coll

    def collection(self, tenant: str) -> Collection:
        """Look up a collection by name (KeyError when unknown)."""
        return self.collections[tenant]

    # -- tenant scoping ------------------------------------------------

    def isolation_filter(self, tenant: str) -> Filter:
        """The hidden-column predicate restricting a query to one tenant's
        rows (kernel-encodable interval around the integer tenant id)."""
        tid = self.collections[tenant].tid
        return IntervalFilter(dim=self.tenant_dim, lo=tid - 0.5,
                              hi=tid + 0.5)

    def _widen(self, f: Filter) -> Filter:
        """Re-express a user filter (bounds over the user's ``m`` dims)
        against the substrate's ``m + 1``-wide schema: box/polygon bounds
        gain an unconstrained trailing (tenant) dim; interval/ball filters
        address dim prefixes and pass through unchanged."""
        extra = self.m_user + 1
        if isinstance(f, BoxFilter):
            lo = np.asarray(f.lo, np.float32)
            hi = np.asarray(f.hi, np.float32)
            if len(lo) < extra:
                lo = np.concatenate(
                    [lo, np.full(extra - len(lo), -np.inf, np.float32)])
                hi = np.concatenate(
                    [hi, np.full(extra - len(hi), np.inf, np.float32)])
            return BoxFilter(lo=lo, hi=hi)
        if isinstance(f, PolygonFilter):
            rlo = np.asarray(f.rest_lo, np.float32)
            rhi = np.asarray(f.rest_hi, np.float32)
            if 2 + len(rlo) < extra:
                pad = extra - 2 - len(rlo)
                rlo = np.concatenate(
                    [rlo, np.full(pad, -np.inf, np.float32)])
                rhi = np.concatenate(
                    [rhi, np.full(pad, np.inf, np.float32)])
            return PolygonFilter(vertices=f.vertices, rest_lo=rlo,
                                 rest_hi=rhi)
        if isinstance(f, ComposeFilter):
            return ComposeFilter(self._widen(f.a), self._widen(f.b), f.op)
        return f

    def scoped_filter(self, tenant: str,
                      filt: Optional[Filter]) -> Filter:
        """Conjoin a user filter (over the user's ``m`` dims) with the
        tenant isolation predicate; the composition stays
        kernel-encodable whenever the user filter is."""
        iso = self.isolation_filter(tenant)
        return iso if filt is None else ComposeFilter(self._widen(filt),
                                                      iso, "and")

    # -- writes --------------------------------------------------------

    def insert(self, tenant: str, docs: Sequence[Document]) -> np.ndarray:
        """Ingest documents into one collection (quota-checked); returns
        the assigned global ids."""
        coll = self.collections[tenant]
        with self._lock:
            if coll.quota_points is not None and \
                    coll.n_live + len(docs) > coll.quota_points:
                raise TenantQuotaError(
                    f"collection {tenant!r} holds {coll.n_live} live "
                    f"points; inserting {len(docs)} exceeds its quota of "
                    f"{coll.quota_points}")
            x = np.stack([d.embedding for d in docs]).astype(np.float32)
            s = np.stack([d.metadata for d in docs]).astype(np.float64)
            s = np.concatenate(
                [s, np.full((len(docs), 1), float(coll.tid))], axis=1)
            gids = self.manager.ingest(x, s)
            for g, d in zip(np.asarray(gids).tolist(), docs):
                coll.docs_by_gid[int(g)] = d
        self.metrics.counter(
            f'tenant_ingested_points_total{{tenant="{tenant}"}}'
        ).inc(len(docs))
        self.metrics.gauge(
            f'tenant_live_points{{tenant="{tenant}"}}').set(coll.n_live)
        return np.asarray(gids, np.int64)

    def delete(self, tenant: str, gids: Sequence[int]) -> int:
        """Lazy-delete a collection's own points; a gid owned by another
        tenant (or by nobody) raises :class:`TenantIsolationError` and
        deletes nothing."""
        coll = self.collections[tenant]
        gids = [int(g) for g in np.asarray(gids, np.int64).tolist()]
        with self._lock:
            foreign = [g for g in gids if g not in coll.docs_by_gid]
            if foreign:
                raise TenantIsolationError(
                    f"collection {tenant!r} does not own gids {foreign}")
            n = self.manager.delete(np.asarray(gids, np.int64))
            for g in gids:
                coll.docs_by_gid.pop(g, None)
        self.metrics.counter(
            f'tenant_deleted_points_total{{tenant="{tenant}"}}').inc(
                len(gids))
        self.metrics.gauge(
            f'tenant_live_points{{tenant="{tenant}"}}').set(coll.n_live)
        return n

    # -- reads ---------------------------------------------------------

    def materialize(self, tenant: str, gids: np.ndarray
                    ) -> List[List[Document]]:
        """Map answer gid rows to the tenant's documents.  A gid outside
        the collection means the isolation predicate was breached — that
        is a hard error, never a silent cross-tenant document leak."""
        coll = self.collections[tenant]
        out: List[List[Document]] = []
        for row in np.asarray(gids):
            docs = []
            for g in row:
                if g < 0:
                    continue
                d = coll.docs_by_gid.get(int(g))
                if d is None:
                    raise TenantIsolationError(
                        f"answer gid {int(g)} is not owned by collection "
                        f"{tenant!r} — isolation predicate breached")
                docs.append(d)
            out.append(docs)
        return out

    def retrieve(self, tenant: str, query_emb: np.ndarray,
                 filt: Optional[Filter] = None, k: int = 10, ef: int = 64,
                 deadline_ms: Optional[float] = None,
                 read_path: Optional[str] = None,
                 trace=None) -> TenantAnswer:
        """Tenant-scoped filtered top-k retrieval (one solo query; the
        serving tier batches heterogeneous requests instead — same
        answers bit-for-bit)."""
        q = np.atleast_2d(np.asarray(query_emb, np.float32))
        res = self.manager.query(q, self.scoped_filter(tenant, filt), k=k,
                                 ef=ef, deadline_ms=deadline_ms,
                                 read_path=read_path, trace=trace)
        gids, dists = res
        degraded = bool(getattr(res, "degraded", False))
        reasons = dict(getattr(res, "reasons", {}) or {})
        self.metrics.counter(
            f'tenant_requests_total{{tenant="{tenant}"}}').inc(q.shape[0])
        return TenantAnswer(docs=self.materialize(tenant, gids),
                            gids=np.asarray(gids, np.int64),
                            dists=np.asarray(dists, np.float32),
                            degraded=degraded, reasons=reasons)

    # -- lifecycle / stats / persistence -------------------------------

    def maintenance(self, async_compaction: bool = False) -> dict:
        """Shared substrate lifecycle tick (seal / TTL / compaction)."""
        return self.manager.maintenance(async_compaction=async_compaction)

    def stats(self) -> dict:
        """Substrate ``stats()`` plus a ``tenants`` block: per collection
        its id, liveness, quota, and per-tenant ``BucketStats``."""
        out = self.manager.stats()
        out["tenants"] = {
            name: {
                "tid": coll.tid,
                "live_points": coll.n_live,
                "quota_points": coll.quota_points,
                "buckets": coll.bucket_stats.snapshot(),
            }
            for name, coll in sorted(self.collections.items())
        }
        return json_sanitize(out)

    def metrics_snapshot(self) -> dict:
        """Strict-JSON observability export (shared registry + per-tenant
        blocks) — ``tools/obs_dump.py`` renders it as Prometheus text."""
        return self.stats()

    def snapshot_to(self, root: str) -> dict:
        """Durable snapshot: shared substrate under ``<root>/substrate``,
        one catalog per tenant under ``<root>/tenants/<name>`` (document
        payloads stored as plain npz + json — no pickling)."""
        root_p = pathlib.Path(root)
        manifest = self.manager.snapshot_to(str(root_p / "substrate"))
        for name, coll in self.collections.items():
            tdir = root_p / "tenants" / name
            tdir.mkdir(parents=True, exist_ok=True)
            gids = sorted(coll.docs_by_gid)
            docs = [coll.docs_by_gid[g] for g in gids]
            tokens = ([d.tokens.astype(np.int32) for d in docs]
                      if docs else [])
            offsets = np.zeros(len(docs) + 1, np.int64)
            if docs:
                offsets[1:] = np.cumsum([len(t) for t in tokens])
            np.savez(
                tdir / "catalog.npz",
                gids=np.asarray(gids, np.int64),
                doc_ids=np.asarray([d.doc_id for d in docs], np.int64),
                embeddings=(np.stack([d.embedding for d in docs])
                            .astype(np.float32) if docs
                            else np.zeros((0, 0), np.float32)),
                metadata=(np.stack([d.metadata for d in docs])
                          .astype(np.float64) if docs
                          else np.zeros((0, 0), np.float64)),
                tokens=(np.concatenate(tokens) if docs
                        else np.zeros(0, np.int32)),
                token_offsets=offsets)
            (tdir / "catalog.json").write_text(json.dumps({
                "name": name, "tid": coll.tid,
                "quota_points": coll.quota_points,
                "n_live": coll.n_live}))
        (root_p / "tenants.json").write_text(json.dumps({
            "next_tid": self._next_tid,
            "tenants": sorted(self.collections)}))
        return manifest

    @classmethod
    def restore(cls, root: str, d_emb: int, m: int,
                stream_cfg: Optional[StreamConfig] = None,
                shard_mesh=None, resume: bool = True) -> "MultiTenantStore":
        """Rebuild the store from a :meth:`snapshot_to` directory: the
        substrate restores via ``SegmentManager.restore`` (bit-for-bit
        query parity) and every tenant catalog rebuilds its gid→document
        mapping."""
        root_p = pathlib.Path(root)
        obj = cls.__new__(cls)
        obj.m_user = int(m)
        obj.tenant_dim = int(m)
        obj.manager = SegmentManager.restore(
            str(root_p / "substrate"), cfg=stream_cfg,
            shard_mesh=shard_mesh, resume=resume)
        obj.obs = obj.manager.obs
        obj.metrics = obj.obs.registry
        obj.collections = {}
        obj._lock = threading.Lock()
        meta = json.loads((root_p / "tenants.json").read_text())
        obj._next_tid = int(meta["next_tid"])
        for name in meta["tenants"]:
            tdir = root_p / "tenants" / name
            cat = json.loads((tdir / "catalog.json").read_text())
            coll = Collection(name=name, tid=int(cat["tid"]),
                              quota_points=cat["quota_points"])
            with np.load(tdir / "catalog.npz") as z:
                offs = z["token_offsets"]
                for i, g in enumerate(z["gids"].tolist()):
                    coll.docs_by_gid[int(g)] = Document(
                        doc_id=int(z["doc_ids"][i]),
                        tokens=z["tokens"][offs[i]:offs[i + 1]],
                        embedding=z["embeddings"][i],
                        metadata=z["metadata"][i])
            obj.collections[name] = coll
            obj.metrics.gauge(
                f'tenant_live_points{{tenant="{name}"}}').set(coll.n_live)
        return obj
