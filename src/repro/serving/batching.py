"""Serving-side batchers (host-side schedulers).

* ``ContinuousBatcher`` — vLLM-style slot model for decode: fixed
  ``n_slots`` lanes over one shared KV cache; requests are admitted into
  free slots as they arrive, prefilled individually, then decoded together
  in lockstep.  Finished slots (EOS or budget) free immediately.
* ``RetrievalBatcher`` — groups queued retrieval requests that share a
  filter and routes each group as ONE batched query through the document
  store, i.e. one segment fan-out over the streaming index (or one planned
  beam search on the monolithic index) instead of per-request searches.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Filter
from ..obs.metrics import NULL_REGISTRY
from .serve_step import make_serve_fns


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray               # [t] int32
    max_new: int
    arrived_step: int = 0
    output: Optional[List[int]] = None


class ContinuousBatcher:
    def __init__(self, model, params, n_slots: int = 8, max_len: int = 512,
                 eos_id: int = 1, temperature: float = 0.0, metrics=None):
        self.model = model
        self.params = params
        self.metrics = NULL_REGISTRY if metrics is None else metrics
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.cache = model.init_cache(n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int32)
        self.budget = np.zeros(n_slots, np.int32)
        self.cur_tok = np.zeros((n_slots, 1), np.int32)
        self.free = list(range(n_slots))
        self.finished: List[Request] = []
        self.prefill_fn, self.decode_fn = make_serve_fns(model, temperature)
        self._key = jax.random.key(0)
        self.steps = 0

    def submit(self, req: Request):
        self.queue.append(req)

    # -- admission: prefill one request into a free slot ----------------------
    def _admit(self):
        while self.free and self.queue:
            slot = self.free.pop()
            req = self.queue.popleft()
            req.output = []
            t = len(req.prompt)
            single = self.model.init_cache(1, self.max_len)
            tok, single = self.prefill_fn(
                self.params, jnp.asarray(req.prompt[None, :]), single)
            # copy the single-request cache into the shared slot
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, slot:slot + 1].set(small)
                if big.ndim >= 2 else big, self.cache, single)
            self.cur_tok[slot] = np.array(tok)[0]
            req.output.append(int(tok[0, 0]))
            self.pos[slot] = t
            self.budget[slot] = req.max_new - 1
            self.active[slot] = req

    # -- one decode tick over all active slots --------------------------------
    def step(self) -> int:
        self._admit()
        if not self.active:
            return 0
        self._key, sub = jax.random.split(self._key)
        tok, _, self.cache = self.decode_fn(
            self.params, jnp.asarray(self.cur_tok), self.cache,
            jnp.asarray(self.pos), sub)
        tok = np.asarray(tok)
        self.steps += 1
        # slot occupancy per decode tick: 1.0 means the lockstep decode
        # wasted no lanes, low values mean admission is starved
        self.metrics.counter("decode_steps_total").inc()
        self.metrics.histogram("decode_slot_occupancy").observe(
            len(self.active) / self.n_slots)
        done_slots = []
        for slot, req in list(self.active.items()):
            t = int(tok[slot, 0])
            req.output.append(t)
            self.pos[slot] += 1
            self.budget[slot] -= 1
            if t == self.eos_id or self.budget[slot] <= 0 \
                    or self.pos[slot] >= self.max_len - 1:
                done_slots.append(slot)
        for slot in done_slots:
            self.finished.append(self.active.pop(slot))
            self.free.append(slot)
        self.cur_tok = np.array(tok)
        return len(self.active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.active) and self.steps < max_steps:
            self.step()
        return self.finished


# ---------------------------------------------------------------------------
# Retrieval batching (streaming segment fan-out)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RetrievalRequest:
    req_id: int
    query_emb: np.ndarray            # [d_emb]
    filt: Filter
    k: int = 10
    deadline_ms: Optional[float] = None   # per-request SLO budget
    enqueued_at: float = 0.0         # stamped by RetrievalBatcher.submit


@dataclasses.dataclass
class RetrievalFailure:
    """Error result for a request the serving layer could not answer.

    ``flush()`` never drops queued requests: a chunk whose store dispatch
    raises maps each of its requests to one of these (instead of a document
    list) while every other chunk drains normally.  ``reason`` is a stable
    machine-readable tag — ``"error"`` for dispatch exceptions,
    ``"over_quota"`` for admission-control rejections
    (``serving/service.py``).
    """
    req_id: int
    error: str
    reason: str = "error"


def _leaf_key(leaf):
    """One pytree leaf -> hashable *value* key.

    Array-like leaves key on ``(dtype, shape, bytes)`` — ``tobytes()``
    alone would collide a ``[2, 1]`` float32 box edge with a ``[2]`` one
    and an int32 leaf with a float32 of the same bits.  A leaf that numpy
    cannot turn into a numeric array (an unregistered filter object, say)
    lands in an object array, whose ``tobytes()`` is its *pointer* —
    identity, not value — so those recurse over the object's field values
    instead (dataclass fields or ``__dict__``), falling back to ``repr``
    for plain constants.
    """
    a = np.asarray(leaf)
    if a.dtype != object:
        return (a.dtype.str, a.shape, a.tobytes())
    if dataclasses.is_dataclass(leaf) and not isinstance(leaf, type):
        state = {f.name: getattr(leaf, f.name)
                 for f in dataclasses.fields(leaf)}
    else:
        state = getattr(leaf, "__dict__", None)
    if state is not None:
        return (type(leaf).__name__,
                tuple((name, _leaf_key(v))
                      for name, v in sorted(state.items())))
    return (type(leaf).__name__, repr(leaf))


def _filter_key(filt: Optional[Filter], k: int):
    """Hashable *value-based* identity for grouping: pytree structure plus
    per-leaf ``(dtype, shape, bytes)`` — two equal-valued but distinct
    filter objects produce the same key and batch together."""
    leaves, treedef = jax.tree_util.tree_flatten(filt)
    return (str(treedef), k, tuple(_leaf_key(leaf) for leaf in leaves))


class RetrievalBatcher:
    """Batches retrieval requests per shared filter.

    Requests arriving between flushes queue up; ``flush()`` partitions them
    by (filter value, k, deadline), stacks each group's query embeddings,
    and issues a single batched ``DocumentStore.retrieve`` per group — over
    a streaming store that is one pruned multi-segment fan-out amortized
    across the whole group.  Groups larger than ``max_batch`` are split.
    Each returned row is a :class:`~repro.serving.rag.RetrievedDocs`
    carrying the underlying query's ``degraded`` / ``reasons`` markers, so
    a deadline overrun reaches the caller instead of being dropped.

    With ``maintenance_every > 0`` (streaming stores only), every that-many
    flushes trigger one lifecycle tick with compaction — the expensive
    multi-segment rewrite — pushed to the manager's background thread.
    The tick itself still pays inline for expiry bookkeeping and, when the
    seal policy fires, for indexing one delta's worth of points
    (``seal_max_points`` bounds that build).
    """

    def __init__(self, store, ef: int = 64, max_batch: int = 64,
                 maintenance_every: int = 0):
        self.store = store
        self.ef = int(ef)
        self.max_batch = int(max_batch)
        self.maintenance_every = int(maintenance_every)
        self._flushes = 0
        self.queue: deque = deque()
        # share the store's registry so queue-wait / batch-occupancy land
        # in the same snapshot as the retrieval latencies
        self.metrics = getattr(store, "metrics", None) or NULL_REGISTRY

    def submit(self, req: RetrievalRequest) -> None:
        if not req.enqueued_at:
            req.enqueued_at = time.perf_counter()
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def flush(self) -> Dict[int, list]:
        """Drain the queue; returns {req_id: [Document, ...]}.

        Every queued request gets an entry: a chunk whose store dispatch
        raises maps each of its requests to a :class:`RetrievalFailure`
        (counted in ``retrieval_failed_total``) and the remaining chunks
        keep draining — one bad filter or a poisoned store cannot black-hole
        the rest of the queue.
        """
        groups: Dict[object, List[RetrievalRequest]] = {}
        while self.queue:
            req = self.queue.popleft()
            groups.setdefault(
                (_filter_key(req.filt, req.k), req.deadline_ms),
                []).append(req)
        results: Dict[int, list] = {}
        t_flush = time.perf_counter()
        wait_hist = self.metrics.histogram("retrieval_queue_wait_ms")
        occ_hist = self.metrics.histogram("retrieval_batch_occupancy")
        for reqs in groups.values():
            for lo in range(0, len(reqs), self.max_batch):
                chunk = reqs[lo:lo + self.max_batch]
                # occupancy: how full each dispatched batch is relative to
                # max_batch — persistently low means filters fragment the
                # queue and the fan-out amortization is not happening
                occ_hist.observe(len(chunk) / self.max_batch)
                for r in chunk:
                    if r.enqueued_at:
                        wait_hist.observe((t_flush - r.enqueued_at) * 1e3)
                q = np.stack([r.query_emb for r in chunk]).astype(np.float32)
                # deadline-free chunks call retrieve without the kwarg so
                # duck-typed stores predating deadline_ms keep working
                kw = ({"deadline_ms": chunk[0].deadline_ms}
                      if chunk[0].deadline_ms is not None else {})
                try:
                    rows = self.store.retrieve(
                        q, chunk[0].filt, k=chunk[0].k, ef=self.ef, **kw)
                except Exception as exc:       # noqa: BLE001 — isolate chunk
                    self.metrics.counter("retrieval_failed_total").inc(
                        len(chunk))
                    for r in chunk:
                        results[r.req_id] = RetrievalFailure(
                            r.req_id, f"{type(exc).__name__}: {exc}")
                    continue
                for r, docs in zip(chunk, rows):
                    results[r.req_id] = docs
        self._flushes += 1
        if (self.maintenance_every > 0
                and self._flushes % self.maintenance_every == 0
                and getattr(self.store, "streaming", False)):
            self.store.maintenance(async_compaction=True)
        return results
