"""Uniform model API: ``build_model(cfg)`` -> object with
``param_specs / loss / logits / init_cache / cache_specs / prefill /
decode_step`` (see transformer.py for the contract)."""
from __future__ import annotations

from .common import ArchConfig


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from .transformer import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        from .ssm_lm import SSMLM
        return SSMLM(cfg)
    if cfg.family == "hybrid":
        from .hybrid import HybridLM
        return HybridLM(cfg)
    if cfg.family in ("encdec", "audio"):
        from .encdec import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
