"""Shared LM loss.

``cross_entropy`` avoids ``take_along_axis`` over the vocab axis: with
vocab-sharded logits that gather would all-gather the full [B,S,V] logits
tensor (hundreds of GB at assigned shapes).  The one-hot formulation reduces
*locally* over each vocab shard and lets XLA finish with an all-reduce of
[B,S] scalars instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0.  logits [b, s, v] (any dtype),
    labels [b, s] int."""
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    v = lg.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        labels.dtype, (1, 1, v), 2)
    gold = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
    mask = labels >= 0
    ce = jnp.where(mask, logz - gold, 0.0)
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)
