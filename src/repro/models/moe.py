"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

TPU/SPMD-friendly formulation (MegaBlocks-lite): token→expert assignments are
sorted by expert id, positions-within-expert computed with a cumsum, tokens
scattered into a dense ``[E, C, d]`` buffer (capacity-dropped), experts run as
one batched matmul (``E`` leading dim shards over the model/data axes for
expert parallelism), and results gather back weighted by the router gates.
No ``[T, E, C]`` one-hot tensors are ever materialized.

Supports shared experts (qwen2-moe: 4 shared + 60 routed top-4) and the
auxiliary load-balancing loss (Switch-style).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, Spec
from .layers import mlp, mlp_specs

Params = Dict[str, jnp.ndarray]


def moe_specs(cfg: ArchConfig) -> Params:
    d, fe = cfg.d_model, cfg.d_expert
    dt = cfg.compute_dtype
    out = {
        "router": Spec((d, cfg.n_experts), jnp.float32),
        "w_gate": Spec((cfg.n_experts, d, fe), dt),
        "w_up": Spec((cfg.n_experts, d, fe), dt),
        "w_down": Spec((cfg.n_experts, fe, d), dt),
    }
    if cfg.n_shared_experts:
        out["shared"] = mlp_specs(cfg, d_ff=cfg.n_shared_experts * cfg.d_expert)
    return out


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)           # round up to 8


def moe(x: jnp.ndarray, p: Params, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [b, s, d] -> (y [b, s, d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)       # [t, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros(e, jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # --- sort-based dispatch ------------------------------------------------
    flat_e = expert_ids.reshape(-1)                        # [t*k]
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    # position within expert group = rank - start_of_group
    counts = jnp.zeros(e, jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - starts[se]
    keep = pos_in_e < cap                                  # capacity drop

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, se, 0),
                 jnp.where(keep, pos_in_e, 0)].add(
        jnp.where(keep[:, None], xt[stok], 0).astype(x.dtype))

    # --- batched expert FFN (E shards over mesh axes) ------------------------
    # EP hint: experts over dp axes when divisible, else capacity over 'data'
    # (keeps the [E, C, d] dispatch buffer from replicating at 235B scale).
    from ..distributed.hints import constrain, dp_axes, mesh_axis_size
    dp = dp_axes()
    if dp is not None and e % mesh_axis_size(dp) == 0:
        buf = constrain(buf, dp, None, None)
    else:
        buf = constrain(buf, None, "data", None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    # --- gather back, weighted by gates --------------------------------------
    vals = out_buf[jnp.where(keep, se, 0), jnp.where(keep, pos_in_e, 0)]
    vals = jnp.where(keep[:, None], vals, 0)
    yt = jnp.zeros((t, d), jnp.float32).at[stok].add(
        vals.astype(jnp.float32) * sg[:, None])
    y = yt.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared_experts:
        y = y + mlp(x, p["shared"])
    return y, aux


def moe_local(x: jnp.ndarray, p: Params, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-local dispatch variant (§Perf `localdisp`): the token->expert
    sort runs independently inside each data-parallel block, so routing
    generates only the canonical EP all-to-all of the dispatch buffers
    instead of global-sort collectives over [T*k] token ids.

    Semantics vs `moe`: identical routing; capacity is enforced per block
    (T/nb * k / E per block) which drops slightly more tokens under skewed
    routing — the standard EP trade."""
    from ..distributed.hints import constrain, dp_axes, mesh_axis_size
    b, s, d = x.shape
    t = b * s
    k, e = cfg.top_k, cfg.n_experts
    dp = dp_axes()
    nb = mesh_axis_size(dp) if dp is not None else 1
    if t % nb != 0 or nb <= 1:
        return moe(x, p, cfg)
    tb = t // nb
    cap = _capacity(tb, cfg)
    xt = x.reshape(nb, tb, d)
    xt = constrain(xt, dp, None, None)

    logits = jnp.einsum("ntd,de->nte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # [nb, tb, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros(e, jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    flat_e = expert_ids.reshape(nb, tb * k)                # block-local sort
    flat_g = gate_vals.reshape(nb, tb * k)
    flat_tok = jnp.tile(jnp.repeat(jnp.arange(tb), k)[None], (nb, 1))
    order = jnp.argsort(flat_e, axis=1)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    stok = jnp.take_along_axis(flat_tok, order, axis=1)
    blk = jnp.broadcast_to(jnp.arange(nb)[:, None], se.shape)

    counts = jnp.zeros((nb, e), jnp.int32).at[blk, se].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((nb, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)
    pos = jnp.arange(tb * k)[None, :] - starts[blk, se]
    keep = pos < cap

    buf = jnp.zeros((nb, e, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, blk, 0), jnp.where(keep, se, 0),
                 jnp.where(keep, pos, 0)].add(
        jnp.where(keep[..., None], xt[blk, stok], 0).astype(x.dtype))
    buf = constrain(buf, dp, None, None, None)

    # expert matmul: weights are E-sharded (EP) -> XLA inserts the
    # block->expert all-to-all here (the canonical EP exchange).
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", buf, p["w_gate"]))
    h = h * jnp.einsum("necd,edf->necf", buf, p["w_up"])
    out_buf = jnp.einsum("necf,efd->necd", h, p["w_down"])

    vals = out_buf[jnp.where(keep, blk, 0), jnp.where(keep, se, 0),
                   jnp.where(keep, pos, 0)]
    vals = jnp.where(keep[..., None], vals, 0)
    yt = jnp.zeros((nb, tb, d), jnp.float32).at[blk, stok].add(
        vals.astype(jnp.float32) * sg[..., None])
    y = yt.astype(x.dtype).reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + mlp(x, p["shared"])
    return y, aux
