"""Attention-free SSM LM (falcon-mamba): a stack of Mamba-1 blocks."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ArchConfig, Params, Spec
from .layers import embed, embed_specs, rms_norm, unembed
from .scan_utils import scan_layers
from .ssm import mamba1, mamba1_decode, mamba1_specs


class SSMLM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.ssm_type == "mamba1"
        self.cfg = cfg

    def _layer_specs(self) -> Params:
        return {"ln": Spec((self.cfg.d_model,), self.cfg.compute_dtype,
                           init="ones"),
                "ssm": mamba1_specs(self.cfg)}

    def param_specs(self) -> Params:
        cfg = self.cfg
        stack = jax.tree.map(
            lambda s: Spec((cfg.n_layers,) + s.shape, s.dtype, s.init, s.scale),
            self._layer_specs(), is_leaf=lambda v: isinstance(v, Spec))
        return {"embed": embed_specs(cfg), "layers": stack,
                "final_norm": Spec((cfg.d_model,), cfg.compute_dtype,
                                   init="ones")}

    def _chunk(self, seq_len: int) -> int:
        if self.cfg.ssm_chunk == -1:
            return seq_len
        return self.cfg.ssm_chunk or 64

    def _layer(self, x, p):
        h = rms_norm(x, p["ln"], self.cfg.norm_eps)
        return x + mamba1(h, p["ssm"], self.cfg, chunk=self._chunk(x.shape[1]))

    def hidden_states(self, params, x):
        body = self._layer
        if self.cfg.remat:
            body = jax.remat(body)

        def scan_fn(x, p):
            return body(x, p), None

        x, _ = scan_layers(scan_fn, x, params["layers"], self.cfg.unroll)
        return rms_norm(x, params["final_norm"], self.cfg.norm_eps)

    def logits(self, params, tokens, patches=None):
        x = embed(tokens, params["embed"])
        h = self.hidden_states(params, x)
        return unembed(h, params["embed"]), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.logits(params, batch["tokens"])
        labels = batch["labels"]
        from .losses import cross_entropy
        return cross_entropy(logits, labels)

    # -- serving: state is O(1) in sequence length ---------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1,
                               cfg.d_inner), cfg.compute_dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.d_state),
                             jnp.float32),
        }

    def cache_specs(self, batch: int, max_len: int) -> Params:
        return {
            "conv": jax.ShapeDtypeStruct(
                (self.cfg.n_layers, batch, self.cfg.conv_kernel - 1,
                 self.cfg.d_inner), self.cfg.compute_dtype),
            "ssm": jax.ShapeDtypeStruct(
                (self.cfg.n_layers, batch, self.cfg.d_inner,
                 self.cfg.d_state), jnp.float32),
        }

    def prefill(self, params, tokens, cache, patches=None):
        """Sequential-scan prefill that also produces final states: we run the
        full forward (chunked scan inside mamba1) and rebuild states by a
        one-token replay of the last conv_kernel-1 inputs.  For the dry-run
        and tests we simply replay tokens through decode_step when short, and
        use the training forward for logits."""
        logits, _ = self.logits(params, tokens)
        return logits[:, -1:], cache

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = embed(token, params["embed"])

        def scan_fn(x, inp):
            p, conv, ssm = inp
            h = rms_norm(x, p["ln"], cfg.norm_eps)
            y, conv, ssm = mamba1_decode(h, p["ssm"], cfg, conv, ssm)
            return x + y, (conv, ssm)

        x, (conv, ssm) = scan_layers(
            scan_fn, x, (params["layers"], cache["conv"], cache["ssm"]),
            cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(h, params["embed"]), {"conv": conv, "ssm": ssm}
