"""Encoder-decoder transformer backbone (whisper-medium).

Per assignment spec the conv/audio frontend is a STUB: the model consumes
precomputed frame embeddings ``[b, n_frames, d_model]`` (``input_specs``
provides them).  Encoder = bidirectional attention stack; decoder = causal
self-attention + cross-attention to the encoder output.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ArchConfig, Params, Spec
from .layers import (_attend, attention, attention_decode, attention_specs,
                     embed, embed_specs, mlp, mlp_specs, rms_norm, rope,
                     unembed)
from .scan_utils import scan_layers

GLOBAL = jnp.int32(-1)


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.n_enc_layers > 0
        self.cfg = cfg

    def _enc_layer_specs(self) -> Params:
        cfg = self.cfg
        dt = cfg.compute_dtype
        return {"ln1": Spec((cfg.d_model,), dt, init="ones"),
                "attn": attention_specs(cfg),
                "ln2": Spec((cfg.d_model,), dt, init="ones"),
                "mlp": mlp_specs(cfg)}

    def _dec_layer_specs(self) -> Params:
        cfg = self.cfg
        dt = cfg.compute_dtype
        return {"ln1": Spec((cfg.d_model,), dt, init="ones"),
                "self_attn": attention_specs(cfg),
                "ln_x": Spec((cfg.d_model,), dt, init="ones"),
                "cross_attn": attention_specs(cfg),
                "ln2": Spec((cfg.d_model,), dt, init="ones"),
                "mlp": mlp_specs(cfg)}

    def param_specs(self) -> Params:
        cfg = self.cfg

        def stack(n, specs):
            return jax.tree.map(
                lambda s: Spec((n,) + s.shape, s.dtype, s.init, s.scale),
                specs, is_leaf=lambda v: isinstance(v, Spec))

        return {
            "embed": embed_specs(cfg),
            "enc_layers": stack(cfg.n_enc_layers, self._enc_layer_specs()),
            "dec_layers": stack(cfg.n_layers, self._dec_layer_specs()),
            "enc_norm": Spec((cfg.d_model,), cfg.compute_dtype, init="ones"),
            "final_norm": Spec((cfg.d_model,), cfg.compute_dtype, init="ones"),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames):
        """frames [b, nf, d] (stub frontend output) -> [b, nf, d]."""
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])[None, :]

        def body(x, p):
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + attention(h, p["attn"], cfg, positions, GLOBAL,
                              causal=False)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return x + mlp(h, p["mlp"]), None

        f = body
        if cfg.remat:
            f = jax.remat(body)
        x, _ = scan_layers(f, frames.astype(cfg.compute_dtype),
                           params["enc_layers"], cfg.unroll)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder train forward ---------------------------------------------------
    def _dec_layer(self, x, p, enc_out, positions, enc_positions):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attention(h, p["self_attn"], cfg, positions, GLOBAL,
                          causal=True)
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        b, sk = enc_out.shape[:2]
        hd = cfg.hd
        k = jnp.einsum("bsd,dq->bsq", enc_out, p["cross_attn"]["wk"]).reshape(
            b, sk, cfg.n_kv, hd)
        v = jnp.einsum("bsd,dq->bsq", enc_out, p["cross_attn"]["wv"]).reshape(
            b, sk, cfg.n_kv, hd)
        x = x + attention(h, p["cross_attn"], cfg, positions, GLOBAL,
                          causal=False, kv=(k, v), kv_positions=enc_positions)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(h, p["mlp"])

    def logits(self, params, tokens, frames):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = embed(tokens, params["embed"])
        positions = jnp.arange(tokens.shape[1])[None, :]
        enc_positions = jnp.arange(enc_out.shape[1])[None, :]
        body = self._dec_layer
        if cfg.remat:
            body = jax.remat(body)

        def scan_fn(x, p):
            return body(x, p, enc_out, positions, enc_positions), None

        x, _ = scan_layers(scan_fn, x, params["dec_layers"], cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(h, params["embed"]), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.logits(params, batch["tokens"], batch["frames"])
        labels = batch["labels"]
        from .losses import cross_entropy
        return cross_entropy(logits, labels)

    # -- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd),
                           cfg.compute_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd),
                           cfg.compute_dtype),
            "xk": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, cfg.n_kv,
                             cfg.hd), cfg.compute_dtype),
            "xv": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, cfg.n_kv,
                             cfg.hd), cfg.compute_dtype),
        }

    def cache_specs(self, batch: int, max_len: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def prefill(self, params, tokens, cache, frames=None):
        """Encode frames, fill cross-attention K/V, run decoder prompt."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        b, nf = enc_out.shape[:2]
        hd = cfg.hd

        def cross_kv(p):
            k = jnp.einsum("bsd,dq->bsq", enc_out, p["cross_attn"]["wk"]
                           ).reshape(b, nf, cfg.n_kv, hd)
            v = jnp.einsum("bsd,dq->bsq", enc_out, p["cross_attn"]["wv"]
                           ).reshape(b, nf, cfg.n_kv, hd)
            return k, v

        xk, xv = jax.vmap(cross_kv)(params["dec_layers"])
        logits, _ = self.logits(params, tokens, frames)
        return logits[:, -1:], {**cache, "xk": xk.astype(cache["xk"].dtype),
                                "xv": xv.astype(cache["xv"].dtype)}

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = embed(token, params["embed"])
        enc_positions = jnp.arange(cfg.n_frames)[None, :]

        def scan_fn(carry, inp):
            x, k_all, v_all = carry
            p, xk, xv, i = inp
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            o, ck, cv = attention_decode(h, p["self_attn"], cfg, ck, cv, pos,
                                         GLOBAL)
            k_all = jax.lax.dynamic_update_index_in_dim(
                k_all, ck.astype(k_all.dtype), i, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(
                v_all, cv.astype(v_all.dtype), i, 0)
            x = x + o
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            b = h.shape[0]
            q = jnp.einsum("bsd,dq->bsq", h, p["cross_attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.hd)
            o = _attend(q, xk, xv, pos[:, None], enc_positions, GLOBAL, False,
                        p["cross_attn"]["wo"], cfg)
            x = x + o
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return (x + mlp(h, p["mlp"]), k_all, v_all), None

        idx = jnp.arange(cfg.n_layers)
        (x, k, v), _ = scan_layers(
            scan_fn, (x, cache["k"], cache["v"]),
            (params["dec_layers"], cache["xk"], cache["xv"], idx), cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(h, params["embed"]), {**cache, "k": k, "v": v}
