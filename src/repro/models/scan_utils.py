"""Scan-or-unroll helper.

The multi-pod dry-run keeps ``lax.scan`` over layers (small HLO, fast
compiles, realistic schedule).  The roofline accounting however needs
per-layer costs, and XLA's cost_analysis counts a while-loop body ONCE
regardless of trip count — so the depth-delta compiles set
``cfg.unroll=True`` which expands layers as a python loop (every instance
counted).  See distributed/hlo_analysis.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_layers(body, carry, xs, unroll: bool = False):
    """Drop-in for ``jax.lax.scan(body, carry, xs)`` with optional unroll."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
