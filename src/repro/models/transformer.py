"""Decoder-only transformer LM (dense + MoE + VLM-backbone variants).

Layers run under a single ``lax.scan`` over stacked parameters, with the
attention window passed as *data* (int32 per layer, -1 = global) so
heterogeneous patterns (gemma3's 5 local : 1 global) share one scan body and
compile to one while loop.  MoE layers use the sort-based dispatch in
``moe.py``.

Three entry points per model:
  ``loss``        — training step objective (causal LM CE + MoE aux)
  ``prefill``     — prompt forward that also fills the KV cache
  ``decode_step`` — single-token step against the cache (serving)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ArchConfig, Params, Spec
from .layers import (attention, attention_decode, embed, embed_specs,
                     attention_specs, mlp, mlp_specs, rms_norm, rope, unembed)
from .moe import moe, moe_local, moe_specs
from .scan_utils import scan_layers


def window_pattern(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (int32, -1 = global)."""
    if cfg.sliding_window is None:
        return np.full(cfg.n_layers, -1, np.int32)
    w = np.full(cfg.n_layers, cfg.sliding_window, np.int32)
    if cfg.global_every:
        w[cfg.global_every - 1::cfg.global_every] = -1    # every Nth global
    return w


class DecoderLM:
    """Config-driven decoder-only LM."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_moe = cfg.family == "moe"
        self.windows = jnp.asarray(window_pattern(cfg))

    # -- parameters ---------------------------------------------------------
    def _layer_specs(self) -> Params:
        cfg = self.cfg
        dt = cfg.compute_dtype
        sp = {
            "ln1": Spec((cfg.d_model,), dt, init="ones"),
            "ln2": Spec((cfg.d_model,), dt, init="ones"),
            "attn": attention_specs(cfg),
        }
        sp["ffn"] = moe_specs(cfg) if self.is_moe else mlp_specs(cfg)
        return sp

    def param_specs(self) -> Params:
        cfg = self.cfg
        stack = jax.tree.map(
            lambda s: Spec((cfg.n_layers,) + s.shape, s.dtype, s.init, s.scale),
            self._layer_specs(), is_leaf=lambda v: isinstance(v, Spec))
        out = {
            "embed": embed_specs(cfg),
            "layers": stack,
            "final_norm": Spec((cfg.d_model,), cfg.compute_dtype, init="ones"),
        }
        if cfg.n_patches:                                 # VLM stub projector
            out["patch_proj"] = Spec((cfg.d_model, cfg.d_model),
                                     cfg.compute_dtype)
        return out

    # -- forward (training / scoring) ----------------------------------------
    def _layer(self, x, p, window, positions):
        cfg = self.cfg
        if cfg.seq_parallel:
            # Megatron-SP: residual stream sharded over sequence on the
            # model axis between blocks; XLA places the all-gather /
            # reduce-scatter pair around attention/MLP.
            from ..distributed.hints import constrain, dp_axes
            x = constrain(x, dp_axes(), "model", None)
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attention(h, p["attn"], cfg, positions, window, causal=True)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if self.is_moe:
            moe_fn = moe_local if cfg.moe_local_dispatch else moe
            y, aux = moe_fn(h, p["ffn"], cfg)
        else:
            y, aux = mlp(h, p["ffn"]), jnp.float32(0.0)
        return x + y, aux

    def hidden_states(self, params: Params, x: jnp.ndarray,
                      positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        body = self._layer
        if cfg.remat and cfg.remat_policy != "none":
            pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                   if cfg.remat_policy == "dots" else None)
            body = jax.remat(body, policy=pol)

        def scan_fn(x, inp):
            p, w = inp
            x, aux = body(x, p, w, positions)
            return x, aux

        x, auxs = scan_layers(scan_fn, x, (params["layers"], self.windows), self.cfg.unroll)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.sum(auxs)

    def inputs_embeds(self, params: Params, tokens: jnp.ndarray,
                      patches: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        x = embed(tokens, params["embed"])
        if self.cfg.n_patches and patches is not None:
            pe = jnp.einsum("bpd,de->bpe", patches.astype(x.dtype),
                            params["patch_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def logits(self, params: Params, tokens: jnp.ndarray,
               patches: Optional[jnp.ndarray] = None):
        x = self.inputs_embeds(params, tokens, patches)
        positions = jnp.arange(x.shape[1])[None, :]
        h, aux = self.hidden_states(params, x, positions)
        return unembed(h, params["embed"]), aux

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """batch: tokens [b, s], labels [b, s] (-1 = ignore), optional
        patches [b, p, d]."""
        logits, aux = self.logits(params, batch["tokens"],
                                  batch.get("patches"))
        labels = batch["labels"]
        if self.cfg.n_patches and "patches" in batch:
            pad = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        from .losses import cross_entropy
        return cross_entropy(logits, labels) + 0.01 * aux

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype)}

    def cache_specs(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
        return {"k": jax.ShapeDtypeStruct(shape, cfg.compute_dtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.compute_dtype)}

    def prefill(self, params: Params, tokens: jnp.ndarray, cache: Params,
                patches: Optional[jnp.ndarray] = None):
        """Prompt forward; returns (last-token logits, filled cache)."""
        cfg = self.cfg
        x = self.inputs_embeds(params, tokens, patches)
        s = x.shape[1]
        positions = jnp.arange(s)[None, :]

        def scan_fn(x, inp):
            p, w = inp
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            b = h.shape[0]
            hd = cfg.hd
            q = jnp.einsum("bsd,dq->bsq", h, p["attn"]["wq"]).reshape(
                b, s, cfg.n_heads, hd)
            k = jnp.einsum("bsd,dq->bsq", h, p["attn"]["wk"]).reshape(
                b, s, cfg.n_kv, hd)
            v = jnp.einsum("bsd,dq->bsq", h, p["attn"]["wv"]).reshape(
                b, s, cfg.n_kv, hd)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            from .layers import _attend
            o = _attend(q, k, v, positions, positions, w, True,
                        p["attn"]["wo"], cfg)
            x = x + o
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            if self.is_moe:
                moe_fn = moe_local if cfg.moe_local_dispatch else moe
                y, _ = moe_fn(h2, p["ffn"], cfg)
            else:
                y = mlp(h2, p["ffn"])
            return x + y, (k, v)

        x, (ks, vs) = scan_layers(scan_fn, x, (params["layers"], self.windows), self.cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(h[:, -1:], params["embed"])
        smax = cache["k"].shape[2]
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], ks.astype(cache["k"].dtype), 0, axis=2),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vs.astype(cache["v"].dtype), 0, axis=2),
        }
        return logits, cache

    def decode_step(self, params: Params, token: jnp.ndarray,
                    cache: Params, pos: jnp.ndarray):
        """token [b, 1] int32, pos [b] current positions.
        Returns (logits [b, 1, v], new cache).

        The cache rides in the scan CARRY with per-layer in-place
        ``dynamic_update_index_in_dim`` writes, so XLA aliases the donated
        input cache to the output — decode never holds two cache copies."""
        cfg = self.cfg
        x = embed(token, params["embed"])

        def scan_fn(carry, inp):
            x, k_all, v_all = carry
            p, w, i = inp
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            o, ck, cv = attention_decode(h, p["attn"], cfg, ck, cv, pos, w)
            k_all = jax.lax.dynamic_update_index_in_dim(
                k_all, ck.astype(k_all.dtype), i, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(
                v_all, cv.astype(v_all.dtype), i, 0)
            x = x + o
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            if self.is_moe:
                moe_fn = moe_local if cfg.moe_local_dispatch else moe
                y, _ = moe_fn(h2, p["ffn"], cfg)
            else:
                y = mlp(h2, p["ffn"])
            return (x + y, k_all, v_all), None

        idx = jnp.arange(cfg.n_layers)
        (x, ks, vs), _ = scan_layers(
            scan_fn, (x, cache["k"], cache["v"]),
            (params["layers"], self.windows, idx), self.cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(h, params["embed"]), {"k": ks, "v": vs}
