"""Model zoo: config-driven LM backbones for the assigned architectures."""
from .api import build_model
from .common import ArchConfig, Spec, abstract_params, init_params

__all__ = ["build_model", "ArchConfig", "Spec", "abstract_params",
           "init_params"]
