"""State-space blocks: Mamba-1 (selective scan, diagonal A) and Mamba-2 (SSD).

TPU adaptation notes (DESIGN.md §2):
* Mamba-1 — the CUDA selective-scan kernel becomes a *chunked associative
  scan*: `lax.scan` over sequence chunks with a parallel `associative_scan`
  inside each chunk, so the materialized decay tensors stay
  ``[b, chunk, d_inner, d_state]`` instead of ``[b, s, ...]``.
* Mamba-2 — implemented in the SSD block-matmul decomposition (intra-chunk
  attention-like term + inter-chunk state passing), which maps the recurrence
  onto MXU matmuls instead of elementwise scans.

Both provide a one-step ``*_decode`` path carrying ``(conv_state, ssm_state)``
for serving.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, Spec
from .layers import rms_norm

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# ---------------------------------------------------------------------------
def mamba1_specs(cfg: ArchConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    dtr = cfg.dt_rank or max(16, d // 16)
    dt = cfg.compute_dtype
    return {
        "in_proj": Spec((d, 2 * di), dt),
        "conv_w": Spec((cfg.conv_kernel, di), dt),
        "conv_b": Spec((di,), dt, init="zeros"),
        "x_proj": Spec((di, dtr + 2 * ds), dt),
        "dt_proj": Spec((dtr, di), dt),
        "dt_bias": Spec((di,), jnp.float32, init="zeros"),
        "a_log": Spec((di, ds), jnp.float32, init="small", scale=0.1),
        "d_skip": Spec((di,), jnp.float32, init="ones"),
        "out_proj": Spec((di, d), dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq. x [b, s, c], w [k, c]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _mamba1_core(xc, dt, bmat, cmat, a, d_skip, h0, chunk: int):
    """Chunked selective scan.
    xc [b,s,di], dt [b,s,di] (softplus'd), bmat/cmat [b,s,ds], a [di,ds] (<0).
    h0 [b,di,ds].  Returns (y [b,s,di], h_final)."""
    b, s, di = xc.shape
    ds = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = xc.shape[1] // chunk
    xs = (xc.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3),
          dt.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3),
          bmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3),
          cmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3))

    def chunk_body(h, inp):
        xck, dtk, bk, ck = inp                           # [b, ck, ...]
        decay = jnp.exp(dtk[..., None] * a[None, None])  # [b, ck, di, ds]
        u = (dtk * xck)[..., None] * bk[:, :, None, :]   # [b, ck, di, ds]

        def comb(l, r):
            al, ul = l
            ar, ur = r
            return al * ar, ar * ul + ur

        a_cum, u_cum = jax.lax.associative_scan(comb, (decay, u), axis=1)
        hs = a_cum * h[:, None] + u_cum                  # [b, ck, di, ds]
        y = jnp.einsum("bcds,bcs->bcd", hs, ck)
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)[:, :s]
    return y + xc[:, :s] * d_skip[None, None], h_final


def mamba1(x: jnp.ndarray, p: Params, cfg: ArchConfig,
           chunk: int = 64) -> jnp.ndarray:
    """Train/prefill forward. x [b, s, d] -> [b, s, d]."""
    di, ds = cfg.d_inner, cfg.d_state
    dtr = cfg.dt_rank or max(16, cfg.d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xc, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_causal_conv(xc, p["conv_w"], p["conv_b"]))
    proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"]).astype(jnp.float32)
    dt_low, bmat, cmat = (proj[..., :dtr], proj[..., dtr:dtr + ds],
                          proj[..., dtr + ds:])
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    h0 = jnp.zeros((x.shape[0], di, ds), jnp.float32)
    y, _ = _mamba1_core(xc.astype(jnp.float32), dt, bmat, cmat, a,
                        p["d_skip"], h0, chunk)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba1_decode(x, p, cfg: ArchConfig, conv_state, ssm_state):
    """One token step. x [b, 1, d]; conv_state [b, k-1, di];
    ssm_state [b, di, ds] (fp32)."""
    di, ds = cfg.d_inner, cfg.d_state
    dtr = cfg.dt_rank or max(16, cfg.d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xc, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([conv_state, xc.astype(conv_state.dtype)], axis=1)
    new_conv = window[:, 1:]
    w = p["conv_w"].astype(jnp.float32)
    xconv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w) \
        + p["conv_b"].astype(jnp.float32)
    xc1 = jax.nn.silu(xconv)                              # [b, di]
    proj = (xc1 @ p["x_proj"].astype(jnp.float32))
    dt_low, bvec, cvec = (proj[..., :dtr], proj[..., dtr:dtr + ds],
                          proj[..., dtr + ds:])
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                  # [b, di]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a[None])              # [b, di, ds]
    h = decay * ssm_state + (dt * xc1)[..., None] * bvec[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cvec) + xc1 * p["d_skip"][None]
    y = (y.astype(x.dtype))[:, None, :] * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"]), new_conv, h


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2)
# ---------------------------------------------------------------------------
def mamba2_specs(cfg: ArchConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.d_state
    nh = di // cfg.ssm_head_dim
    dt = cfg.compute_dtype
    return {
        "in_proj": Spec((d, 2 * di + 2 * ds + nh), dt),
        "conv_w": Spec((cfg.conv_kernel, di + 2 * ds), dt),
        "conv_b": Spec((di + 2 * ds,), dt, init="zeros"),
        "a_log": Spec((nh,), jnp.float32, init="small", scale=0.5),
        "dt_bias": Spec((nh,), jnp.float32, init="zeros"),
        "d_skip": Spec((nh,), jnp.float32, init="ones"),
        "norm_w": Spec((di,), dt, init="ones"),
        "out_proj": Spec((di, d), dt),
    }


def _ssd_core(xh, dt, bmat, cmat, a_log, h0, chunk: int):
    """SSD block decomposition.
    xh [b,s,H,hd] (fp32), dt [b,s,H] (softplus'd), bmat/cmat [b,s,ds],
    a_log [H].  h0 [b,H,hd,ds].  Returns (y [b,s,H,hd], h_final)."""
    b, s, nh, hd = xh.shape
    ds = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // chunk
    xs = (xh.reshape(b, nc, chunk, nh, hd).transpose(1, 0, 2, 3, 4),
          dt.reshape(b, nc, chunk, nh).transpose(1, 0, 2, 3),
          bmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3),
          cmat.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3))
    a = -jnp.exp(a_log)                                   # [H] < 0

    def chunk_body(h, inp):
        xk, dtk, bk, ck = inp                             # [b,ck,...]
        la = jnp.cumsum(dtk * a[None, None], axis=1)      # [b,ck,H] log decay
        # intra-chunk: att[i,j] = (C_i·B_j) exp(la_i - la_j) dt_j,  j <= i
        cb = jnp.einsum("bis,bjs->bij", ck, bk)           # [b,ck,ck]
        ldiff = la[:, :, None, :] - la[:, None, :, :]     # [b,i,j,H]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        att = jnp.where(causal[None, :, :, None],
                        cb[..., None] * jnp.exp(ldiff), 0.0)
        att = att * dtk[:, None, :, :]                    # weight by dt_j
        y_intra = jnp.einsum("bijh,bjhd->bihd", att, xk)
        # inter-chunk: y_i += exp(la_i) * C_i · S_prev
        y_inter = jnp.einsum("bis,bhds->bihd",
                             ck, h) * jnp.exp(la)[..., None]
        # state update: S_new = exp(la_end) S_prev + sum_j exp(la_end-la_j) dt_j x_j B_j^T
        w_j = jnp.exp(la[:, -1:, :] - la) * dtk           # [b,ck,H]
        s_chunk = jnp.einsum("bjh,bjhd,bjs->bhds", w_j, xk, bk)
        h_new = jnp.exp(la[:, -1])[:, :, None, None] * h + s_chunk
        return h_new, y_intra + y_inter

    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, nh, hd)[:, :s]
    return y, h_final


def mamba2(x: jnp.ndarray, p: Params, cfg: ArchConfig,
           chunk: int = 128) -> jnp.ndarray:
    di, ds = cfg.d_inner, cfg.d_state
    nh = di // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, rest = proj[..., :di], proj[..., di:]
    xbc, dt_raw = rest[..., : di + 2 * ds], rest[..., di + 2 * ds:]
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xc, bmat, cmat = (xbc[..., :di], xbc[..., di:di + ds],
                      xbc[..., di + ds:])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    b, s, _ = x.shape
    xh = xc.astype(jnp.float32).reshape(b, s, nh, hd)
    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    y, _ = _ssd_core(xh, dt, bmat.astype(jnp.float32),
                     cmat.astype(jnp.float32), p["a_log"], h0, chunk)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba2_decode(x, p, cfg: ArchConfig, conv_state, ssm_state):
    """x [b,1,d]; conv_state [b,k-1,di+2ds]; ssm_state [b,H,hd,ds] fp32."""
    di, ds = cfg.d_inner, cfg.d_state
    nh = di // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, rest = proj[..., :di], proj[..., di:]
    xbc, dt_raw = rest[..., : di + 2 * ds], rest[..., di + 2 * ds:]
    window = jnp.concatenate([conv_state, xbc.astype(conv_state.dtype)], axis=1)
    new_conv = window[:, 1:]
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w) \
        + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)
    xc, bvec, cvec = (xbc1[..., :di], xbc1[..., di:di + ds],
                      xbc1[..., di + ds:])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None])                          # [b,H]
    xh = xc.reshape(-1, nh, hd)
    h = decay[:, :, None, None] * ssm_state \
        + (dt[:, :, None] * xh)[..., None] * bvec[:, None, None, :]
    y = jnp.einsum("bhds,bs->bhd", h, cvec) + xh * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"]), new_conv, h
