"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full / causal /
sliding-window, train + KV-cache decode), SwiGLU MLP.

All functions are pure; parameters come in as dicts (see common.py).  The
attention mask is parameterized by a *dynamic* per-layer window scalar
(-1 = global) so heterogeneous layer patterns (gemma3's 5 local : 1 global)
run under a single `lax.scan` body — no per-layer retracing.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, Spec

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x [b, s, h, hd], positions [b, s] (or [s])."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [b, s, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
def attention_specs(cfg: ArchConfig, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.hd
    dt = cfg.compute_dtype
    return {
        "wq": Spec((d, cfg.n_heads * hd), dt),
        "wk": Spec((d, cfg.n_kv * hd), dt),
        "wv": Spec((d, cfg.n_kv * hd), dt),
        "wo": Spec((cfg.n_heads * hd, d), dt),
    }


def _window_mask(q_pos, k_pos, window, causal: bool):
    """[.., sq] x [.., sk] positions -> additive mask [.., sq, sk].
    window < 0 => unbounded (global); causal applies q >= k."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    ok &= jnp.where(window >= 0, diff <= jnp.maximum(window, 0), True)
    return ok


def attention(
    x: jnp.ndarray,                 # [b, s, d]
    p: Params,
    cfg: ArchConfig,
    positions: jnp.ndarray,         # [b, s] absolute positions
    window: jnp.ndarray,            # scalar int32; -1 = global
    causal: bool = True,
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,    # cross-attn K/V
    kv_positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv is None:
        k = jnp.einsum("bsd,dq->bsq", x, p["wk"]).reshape(b, s, cfg.n_kv, hd)
        v = jnp.einsum("bsd,dq->bsq", x, p["wv"]).reshape(b, s, cfg.n_kv, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions
    else:
        k, v = kv                                        # [b, sk, n_kv, hd]
        k_pos = kv_positions
    return _attend(q, k, v, positions, k_pos, window, causal, p["wo"], cfg)


def _attend_block(q, k, v, q_pos, k_pos, window, causal):
    """Unchunked grouped-GQA core: q [b,sq,kv,g,hd] x k/v [b,sk,kv,hd] ->
    [b,sq,kv,g,hd].  Never materializes a head-repeated KV copy — for
    kv << n_heads (starcoder2: 4 vs 48) that repeat would cost 12x the
    cache size in activation memory."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) \
        / jnp.sqrt(hd).astype(jnp.float32)
    ok = _window_mask(q_pos, k_pos, window, causal)[:, None, None, :, :]
    scores = jnp.where(ok, scores.astype(jnp.float32), -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", attn, v)


def _attend(q, k, v, q_pos, k_pos, window, causal, wo, cfg: ArchConfig):
    """Attention with query-block chunking: never materializes the full
    [b, h, sq, sk] score tensor beyond one query block (production-required
    at 32k+ context; the Pallas flash kernel is the further §Perf step)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv                                          # GQA group size
    qg = q.reshape(b, sq, kv, g, hd)
    if q_pos.ndim == 1:
        q_pos = q_pos[None, :]
    if k_pos.ndim == 1:
        k_pos = k_pos[None, :]
    q_pos = jnp.broadcast_to(q_pos, (b, sq))
    chunk = cfg.attn_q_chunk
    if sq <= chunk or sq % chunk != 0:
        o = _attend_block(qg, k, v, q_pos, k_pos, window, causal)
    else:
        from .scan_utils import scan_layers
        nc = sq // chunk
        qs = qg.reshape(b, nc, chunk, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = q_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            qc, pc = inp
            return carry, _attend_block(qc, k, v, pc, k_pos, window, causal)

        _, os = scan_layers(body, 0, (qs, ps), cfg.unroll)
        o = os.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kv, g, hd)
    return jnp.einsum("bqo,od->bqd", o.reshape(b, sq, h * hd), wo)


def attention_decode(
    x: jnp.ndarray,                 # [b, 1, d] current token(s)
    p: Params,
    cfg: ArchConfig,
    cache_k: jnp.ndarray,           # [b, smax, n_kv, hd]
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,               # [b] current position (cache fill level)
    window: jnp.ndarray,            # scalar int32; -1 = global
):
    """One decode step: append K/V at `pos`, attend over the filled prefix
    (optionally windowed).  Returns (out [b, 1, d], cache_k, cache_v)."""
    b, s1, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(b, s1, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"]).reshape(b, s1, cfg.n_kv, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"]).reshape(b, s1, cfg.n_kv, hd)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)
    cache_k = _scatter_t(cache_k, k, pos)
    cache_v = _scatter_t(cache_v, v, pos)

    smax = cache_k.shape[1]
    g = cfg.n_heads // cfg.n_kv
    qg = q.reshape(b, s1, cfg.n_kv, g, hd)
    # grouped GQA decode: contract against the raw cache, no head-repeat copy
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, cache_k) \
        / jnp.sqrt(hd).astype(jnp.float32)
    k_positions = jnp.arange(smax)[None, :]              # [1, smax]
    valid = k_positions <= pos[:, None]
    in_win = jnp.where(window >= 0,
                       (pos[:, None] - k_positions) <= jnp.maximum(window, 0),
                       True)
    ok = (valid & in_win)[:, None, None, None, :]
    scores = jnp.where(ok, scores.astype(jnp.float32), -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", attn, cache_v).reshape(
        b, s1, cfg.n_heads * hd)
    return jnp.einsum("bqo,od->bqd", o, p["wo"]), cache_k, cache_v


def _scatter_t(cache, new, pos):
    """Write new [b, 1, ...] into cache [b, smax, ...] at per-batch pos [b]."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), pos].set(new[:, 0].astype(cache.dtype))


# ---------------------------------------------------------------------------
def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.compute_dtype
    if cfg.mlp_gated:
        return {"w_gate": Spec((d, f), dt), "w_up": Spec((d, f), dt),
                "w_down": Spec((f, d), dt)}
    return {"w_up": Spec((d, f), dt), "w_down": Spec((f, d), dt)}


def mlp(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    if "w_gate" in p:                                    # SwiGLU
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:                                                # GELU (starcoder2 etc.)
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def embed_specs(cfg: ArchConfig) -> Params:
    out = {"embedding": Spec((cfg.vocab, cfg.d_model), cfg.compute_dtype)}
    if not cfg.tie_embeddings:
        out["unembed"] = Spec((cfg.vocab, cfg.d_model), cfg.compute_dtype)
    return out


def embed(tokens: jnp.ndarray, p: Params) -> jnp.ndarray:
    return p["embedding"][tokens]


def unembed(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    table = p.get("unembed", p["embedding"])
    return jnp.einsum("bsd,vd->bsv", x, table)
