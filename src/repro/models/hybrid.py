"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
applied after every ``attn_every`` SSM layers (same weights every time).

54 mamba layers / attn_every=6 => 9 groups; group g = 6 scanned mamba2
layers followed by the shared (attention + MLP) block.  The shared block's
KV cache is per-invocation: ``[n_groups, b, smax, n_kv, hd]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ArchConfig, Params, Spec
from .layers import (attention, attention_decode, attention_specs, embed,
                     embed_specs, mlp, mlp_specs, rms_norm, unembed)
from .scan_utils import scan_layers
from .ssm import mamba2, mamba2_decode, mamba2_specs


class HybridLM:
    def __init__(self, cfg: ArchConfig):
        assert cfg.ssm_type == "mamba2" and cfg.attn_every > 0
        assert cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.n_groups = cfg.n_layers // cfg.attn_every

    def _ssm_layer_specs(self) -> Params:
        return {"ln": Spec((self.cfg.d_model,), self.cfg.compute_dtype,
                           init="ones"),
                "ssm": mamba2_specs(self.cfg)}

    def param_specs(self) -> Params:
        cfg = self.cfg
        # stacked as [n_groups, attn_every, ...] for the nested scan
        stack = jax.tree.map(
            lambda s: Spec((self.n_groups, cfg.attn_every) + s.shape,
                           s.dtype, s.init, s.scale),
            self._ssm_layer_specs(), is_leaf=lambda v: isinstance(v, Spec))
        shared = {
            "ln1": Spec((cfg.d_model,), cfg.compute_dtype, init="ones"),
            "attn": attention_specs(cfg),
            "ln2": Spec((cfg.d_model,), cfg.compute_dtype, init="ones"),
            "mlp": mlp_specs(cfg),
        }
        return {"embed": embed_specs(cfg), "ssm_layers": stack,
                "shared": shared,
                "final_norm": Spec((cfg.d_model,), cfg.compute_dtype,
                                   init="ones")}

    # -- forward --------------------------------------------------------------
    def _shared_block(self, x, p, positions, window):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attention(h, p["attn"], cfg, positions, window, causal=True)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(h, p["mlp"])

    def hidden_states(self, params, x):
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])[None, :]
        window = jnp.int32(cfg.sliding_window if cfg.sliding_window else -1)

        chunk = (x.shape[1] if cfg.ssm_chunk == -1
                 else (cfg.ssm_chunk or 128))

        def ssm_layer(x, p):
            h = rms_norm(x, p["ln"], cfg.norm_eps)
            return x + mamba2(h, p["ssm"], cfg, chunk=chunk), None

        def group(x, pg):
            body = ssm_layer
            if cfg.remat:
                body = jax.remat(ssm_layer)
            x, _ = scan_layers(body, x, pg, cfg.unroll)
            x = self._shared_block(x, params["shared"], positions, window)
            return x, None

        x, _ = scan_layers(group, x, params["ssm_layers"], cfg.unroll)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def logits(self, params, tokens, patches=None):
        x = embed(tokens, params["embed"])
        return unembed(self.hidden_states(params, x), params["embed"]), \
            jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.logits(params, batch["tokens"])
        labels = batch["labels"]
        from .losses import cross_entropy
        return cross_entropy(logits, labels)

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        nh = cfg.d_inner // cfg.ssm_head_dim
        g = self.n_groups
        return {
            "conv": jnp.zeros((g, cfg.attn_every, batch, cfg.conv_kernel - 1,
                               cfg.d_inner + 2 * cfg.d_state),
                              cfg.compute_dtype),
            "ssm": jnp.zeros((g, cfg.attn_every, batch, nh, cfg.ssm_head_dim,
                              cfg.d_state), jnp.float32),
            "k": jnp.zeros((g, batch, max_len, cfg.n_kv, cfg.hd),
                           cfg.compute_dtype),
            "v": jnp.zeros((g, batch, max_len, cfg.n_kv, cfg.hd),
                           cfg.compute_dtype),
        }

    def cache_specs(self, batch: int, max_len: int) -> Params:
        dummy = jax.eval_shape(lambda: self.init_cache(batch, max_len))
        return dummy

    def prefill(self, params, tokens, cache, patches=None):
        logits, _ = self.logits(params, tokens)
        return logits[:, -1:], cache

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = embed(token, params["embed"])
        window = jnp.int32(cfg.sliding_window if cfg.sliding_window else -1)

        def ssm_step(x, inp):
            p, conv, ssm = inp
            h = rms_norm(x, p["ln"], cfg.norm_eps)
            y, conv, ssm = mamba2_decode(h, p["ssm"], cfg, conv, ssm)
            return x + y, (conv, ssm)

        def group(carry, inp):
            x, k_all, v_all = carry
            pg, conv_g, ssm_g, i = inp
            x, (conv_g, ssm_g) = scan_layers(ssm_step, x, (pg, conv_g, ssm_g),
                                             cfg.unroll)
            sp = params["shared"]
            h = rms_norm(x, sp["ln1"], cfg.norm_eps)
            ck = jax.lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            o, ck, cv = attention_decode(h, sp["attn"], cfg, ck, cv, pos,
                                         window)
            k_all = jax.lax.dynamic_update_index_in_dim(
                k_all, ck.astype(k_all.dtype), i, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(
                v_all, cv.astype(v_all.dtype), i, 0)
            x = x + o
            h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
            x = x + mlp(h2, sp["mlp"])
            return (x, k_all, v_all), (conv_g, ssm_g)

        idx = jnp.arange(self.n_groups)
        (x, k, v), (conv, ssm) = scan_layers(
            group, (x, cache["k"], cache["v"]),
            (params["ssm_layers"], cache["conv"], cache["ssm"], idx),
            cfg.unroll)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(h, params["embed"]), {"conv": conv, "ssm": ssm,
                                             "k": k, "v": v}
