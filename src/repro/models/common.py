"""Architecture config + spec-driven parameter utilities.

Parameters are plain nested dicts of jnp arrays ("pytree params", no flax).
Every module defines its parameters once as *specs* (shape + init scale);
``init_params`` materializes them with jax.random, ``abstract_params`` turns
them into ShapeDtypeStructs for the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One config covers every assigned family (unused fields ignored)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # -- attention pattern ------------------------------------------------
    sliding_window: Optional[int] = None    # local window size (tokens)
    global_every: Optional[int] = None      # gemma3: 1 global per N layers
    mlp_gated: bool = True                  # SwiGLU (True) vs GELU 2-matrix

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0                       # per-expert ffn width
    capacity_factor: float = 1.25

    # -- SSM ---------------------------------------------------------------
    ssm_type: Optional[str] = None          # mamba1 | mamba2
    d_state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    ssm_head_dim: int = 64                  # mamba2 head dim
    dt_rank: Optional[int] = None

    # -- hybrid (zamba2): one *shared* attention block every k ssm layers --
    attn_every: int = 0

    # -- encoder-decoder (whisper) -----------------------------------------
    n_enc_layers: int = 0
    n_frames: int = 1500                    # stub conv-frontend output length

    # -- VLM stub frontend ---------------------------------------------------
    n_patches: int = 0

    # -- compute -----------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    unroll: bool = False            # unroll layer scans (roofline accounting)
    ssm_chunk: int = 0              # 0 = default chunk; -1 = single chunk
    attn_q_chunk: int = 1024        # query-block size for chunked attention
    seq_parallel: bool = False      # shard residual stream seq over 'model'
    moe_local_dispatch: bool = False  # per-dp-block dispatch sort (EP a2a)
    remat_policy: str = "full"      # full | dots | none
    decode_shard: str = "auto"      # auto | seq | heads (KV cache layout)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        from .api import build_model
        specs = build_model(self).param_specs()
        return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(specs))

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        total = self.n_params()
        if self.family != "moe":
            return total
        per_expert = 3 * self.d_model * self.d_expert
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return total - inactive


# ---------------------------------------------------------------------------
# Spec-driven params
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"            # normal | zeros | ones | small
    scale: float = 1.0


def abstract_params(specs: Params) -> Params:
    return jax.tree.map(
        lambda sp: jax.ShapeDtypeStruct(sp.shape, sp.dtype), specs,
        is_leaf=lambda v: isinstance(v, Spec))


def init_params(specs: Params, key: jax.Array) -> Params:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda v: isinstance(v, Spec))
    keys = jax.random.split(key, len(leaves))

    def mk(sp: Spec, k):
        if sp.init == "zeros":
            return jnp.zeros(sp.shape, sp.dtype)
        if sp.init == "ones":
            return jnp.ones(sp.shape, sp.dtype)
        fan_in = sp.shape[-2] if len(sp.shape) >= 2 else sp.shape[-1]
        std = sp.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, sp.shape, jnp.float32) * std).astype(sp.dtype)

    return jax.tree.unflatten(treedef, [mk(sp, k) for sp, k in zip(leaves, keys)])


def count_params(specs: Params) -> int:
    return sum(int(math.prod(sp.shape)) for sp in jax.tree.leaves(
        specs, is_leaf=lambda v: isinstance(v, Spec)) if isinstance(sp, Spec))
