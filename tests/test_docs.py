"""Docs-consistency gate (same checks CI runs via tools/check_docs.py)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))
import check_docs  # noqa: E402


def test_every_benchmark_is_documented():
    """docs/benchmarks.md must mention every benchmarks/bench_*.py."""
    assert check_docs.check_bench_docs() == []


def test_readme_links_docs():
    """README must link docs/architecture.md and docs/benchmarks.md."""
    assert check_docs.check_readme_links() == []


def test_streaming_and_distributed_docstrings():
    """Docstring lint over src/repro/streaming and src/repro/distributed."""
    assert check_docs.check_docstrings() == []
