"""Filter predicates vs analytic oracles (incl. hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.filters import BallFilter, BoxFilter, ComposeFilter, PolygonFilter
from repro.core.workloads import (make_ball_filter, make_box_filter,
                                  make_compose_filter, make_polygon_filter)


def test_box_contains():
    f = BoxFilter(lo=jnp.asarray([0.2, 0.2]), hi=jnp.asarray([0.6, 0.8]))
    s = jnp.asarray([[0.3, 0.5], [0.1, 0.5], [0.6, 0.8], [0.61, 0.5]])
    assert np.array_equal(np.asarray(f.contains(s)), [True, False, True, False])


def test_ball_contains():
    f = BallFilter(center=jnp.asarray([0.5, 0.5]), radius=jnp.float32(0.2))
    s = jnp.asarray([[0.5, 0.5], [0.5, 0.69], [0.5, 0.71], [0.9, 0.9]])
    assert np.array_equal(np.asarray(f.contains(s)), [True, True, False, False])


def test_ball_extra_dims_ignored():
    """Ball over first 2 dims only; dim 3 is unconstrained."""
    f = BallFilter(center=jnp.asarray([0.5, 0.5]), radius=jnp.float32(0.2))
    s = jnp.asarray([[0.5, 0.5, 99.0], [0.9, 0.9, 0.0]])
    assert np.array_equal(np.asarray(f.contains(s)), [True, False])


def test_polygon_square():
    """Unit test: axis-aligned square polygon == box."""
    verts = jnp.asarray([[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]])
    f = PolygonFilter(vertices=verts, rest_lo=jnp.zeros(0), rest_hi=jnp.zeros(0))
    rng = np.random.default_rng(0)
    s = rng.uniform(0, 1, size=(500, 2)).astype(np.float32)
    got = np.asarray(f.contains(jnp.asarray(s)))
    want = np.all((s >= 0.2) & (s <= 0.8), axis=1)
    # boundary points may differ; exclude near-boundary
    interior = np.all(np.abs(s - 0.2) > 1e-3, axis=1) & np.all(np.abs(s - 0.8) > 1e-3, axis=1)
    assert np.array_equal(got[interior], want[interior])


def _winding_oracle(pt, verts):
    """Crossing-number oracle in pure python."""
    x, y = pt
    inside = False
    n = len(verts)
    for i in range(n):
        x1, y1 = verts[i]
        x2, y2 = verts[(i + 1) % n]
        if (y1 > y) != (y2 > y):
            xint = x1 + (y - y1) / (y2 - y1) * (x2 - x1)
            if x < xint:
                inside = not inside
    return inside


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), px=st.floats(0, 1), py=st.floats(0, 1))
def test_polygon_vs_oracle(seed, px, py):
    f = make_polygon_filter(2, 0.1, n_vertices=5, seed=seed)
    verts = np.asarray(f.vertices)
    got = bool(np.asarray(f.contains(jnp.asarray([[px, py]], jnp.float32)))[0])
    want = _winding_oracle((px, py), verts)
    # skip points within eps of any edge (fp boundary sensitivity)
    from numpy.linalg import norm
    eps = 1e-4
    p = np.array([px, py])
    for i in range(len(verts)):
        a, b = verts[i], verts[(i + 1) % len(verts)]
        t = np.clip(np.dot(p - a, b - a) / (norm(b - a) ** 2 + 1e-12), 0, 1)
        if norm(p - (a + t * (b - a))) < eps:
            return
    assert got == want


def test_compose_andnot():
    f = make_compose_filter(2, 0.1, seed=5)
    rng = np.random.default_rng(1)
    s = rng.uniform(0, 1, size=(1000, 2)).astype(np.float32)
    got = np.asarray(f.contains(jnp.asarray(s)))
    a = np.asarray(f.a.contains(jnp.asarray(s)))
    b = np.asarray(f.b.contains(jnp.asarray(s)))
    assert np.array_equal(got, a & ~b)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), ratio=st.floats(0.01, 0.3),
       m=st.integers(2, 4))
def test_workload_filters_selectivity(seed, ratio, m):
    """Generated filters hit roughly the requested volume ratio on uniform
    metadata (within loose tolerance — the paper's 'filter ratio')."""
    rng = np.random.default_rng(seed)
    s = rng.uniform(0, 1, size=(4000, m)).astype(np.float32)
    f = make_box_filter(m, ratio, seed=seed)
    frac = float(np.asarray(f.contains(jnp.asarray(s))).mean())
    assert 0.2 * ratio < frac < 5 * ratio + 0.02


def test_bounding_boxes_contain_filters():
    for mk in (make_box_filter, make_ball_filter, make_polygon_filter,
               make_compose_filter):
        f = mk(2, 0.08, seed=7)
        lo, hi = f.bounding_box()
        rng = np.random.default_rng(3)
        s = rng.uniform(0, 1, size=(2000, 2)).astype(np.float32)
        inside = np.asarray(f.contains(jnp.asarray(s)))
        in_bb = np.all((s >= lo[:2] - 1e-6) & (s <= hi[:2] + 1e-6), axis=1)
        assert not np.any(inside & ~in_bb)        # bbox is conservative


def test_compose_mixed_dim_bounding_box():
    """Regression: 2D ball AND 3D box (different dim prefixes) must compose
    a finite 3D bounding box (caught by examples/spatial_filters.py)."""
    f = make_ball_filter(3, 0.08, seed=2)       # ComposeFilter(ball2d, box3d)
    lo, hi = f.bounding_box()
    assert len(lo) == 3 and len(hi) == 3
    assert np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))
    assert f.characteristic_length() < 10.0
