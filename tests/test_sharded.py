"""Mesh-sharded segment search: exactness, masking, pruning, manager path,
and the size-bucketed incrementally maintained pack (parity vs a
from-scratch build, bucket-capacity isolation, whole-block pruning)."""
import numpy as np
import pytest

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_box_filter, make_dataset,
                                  make_polygon_filter, recall)
from repro.distributed.segment_shards import (BucketedShardPack, PackView,
                                              SegmentShardSource,
                                              bucket_cap_for,
                                              build_bucketed_pack,
                                              build_shard_pack,
                                              make_shard_mesh, pack_search,
                                              pack_search_blocks)
from repro.kernels import filtered_topk
from repro.streaming import SegmentManager, StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=3, m_intra=10, m_cross=3)


def _assert_same_topk(g_a, d_a, g_b, d_b):
    """Distances must match bit-for-bit; gids wherever distances are
    unique (equal-distance neighbors may legally reorder)."""
    assert np.array_equal(d_a, d_b)
    uniq = np.ones_like(g_a, bool)
    uniq[:, 1:] &= d_a[:, 1:] != d_a[:, :-1]
    uniq[:, :-1] &= d_a[:, :-1] != d_a[:, 1:]
    assert np.array_equal(g_a[uniq], g_b[uniq])


def _segmented_dataset(seed, n_segments, d=32, m=3):
    """Random per-segment point sets with disjoint global ids + the
    concatenated monolithic view."""
    rng = np.random.default_rng(seed)
    sources, gid0 = [], 0
    for sid in range(n_segments):
        n = int(rng.integers(120, 800))
        x = rng.normal(size=(n, d)).astype(np.float32)
        s = rng.uniform(size=(n, m))
        g = np.arange(gid0, gid0 + n, dtype=np.int64)
        gid0 += n
        sources.append(SegmentShardSource(sid, x, s, g,
                                          float(s[:, m - 1].min()),
                                          float(s[:, m - 1].max())))
    x_all = np.concatenate([src.x for src in sources])
    s_all = np.concatenate([src.s for src in sources])
    g_all = np.concatenate([src.gids for src in sources])
    return sources, x_all, s_all, g_all


def _filters(m, seed):
    yield None
    yield make_box_filter(m, 0.4, seed=seed)
    yield make_ball_filter(m, 0.5, seed=seed)
    yield ComposeFilter(BoxFilter(lo=np.zeros(m, np.float32),
                                  hi=np.ones(m, np.float32)),
                        IntervalFilter(dim=m - 1, lo=np.float32(0.3)), "and")
    yield make_polygon_filter(m, 0.6, seed=seed)   # no kernel encoding


@pytest.mark.parametrize("seed,n_segments,n_shards,k", [
    (0, 1, 1, 1), (1, 2, 3, 10), (2, 3, 2, 7), (3, 4, 4, 33),
    (4, 2, 6, 300),                    # k > per-shard capacity
])
def test_shard_merge_matches_single_device_exactly(seed, n_segments,
                                                   n_shards, k):
    """Property (randomized workloads): the sharded fan-out + exact merge
    returns bit-for-bit the distances of the monolithic single-device
    kernel, for every filter kind including the jnp fallback."""
    sources, x_all, s_all, g_all = _segmented_dataset(seed, n_segments)
    pack = build_shard_pack(sources, n_shards=n_shards, epoch=0)
    rng = np.random.default_rng(seed + 100)
    q = rng.normal(size=(8, x_all.shape[1])).astype(np.float32)
    for filt in _filters(3, seed):
        gi, di = pack_search(pack, q, filt, k=k)
        mi, md = filtered_topk(q, x_all, s_all, filt, min(k, len(g_all)))
        mi, md = np.asarray(mi), np.asarray(md, np.float32)
        mg = np.where(mi >= 0, g_all[np.maximum(mi, 0)], -1)
        kk = mg.shape[1]
        assert np.array_equal(di[:, :kk], md), f"dists differ for {filt}"
        # gids must match wherever distances are unique (ties may reorder)
        uniq = np.ones_like(mg, bool)
        uniq[:, 1:] &= md[:, 1:] != md[:, :-1]
        uniq[:, :-1] &= md[:, :-1] != md[:, 1:]
        assert np.array_equal(gi[:, :kk][uniq], mg[uniq])


try:                                     # richer search space when available
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), n_segments=st.integers(1, 4),
           n_shards=st.integers(1, 6), k=st.integers(1, 40))
    def test_shard_merge_matches_single_device_hypothesis(seed, n_segments,
                                                          n_shards, k):
        """Same exactness property, hypothesis-driven."""
        sources, x_all, s_all, g_all = _segmented_dataset(seed, n_segments)
        pack = build_shard_pack(sources, n_shards=n_shards, epoch=0)
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(4, x_all.shape[1])).astype(np.float32)
        f = make_box_filter(3, 0.5, seed=seed)
        gi, di = pack_search(pack, q, f, k=k)
        mi, md = filtered_topk(q, x_all, s_all, f, min(k, len(g_all)))
        md = np.asarray(md, np.float32)
        assert np.array_equal(di[:, :md.shape[1]], md)
except ImportError:                      # pragma: no cover - optional dep
    pass


def test_pack_on_mesh_and_dead_masking():
    """Mesh-placed pack answers identically; mark_dead masks points from
    every later query without restacking."""
    sources, x_all, s_all, g_all = _segmented_dataset(7, 3)
    mesh = make_shard_mesh()
    pack = build_shard_pack(sources, n_shards=2 * mesh.devices.size,
                            epoch=0, mesh=mesh)
    rng = np.random.default_rng(7)
    q = rng.normal(size=(6, 32)).astype(np.float32)
    gi0, di0 = pack_search(pack, q, None, k=12)
    dead = g_all[rng.choice(len(g_all), 150, replace=False)]
    assert pack.mark_dead(dead) == 150
    gi1, _ = pack_search(pack, q, None, k=12)
    assert not (set(gi1[gi1 >= 0].tolist()) & set(dead.tolist()))
    # masking is monotone: surviving results are the old ones minus dead
    alive0 = [g for g in gi0[0].tolist() if g not in set(dead.tolist())]
    assert gi1[0].tolist()[: len(alive0)] == alive0


def test_pack_temporal_pruning_masks_rows():
    """Rows whose segment span misses the window contribute nothing."""
    sources, x_all, s_all, g_all = _segmented_dataset(11, 3)
    pack = build_shard_pack(sources, n_shards=2, epoch=0)
    q = np.zeros((2, 32), np.float32)
    gi, _ = pack_search(pack, q, None, k=5, t_lo=2.0, t_hi=3.0)
    assert np.all(gi == -1)
    active = pack.active_rows(2.0, 3.0)
    assert not active.any()
    assert pack.active_rows(-np.inf, np.inf).all()


def test_manager_sharded_path_matches_graph_path():
    """End-to-end: the sharded kernel read path is exact, so it must reach
    at least the recall of the default graph path on the same manager
    state, and must agree with brute-force ground truth."""
    x, s = make_dataset(2500, 24, 3, seed=5)
    s[:, 2] = np.arange(2500) / 2500
    cfg = StreamConfig(time_dim=2, seal_max_points=600, n_shards=3,
                       index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg, shard_mesh=make_shard_mesh())
    mgr.ingest(x, s)
    rng = np.random.default_rng(6)
    q = (x[rng.integers(0, 2500, 8)]
         + 0.05 * rng.normal(size=(8, 24)).astype(np.float32))
    f = ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                hi=np.ones(3, np.float32)),
                      IntervalFilter(dim=2, lo=np.float32(0.2)), "and")
    gt, _ = ground_truth(x, s, q, f, 10, valid=mgr.alive)
    ids_sh, _ = mgr.query(q, f, k=10)                      # n_shards=3 path
    ids_gr, _ = mgr.query(q, f, k=10, ef=128, use_shards=False)
    r_sh, r_gr = recall(ids_sh, gt), recall(ids_gr, gt)
    assert r_sh >= r_gr
    assert r_sh >= 0.99                   # exact on sealed; delta also exact
    # epoch bump (a new seal) delta-updates the cached pack in place —
    # same device-resident object, advanced epoch, no full rebuild
    pack0 = mgr._pack
    epoch0 = pack0.epoch
    mgr.ingest(x[:700], s[:700] * np.array([1, 1, 0]) + np.array([0, 0, 1.5]))
    f_old = ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                    hi=np.ones(3, np.float32)),
                          IntervalFilter(dim=2, lo=np.float32(0.2),
                                         hi=np.float32(1.2)), "and")
    ids2, _ = mgr.query(q, f_old, k=10)   # window excludes the new batch
    assert mgr._pack is pack0
    assert mgr._pack.epoch > epoch0 and mgr._pack.epoch == mgr.epoch
    assert recall(ids2, gt) >= 0.99       # old-window results unchanged


# ---------------------------------------------------------------------------
# Size-bucketed incrementally maintained pack
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 3])
def test_bucketed_pack_matches_legacy_cold(n_shards):
    """A cold-built bucketed pack answers exactly like the legacy
    monolithic pack for every filter kind (incl. the jnp fallback)."""
    sources, x_all, s_all, g_all = _segmented_dataset(13, 4)
    legacy = build_shard_pack(sources, n_shards=n_shards)
    bucketed = build_bucketed_pack(sources, n_shards=n_shards)
    rng = np.random.default_rng(13)
    q = rng.normal(size=(6, 32)).astype(np.float32)
    for filt in _filters(3, 13):
        gl, dl = pack_search(legacy, q, filt, k=17)
        gb, db = pack_search(bucketed, q, filt, k=17)
        _assert_same_topk(gl, dl, gb, db)


def test_bucketed_incremental_add_remove_reuse():
    """Adds, removals, slot reuse, and deletes keep the incrementally
    maintained pack bit-for-bit equal to a from-scratch build of the same
    live segments."""
    sources, _, _, g_all = _segmented_dataset(17, 5)
    rng = np.random.default_rng(17)
    q = rng.normal(size=(5, 32)).astype(np.float32)
    pack = BucketedShardPack(n_shards=2, d=32, m=3)
    for src in sources[:4]:
        pack.add_segment(src)
    # remove one segment, re-add another into the freed slot (reuse)
    assert pack.remove_segment(sources[1].seg_id)
    assert not pack.remove_segment(999)           # unknown id: no-op
    pack.add_segment(sources[4])
    live_sources = [sources[0], sources[2], sources[3], sources[4]]
    fresh = build_shard_pack(live_sources, n_shards=2)
    scratch = build_bucketed_pack(live_sources, n_shards=2)
    for filt in (None, make_box_filter(3, 0.5, seed=17)):
        gi, di = pack_search(pack, q, filt, k=11)
        gf, df = pack_search(fresh, q, filt, k=11)
        gs, ds = pack_search(scratch, q, filt, k=11)
        _assert_same_topk(gi, di, gf, df)
        _assert_same_topk(gi, di, gs, ds)
    # deletes scatter PAD_META functionally and stay in lockstep
    live_gids = np.concatenate([s.gids for s in live_sources])
    dead = rng.choice(live_gids, 120, replace=False)
    assert pack.mark_dead(dead) == fresh.mark_dead(dead) == 120
    gi, di = pack_search(pack, q, None, k=11)
    gf, df = pack_search(fresh, q, None, k=11)
    _assert_same_topk(gi, di, gf, df)
    assert not (set(gi[gi >= 0].tolist()) & set(dead.tolist()))


def test_jumbo_segment_does_not_inflate_buckets():
    """Regression for the padding tax: one jumbo post-compaction segment
    must not inflate the padded capacity (or device bytes) of the buckets
    holding the small segments."""
    rng = np.random.default_rng(23)
    sources, gid0 = [], 0
    for sid, n in enumerate([300, 280, 330, 310, 4000]):
        x = rng.normal(size=(n, 32)).astype(np.float32)
        s = rng.uniform(size=(n, 3))
        g = np.arange(gid0, gid0 + n, dtype=np.int64)
        gid0 += n
        sources.append(SegmentShardSource(sid, x, s, g,
                                          float(s[:, 2].min()),
                                          float(s[:, 2].max())))
    smalls, jumbo = sources[:4], sources[4]
    n_shards = 2
    pack = build_bucketed_pack(smalls, n_shards=n_shards)
    small_cap = bucket_cap_for(330, n_shards)
    assert sorted(pack.buckets) == [small_cap]
    # the jumbo lands in its own bucket; the small bucket is untouched
    pack.add_segment(jumbo)
    jumbo_cap = bucket_cap_for(4000, n_shards)
    assert sorted(pack.buckets) == sorted({small_cap, jumbo_cap})
    assert jumbo_cap > small_cap
    assert pack.buckets[small_cap].cap == small_cap
    # per-bucket padding bound: cap <= 2x the tile-aligned largest shard
    for srcs, cap in ((smalls, small_cap), ([jumbo], jumbo_cap)):
        largest = max(-(-len(s.gids) // n_shards) for s in srcs)
        aligned = -(-largest // 256) * 256
        assert cap <= 2 * aligned
    # the monolithic layout pays the tax on every row; the buckets don't
    legacy = build_shard_pack(sources, n_shards=n_shards)
    assert legacy.cap == jumbo_cap
    assert pack.nbytes < legacy.nbytes
    # and the answers are still identical
    q = rng.normal(size=(4, 32)).astype(np.float32)
    gi, di = pack_search(pack, q, None, k=9)
    gl, dl = pack_search(legacy, q, None, k=9)
    _assert_same_topk(gi, di, gl, dl)


def test_host_topk_deterministic_under_block_order():
    """The exact merge's output is invariant to candidate order — finite
    distance ties at the argpartition boundary resolve by gid, inf padding
    collapses to -1."""
    import itertools

    from repro.distributed.segment_shards import host_topk
    d0 = np.array([1.0, 2.0, 2.0, 2.0, 3.0, np.inf], np.float32)
    g0 = np.array([50, 30, 10, 20, 5, -1], np.int64)
    ref = None
    for perm in itertools.permutations(range(6)):
        gi, di = host_topk(g0[list(perm)][None], d0[list(perm)][None], 3)
        if ref is None:
            ref = (gi, di)
        assert np.array_equal(gi, ref[0]) and np.array_equal(di, ref[1])
    assert ref[0].tolist() == [[50, 10, 20]]      # boundary tie -> min gids
    assert ref[1].tolist() == [[1.0, 2.0, 2.0]]
    # rows narrower than k pad with -1/inf
    gi, di = host_topk(g0[None, :2], d0[None, :2], 5)
    assert gi.shape == (1, 5) and gi[0, 2:].tolist() == [-1, -1, -1]


def test_retired_bucket_releases_device_memory():
    """Removing a bucket's last segment frees the whole capacity class —
    a retired jumbo must not pin device memory at its historical peak."""
    sources, _, _, _ = _segmented_dataset(37, 2)
    rng = np.random.default_rng(37)
    jumbo = SegmentShardSource(
        99, rng.normal(size=(5000, 32)).astype(np.float32),
        rng.uniform(size=(5000, 3)),
        np.arange(10_000, 15_000, dtype=np.int64), 0.0, 1.0)
    pack = build_bucketed_pack(sources, n_shards=2)
    base_nbytes = pack.nbytes
    pack.add_segment(jumbo)
    jumbo_cap = bucket_cap_for(5000, 2)
    assert jumbo_cap in pack.buckets and pack.nbytes > base_nbytes
    view = pack.view()                    # in-flight query snapshot
    assert pack.remove_segment(99)
    assert jumbo_cap not in pack.buckets  # capacity class released
    assert pack.nbytes == base_nbytes
    # the captured view still answers from its own references
    q = rng.normal(size=(2, 32)).astype(np.float32)
    gi, _ = pack_search(view, q, None, k=5)
    assert (gi >= 10_000).any()
    # and a new jumbo re-creates the class from scratch
    pack.add_segment(jumbo)
    assert jumbo_cap in pack.buckets
    gi2, _ = pack_search(pack, q, None, k=5)
    assert (gi2 >= 10_000).any()


def test_bucketed_whole_block_pruning():
    """Temporal pruning skips entire bucket device blocks: a window that
    misses a bucket's segments produces no candidate block for it."""
    rng = np.random.default_rng(29)
    mk = lambda sid, n, t0: SegmentShardSource(
        sid, rng.normal(size=(n, 32)).astype(np.float32),
        np.concatenate([rng.uniform(size=(n, 2)),
                        np.full((n, 1), t0)], axis=1),
        np.arange(sid * 10000, sid * 10000 + n, dtype=np.int64), t0, t0 + 0.1)
    pack = build_bucketed_pack([mk(0, 200, 0.0), mk(1, 3000, 5.0)],
                               n_shards=2)
    q = rng.normal(size=(3, 32)).astype(np.float32)
    view = pack.view()
    assert isinstance(view, PackView) and len(view.buckets) == 2
    assert len(pack_search_blocks(view, q, None, 5)) == 2
    # window hits only the small bucket -> one dispatch, one block
    blocks = pack_search_blocks(view, q, None, 5, t_lo=-1.0, t_hi=1.0)
    assert len(blocks) == 1
    assert set(blocks[0][0][blocks[0][0] >= 0].tolist()) <= set(range(200))
    # window missing everything -> zero dispatches and -1/inf padding
    assert pack_search_blocks(view, q, None, 5, t_lo=9.0, t_hi=10.0) == []
    gi, di = pack_search(view, q, None, k=5, t_lo=9.0, t_hi=10.0)
    assert np.all(gi == -1) and np.all(np.isinf(di))


def test_bucketed_pack_on_mesh_matches():
    """Mesh-placed bucketed pack answers identically to the mesh-placed
    legacy pack, including after functional dead-masking."""
    sources, x_all, s_all, g_all = _segmented_dataset(31, 3)
    mesh = make_shard_mesh()
    n_shards = 2 * mesh.devices.size
    legacy = build_shard_pack(sources, n_shards=n_shards, mesh=mesh)
    pack = build_bucketed_pack(sources, n_shards=n_shards, mesh=mesh)
    # every bucket block must stay shard-axis partitionable on the mesh —
    # _init_slots aligns allocation even when n_shards doesn't divide the
    # device count (checked with n_shards=3 below)
    for p in (pack, build_bucketed_pack(sources, n_shards=3, mesh=mesh)):
        for b in p.buckets.values():
            assert b.n_rows % mesh.devices.size == 0
    rng = np.random.default_rng(31)
    q = rng.normal(size=(6, 32)).astype(np.float32)
    gi, di = pack_search(pack, q, None, k=12)
    gl, dl = pack_search(legacy, q, None, k=12)
    _assert_same_topk(gi, di, gl, dl)
    dead = g_all[rng.choice(len(g_all), 150, replace=False)]
    assert pack.mark_dead(dead) == 150
    gi1, _ = pack_search(pack, q, None, k=12)
    assert not (set(gi1[gi1 >= 0].tolist()) & set(dead.tolist()))


def _apply_stream_ops(mgr, rng, ops, d=24):
    """Drive one manager through an interleaving of lifecycle ops."""
    t = getattr(mgr, "_test_t", 0.0)
    for op in ops:
        if op == 0 or mgr.n_total == 0:           # ingest
            nb = int(rng.integers(40, 150))
            x = rng.normal(size=(nb, d)).astype(np.float32)
            s = rng.uniform(size=(nb, 3))
            s[:, 2] = t + np.linspace(0.0, 0.05, nb)
            t += 0.25
            mgr.ingest(x, s)
        elif op == 1:                             # delete
            g = rng.integers(0, mgr.n_total, size=25)
            mgr.delete(g)
        elif op == 2:                             # seal
            mgr.seal()
        elif op == 3:                             # compact (merges + GC)
            mgr.compact()
        elif op == 4:                             # expire (finite ttl)
            mgr.expire()
    mgr._test_t = t


def _check_incremental_matches_from_scratch(seed, n_shards, ops):
    """Shared property body: after an arbitrary interleaving of ingest /
    delete / seal / compact / expire, the incrementally maintained pack
    answers bit-for-bit (dists; gids up to equal-distance ties) identically
    to a from-scratch ``build_shard_pack`` — through the raw pack search
    AND the full fan-out query path, for n_shards = 1 and > 1."""
    rng = np.random.default_rng(seed)
    cfg = StreamConfig(time_dim=2, seal_max_points=120, n_shards=n_shards,
                       compact_max_segments=3, ttl=1.5, index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg)
    _apply_stream_ops(mgr, rng, [0, 2])           # one sealed segment
    q = rng.normal(size=(4, 24)).astype(np.float32)
    mgr.query(q, None, k=5)                       # cold-build the pack
    pack0 = mgr._pack
    assert isinstance(pack0, BucketedShardPack)
    _apply_stream_ops(mgr, rng, ops)
    mgr.seal()
    # the pack must have been maintained by deltas, never invalidated
    if mgr._pack is not None:
        assert mgr._pack is pack0
        assert mgr._pack.epoch == mgr.epoch
    epoch, segments, _ = mgr.snapshot()
    live = [g for g in segments if g.n_live > 0]
    filters = [None, make_box_filter(3, 0.6, seed=seed),
               IntervalFilter(dim=2, lo=np.float32(0.2))]
    if live:
        view = mgr.shard_pack(epoch, live)
        assert isinstance(view, PackView) and view.epoch == epoch
        sources = [SegmentShardSource(g.seg_id, *g.live_points(),
                                      g.t_min, g.t_max) for g in live]
        fresh = build_shard_pack(sources, n_shards)
        for filt in filters:
            gi, di = pack_search(view, q, filt, k=15)
            gf, df = pack_search(fresh, q, filt, k=15)
            _assert_same_topk(gi, di, gf, df)
    # fan-out parity: the full query path (delta + buckets + liveness)
    # after a forced cold rebuild must reproduce the incremental answer
    for filt in filters:
        gi, di = mgr.query(q, filt, k=15)
        mgr._pack = None
        gr, dr = mgr.query(q, filt, k=15)
        _assert_same_topk(gi, di, gr, dr)


@pytest.mark.parametrize("seed,n_shards,ops", [
    (101, 1, [0, 1, 2, 0, 3, 1, 4]),     # fan-out path, all op kinds
    (202, 3, [0, 2, 1, 3, 0, 0, 4, 2]),  # sharded path, expiry + merges
    (303, 3, [1, 0, 3, 3, 2, 1]),        # repeated compaction, GC rewrite
])
def test_incremental_pack_matches_from_scratch(seed, n_shards, ops):
    """Deterministic interleavings of the parity property (always runs;
    the hypothesis variant below widens the search space when available)."""
    _check_incremental_matches_from_scratch(seed, n_shards, ops)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), n_shards=st.sampled_from([1, 3]),
           ops=st.lists(st.integers(0, 4), min_size=3, max_size=8))
    def test_incremental_pack_matches_from_scratch_hypothesis(seed, n_shards,
                                                              ops):
        """Same parity property, hypothesis-driven op interleavings."""
        _check_incremental_matches_from_scratch(seed, n_shards, ops)
except ImportError:                      # pragma: no cover - optional dep
    pass
