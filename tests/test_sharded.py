"""Mesh-sharded segment search: exactness, masking, pruning, manager path."""
import numpy as np
import pytest

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_box_filter, make_dataset,
                                  make_polygon_filter, recall)
from repro.distributed.segment_shards import (SegmentShardSource,
                                              build_shard_pack,
                                              make_shard_mesh, pack_search)
from repro.kernels import filtered_topk
from repro.streaming import SegmentManager, StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=3, m_intra=10, m_cross=3)


def _segmented_dataset(seed, n_segments, d=32, m=3):
    """Random per-segment point sets with disjoint global ids + the
    concatenated monolithic view."""
    rng = np.random.default_rng(seed)
    sources, gid0 = [], 0
    for sid in range(n_segments):
        n = int(rng.integers(120, 800))
        x = rng.normal(size=(n, d)).astype(np.float32)
        s = rng.uniform(size=(n, m))
        g = np.arange(gid0, gid0 + n, dtype=np.int64)
        gid0 += n
        sources.append(SegmentShardSource(sid, x, s, g,
                                          float(s[:, m - 1].min()),
                                          float(s[:, m - 1].max())))
    x_all = np.concatenate([src.x for src in sources])
    s_all = np.concatenate([src.s for src in sources])
    g_all = np.concatenate([src.gids for src in sources])
    return sources, x_all, s_all, g_all


def _filters(m, seed):
    yield None
    yield make_box_filter(m, 0.4, seed=seed)
    yield make_ball_filter(m, 0.5, seed=seed)
    yield ComposeFilter(BoxFilter(lo=np.zeros(m, np.float32),
                                  hi=np.ones(m, np.float32)),
                        IntervalFilter(dim=m - 1, lo=np.float32(0.3)), "and")
    yield make_polygon_filter(m, 0.6, seed=seed)   # no kernel encoding


@pytest.mark.parametrize("seed,n_segments,n_shards,k", [
    (0, 1, 1, 1), (1, 2, 3, 10), (2, 3, 2, 7), (3, 4, 4, 33),
    (4, 2, 6, 300),                    # k > per-shard capacity
])
def test_shard_merge_matches_single_device_exactly(seed, n_segments,
                                                   n_shards, k):
    """Property (randomized workloads): the sharded fan-out + exact merge
    returns bit-for-bit the distances of the monolithic single-device
    kernel, for every filter kind including the jnp fallback."""
    sources, x_all, s_all, g_all = _segmented_dataset(seed, n_segments)
    pack = build_shard_pack(sources, n_shards=n_shards, epoch=0)
    rng = np.random.default_rng(seed + 100)
    q = rng.normal(size=(8, x_all.shape[1])).astype(np.float32)
    for filt in _filters(3, seed):
        gi, di = pack_search(pack, q, filt, k=k)
        mi, md = filtered_topk(q, x_all, s_all, filt, min(k, len(g_all)))
        mi, md = np.asarray(mi), np.asarray(md, np.float32)
        mg = np.where(mi >= 0, g_all[np.maximum(mi, 0)], -1)
        kk = mg.shape[1]
        assert np.array_equal(di[:, :kk], md), f"dists differ for {filt}"
        # gids must match wherever distances are unique (ties may reorder)
        uniq = np.ones_like(mg, bool)
        uniq[:, 1:] &= md[:, 1:] != md[:, :-1]
        uniq[:, :-1] &= md[:, :-1] != md[:, 1:]
        assert np.array_equal(gi[:, :kk][uniq], mg[uniq])


try:                                     # richer search space when available
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), n_segments=st.integers(1, 4),
           n_shards=st.integers(1, 6), k=st.integers(1, 40))
    def test_shard_merge_matches_single_device_hypothesis(seed, n_segments,
                                                          n_shards, k):
        """Same exactness property, hypothesis-driven."""
        sources, x_all, s_all, g_all = _segmented_dataset(seed, n_segments)
        pack = build_shard_pack(sources, n_shards=n_shards, epoch=0)
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(4, x_all.shape[1])).astype(np.float32)
        f = make_box_filter(3, 0.5, seed=seed)
        gi, di = pack_search(pack, q, f, k=k)
        mi, md = filtered_topk(q, x_all, s_all, f, min(k, len(g_all)))
        md = np.asarray(md, np.float32)
        assert np.array_equal(di[:, :md.shape[1]], md)
except ImportError:                      # pragma: no cover - optional dep
    pass


def test_pack_on_mesh_and_dead_masking():
    """Mesh-placed pack answers identically; mark_dead masks points from
    every later query without restacking."""
    sources, x_all, s_all, g_all = _segmented_dataset(7, 3)
    mesh = make_shard_mesh()
    pack = build_shard_pack(sources, n_shards=2 * mesh.devices.size,
                            epoch=0, mesh=mesh)
    rng = np.random.default_rng(7)
    q = rng.normal(size=(6, 32)).astype(np.float32)
    gi0, di0 = pack_search(pack, q, None, k=12)
    dead = g_all[rng.choice(len(g_all), 150, replace=False)]
    assert pack.mark_dead(dead) == 150
    gi1, _ = pack_search(pack, q, None, k=12)
    assert not (set(gi1[gi1 >= 0].tolist()) & set(dead.tolist()))
    # masking is monotone: surviving results are the old ones minus dead
    alive0 = [g for g in gi0[0].tolist() if g not in set(dead.tolist())]
    assert gi1[0].tolist()[: len(alive0)] == alive0


def test_pack_temporal_pruning_masks_rows():
    """Rows whose segment span misses the window contribute nothing."""
    sources, x_all, s_all, g_all = _segmented_dataset(11, 3)
    pack = build_shard_pack(sources, n_shards=2, epoch=0)
    q = np.zeros((2, 32), np.float32)
    gi, _ = pack_search(pack, q, None, k=5, t_lo=2.0, t_hi=3.0)
    assert np.all(gi == -1)
    active = pack.active_rows(2.0, 3.0)
    assert not active.any()
    assert pack.active_rows(-np.inf, np.inf).all()


def test_manager_sharded_path_matches_graph_path():
    """End-to-end: the sharded kernel read path is exact, so it must reach
    at least the recall of the default graph path on the same manager
    state, and must agree with brute-force ground truth."""
    x, s = make_dataset(2500, 24, 3, seed=5)
    s[:, 2] = np.arange(2500) / 2500
    cfg = StreamConfig(time_dim=2, seal_max_points=600, n_shards=3,
                       index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg, shard_mesh=make_shard_mesh())
    mgr.ingest(x, s)
    rng = np.random.default_rng(6)
    q = (x[rng.integers(0, 2500, 8)]
         + 0.05 * rng.normal(size=(8, 24)).astype(np.float32))
    f = ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                hi=np.ones(3, np.float32)),
                      IntervalFilter(dim=2, lo=np.float32(0.2)), "and")
    gt, _ = ground_truth(x, s, q, f, 10, valid=mgr.alive)
    ids_sh, _ = mgr.query(q, f, k=10)                      # n_shards=3 path
    ids_gr, _ = mgr.query(q, f, k=10, ef=128, use_shards=False)
    r_sh, r_gr = recall(ids_sh, gt), recall(ids_gr, gt)
    assert r_sh >= r_gr
    assert r_sh >= 0.99                   # exact on sealed; delta also exact
    # epoch bump (a new seal) invalidates and rebuilds the pack
    pack0 = mgr._pack
    mgr.ingest(x[:700], s[:700] * np.array([1, 1, 0]) + np.array([0, 0, 1.5]))
    f_old = ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                    hi=np.ones(3, np.float32)),
                          IntervalFilter(dim=2, lo=np.float32(0.2),
                                         hi=np.float32(1.2)), "and")
    ids2, _ = mgr.query(q, f_old, k=10)   # window excludes the new batch
    assert mgr._pack is not pack0
    assert recall(ids2, gt) >= 0.99       # old-window results unchanged
