"""Cost-based sealed-segment read path: planner decision logic, the
BucketStats schema contract, the scan-parity / graph-recall property
harness over lifecycle interleavings, beam-search tie-break determinism,
graph persistence pinning, and the bench-registry smoke test."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.cubegraph import CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, recall
from repro.streaming import SegmentManager, StreamConfig
from repro.streaming.planner import (REQUIRED_STATS_KEYS, PlanDecision,
                                     PlannerCosts, decide_bucket,
                                     plan_read_paths)

IDX_CFG = CubeGraphConfig(n_layers=3, m_intra=10, m_cross=3)

# Cost overlays that pin the auto planner to one side: parity legs use
# SCAN_BIASED (graph priced absurdly high -> every decision is scan, and
# the dispatch must be byte-for-byte the forced-scan one); recall legs use
# GRAPH_BIASED (graph free + every guard disabled -> every usable bucket
# traverses).
SCAN_BIASED = PlannerCosts(hop_cost=1e12)
GRAPH_BIASED = PlannerCosts(hop_cost=0.0, seed_cost=0.0, base_hops=0.0,
                            hops_per_log2=0.0, min_graph_rows=0,
                            min_selectivity=0.0)


def _graph_cfg(n_shards, quantize=None, read_path="auto"):
    return StreamConfig(time_dim=2, seal_max_points=120, n_shards=n_shards,
                        compact_max_segments=3, ttl=1.5, index_cfg=IDX_CFG,
                        read_path=read_path, quantize=quantize,
                        graph_ef=128)


def _apply_stream_ops(mgr, rng, ops, d=24):
    """Drive one manager through an interleaving of lifecycle ops (same op
    coding as tests/test_sharded.py: ingest/delete/seal/compact/expire)."""
    t = getattr(mgr, "_test_t", 0.0)
    for op in ops:
        if op == 0 or mgr.n_total == 0:           # ingest
            nb = int(rng.integers(40, 150))
            x = rng.normal(size=(nb, d)).astype(np.float32)
            s = rng.uniform(size=(nb, 3))
            s[:, 2] = t + np.linspace(0.0, 0.05, nb)
            t += 0.25
            mgr.ingest(x, s)
        elif op == 1:                             # delete
            g = rng.integers(0, mgr.n_total, size=25)
            mgr.delete(g)
        elif op == 2:                             # seal
            mgr.seal()
        elif op == 3:                             # compact (merges + GC)
            mgr.compact()
        elif op == 4:                             # expire (finite ttl)
            mgr.expire()
    mgr._test_t = t


# ---------------------------------------------------------------------------
# Planner decision logic + the BucketStats schema contract (unit level)
# ---------------------------------------------------------------------------

def _contract_stats(**over):
    row = {k: 1 for k in REQUIRED_STATS_KEYS}
    row["pruning_rate"] = 0.0
    row["selectivity"] = 0.5
    row.update(over)
    return row


def test_bucket_stats_snapshot_satisfies_planner_contract():
    """The metrics-side snapshot must expose every key the planner
    consumes — a rename in obs/metrics.py fails here loudly instead of
    silently degrading plans."""
    from repro.obs.metrics import BucketStats
    bs = BucketStats()
    bs.observe(1024, rows=4, active_rows=2, candidates=5,
               candidate_slots=10, cache_hit=True)
    snap = bs.snapshot()
    assert set(snap) == {"1024"}                 # keys are str(cap)
    missing = set(REQUIRED_STATS_KEYS) - set(snap["1024"])
    assert not missing, f"BucketStats snapshot lost planner keys: {missing}"
    # the raw-counter half of the contract is BucketStats._COUNTS
    assert set(BucketStats._COUNTS) <= set(REQUIRED_STATS_KEYS)
    # and the planner runs on a row carrying EXACTLY the contract keys, so
    # a planner-side key addition that obs does not serve also fails loudly
    row = {k: snap["1024"][k] for k in REQUIRED_STATS_KEYS}
    dec = decide_bucket(1024, 2, 8, True, row, PlannerCosts(), "auto")
    assert isinstance(dec, PlanDecision) and dec.mode in ("scan", "graph")


def test_decide_bucket_guards_and_forcing():
    """Mode gates: graph needs a staged block + live seeds; forcing wins
    over cost; tiny buckets and starving filters stay on scan."""
    c = PlannerCosts()
    assert decide_bucket(1024, 8, 0, True, None, c, "graph").mode == "scan"
    assert decide_bucket(1024, 8, 9, False, None, c, "graph").mode == "scan"
    assert decide_bucket(1024, 8, 9, True, None, c, "graph").mode == "graph"
    assert decide_bucket(1024, 8, 9, True, None, c, "scan").mode == "scan"
    small = decide_bucket(256, 1, 9, True, None, c, "auto")
    assert (small.mode, small.reason) == ("scan", "small_bucket")
    starved = decide_bucket(4096, 64, 9, True,
                            _contract_stats(selectivity=0.001), c, "auto")
    assert (starved.mode, starved.reason) == ("scan", "selective_filter")
    # large bucket, benign filter: the estimates decide, and the reason
    # names the winning side (graph_cheaper / scan_cheaper)
    big = decide_bucket(4096, 64, 9, True, _contract_stats(), c, "auto")
    assert big.reason in ("graph_cheaper", "scan_cheaper")
    assert (big.mode == "graph") == (big.reason == "graph_cheaper")
    assert (big.mode == "graph") == (big.est_graph < big.est_scan)


def test_plan_read_paths_respects_graph_allowed():
    """A non-encodable filter forces scan across the pack (the traversal
    kernel shares the scan kernel's predicate encoding)."""
    rng = np.random.default_rng(7)
    mgr = SegmentManager(24, 3, _graph_cfg(1))
    _apply_stream_ops(mgr, rng, [0, 2])
    epoch, segments, _ = mgr.snapshot()
    view = mgr.shard_pack(epoch, [g for g in segments if g.n_live > 0])
    plan = plan_read_paths(view, "graph", {}, PlannerCosts(),
                           -np.inf, np.inf, graph_allowed=False)
    assert plan and all(p.mode == "scan" for p in plan.values())
    assert all(p.reason == "filter_not_encodable" for p in plan.values())
    plan = plan_read_paths(view, "graph", {}, PlannerCosts(),
                           -np.inf, np.inf, graph_allowed=True)
    assert plan and all(p.mode == "graph" for p in plan.values())


# ---------------------------------------------------------------------------
# Property harness: auto==scan parity + graph recall over op interleavings
# ---------------------------------------------------------------------------

def _check_parity_and_recall(seed, n_shards, ops, quantize):
    """After an arbitrary lifecycle interleaving: (1) whenever the planner
    chooses scan for every bucket, ``read_path="auto"`` answers bit-for-bit
    identically to forced ``"scan"``; (2) whenever it chooses graph, the
    merged answer keeps recall@10 >= 0.95 against exact brute force over
    the live points."""
    rng = np.random.default_rng(seed)
    cfg = _graph_cfg(n_shards, quantize)
    mgr = SegmentManager(24, 3, cfg)
    _apply_stream_ops(mgr, rng, ops)
    mgr.seal()
    q = rng.normal(size=(4, 24)).astype(np.float32)
    gids = np.arange(mgr.n_total)
    x_all, s_all, present = mgr.get_points(gids)
    valid = mgr.alive & present
    filters = [None, make_box_filter(3, 0.6, seed=seed),
               IntervalFilter(dim=2, lo=np.float32(0.2))]
    for filt in filters:
        # (1) parity leg: scan-biased costs -> planner must pick scan
        # everywhere -> identical bytes to the forced scan path
        mgr.cfg = dataclasses.replace(cfg, planner_costs=SCAN_BIASED)
        ga, da = mgr.query(q, filt, k=10)
        if mgr.last_plan:
            assert all(p.mode == "scan" for p in mgr.last_plan.values())
        gs, ds = mgr.query(q, filt, k=10, read_path="scan")
        assert np.array_equal(ga, gs)
        assert np.array_equal(da, ds)
        # (2) recall leg: graph-biased costs -> every usable bucket
        # traverses; answers stay above the paper's recall floor
        mgr.cfg = dataclasses.replace(cfg, planner_costs=GRAPH_BIASED)
        gg, _ = mgr.query(q, filt, k=10)
        if valid.any():
            gt, _ = ground_truth(x_all, s_all, q, filt, 10, valid=valid)
            assert recall(gg, gt) >= 0.95, (filt, recall(gg, gt))
    mgr.cfg = cfg


@pytest.mark.parametrize("seed,n_shards,ops,quantize", [
    (11, 1, [0, 1, 2, 0, 3, 1, 4], None),     # all op kinds, fp32
    (22, 3, [0, 2, 1, 3, 0, 0, 4, 2], None),  # sharded, expiry + merges
    (33, 1, [0, 1, 2, 0, 3, 1, 4], "int8"),   # quantized candidates+rerank
    (44, 3, [0, 2, 0, 2, 1, 3], "int8"),      # quantized, multi-segment
])
def test_planner_parity_and_recall(seed, n_shards, ops, quantize):
    """Deterministic interleavings of the parity/recall property (always
    run; the hypothesis variant widens the search space when available)."""
    _check_parity_and_recall(seed, n_shards, ops, quantize)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), n_shards=st.sampled_from([1, 3]),
           ops=st.lists(st.integers(0, 4), min_size=3, max_size=8),
           quantize=st.sampled_from([None, "int8"]))
    def test_planner_parity_and_recall_hypothesis(seed, n_shards, ops,
                                                  quantize):
        """Hypothesis-driven interleavings of the same property."""
        _check_parity_and_recall(seed, n_shards, ops, quantize)
except ImportError:                               # pragma: no cover
    pass


def test_graph_fallback_dispatches_are_observed(monkeypatch):
    """When the traversal kernel declines a bucket (returns None) the
    scan fallback must still feed BucketStats — the planner's observation
    loop would otherwise silently starve for exactly the buckets that
    fall back (regression: the fallback calls dropped ``observe``)."""
    import repro.kernels.graph_topk as gt
    rng = np.random.default_rng(13)
    mgr = SegmentManager(24, 3, _graph_cfg(1, read_path="graph"))
    _apply_stream_ops(mgr, rng, [0, 2])
    mgr.seal()
    q = rng.normal(size=(2, 24)).astype(np.float32)
    mgr.query(q, None, k=5)                       # build pack + compile

    def _dispatches():
        return sum(row["dispatches"]
                   for row in mgr.stats()["obs"]["buckets"].values())

    monkeypatch.setattr(gt, "bucket_graph_topk", lambda *a, **k: None)
    before = _dispatches()
    g, _ = mgr.query(q, None, k=5)
    assert mgr.last_plan and all(p.mode == "graph"
                                 for p in mgr.last_plan.values())
    assert (g >= 0).any()                         # fallback answered
    assert _dispatches() > before


# ---------------------------------------------------------------------------
# Beam-search (dist, gid) tie-break determinism (core regression)
# ---------------------------------------------------------------------------

def test_core_beam_search_tie_key_invariant_to_build_order():
    """Duplicated vectors produce exact distance ties; with ``tie_gids``
    the core beam search must emit the same (gid, dist) rows regardless of
    the row order the index was built from and of the routing mode —
    the per-segment analogue of test_quant.py's reranked-tie invariant."""
    rng = np.random.default_rng(33)
    base = rng.normal(size=(50, 16)).astype(np.float32)
    x = np.concatenate([base, base[:5]])          # 5 exact duplicate pairs
    s = rng.uniform(size=(55, 3))
    s[50:] = s[:5]                                # duplicates share metadata
    gids = np.arange(55, dtype=np.int64)
    perm = rng.permutation(55)
    cfg = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=3)
    idx_a = CubeGraphIndex.build(x, s, cfg)
    idx_b = CubeGraphIndex.build(x[perm], s[perm], cfg)
    q = base[:3] + np.float32(1e-4)
    filt = BoxFilter(lo=np.full(3, -1.0, np.float32),
                     hi=np.full(3, 2.0, np.float32))
    outs = []
    for mode in ("predetermined", "onthefly"):
        ia, da = idx_a.query(q, filt, k=12, ef=64, mode=mode, tie_gids=gids)
        ib, db = idx_b.query(q, filt, k=12, ef=64, mode=mode,
                             tie_gids=perm.astype(np.int64))
        ga = np.where(ia >= 0, gids[np.maximum(ia, 0)], -1)
        gb = np.where(ib >= 0, perm[np.maximum(ib, 0)], -1)
        outs.append((ga, da))
        outs.append((gb, db))
    g0, d0 = outs[0]
    for g, d in outs[1:]:
        assert np.array_equal(g0, g)
        assert np.allclose(d0, d, atol=1e-5)
    # every duplicate pair that made the list is ordered by ascending gid
    for row in g0:
        pos = {int(g): i for i, g in enumerate(row) if g >= 0}
        for lo in range(5):
            if lo in pos and lo + 50 in pos:
                assert pos[lo] < pos[lo + 50]


def test_manager_unsharded_tiebreak_is_dist_gid():
    """The per-segment (unsharded) read path orders exact duplicates
    across segments by ascending gid — stable under repetition and equal
    to the sharded scan's ordering contract."""
    rng = np.random.default_rng(5)
    base = rng.normal(size=(60, 24)).astype(np.float32)
    dup = base[:3]
    cfg = StreamConfig(time_dim=2, seal_max_points=10 ** 9, n_shards=0,
                       index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg)
    meta = rng.uniform(size=(3, 3))
    for blk in range(3):
        x = np.concatenate([dup, base[15 * (blk + 1): 15 * (blk + 2)]])
        s = np.concatenate([meta, rng.uniform(size=(15, 3))])
        mgr.ingest(x, s)
        mgr.seal()
    # gids 0..17 / 18..35 / 36..53; the query vector appears at 0, 18, 36
    q = dup[:1]
    g1, d1 = mgr.query(q, None, k=9, use_shards=False)
    g2, d2 = mgr.query(q, None, k=9, use_shards=False)
    assert np.array_equal(g1, g2) and np.array_equal(d1, d2)
    assert g1[0, :3].tolist() == [0, 18, 36]      # zero-dist ties by gid
    assert np.allclose(d1[0, :3], d1[0, 0])


# ---------------------------------------------------------------------------
# Persistence: restore never rebuilds graphs
# ---------------------------------------------------------------------------

def test_graph_restore_never_rebuilds(tmp_path, monkeypatch):
    """A restored replica serves the graph read path from the persisted
    index arrays: CubeGraphIndex.build must never run, and traversal
    answers match the writer bit-for-bit."""
    rng = np.random.default_rng(17)
    cfg = _graph_cfg(1, read_path="graph")
    mgr = SegmentManager(24, 3, cfg)
    _apply_stream_ops(mgr, rng, [0, 2, 0, 2, 1])
    mgr.seal()
    q = rng.normal(size=(4, 24)).astype(np.float32)
    ids0, dd0 = mgr.query(q, None, k=10)
    assert mgr.last_plan and any(p.mode == "graph"
                                 for p in mgr.last_plan.values())
    snap = os.path.join(str(tmp_path), "snap")
    mgr.snapshot_to(snap)

    def _boom(*a, **k):
        raise AssertionError("restore rebuilt a segment graph")
    monkeypatch.setattr(CubeGraphIndex, "build", _boom)
    m2 = SegmentManager.restore(snap, cfg=cfg, resume=False)
    ids1, dd1 = m2.query(q, None, k=10)
    assert np.array_equal(ids0, ids1) and np.array_equal(dd0, dd1)
    assert m2.last_plan and any(p.mode == "graph"
                                for p in m2.last_plan.values())


# ---------------------------------------------------------------------------
# Bench registry: every section imports and exposes its entry point
# ---------------------------------------------------------------------------

def test_bench_registry_imports_loudly():
    """Every registered benchmark module must import cleanly and expose
    its entry point — guarding the failure mode where one bad import
    silently dropped every section from the suite."""
    from benchmarks.run import SECTIONS, load_sections
    loaded, errors = load_sections()
    assert not errors, \
        "; ".join(f"{n}: {type(e).__name__}: {e}" for n, e in errors)
    assert [n for n, _ in loaded] == [n for n, _, _ in SECTIONS]
    assert all(callable(fn) for _, fn in loaded)
    # exp15 (this PR's experiment) must be registered and summarized
    assert any(n == "exp15_read_path_planner" for n, _, _ in SECTIONS)
    from benchmarks.common import STREAMING_SECTIONS
    assert any("exp15_read_path_planner".startswith(p)
               for p in STREAMING_SECTIONS)
