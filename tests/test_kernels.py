"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filters import (BallFilter, BoxFilter, ComposeFilter,
                                IntervalFilter)
from repro.core.workloads import (make_ball_filter, make_box_filter,
                                  make_compose_filter, make_dataset,
                                  make_polygon_filter, ground_truth)
from repro.kernels import filtered_topk, pairwise_dist
from repro.kernels import ref
from repro.kernels.ops import encode_filter


@pytest.mark.parametrize("bq,n,d", [(4, 64, 16), (16, 300, 48), (33, 513, 130),
                                    (1, 1000, 96), (128, 256, 128)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_distance_kernel_shapes(bq, n, d, metric):
    rng = np.random.default_rng(bq * 1000 + n + d)
    q = rng.normal(size=(bq, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(pairwise_dist(q, x, metric=metric))
    want = np.asarray(ref.pairwise_sq_l2(q, x) if metric == "l2"
                      else ref.pairwise_neg_ip(q, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(8, 64)), dtype)
    x = jnp.asarray(rng.normal(size=(128, 64)), dtype)
    got = np.asarray(pairwise_dist(q, x))
    want = np.asarray(ref.pairwise_sq_l2(q, x))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("mk,kind", [
    (make_box_filter, "box"),
    (make_ball_filter, "ball"),
    (make_compose_filter, "box_not_ball"),
])
@pytest.mark.parametrize("m", [2, 3])
def test_filter_encoding_matches_object(mk, kind, m):
    f = mk(m, 0.1, seed=11)
    enc = encode_filter(f, m)
    if enc is None:
        pytest.skip("no kernel encoding for this m (jnp fallback path)")
    got_kind, params = enc
    rng = np.random.default_rng(2)
    s = rng.uniform(0, 1, size=(2000, m)).astype(np.float32)
    want = np.asarray(f.contains(jnp.asarray(s)))
    sp = np.full((2000, 128), 0.0, np.float32)
    sp[:, :m] = s
    got = np.asarray(ref.filter_mask_ref(jnp.asarray(sp), got_kind,
                                         jnp.asarray(params)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("bq,n,d,k", [(4, 200, 32, 5), (16, 1000, 64, 10),
                                      (7, 333, 100, 20), (32, 2048, 128, 50)])
def test_filtered_topk_vs_ground_truth(bq, n, d, k):
    x, s = make_dataset(n, d, 2, seed=n)
    rng = np.random.default_rng(1)
    q = x[rng.integers(0, n, bq)] + 0.01
    f = make_box_filter(2, 0.1, seed=n)
    ids, dd = filtered_topk(q, x, s, f, k)
    gt_i, gt_d = ground_truth(x, s, q, f, k)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])
    np.testing.assert_allclose(
        np.where(np.isfinite(np.asarray(dd)), np.asarray(dd), 0),
        np.where(np.isfinite(gt_d), gt_d, 0), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("mkf", [make_ball_filter, make_compose_filter,
                                 make_polygon_filter])
def test_filtered_topk_filter_shapes(mkf):
    """Complex filter shapes (kernel path where encodable, jnp fallback else)."""
    x, s = make_dataset(800, 32, 2, seed=3)
    q = x[:8] + 0.01
    f = mkf(2, 0.1, seed=4)
    ids, dd = filtered_topk(q, x, s, f, 10)
    gt_i, _ = ground_truth(x, s, q, f, 10)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])


def test_interval_halfopen_encoding():
    """[t0, inf) encodes as 'box' with NO synthetic upper bound: the packed
    hi row keeps its pass-all default and only padding rows (meta=+2e30)
    fail it."""
    f = IntervalFilter(dim=2, lo=jnp.float32(0.4))
    enc = encode_filter(f, 3)
    assert enc is not None
    kind, params = enc
    assert kind == "box"
    assert params[0, 2] == np.float32(0.4)
    assert np.all(params[1, :] >= 1e30)          # upper edge untouched
    x, s = make_dataset(600, 16, 3, seed=9)
    ids, _ = filtered_topk(x[:6], x, s, f, 10)
    gt_i, _ = ground_truth(x, s, x[:6], f, 10)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])
    assert np.all(s[np.asarray(ids)[np.asarray(ids) >= 0], 2] >= 0.4)


@pytest.mark.parametrize("lo,hi", [(0.3, None), (None, 0.6), (0.2, 0.7)])
def test_interval_and_box_composition(lo, hi):
    """box AND interval folds into one packed box (open ends stay open)."""
    box = BoxFilter(lo=jnp.asarray([0.1, 0.1, 0.0]),
                    hi=jnp.asarray([0.9, 0.9, 1.0]))
    iv = IntervalFilter(dim=2,
                        lo=None if lo is None else jnp.float32(lo),
                        hi=None if hi is None else jnp.float32(hi))
    f = ComposeFilter(box, iv, "and")
    enc = encode_filter(f, 3)
    assert enc is not None and enc[0] == "box"
    x, s = make_dataset(600, 16, 3, seed=10)
    ids, _ = filtered_topk(x[:6], x, s, f, 10)
    gt_i, _ = ground_truth(x, s, x[:6], f, 10)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])


def test_ball_and_interval_box_ball_kind():
    """ball AND interval uses the fused 'box_ball' kind (no jnp fallback)."""
    ball = BallFilter(center=jnp.asarray([0.5, 0.5]), radius=jnp.float32(0.35))
    iv = IntervalFilter(dim=2, lo=jnp.float32(0.25), hi=jnp.float32(0.9))
    f = ComposeFilter(ball, iv, "and")
    enc = encode_filter(f, 3)
    assert enc is not None and enc[0] == "box_ball"
    x, s = make_dataset(800, 24, 3, seed=11)
    ids, _ = filtered_topk(x[:6], x, s, f, 10)
    gt_i, _ = ground_truth(x, s, x[:6], f, 10)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])
    # the ref oracle agrees with the object predicate for this kind
    rng = np.random.default_rng(12)
    sp = np.full((1500, 128), 2e30, np.float32)
    sp[:, :3] = rng.uniform(0, 1, size=(1500, 3))
    want = np.asarray(f.contains(jnp.asarray(sp[:, :3])))
    got = np.asarray(ref.filter_mask_ref(jnp.asarray(sp[:, :3]), enc[0],
                                         jnp.asarray(enc[1])))
    assert np.array_equal(got, want)


def test_filtered_topk_empty_filter():
    """A filter matching nothing returns all -1 / inf."""
    x, s = make_dataset(200, 16, 2, seed=5)
    f = BoxFilter(lo=jnp.asarray([5.0, 5.0]), hi=jnp.asarray([6.0, 6.0]))
    ids, dd = filtered_topk(x[:4], x, s, f, 10)
    assert np.all(np.asarray(ids) == -1)
    assert np.all(~np.isfinite(np.asarray(dd)))


def test_filtered_topk_sorted():
    x, s = make_dataset(500, 24, 3, seed=6)
    f = make_box_filter(3, 0.2, seed=7)
    _, dd = filtered_topk(x[:8], x, s, f, 16)
    dd = np.asarray(dd)
    finite = np.where(np.isfinite(dd), dd, 1e30)
    assert np.all(np.diff(finite, axis=1) >= -1e-5)


@pytest.mark.parametrize("bkv,g,smax,hd,ts", [
    (4, 8, 512, 128, 128), (2, 16, 1024, 128, 256), (8, 8, 256, 256, 128)])
def test_flash_decode_vs_oracle(bkv, g, smax, hd, ts):
    from repro.kernels.flash_decode import flash_decode_kernel_call
    from repro.kernels.ref import flash_decode_ref
    rng = np.random.default_rng(bkv * 100 + g)
    q = rng.normal(size=(bkv, g, hd)).astype(np.float32)
    k = rng.normal(size=(bkv, smax, hd)).astype(np.float32)
    v = rng.normal(size=(bkv, smax, hd)).astype(np.float32)
    lengths = rng.integers(1, smax, size=bkv).astype(np.int32)
    got = np.asarray(flash_decode_kernel_call(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(lengths), ts=ts))
    want = np.asarray(flash_decode_ref(q, k, v, jnp.asarray(lengths)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16():
    from repro.kernels.flash_decode import flash_decode_kernel_call
    from repro.kernels.ref import flash_decode_ref
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 8, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 256, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 256, 128)), jnp.bfloat16)
    lengths = jnp.asarray([100, 255], jnp.int32)
    got = np.asarray(flash_decode_kernel_call(q, k, v, lengths, ts=128),
                     np.float32)
    want = np.asarray(flash_decode_ref(q, k, v, lengths), np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
