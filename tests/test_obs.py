"""Observability substrate: traced-vs-untraced bit parity (fp32 + int8),
span-tree latency accounting, near-zero disabled path, log-bucketed
histogram percentile guarantees, strict-JSON ``stats()`` / snapshot
exports, BucketStats planner-contract numbers, and the Prometheus dump."""
import json
import os
import sys
import tempfile
import tracemalloc

import numpy as np
import pytest

from repro.core import CubeGraphConfig, IntervalFilter
from repro.obs import (NULL_METRIC, NULL_REGISTRY, NULL_TRACE, BucketStats,
                       Histogram, MetricsRegistry, QueryTrace, StreamObs,
                       json_sanitize, prometheus_text)
from repro.streaming import SegmentManager, StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=3)


def _stream_cfg(**kw):
    kw.setdefault("time_dim", 2)
    kw.setdefault("seal_max_points", 256)
    kw.setdefault("index_cfg", IDX_CFG)
    return StreamConfig(**kw)


def _fill_manager(cfg, n_batches=4, n=200, d=16, seed=0):
    rng = np.random.default_rng(seed)
    mgr = SegmentManager(d, 3, cfg)
    for i in range(n_batches):
        x = rng.normal(size=(n, d)).astype(np.float32)
        s = rng.uniform(size=(n, 3))
        s[:, 2] = i + np.linspace(0, 0.9, n)
        mgr.ingest(x, s)
    mgr.maintenance()
    return mgr, rng


# ---------------------------------------------------------------------------
# Tracing is free of observable effect: bit-for-bit parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [None, "int8"])
@pytest.mark.parametrize("n_shards", [1, 3])
def test_traced_query_bit_identical(quantize, n_shards):
    """The same manager answers the same query identically with tracing on
    vs off — across the fp32 and int8 read paths and shard counts."""
    cfg = _stream_cfg(n_shards=n_shards, quantize=quantize)
    mgr, rng = _fill_manager(cfg)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    filt = IntervalFilter(dim=2, lo=0.5, hi=2.5)
    g0, d0 = mgr.query(q, filt, k=5)
    g1, d1, trace = mgr.query(q, filt, k=5, return_trace=True)
    g2, d2 = mgr.query(q, filt, k=5)
    assert np.array_equal(g0, g1) and np.array_equal(d0, d1)
    assert np.array_equal(g0, g2) and np.array_equal(d0, d2)
    assert trace.total_ms > 0.0
    # the span tree has the sealed scan and the exact merge
    names = [s["name"] for s in trace.to_dict()["spans"]]
    assert "sealed_scan" in names and "merge" in names


def test_trace_spans_account_for_total():
    """Direct children of the root span sum to within 5% of the root's own
    measured duration — the tree is a faithful latency decomposition, not
    a sampling."""
    cfg = _stream_cfg(n_shards=2)
    mgr, rng = _fill_manager(cfg, n_batches=6, n=400, d=32)
    q = rng.normal(size=(16, 32)).astype(np.float32)
    filt = IntervalFilter(dim=2, lo=0.5)
    mgr.query(q, filt, k=10)                 # compile outside the trace
    best = 0.0
    for _ in range(3):                       # best-of-3 shields CI jitter
        _, _, trace = mgr.query(q, filt, k=10, return_trace=True)
        td = trace.to_dict()
        covered = sum(s["ms"] for s in td["spans"])
        assert covered <= td["ms"] * (1 + 1e-6)
        best = max(best, covered / td["ms"])
        if best >= 0.95:
            break
    assert best >= 0.95, f"spans cover only {best:.1%} of the root span"


def test_trace_bucket_spans_carry_dispatch_attrs():
    """Per-bucket dispatch spans record cap/rows/candidates/cache_hit —
    the attributes the planner's offline analysis keys on."""
    cfg = _stream_cfg(n_shards=2)
    mgr, rng = _fill_manager(cfg)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    mgr.query(q, None, k=5)                  # warm the dispatch cache
    _, _, trace = mgr.query(q, None, k=5, return_trace=True)
    sealed = [s for s in trace.to_dict()["spans"]
              if s["name"] == "sealed_scan"]
    assert sealed, "sealed scan span missing"
    dispatches = [s for s in sealed[0].get("spans", [])
                  if s["name"] == "bucket_dispatch"]
    assert dispatches, "no per-bucket dispatch spans"
    for sp in dispatches:
        attrs = sp["attrs"]
        assert attrs["cap"] >= attrs["active_rows"] > 0
        assert attrs["candidates"] >= 0
        assert attrs["cache_hit"] is True   # warmed above


# ---------------------------------------------------------------------------
# Disabled path: shared singletons, no growth
# ---------------------------------------------------------------------------
def test_disabled_obs_uses_null_singletons():
    cfg = _stream_cfg(n_shards=2, obs_enabled=False)
    mgr, rng = _fill_manager(cfg, n_batches=2)
    assert mgr.obs.registry.counter("x") is NULL_METRIC
    assert mgr.obs.registry.histogram("y") is NULL_METRIC
    assert mgr.obs.bucket_stats is None
    q = rng.normal(size=(2, 16)).astype(np.float32)
    mgr.query(q, None, k=3)
    snap = mgr.stats()["obs"]
    assert snap["enabled"] is False
    assert snap["metrics"]["counters"] == {}
    assert snap["buckets"] == {}


def test_disabled_obs_is_allocation_free():
    """Hammering the disabled registry/trace API allocates (almost)
    nothing: every call returns a pre-built shared singleton."""
    reg = MetricsRegistry(enabled=False)
    # warm up any lazy interpreter state before measuring
    reg.counter("a").inc()
    reg.histogram("b").observe(1.0)
    with NULL_TRACE.span("s", attr=1):
        pass
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    for _ in range(1000):
        reg.counter("a").inc()
        reg.gauge("g").set(2.0)
        reg.histogram("b").observe(1.0)
        with NULL_TRACE.span("s", attr=1) as sp:
            sp.annotate(more=2)
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    grown = sum(st.size_diff for st in after.compare_to(before, "filename")
                if st.size_diff > 0)
    assert grown < 16 * 1024, f"disabled obs path allocated {grown} bytes"


# ---------------------------------------------------------------------------
# Histogram percentile guarantee
# ---------------------------------------------------------------------------
def _check_percentile_bound(values, q):
    h = Histogram("h")
    for v in values:
        h.observe(v)
    rank = max(int(np.ceil(q * len(values))), 1)
    true = float(np.sort(np.asarray(values, float))[rank - 1])
    est = h.percentile(q)
    assert true <= est * (1 + 1e-9)
    assert est <= true * 2 ** 0.25 * (1 + 1e-9)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=1e-5, max_value=1e6,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=200),
           st.sampled_from([0.5, 0.95, 0.99]))
    def test_histogram_percentile_bound(values, q):
        """Log-bucketed estimate is an upper bound within one sub-bucket
        width: true <= est <= true * 2**(1/4)."""
        _check_percentile_bound(values, q)
except ImportError:                      # pragma: no cover - fallback
    @pytest.mark.parametrize("seed", range(10))
    def test_histogram_percentile_bound(seed):
        rng = np.random.default_rng(seed)
        values = rng.uniform(1e-5, 1e6, size=rng.integers(1, 200))
        for q in (0.5, 0.95, 0.99):
            _check_percentile_bound(values.tolist(), q)


def test_histogram_snapshot_fields():
    h = Histogram("h")
    assert h.snapshot()["count"] == 0 and h.snapshot()["p50"] is None
    for v in (0.5, 1.0, 2.0, 4.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 4.0
    assert abs(s["sum"] - 7.5) < 1e-9
    assert s["p50"] >= 1.0 and s["p99"] <= 4.0 * 2 ** 0.25


# ---------------------------------------------------------------------------
# Strict-JSON stats / snapshot exports
# ---------------------------------------------------------------------------
def test_stats_strict_json_pre_ingest():
    """Before the first ingest the watermark is -inf — stats() must still
    be strict-JSON (inf -> null, the persistence convention)."""
    mgr = SegmentManager(8, 3, _stream_cfg(n_shards=1))
    st_ = mgr.stats()
    json.dumps(st_, allow_nan=False)
    assert st_["now"] is None


def test_stats_strict_json_live():
    """With live segments, a TTL, deletions, and obs populated, the whole
    stats() tree round-trips through strict JSON."""
    cfg = _stream_cfg(n_shards=2, ttl=100.0)
    mgr, rng = _fill_manager(cfg)
    mgr.delete(np.arange(5, dtype=np.int64))
    mgr.query(rng.normal(size=(2, 16)).astype(np.float32),
              IntervalFilter(dim=2, lo=0.5), k=3)
    st_ = mgr.stats()
    blob = json.dumps(st_, allow_nan=False)
    back = json.loads(blob)
    assert back["obs"]["metrics"]["counters"]["query_batches_total"] == 1
    assert back["obs"]["buckets"]          # sharded path populated stats


def test_json_sanitize_edges():
    raw = {("a",): np.float64("inf"), "b": (np.int32(3), float("nan")),
           "c": np.arange(2), 1: True}
    out = json_sanitize(raw)
    json.dumps(out, allow_nan=False)
    assert out["('a',)"] is None and out["b"] == [3, None]
    assert out["c"] == [0, 1] and out["1"] is True


# ---------------------------------------------------------------------------
# BucketStats planner contract + lifecycle metrics
# ---------------------------------------------------------------------------
def test_bucket_stats_contract():
    bs = BucketStats()
    bs.observe(256, rows=4, active_rows=2, candidates=10,
               candidate_slots=40, cache_hit=False)
    bs.observe(256, rows=4, active_rows=0)            # fully pruned
    bs.observe(512, rows=1, active_rows=1, candidates=8,
               candidate_slots=8, cache_hit=True)
    snap = bs.snapshot()
    b256 = snap["256"]
    assert b256["queries"] == 2 and b256["dispatches"] == 1
    assert b256["blocks_pruned"] == 6 and b256["pruning_rate"] == 0.75
    assert b256["rows_scanned"] == 2 * 256
    assert b256["selectivity"] == 0.25
    assert b256["cache_misses"] == 1 and b256["cache_hits"] == 0
    assert snap["512"]["selectivity"] == 1.0
    assert snap["512"]["cache_hits"] == 1


def test_query_populates_bucket_stats_and_gauges():
    cfg = _stream_cfg(n_shards=2)
    mgr, rng = _fill_manager(cfg)
    filt = IntervalFilter(dim=2, lo=0.5, hi=2.5)
    for _ in range(3):
        mgr.query(rng.normal(size=(4, 16)).astype(np.float32), filt, k=5)
    obs = mgr.stats()["obs"]
    buckets = obs["buckets"]
    assert buckets, "sharded queries recorded no bucket stats"
    for row in buckets.values():
        assert row["queries"] >= row["dispatches"] > 0
        assert row["rows_scanned"] > 0
        assert 0.0 <= row["pruning_rate"] <= 1.0
        assert row["cache_hits"] + row["cache_misses"] == row["dispatches"]
    gauges = obs["metrics"]["gauges"]
    assert gauges["pack_nbytes"] > 0
    assert any(k.startswith("pack_bucket_rows") for k in gauges)
    hist = obs["metrics"]["histograms"]["query_ms"]
    assert hist["count"] == 3 and hist["p50"] > 0


def test_persistence_metrics_and_recovery_counters():
    with tempfile.TemporaryDirectory() as tmp:
        root = os.path.join(tmp, "snap")
        cfg = _stream_cfg(n_shards=1, persist_dir=root, wal_fsync_every=2)
        mgr, rng = _fill_manager(cfg, n_batches=2)
        mgr.delete(np.arange(3, dtype=np.int64))       # lands in the WAL
        m = mgr.stats()["obs"]["metrics"]
        assert m["histograms"]["wal_append_ms"]["count"] > 0
        assert m["histograms"]["wal_fsync_ms"]["count"] > 0
        assert m["counters"]["checkpoints_total"] > 0
        assert m["histograms"]["checkpoint_ms"]["count"] > 0
        mgr.persist.close()

        restored = SegmentManager.restore(root)
        rm = restored.stats()["obs"]["metrics"]["counters"]
        assert rm["recovery_restores_total"] == 1
        assert rm["recovery_replayed_records_total"] >= 1   # the delete
        assert rm['recovery_replayed_records_total{type="delete"}'] == 1
        g, d = restored.query(rng.normal(size=(2, 16)).astype(np.float32),
                              None, k=3)
        assert (g >= 0).any()


# ---------------------------------------------------------------------------
# Registry behaviors + Prometheus rendering
# ---------------------------------------------------------------------------
def test_registry_drop_prefix_and_types():
    reg = MetricsRegistry()
    reg.counter("a_total").inc(2)
    reg.gauge('pack_bucket_rows{cap="256"}').set(7)
    reg.gauge("keep").set(1.5)
    reg.drop_prefix("pack_bucket_")
    snap = reg.snapshot()
    assert "keep" in snap["gauges"]
    assert not any(k.startswith("pack_bucket_") for k in snap["gauges"])
    assert snap["counters"]["a_total"] == 2


def test_prometheus_text_rendering():
    reg = MetricsRegistry()
    reg.counter("reqs_total").inc(3)
    reg.gauge('occ{cap="256"}').set(0.5)
    h = reg.histogram("lat_ms")
    for v in (1.0, 2.0, 4.0):
        h.observe(v)
    text = reg.prometheus_text()
    assert "# TYPE cubegraph_reqs_total counter" in text
    assert "cubegraph_reqs_total 3" in text
    assert 'cubegraph_occ{cap="256"} 0.5' in text
    assert 'cubegraph_lat_ms{quantile="0.50"}' in text
    assert "cubegraph_lat_ms_count 3" in text


def test_obs_dump_tool_roundtrip(tmp_path):
    """stats() JSON -> tools/obs_dump.py render includes the per-cap
    bucket gauges and the registry metrics."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
    try:
        import obs_dump
    finally:
        sys.path.pop(0)
    cfg = _stream_cfg(n_shards=2)
    mgr, rng = _fill_manager(cfg, n_batches=2)
    mgr.query(rng.normal(size=(2, 16)).astype(np.float32), None, k=3)
    text = obs_dump.render(mgr.stats())
    assert "cubegraph_query_batches_total 1" in text
    assert "cubegraph_bucket_pruning_rate" in text
    assert 'cap="' in text


def test_multi_tenant_obs_dump_tenant_labels():
    """MultiTenantStore.stats() carries a per-collection ``tenants`` block
    and obs_dump renders it as ``{tenant=}``-labeled gauges — scalar
    collection facts plus each tenant's own BucketStats rows with a
    compound ``{tenant=,cap=}`` label — alongside the shared-registry
    tenant-suffixed counters."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
    try:
        import obs_dump
    finally:
        sys.path.pop(0)
    from repro.serving.rag import Document
    from repro.serving.service import CubeGraphService, ServeRequest
    from repro.serving.tenancy import MultiTenantStore

    rng = np.random.default_rng(0)
    store = MultiTenantStore(
        8, 3, stream_cfg=_stream_cfg(n_shards=2, seal_max_points=64))
    svc = CubeGraphService(store)
    for tenant in ("acme", "globex"):
        store.create_collection(tenant, quota_points=1000)
        store.insert(tenant, [
            Document(i, np.arange(3, dtype=np.int32),
                     rng.normal(size=8).astype(np.float32),
                     np.array([0.5, 0.5, float(i)]))
            for i in range(150)])
    store.maintenance()
    for rid in range(4):
        svc.submit(ServeRequest(
            req_id=rid, tenant=("acme", "globex")[rid % 2],
            query_emb=rng.normal(size=8).astype(np.float32), k=5))
    svc.flush()

    stats = store.stats()
    json.dumps(stats, allow_nan=False)          # strict-JSON export holds
    assert set(stats["tenants"]) == {"acme", "globex"}
    assert stats["tenants"]["acme"]["live_points"] == 150
    # per-tenant BucketStats populated by the grouped dispatch callback
    assert stats["tenants"]["acme"]["buckets"], "tenant bucket stats empty"

    text = obs_dump.render(stats)
    assert 'cubegraph_tenant_live_points{tenant="acme"} 150' in text
    assert 'cubegraph_tenant_quota_points{tenant="globex"} 1000' in text
    assert 'cubegraph_tenant_bucket_rows_scanned{tenant="acme",cap="' in text
    # registry counters with the tenant label-suffix idiom flow through too
    assert 'cubegraph_tenant_requests_total{tenant="acme"} 2' in text


def test_document_store_metrics_snapshot():
    from repro.serving.rag import Document, DocumentStore
    rng = np.random.default_rng(0)
    docs = [Document(i, np.arange(4, dtype=np.int32),
                     rng.normal(size=8).astype(np.float32),
                     np.array([0.5, 0.5, float(i)]))
            for i in range(64)]
    store = DocumentStore(docs, index_cfg=IDX_CFG, streaming=True,
                          stream_cfg=_stream_cfg(n_shards=1,
                                                 seal_max_points=32))
    store.retrieve(rng.normal(size=8).astype(np.float32),
                   IntervalFilter(dim=2, lo=0.0), k=4)
    snap = store.metrics_snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["metrics"]["counters"]["retrieve_requests_total"] == 1
    assert snap["metrics"]["histograms"]["retrieve_ms"]["count"] == 1
    # serving metrics share the manager registry: lifecycle counters too
    assert snap["metrics"]["counters"]["lifecycle_ingested_points_total"] == 64
