"""CubeGraph index behaviour: recall targets, invariants, both search modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_box_filter, make_compose_filter,
                                  make_dataset, make_polygon_filter, recall)


@pytest.fixture(scope="module")
def built():
    x, s = make_dataset(3000, 32, 2, seed=1)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=4, m_intra=12,
                                                     m_cross=4))
    rng = np.random.default_rng(2)
    q = x[rng.integers(0, 3000, 24)] + 0.05 * rng.normal(size=(24, 32)).astype(np.float32)
    return x, s, idx, q


def test_build_structure(built):
    x, s, idx, q = built
    assert idx.n_built_layers >= 2
    for lg in idx.layers:
        nb = np.asarray(lg.nbrs)
        # intra edges stay inside the cube
        src_cube = lg.cube_of[:, None].repeat(nb.shape[1], 1)
        ok = nb >= 0
        assert np.all(lg.cube_of[nb[ok]] == src_cube[ok])
        # cross edges leave the cube
        xn = np.asarray(lg.xnbrs)
        okx = xn >= 0
        if okx.any():
            src = lg.cube_of[:, None].repeat(xn.shape[1], 1)
            assert np.all(lg.cube_of[xn[okx]] != src[okx])


@pytest.mark.parametrize("ratio", [0.02, 0.05, 0.15])
def test_predetermined_recall(built, ratio):
    x, s, idx, q = built
    f = make_box_filter(2, ratio, seed=int(ratio * 100))
    gt, _ = ground_truth(x, s, q, f, 10)
    ids, d = idx.query(q, f, k=10, ef=96, mode="predetermined")
    assert recall(ids, gt) >= 0.9


@pytest.mark.parametrize("mk", [make_ball_filter, make_polygon_filter,
                                make_compose_filter])
def test_onthefly_recall(built, mk):
    x, s, idx, q = built
    f = mk(2, 0.08, seed=9)
    gt, _ = ground_truth(x, s, q, f, 10)
    ids, d = idx.query(q, f, k=10, ef=96, mode="onthefly")
    assert recall(ids, gt) >= 0.85


def test_results_satisfy_filter(built):
    x, s, idx, q = built
    f = make_ball_filter(2, 0.1, seed=3)
    ids, d = idx.query(q, f, k=10, ef=64)
    ok = ids >= 0
    flat = ids[ok]
    assert np.all(np.asarray(f.contains(jnp.asarray(s[flat]))))


def test_results_sorted_and_consistent(built):
    x, s, idx, q = built
    f = make_box_filter(2, 0.1, seed=4)
    ids, d = idx.query(q, f, k=10, ef=64)
    finite = np.where(np.isfinite(d), d, 1e30)
    assert np.all(np.diff(finite, axis=1) >= -1e-5)
    # reported distances match recomputed distances
    for row_i, row_d in zip(ids, d):
        for i, dv in zip(row_i, row_d):
            if i >= 0:
                true = float(((x[i] - x[0]) ** 2).sum())  # placeholder sanity
    # recompute properly for first query
    for i, dv in zip(ids[0], d[0]):
        if i >= 0:
            true = float(((x[i].astype(np.float64) - q[0].astype(np.float64)) ** 2).sum())
            assert abs(true - dv) < 1e-2 * max(1.0, true)


def test_recall_improves_with_ef(built):
    x, s, idx, q = built
    f = make_box_filter(2, 0.03, seed=5)
    gt, _ = ground_truth(x, s, q, f, 10)
    r_small = recall(idx.query(q, f, k=10, ef=16)[0], gt)
    r_large = recall(idx.query(q, f, k=10, ef=128)[0], gt)
    assert r_large >= r_small - 0.02
    assert r_large >= 0.9


def test_layer_override(built):
    """Explicit layer selection still returns filtered results (Exp-6 knob)."""
    x, s, idx, q = built
    f = make_box_filter(2, 0.05, seed=6)
    gt, _ = ground_truth(x, s, q, f, 10)
    for layer in range(idx.n_built_layers):
        ids, _ = idx.query(q, f, k=10, ef=96, layer=layer)
        assert recall(ids, gt) >= 0.6


def test_3d_metadata():
    x, s = make_dataset(2000, 24, 3, seed=7)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=3, m_intra=10,
                                                     m_cross=3))
    q = x[:16] + 0.02
    f = make_box_filter(3, 0.1, seed=8)
    gt, _ = ground_truth(x, s, q, f, 10)
    ids, _ = idx.query(q, f, k=10, ef=96)
    assert recall(ids, gt) >= 0.85


def test_empty_filter_region():
    x, s = make_dataset(500, 16, 2, seed=9)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=3))
    from repro.core.filters import BoxFilter
    f = BoxFilter(lo=jnp.asarray([2.0, 2.0]), hi=jnp.asarray([3.0, 3.0]))
    ids, d = idx.query(x[:4], f, k=5, ef=32)
    assert np.all(ids == -1)
