"""Hypothesis property tests on system-level invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_ball_filter,
                                  make_box_filter, make_dataset, recall)
from repro.kernels import filtered_topk


@pytest.fixture(scope="module")
def small_index():
    x, s = make_dataset(1200, 24, 2, seed=42)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=3, m_intra=10,
                                                     m_cross=3))
    return x, s, idx


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 500), ratio=st.floats(0.02, 0.3),
       k=st.integers(1, 20))
def test_results_always_satisfy_filter(small_index, seed, ratio, k):
    x, s, idx = small_index
    f = make_box_filter(2, ratio, seed=seed)
    ids, d = idx.query(x[:4], f, k=k, ef=max(32, 2 * k))
    ok = ids >= 0
    if ok.any():
        assert bool(f.contains(jnp.asarray(s[ids[ok]])).all())
    # distances ascending per row
    dd = np.where(np.isfinite(d), d, np.inf)
    assert np.all(np.diff(dd, axis=1) >= -1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), ratio=st.floats(0.05, 0.3))
def test_exhaustive_ef_reaches_full_recall(small_index, seed, ratio):
    """With ef ~ |D_phi| the beam search must converge to the exact answer."""
    x, s, idx = small_index
    f = make_box_filter(2, ratio, seed=seed)
    gt, _ = ground_truth(x, s, x[:4], f, 5)
    ids, _ = idx.query(x[:4], f, k=5, ef=512, max_iters=2048)
    assert recall(ids, gt) >= 0.95


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 300), k=st.integers(1, 32))
def test_kernel_topk_matches_oracle_property(seed, k):
    x, s = make_dataset(600, 16, 2, seed=seed)
    f = make_ball_filter(2, 0.2, seed=seed)
    ids, dd = filtered_topk(x[:3], x, s, f, k)
    gt_i, _ = ground_truth(x, s, x[:3], f, k)
    for a, b in zip(np.asarray(ids), gt_i):
        assert set(a[a >= 0]) == set(b[b >= 0])


@settings(max_examples=8, deadline=None)
@given(n_add=st.integers(10, 120))
def test_insert_preserves_filter_invariant(small_index, n_add):
    x, s, _ = small_index
    idx = CubeGraphIndex.build(x[:800], s[:800],
                               CubeGraphConfig(n_layers=3, m_intra=10,
                                               m_cross=3))
    idx.insert_batch(x[800:800 + n_add], s[800:800 + n_add])
    f = make_box_filter(2, 0.15, seed=1)
    ids, _ = idx.query(x[:4], f, k=10, ef=64)
    ok = ids >= 0
    if ok.any():
        assert bool(f.contains(jnp.asarray(s[ids[ok]])).all())
    assert idx.n == 800 + n_add
