"""Multi-tenant serving tier: heterogeneous-batch bit parity, admission
control backpressure, per-request deadlines, value-based filter batching,
tenant isolation under racing writers, and snapshot round-trips.

The load-bearing claims here are *equalities*, not trends:

* a mixed-tenant mixed-filter service flush answers each request
  **bit-for-bit** like a solo ``MultiTenantStore.retrieve`` (and a
  heterogeneous ``DocumentStore.retrieve_grouped`` batch like per-request
  ``retrieve`` calls) — continuous filtered batching is a pure
  performance transform;
* one tenant's answers equal a dedicated single-tenant store's answers
  regardless of what another tenant inserts/deletes concurrently —
  isolation is correctness, not best-effort filtering.
"""
import threading

import numpy as np
import pytest

from repro.core import (BallFilter, BoxFilter, ComposeFilter,
                        CubeGraphConfig, IntervalFilter)
from repro.serving.batching import (RetrievalBatcher, RetrievalFailure,
                                    RetrievalRequest, _filter_key)
from repro.serving.rag import Document, DocumentStore
from repro.serving.service import (AdmissionController, CubeGraphService,
                                   ServeRequest)
from repro.serving.tenancy import (MultiTenantStore, TenantIsolationError,
                                   TenantQuotaError)
from repro.streaming import StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=4)
D, M = 8, 3


def _stream_cfg(**kw):
    kw.setdefault("time_dim", 2)
    kw.setdefault("seal_max_points", 64)
    kw.setdefault("n_shards", 2)
    kw.setdefault("index_cfg", IDX_CFG)
    return StreamConfig(**kw)


def _docs(rng, n, base=0):
    return [Document(doc_id=base + i,
                     tokens=np.arange(3, dtype=np.int32),
                     embedding=rng.standard_normal(D).astype(np.float32),
                     metadata=np.array([rng.uniform(0, 10),
                                        rng.uniform(0, 10), float(i)]))
            for i in range(n)]


def _two_tenant_store(rng, n=150, **cfg_kw):
    store = MultiTenantStore(D, M, stream_cfg=_stream_cfg(**cfg_kw))
    for tenant, base in (("a", 0), ("b", 10_000)):
        store.create_collection(tenant)
        store.insert(tenant, _docs(rng, n, base=base))
    store.maintenance()
    return store


def _filters():
    return (BoxFilter(lo=np.float32([0, 0, -1e9]),
                      hi=np.float32([8, 8, 1e9])),
            ComposeFilter(BoxFilter(lo=np.float32([0, 0, -1e9]),
                                    hi=np.float32([9, 9, 1e9])),
                          IntervalFilter(dim=2, lo=10.0, hi=120.0), "and"),
            None)


# ---------------------------------------------------------------------------
# Continuous filtered batching: bit-for-bit parity
# ---------------------------------------------------------------------------
def test_service_flush_bit_equals_solo_retrieve():
    """A mixed-tenant mixed-filter mixed-k flush through the service must
    return, per request, exactly the gids/dists/documents a solo
    tenant-scoped ``retrieve`` returns — the heterogeneous batch shares
    per-bucket device reads without perturbing any answer."""
    rng = np.random.default_rng(0)
    store = _two_tenant_store(rng)
    svc = CubeGraphService(store)
    filters = _filters()
    reqs = [ServeRequest(req_id=rid, tenant=("a", "b")[rid % 2],
                         query_emb=rng.standard_normal(D)
                         .astype(np.float32),
                         filt=filters[rid % 3], k=(5, 10)[rid % 2])
            for rid in range(12)]
    for r in reqs:
        assert svc.submit(r) is None
    answers = svc.flush()
    assert set(answers) == {r.req_id for r in reqs}
    for r in reqs:
        sr = answers[r.req_id]
        solo = store.retrieve(r.tenant, r.query_emb, r.filt, k=r.k)
        assert np.array_equal(sr.gids, solo.gids[0])
        assert np.array_equal(sr.dists, solo.dists[0])
        assert [d.doc_id for d in sr.docs] == \
            [d.doc_id for d in solo.docs[0]]
        assert not sr.degraded
        # answers are retained for pollers too
        assert svc.take_result(r.req_id) is sr
    assert svc.take_result(reqs[0].req_id) is None      # popped once


def test_document_store_retrieve_grouped_parity():
    """``DocumentStore.retrieve_grouped`` over heterogeneous (filter, k)
    requests returns per-request rows identical to solo ``retrieve``."""
    rng = np.random.default_rng(1)
    store = DocumentStore(_docs(rng, 200), index_cfg=IDX_CFG,
                          streaming=True, stream_cfg=_stream_cfg())
    store.maintenance()
    filters = (BoxFilter(lo=np.float32([0, 0, -1e9]),
                         hi=np.float32([7, 7, 1e9])),
               BallFilter(center=np.float32([5, 5]),
                          radius=np.float32(4.0)),
               None)
    reqs = [RetrievalRequest(req_id=rid,
                             query_emb=rng.standard_normal(D)
                             .astype(np.float32),
                             filt=filters[rid % 3], k=(4, 9)[rid % 2])
            for rid in range(9)]
    grouped = store.retrieve_grouped(reqs)
    for r in reqs:
        solo = store.retrieve(r.query_emb, r.filt, k=r.k)[0]
        assert [d.doc_id for d in grouped[r.req_id]] == \
            [d.doc_id for d in solo]
        assert grouped[r.req_id].degraded == solo.degraded


# ---------------------------------------------------------------------------
# Admission control: explicit over_quota backpressure
# ---------------------------------------------------------------------------
def test_admission_over_quota_backpressure():
    rng = np.random.default_rng(2)
    store = _two_tenant_store(rng, n=80)
    svc = CubeGraphService(store, admission=AdmissionController(
        max_queue_per_tenant=3))
    q = rng.standard_normal(D).astype(np.float32)
    failures = []
    for rid in range(5):
        res = svc.submit(ServeRequest(req_id=rid, tenant="a", query_emb=q))
        if res is not None:
            failures.append(res)
    assert len(failures) == 2
    assert all(isinstance(f, RetrievalFailure) and f.reason == "over_quota"
               for f in failures)
    # rejections are poll-visible and counted per tenant
    assert svc.take_result(failures[0].req_id).reason == "over_quota"
    snap = store.metrics.snapshot()["counters"]
    assert snap['tenant_rejected_total{tenant="a"}'] == 2
    # tenant b is unaffected by a's full queue
    assert svc.submit(ServeRequest(req_id=99, tenant="b",
                                   query_emb=q)) is None
    # admitted requests still answer normally
    answers = svc.flush()
    assert sum(1 for v in answers.values()
               if not isinstance(v, RetrievalFailure)) == 4
    with pytest.raises(KeyError):
        svc.submit(ServeRequest(req_id=100, tenant="nobody", query_emb=q))


def test_admission_global_cap():
    rng = np.random.default_rng(3)
    store = _two_tenant_store(rng, n=80)
    svc = CubeGraphService(store, admission=AdmissionController(
        max_queue_per_tenant=64, max_queue_total=2))
    q = rng.standard_normal(D).astype(np.float32)
    outcomes = [svc.submit(ServeRequest(req_id=i, tenant=("a", "b")[i % 2],
                                        query_emb=q)) for i in range(4)]
    assert [o is None for o in outcomes] == [True, True, False, False]


# ---------------------------------------------------------------------------
# Per-request deadlines / degraded propagation
# ---------------------------------------------------------------------------
def test_deadline_degrades_only_its_own_group():
    """An already-expired deadline on one request degrades *that* answer
    (with a per-reason skip count) while the other tenants/groups in the
    same flush answer completely."""
    rng = np.random.default_rng(4)
    store = _two_tenant_store(rng)
    svc = CubeGraphService(store)
    q = rng.standard_normal(D).astype(np.float32)
    svc.submit(ServeRequest(req_id=0, tenant="a", query_emb=q,
                            deadline_ms=0.0))
    svc.submit(ServeRequest(req_id=1, tenant="b", query_emb=q))
    answers = svc.flush()
    assert answers[0].degraded
    assert answers[0].reasons.get("deadline_sealed_scan", 0) >= 1
    assert not answers[1].degraded
    assert (answers[1].gids >= 0).any()
    snap = store.metrics.snapshot()["counters"]
    assert snap['tenant_degraded_total{tenant="a"}'] == 1


def test_retrieval_batcher_deadline_and_degraded_rows():
    """Satellite: ``RetrievalRequest.deadline_ms`` flows through
    ``DocumentStore.retrieve(deadline_ms=...)`` and each returned row
    carries the query's degraded markers."""
    rng = np.random.default_rng(5)
    store = DocumentStore(_docs(rng, 200), index_cfg=IDX_CFG,
                          streaming=True, stream_cfg=_stream_cfg())
    store.maintenance()
    batcher = RetrievalBatcher(store)
    q = rng.standard_normal(D).astype(np.float32)
    batcher.submit(RetrievalRequest(req_id=0, query_emb=q, filt=None,
                                    k=5, deadline_ms=0.0))
    batcher.submit(RetrievalRequest(req_id=1, query_emb=q, filt=None, k=5))
    rows = batcher.flush()
    assert rows[0].degraded and rows[0].reasons
    assert not rows[1].degraded and len(rows[1]) > 0
    # solo retrieve agrees on the degraded marker shape
    solo = store.retrieve(q, None, k=5, deadline_ms=0.0)[0]
    assert solo.degraded and solo.reasons.get("deadline_sealed_scan", 0) >= 1


# ---------------------------------------------------------------------------
# Value-based filter keys (satellite regression)
# ---------------------------------------------------------------------------
def test_filter_key_is_value_based():
    """Two equal-valued but *distinct* filter objects key identically (so
    they batch together); different shapes/dtypes with the same bytes do
    NOT collide."""
    lo, hi = np.float32([0, 0, -1e9]), np.float32([8, 8, 1e9])
    f1 = BoxFilter(lo=lo.copy(), hi=hi.copy())
    f2 = BoxFilter(lo=lo.copy(), hi=hi.copy())
    assert f1 is not f2
    assert _filter_key(f1, 5) == _filter_key(f2, 5)
    assert _filter_key(f1, 5) != _filter_key(f2, 6)
    # equal-valued compositions too (object leaves recurse by value)
    c1 = ComposeFilter(BoxFilter(lo=lo.copy(), hi=hi.copy()),
                       IntervalFilter(dim=2, lo=1.0, hi=2.0), "and")
    c2 = ComposeFilter(BoxFilter(lo=lo.copy(), hi=hi.copy()),
                       IntervalFilter(dim=2, lo=1.0, hi=2.0), "and")
    assert _filter_key(c1, 5) == _filter_key(c2, 5)
    assert _filter_key(c1, 5) != _filter_key(
        ComposeFilter(BoxFilter(lo=lo.copy(), hi=hi.copy()),
                      IntervalFilter(dim=2, lo=1.0, hi=2.5), "and"), 5)
    # same bytes, different shape / dtype must stay distinct
    flat = BoxFilter(lo=np.float32([1, 2]), hi=np.float32([3, 4]))
    col = BoxFilter(lo=np.float32([[1], [2]]), hi=np.float32([[3], [4]]))
    assert _filter_key(flat, 5) != _filter_key(col, 5)
    as_int = BoxFilter(lo=np.int32([1, 2]), hi=np.int32([3, 4]))
    assert _filter_key(flat, 5) != _filter_key(as_int, 5)


def test_equal_valued_filters_batch_together():
    """Regression: the batcher used to group by object identity, issuing
    one store dispatch per *instance* of the same filter value.  Equal
    values must share one batched ``retrieve`` call."""
    rng = np.random.default_rng(6)
    store = DocumentStore(_docs(rng, 150), index_cfg=IDX_CFG,
                          streaming=True, stream_cfg=_stream_cfg())
    store.maintenance()
    calls = []
    inner = store.retrieve
    store.retrieve = lambda *a, **kw: (calls.append(1) or inner(*a, **kw))
    batcher = RetrievalBatcher(store)
    for rid in range(4):
        batcher.submit(RetrievalRequest(
            req_id=rid, query_emb=rng.standard_normal(D)
            .astype(np.float32),
            filt=BoxFilter(lo=np.float32([0, 0, -1e9]),
                           hi=np.float32([8, 8, 1e9])), k=5))
    rows = batcher.flush()
    assert len(calls) == 1, "equal-valued filters were not batched"
    assert set(rows) == {0, 1, 2, 3}


# ---------------------------------------------------------------------------
# Tenant isolation: quotas, ownership, racing writers
# ---------------------------------------------------------------------------
def test_quota_and_ownership_errors():
    rng = np.random.default_rng(7)
    store = MultiTenantStore(D, M, stream_cfg=_stream_cfg())
    store.create_collection("a", quota_points=100)
    store.create_collection("b")
    a_gids = store.insert("a", _docs(rng, 90))
    b_gids = store.insert("b", _docs(rng, 50, base=10_000))
    with pytest.raises(TenantQuotaError):
        store.insert("a", _docs(rng, 20, base=500))
    assert store.collection("a").n_live == 90           # nothing ingested
    # deleting makes room again
    store.delete("a", a_gids[:40])
    store.insert("a", _docs(rng, 20, base=500))
    with pytest.raises(TenantIsolationError):
        store.delete("a", b_gids[:2])
    assert store.collection("b").n_live == 50           # nothing deleted
    with pytest.raises(TenantIsolationError):
        store.materialize("a", np.asarray([[int(b_gids[0])]]))


def test_concurrent_cross_tenant_race_is_invisible():
    """Satellite: tenant b races inserts/deletes/maintenance against
    tenant a's retrieves on the shared substrate.  Every answer tenant a
    observes — mid-race and after — must be bit-for-bit the answer of a
    dedicated single-tenant oracle store that never saw tenant b."""
    rng = np.random.default_rng(8)
    a_docs = _docs(rng, 150)
    store = MultiTenantStore(D, M, stream_cfg=_stream_cfg())
    store.create_collection("a")
    store.create_collection("b")
    store.insert("a", a_docs)
    store.insert("b", _docs(rng, 100, base=10_000))
    store.maintenance()
    oracle = DocumentStore(a_docs, index_cfg=IDX_CFG, streaming=True,
                           stream_cfg=_stream_cfg())
    oracle.maintenance()
    qs = rng.standard_normal((3, D)).astype(np.float32)
    filt = BoxFilter(lo=np.float32([0, 0, -1e9]),
                     hi=np.float32([8, 8, 1e9]))
    expect_gids, expect_dists = oracle.manager.query(qs, filt, k=10)
    expect_ids = [[a_docs[g].doc_id for g in row if g >= 0]
                  for row in np.asarray(expect_gids)]

    errors, answers = [], []
    b_rng = np.random.default_rng(9)

    def churn_b():
        try:
            for i in range(4):
                gids = store.insert(
                    "b", _docs(b_rng, 30, base=20_000 + 100 * i))
                store.delete("b", gids[::3])
                store.maintenance()
        except Exception as exc:  # noqa: BLE001 — surface in main thread
            errors.append(exc)

    def read_a():
        try:
            for _ in range(8):
                answers.append(store.retrieve("a", qs, filt, k=10))
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=churn_b),
               threading.Thread(target=read_a)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    answers.append(store.retrieve("a", qs, filt, k=10))  # post-race too
    for ans in answers:
        assert np.array_equal(ans.dists, np.asarray(expect_dists,
                                                    np.float32))
        assert [[d.doc_id for d in row] for row in ans.docs] == expect_ids


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------
def test_multi_tenant_snapshot_roundtrip(tmp_path):
    rng = np.random.default_rng(10)
    store = MultiTenantStore(D, M, stream_cfg=_stream_cfg())
    store.create_collection("a", quota_points=500)
    store.create_collection("b")
    store.insert("a", _docs(rng, 120))
    b_gids = store.insert("b", _docs(rng, 80, base=10_000))
    store.delete("b", b_gids[:10])
    store.maintenance()
    q = rng.standard_normal((2, D)).astype(np.float32)
    before = store.retrieve("a", q, _filters()[0], k=8)

    store.snapshot_to(str(tmp_path / "snap"))
    restored = MultiTenantStore.restore(str(tmp_path / "snap"), D, M)
    after = restored.retrieve("a", q, _filters()[0], k=8)
    assert np.array_equal(before.gids, after.gids)
    assert np.array_equal(before.dists, after.dists)
    assert [[d.doc_id for d in row] for row in before.docs] == \
        [[d.doc_id for d in row] for row in after.docs]
    assert restored.collection("a").quota_points == 500
    assert restored.collection("b").n_live == 70
    # tid allocation resumes past restored collections
    assert restored.create_collection("c").tid == 3


# ---------------------------------------------------------------------------
# Async loop + traffic harness smoke
# ---------------------------------------------------------------------------
def test_async_loop_answers_polled_requests():
    rng = np.random.default_rng(11)
    store = _two_tenant_store(rng, n=80)
    svc = CubeGraphService(store)
    svc.start(interval_ms=1.0)
    try:
        q = rng.standard_normal(D).astype(np.float32)
        assert svc.submit(ServeRequest(req_id=0, tenant="a",
                                       query_emb=q, k=5)) is None
        deadline = 30.0
        import time
        t0 = time.monotonic()
        res = None
        while res is None and time.monotonic() - t0 < deadline:
            res = svc.take_result(0)
            if res is None:
                time.sleep(0.01)
    finally:
        svc.stop()
    assert res is not None and not isinstance(res, RetrievalFailure)
    solo = store.retrieve("a", q, None, k=5)
    assert np.array_equal(res.gids, solo.gids[0])
    assert np.array_equal(res.dists, solo.dists[0])


def test_workload_smoke_report_schema():
    """The geo-temporal harness's smoke configuration produces the full
    SLO report schema with the isolation check green — the same
    invocation CI runs via ``python -m repro.serving.workload --smoke``."""
    from repro.serving.workload import SLO_REPORT_KEYS, _smoke
    report = _smoke()
    assert all(key in report for key in SLO_REPORT_KEYS)
    assert report["isolation_ok"] and report["isolation_checks"] > 0
    assert report["n_requests"] > 0
    assert report["n_answered"] == report["n_requests"]
    assert report["recall_at_10"] >= 0.95
