"""Checkpointing: atomicity, integrity, elastic restore, data-order resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.training.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": jnp.ones((16, 8)), "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    st = _state()
    cm.save(10, st, extra={"data_step": 10})
    restored, manifest = cm.restore(st)
    assert manifest["step"] == 10
    assert manifest["extra"]["data_step"] == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keeps_latest_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        cm.save(s, st)
    assert cm.available_steps() == [3, 4]


def test_corruption_falls_back(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5)
    st = _state()
    cm.save(1, st)
    cm.save(2, st)
    # corrupt latest: flip bytes in one array file
    cdir = os.path.join(str(tmp_path), "step_00000002")
    manifest = json.load(open(os.path.join(cdir, "manifest.json")))
    victim = list(manifest["leaves"].values())[0]["file"]
    with open(os.path.join(cdir, victim), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    restored, m = cm.restore(st)
    assert m["step"] == 1                         # fell back to valid step


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp directory (simulated crash mid-save) is never restorable."""
    cm = CheckpointManager(str(tmp_path))
    st = _state()
    cm.save(5, st)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert cm.available_steps() == [5]


def test_elastic_restore_resharded(tmp_path):
    """Restore onto a different sharding (device count change simulated by a
    different PartitionSpec on one device)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path))
    st = _state()
    cm.save(3, st)
    from repro.launch.mesh import mesh_compat_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_compat_kwargs(1))
    sh = jax.tree.map(lambda a: NamedSharding(mesh, P()), st)
    restored, _ = cm.restore(st, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_data_resume_bit_identical():
    """The stateless pipeline regenerates identical batches from a cursor."""
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    p1 = SyntheticTokenPipeline(cfg)
    ref = [p1.batch(s) for s in range(10)]
    p2 = SyntheticTokenPipeline(cfg)              # "restarted job"
    for s in (5, 6, 9):
        np.testing.assert_array_equal(p2.batch(s)["tokens"],
                                      ref[s]["tokens"])


def test_host_sharded_pipeline_partitions():
    """n_hosts shards partition the global batch without overlap."""
    full = SyntheticTokenPipeline(DataConfig(vocab=31, seq_len=8,
                                             global_batch=8, seed=4))
    parts = [SyntheticTokenPipeline(DataConfig(vocab=31, seq_len=8,
                                               global_batch=8, seed=4,
                                               n_hosts=4, host_id=h))
             for h in range(4)]
    want = full.batch(2)["tokens"]
    got = np.concatenate([p.batch(2)["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(want, got)
