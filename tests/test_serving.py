"""Serving: prefill/decode consistency, generation, continuous batching, RAG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, init_params
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.serve_step import generate, make_serve_fns


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("codeqwen1.5-7b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    return cfg, model, params


def test_prefill_matches_stepwise_decode(dense_model):
    """Greedy decode after prefill(prompt) == prefill(prompt + generated)."""
    cfg, model, params = dense_model
    rng = np.random.default_rng(1)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 6)), jnp.int32)
    out = generate(model, params, prompt, max_new=4, max_len=16)
    # re-score: the argmax of logits at each position must reproduce tokens
    full = jnp.concatenate([prompt, out[:, :-1]], axis=1)
    logits, _ = model.logits(params, full)
    pred = jnp.argmax(logits[:, 5:, :].astype(jnp.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(out))


def test_generate_is_deterministic_greedy(dense_model):
    cfg, model, params = dense_model
    prompt = jnp.ones((1, 4), jnp.int32)
    a = generate(model, params, prompt, max_new=6, max_len=16)
    b = generate(model, params, prompt, max_new=6, max_len=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_continuous_batcher_matches_unbatched(dense_model):
    """Slot-batched greedy decoding must equal standalone generation."""
    cfg, model, params = dense_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab, size=(L,)).astype(np.int32)
               for L in (3, 5, 4, 6, 3)]
    want = [np.asarray(generate(model, params, jnp.asarray(p[None, :]),
                                max_new=5, max_len=32))[0]
            for p in prompts]
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=32,
                                eos_id=-1)
    for i, p in enumerate(prompts):
        batcher.submit(Request(req_id=i, prompt=p, max_new=5))
    done = batcher.run_until_drained()
    assert len(done) == len(prompts)
    by_id = {r.req_id: r.output for r in done}
    for i, w in enumerate(want):
        np.testing.assert_array_equal(np.asarray(by_id[i]), w)


def test_batcher_frees_slots(dense_model):
    cfg, model, params = dense_model
    batcher = ContinuousBatcher(model, params, n_slots=2, max_len=32,
                                eos_id=-1)
    for i in range(4):
        batcher.submit(Request(req_id=i, prompt=np.ones(3, np.int32),
                               max_new=3))
    done = batcher.run_until_drained()
    assert len(done) == 4                  # 4 requests through 2 slots


def test_ssm_decode_matches_forward():
    """Mamba decode steps reproduce the training forward logits."""
    cfg = get_config("falcon-mamba-7b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 8)), jnp.int32)
    logits_fwd, _ = model.logits(params, toks)
    cache = model.init_cache(1, 16)
    outs = []
    for t in range(8):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.asarray([t], jnp.int32))
        outs.append(np.asarray(lg.astype(jnp.float32))[0, 0])
    fwd = np.asarray(logits_fwd.astype(jnp.float32))[0]
    np.testing.assert_allclose(np.stack(outs), fwd, rtol=6e-2, atol=6e-2)
