"""Cluster fault handling policies: heartbeats, stragglers, elastic plans."""
import numpy as np

from repro.training.fault_tolerance import (FaultTolerantRunner,
                                            HeartbeatConfig, HeartbeatMonitor,
                                            plan_elastic_mesh)


def test_dead_host_detection():
    cfg = HeartbeatConfig(interval_s=1.0, miss_threshold=3)
    mon = HeartbeatMonitor(hosts=range(4), cfg=cfg)
    now = 100.0
    for h in range(4):
        mon.beat(h, now=now)
    mon.beat(0, now=now + 10)
    mon.beat(1, now=now + 10)
    mon.beat(2, now=now + 10)
    # host 3 silent for 10s > 3 beats x 1s
    assert mon.dead_hosts(now=now + 10) == [3]


def test_straggler_detection():
    mon = HeartbeatMonitor(hosts=range(4))
    for step in range(10):
        for h in range(4):
            t = 1.0 if h != 2 else 3.5       # host 2 is 3.5x slower
            mon.beat(h, step_time_s=t)
    assert mon.stragglers() == [2]


def test_no_false_stragglers():
    mon = HeartbeatMonitor(hosts=range(8))
    rng = np.random.default_rng(0)
    for step in range(20):
        for h in range(8):
            mon.beat(h, step_time_s=1.0 + 0.05 * rng.random())
    assert mon.stragglers() == []


def test_elastic_plan_shrinks_data_axis():
    p = plan_elastic_mesh(256, model_parallel=16)
    assert p.mesh_shape == (16, 16)
    # lose 32 chips -> largest pow2 data axis that fits
    p = plan_elastic_mesh(224, model_parallel=16)
    assert p.mesh_shape == (8, 16)
    assert p.axis_names == ("data", "model")
    # multi-pod
    p = plan_elastic_mesh(512, model_parallel=16, pods=2)
    assert p.mesh_shape == (2, 16, 16)
    p = plan_elastic_mesh(480, model_parallel=16, pods=2)
    assert p.mesh_shape == (2, 8, 16)


def test_runner_checkpoints_and_flags(tmp_path):
    from repro.training.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path))
    mon = HeartbeatMonitor(hosts=range(2),
                           cfg=HeartbeatConfig(interval_s=10.0))
    runner = FaultTolerantRunner(cm, mon, ckpt_every=5)
    state = {"w": np.ones(4)}
    for step in range(1, 11):
        runner.maybe_checkpoint(step, state, data_step=step)
    assert cm.available_steps() == [5, 10]
    # host 1 goes silent; host 0 keeps beating
    mon.beat(1, now=200.0)
    mon.beat(0, now=290.0)
    status = runner.check_cluster(now=300.0)   # gap: host0=10s, host1=100s
    assert status["dead"] == [1]
    assert status["action"] == "elastic_restart"
