"""End-to-end spatio-temporal RAG (the paper's application layer)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CubeGraphConfig
from repro.core.workloads import make_box_filter, make_dataset
from repro.models import build_model, init_params
from repro.serving.rag import Document, DocumentStore, RAGPipeline


@pytest.fixture(scope="module")
def store_and_model():
    x, s = make_dataset(1200, 24, 3, seed=1)     # 2D geo + time
    rng = np.random.default_rng(2)
    docs = [Document(doc_id=i,
                     tokens=rng.integers(2, 250, size=12).astype(np.int32),
                     embedding=x[i], metadata=s[i]) for i in range(1200)]
    store = DocumentStore(docs, CubeGraphConfig(n_layers=3, m_intra=10,
                                                m_cross=3))
    cfg = get_config("internvl2-2b", smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, n_patches=0)   # pure-text RAG here
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    return x, s, store, model, params


def test_retrieval_respects_filter(store_and_model):
    x, s, store, model, params = store_and_model
    f = make_box_filter(3, 0.1, seed=3)
    q_emb = x[7]
    got = store.retrieve(q_emb, f, k=5, ef=64)[0]
    import jax.numpy as jnp
    for d in got:
        assert bool(f.contains(jnp.asarray(d.metadata[None, :]))[0])


def test_rag_answer_end_to_end(store_and_model):
    x, s, store, model, params = store_and_model
    pipe = RAGPipeline(store, model, params, max_context=64)
    f = make_box_filter(3, 0.2, seed=4)
    rng = np.random.default_rng(5)
    query = rng.integers(2, 250, size=6).astype(np.int32)
    out, docs = pipe.answer(query, f, k=3, max_new=8)
    assert len(out) == 8
    assert all(0 <= t < model.cfg.vocab for t in out)
    assert 1 <= len(docs) <= 3


def test_rag_store_insert(store_and_model):
    """Streaming ingestion: new documents become retrievable (paper §4.4)."""
    x, s, store, model, params = store_and_model
    rng = np.random.default_rng(6)
    n0 = store.index.n
    new_docs = [Document(doc_id=n0 + i,
                         tokens=rng.integers(2, 250, size=12).astype(np.int32),
                         embedding=x[i] + 0.01,
                         metadata=np.asarray([0.5, 0.5, 0.5]))
                for i in range(8)]
    store.insert(new_docs)
    assert store.index.n == n0 + 8
    from repro.core.filters import BoxFilter
    import jax.numpy as jnp
    f = BoxFilter(lo=jnp.asarray([0.45, 0.45, 0.45]),
                  hi=jnp.asarray([0.55, 0.55, 0.55]))
    got = store.retrieve(x[0] + 0.01, f, k=4, ef=64)[0]
    assert any(d.doc_id >= n0 for d in got)
