"""Shared pytest fixtures for the suite.

The full suite JIT-compiles several hundred distinct XLA executables
(every test module brings its own shapes/meshes/quant variants).  The
CPU backend keeps them all alive via jax's global compilation caches,
and past a threshold the accumulated JIT code can segfault a late
``backend_compile`` (observed deterministically in
``test_updates.py::test_insert_discoverable`` once the tiering suite
joined the run, while every module passes in isolation).  Dropping the
caches between modules keeps the resident compiled-code footprint
bounded by one module's working set; cross-module cache reuse is
negligible since modules rarely share shapes.
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Release compiled executables after each test module."""
    yield
    jax.clear_caches()
    gc.collect()
