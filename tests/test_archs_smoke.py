"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, SHAPES, cell_supported
from repro.models import abstract_params, build_model, init_params

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family in ("audio", "encdec"):
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.n_frames,
                                                    cfg.d_model), jnp.float32)
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(ks[2], (B, cfg.n_patches,
                                                     cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    if cfg.family in ("audio", "encdec"):
        logits, _ = model.logits(params, batch["tokens"], batch["frames"])
        want_s = S
    elif cfg.n_patches:
        logits, _ = model.logits(params, batch["tokens"], batch["patches"])
        want_s = S + cfg.n_patches
    else:
        logits, _ = model.logits(params, batch["tokens"])
        want_s = S
    assert logits.shape == (B, want_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache2 = model.decode_step(params, tok, cache, pos)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # second step at pos 1 also works and differs
    logits2, _ = model.decode_step(params, tok, cache2, pos + 1)
    assert logits2.shape == (B, 1, cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_params_match(arch):
    """ShapeDtypeStruct specs agree with materialized params (dry-run path)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    specs = abstract_params(model.param_specs())
    params = init_params(model.param_specs(), jax.random.key(0))
    ss = jax.tree.map(lambda s: (s.shape, s.dtype), specs)
    ps = jax.tree.map(lambda p: (p.shape, p.dtype), params)
    assert ss == ps


def test_full_configs_param_counts():
    """Full (non-smoke) configs roughly match the published sizes."""
    import math
    expected = {
        "codeqwen1.5-7b": (6e9, 9e9),
        "starcoder2-15b": (13e9, 17e9),
        "minicpm-2b": (2e9, 3.5e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),      # total incl. all experts
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "whisper-medium": (0.5e9, 1.0e9),
        "zamba2-2.7b": (2e9, 3.5e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "internvl2-2b": (1.5e9, 3e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_long_500k_applicability():
    """Skip matrix matches DESIGN.md §3.2."""
    runs = {a: cell_supported(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCH_IDS}
    assert runs["falcon-mamba-7b"] and runs["zamba2-2.7b"] and runs["gemma3-1b"]
    for a in ("codeqwen1.5-7b", "starcoder2-15b", "minicpm-2b",
              "qwen2-moe-a2.7b", "qwen3-moe-235b-a22b", "whisper-medium",
              "internvl2-2b"):
        assert not runs[a], a
