"""Durable streaming snapshots: WAL + manifest persistence + crash recovery.

The acceptance property: a ``SegmentManager`` restored from disk answers a
64-query batch **bit-for-bit identically** (gids and distances) to the live
manager it was snapshotted from, on both the per-segment fan-out and the
``n_shards > 1`` sharded read paths, across arbitrary interleavings of
ingest / delete / seal / compact / expire / GC.  The crash-injection tests
kill persistence at its three worst instants (mid-WAL-append, mid-segment-
write, between segment write and manifest rename) and assert restore always
recovers the last consistent manifest without duplicating or losing
acknowledged points.
"""
import os
import shutil

import numpy as np
import pytest

from repro.core import CubeGraphConfig, CubeGraphIndex, IntervalFilter
from repro.core.cubegraph import load_index, save_index
from repro.core.workloads import make_dataset
from repro.streaming import RestoreError, SegmentManager, StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=2)
D, M, TIME_DIM = 8, 2, 1
OPS = ("ingest", "delete", "seal", "compact", "expire", "gc")


def _stream_cfg(persist_dir=None, n_shards=2, seal=48, ttl=np.inf):
    return StreamConfig(time_dim=TIME_DIM, seal_max_points=seal, ttl=ttl,
                        compact_max_segments=3, n_shards=n_shards,
                        store_chunk=64, persist_dir=persist_dir,
                        index_cfg=IDX_CFG)


def _run_program(mgr, rng, op_kinds):
    """Apply one op interleaving; ingests use a monotone event time."""
    t = getattr(mgr, "_test_t", 0)
    for kind in op_kinds:
        if kind == "ingest":
            n = int(rng.integers(10, 60))
            x = rng.normal(size=(n, D)).astype(np.float32)
            s = rng.uniform(size=(n, M))
            s[:, TIME_DIM] = (t + np.arange(n)) / 100.0
            t += n
            mgr.ingest(x, s)
        elif kind == "delete" and mgr.n_total:
            k = max(1, mgr.n_total // 6)
            mgr.delete(rng.integers(0, mgr.n_total, size=k))
        elif kind == "seal":
            mgr.seal()
        elif kind == "compact":
            mgr.compact()
        elif kind == "expire":
            mgr.expire()
        elif kind == "gc":
            mgr.gc_store()
    mgr._test_t = t


_LIVENESS_KEYS = ("n_total", "n_live", "delta_live", "n_segments",
                  "segment_live", "segment_spans", "now", "sealed",
                  "deleted", "expired_points", "expired_segments",
                  "store_gc_points", "store_resident_points")


def _assert_bit_identical(live, restored, rng, b=64, k=5):
    """Restored manager == live manager: liveness stats and bit-for-bit
    query results on both read paths, filtered and unfiltered."""
    ls, rs = live.stats(), restored.stats()
    for key in _LIVENESS_KEYS:
        assert ls[key] == rs[key], f"stats[{key}]: {ls[key]} != {rs[key]}"
    q = rng.normal(size=(b, D)).astype(np.float32)
    t_mid = (live.now / 2.0) if np.isfinite(live.now) else 0.0
    filters = [None, IntervalFilter(dim=TIME_DIM, lo=np.float32(t_mid))]
    for filt in filters:
        for use_shards in (False, True):
            gl, dl = live.query(q, filt, k=k, ef=48, use_shards=use_shards)
            gr, dr = restored.query(q, filt, k=k, ef=48,
                                    use_shards=use_shards)
            path = "sharded" if use_shards else "fanout"
            assert np.array_equal(gl, gr), f"gids differ on {path}/{filt}"
            assert np.array_equal(dl, dr), f"dists differ on {path}/{filt}"


def _roundtrip_example(seed, n_ops, tmp_root):
    """One property example: random interleaving -> snapshot -> restore."""
    rng = np.random.default_rng(seed)
    mgr = SegmentManager(D, M, _stream_cfg(ttl=1.5))
    kinds = ["ingest"] + [OPS[int(rng.integers(0, len(OPS)))]
                          for _ in range(n_ops - 1)]
    _run_program(mgr, rng, kinds)
    snap = os.path.join(tmp_root, f"snap-{seed}")
    mgr.snapshot_to(snap)
    restored = SegmentManager.restore(snap, resume=False)
    _assert_bit_identical(mgr, restored, np.random.default_rng(seed + 1))
    shutil.rmtree(snap)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n_ops=st.integers(2, 8))
    def test_roundtrip_property_hypothesis(seed, n_ops, tmp_path_factory):
        """Acceptance (hypothesis, >= 25 examples): arbitrary op
        interleavings -> snapshot -> restore -> identical query results and
        liveness stats on both read paths."""
        _roundtrip_example(seed, n_ops, str(tmp_path_factory.mktemp("prop")))
except ImportError:                      # pragma: no cover - optional dep
    pass


@pytest.mark.parametrize("seed,n_ops", [(0, 4), (1, 6), (2, 8), (3, 5),
                                        (4, 7), (5, 3)])
def test_roundtrip_property_random(seed, n_ops, tmp_path):
    """Same acceptance property on fixed seeds (runs without hypothesis)."""
    _roundtrip_example(seed * 977 + 13, n_ops, str(tmp_path))


def test_incremental_persistence_roundtrip(tmp_path):
    """StreamConfig(persist_dir=...): the home directory alone (WAL +
    checkpoints, no explicit snapshot call) restores bit-for-bit."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(7)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, ttl=1.5))
    _run_program(mgr, rng, ["ingest", "ingest", "delete", "ingest",
                            "expire", "gc", "compact", "ingest", "delete"])
    restored = SegmentManager.restore(root)
    _assert_bit_identical(mgr, restored, np.random.default_rng(8))
    # the restored replica resumes journaling: mutate it, restore again
    rng2 = np.random.default_rng(9)
    _run_program(restored, rng2, ["ingest", "delete"])
    again = SegmentManager.restore(root, resume=False)
    _assert_bit_identical(restored, again, np.random.default_rng(10))


def test_expiring_all_dead_segment_is_checkpointed(tmp_path):
    """Regression: expiry of a segment whose points were all already
    deleted flips no liveness bit, but the segment-list transition must
    still reach the manifest — otherwise restore resurrects the segment."""
    root = str(tmp_path / "home")
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=20,
                                           ttl=0.3))
    rng = np.random.default_rng(15)
    x = rng.normal(size=(20, D)).astype(np.float32)
    s = rng.uniform(size=(20, M))
    s[:, TIME_DIM] = np.arange(20) / 100.0
    mgr.ingest(x, s)                       # seals one segment
    assert len(mgr.segments) == 1
    mgr.delete(np.arange(20))              # segment fully dead, still listed
    mgr.ingest(x, s + np.array([0.0, 1.0]))  # advance event time past ttl
    mgr.expire()                           # drops the all-dead segment
    restored = SegmentManager.restore(root, resume=False)
    _assert_bit_identical(mgr, restored, np.random.default_rng(16))


def test_wal_only_restore_before_first_seal(tmp_path):
    """A crash before any seal restores purely from the WAL tail."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(11)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=10_000))
    _run_program(mgr, rng, ["ingest", "delete", "ingest"])
    assert len(mgr.segments) == 0
    restored = SegmentManager.restore(root, resume=False)
    _assert_bit_identical(mgr, restored, np.random.default_rng(12))


# ---------------------------------------------------------------------------
# Crash injection
# ---------------------------------------------------------------------------
class _Crash(RuntimeError):
    """The simulated kill signal raised from a persistence fault point."""


class _FaultHook:
    """Raise :class:`_Crash` at the ``n``-th hit of one fault point."""

    def __init__(self, point, skip=0):
        self.point = point
        self.skip = skip

    def __call__(self, point):
        if point == self.point:
            if self.skip == 0:
                raise _Crash(point)
            self.skip -= 1


def _ingest_block(mgr, rng, n, t0):
    x = rng.normal(size=(n, D)).astype(np.float32)
    s = rng.uniform(size=(n, M))
    s[:, TIME_DIM] = (t0 + np.arange(n)) / 100.0
    mgr.ingest(x, s)


def _live_gids(mgr):
    return set(np.nonzero(mgr.alive)[0].tolist())


def _queried_gids(mgr, rng, k=10):
    q = rng.normal(size=(16, D)).astype(np.float32)
    out = set()
    for use_shards in (False, True):
        g, _ = mgr.query(q, None, k=k, ef=64, use_shards=use_shards)
        for row in g:
            real = [int(v) for v in row if v >= 0]
            assert len(real) == len(set(real)), "duplicate gid in one row"
            out |= set(real)
    return out


@pytest.mark.parametrize("point", ["wal.append", "segment.write",
                                   "manifest.rename"])
def test_crash_injection_recovers_consistent_state(point, tmp_path):
    """Kill persistence mid-WAL-append, mid-segment-write, and between
    segment write and manifest rename: restore must recover every
    acknowledged point exactly once and stay internally consistent."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(21)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=40))
    _ingest_block(mgr, rng, 35, 0)         # acked, below seal threshold
    mgr.delete([1, 3, 5])                  # acked
    acked_live = _live_gids(mgr)

    hook = _FaultHook(point)
    mgr.persist.fault_hook = hook
    mgr.persist.wal.fault_hook = hook if point == "wal.append" else None
    with pytest.raises(_Crash):
        _ingest_block(mgr, rng, 30, 35)    # crashes (wal now, or at seal)

    restored = SegmentManager.restore(root)    # resume journaling
    got_live = _live_gids(restored)
    # acknowledged points survive, exactly once, and none are duplicated
    assert acked_live <= got_live
    assert restored.n_total in (35, 65)    # pre-op or fully-applied op
    assert len(got_live) == restored.n_live
    queried = _queried_gids(restored, np.random.default_rng(22))
    assert queried <= got_live
    assert not ({1, 3, 5} & got_live), "deleted points resurrected"
    # the torn artifact / WAL tail never blocks a later healthy lifecycle:
    # the resumed replica keeps journaling and restores again losslessly
    _ingest_block(restored, np.random.default_rng(23), 50, 70)
    again = SegmentManager.restore(root, resume=False)
    assert again.n_live == restored.n_live
    assert _live_gids(again) == _live_gids(restored)


def test_crash_midway_keeps_previous_manifest_loadable(tmp_path):
    """Crashing the N-th checkpoint leaves the (N-1)-th fully usable."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(31)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=30))
    _ingest_block(mgr, rng, 64, 0)         # two seals -> two checkpoints
    n_before = mgr.n_total
    live_before = _live_gids(mgr)
    mgr.persist.fault_hook = _FaultHook("manifest.rename")
    with pytest.raises(_Crash):
        _ingest_block(mgr, rng, 40, 64)    # third seal crashes pre-rename
    restored = SegmentManager.restore(root, resume=False)
    # the crashed batch was WAL-logged before the torn checkpoint, so the
    # restored state may include it (in the delta) — never half a segment
    assert _live_gids(restored) >= live_before
    assert restored.n_total in (n_before, n_before + 40)
    assert sum(restored.stats()["segment_live"]) + restored.delta.n_live \
        == restored.n_live


def test_concurrent_compaction_vs_snapshot(tmp_path):
    """`compact_async` racing `snapshot_to` under real threads: every
    snapshot restores to either the pre- or post-publish epoch — never a
    torn mix — and the exact sharded read path answers identically."""
    rng = np.random.default_rng(41)
    mgr = SegmentManager(D, M, _stream_cfg(seal=40))
    _ingest_block(mgr, rng, 280, 0)
    mgr.delete(rng.integers(0, 280, size=120))
    epoch_before = mgr.epoch

    snaps = []
    t = mgr.compact_async()
    i = 0
    while t.is_alive() or i < 2:           # overlap + at least 2 snapshots
        snap = str(tmp_path / f"snap-{i}")
        mgr.snapshot_to(snap)
        snaps.append(snap)
        i += 1
        if i > 8:
            break
    mgr.wait_for_compaction()
    assert mgr.epoch > epoch_before        # the race actually published

    q = rng.normal(size=(32, D)).astype(np.float32)
    gl, dl = mgr.query(q, None, k=8)       # exact path: compaction-invariant
    live_set = _live_gids(mgr)
    for snap in snaps:
        r = SegmentManager.restore(snap, resume=False)
        # no torn mix: each live gid lives in exactly one place
        seen = []
        for seg in r.segments:
            seen.extend(seg.gids[seg.index.valid].tolist())
        seen.extend(r.delta.gids[: r.delta.size][
            r.delta.valid[: r.delta.size]].tolist())
        assert len(seen) == len(set(seen)), f"{snap}: gid in two segments"
        assert set(seen) == live_set, f"{snap}: liveness diverged"
        gr, dr = r.query(q, None, k=8)
        assert np.array_equal(dl, dr)
        assert np.array_equal(gl, gr)


def test_torn_wal_tail_at_file_level(tmp_path):
    """A SIGKILL/power-cut torn frame (simulated by truncating the WAL
    mid-frame on disk) loses only the torn record; a resuming replica
    truncates the tail and keeps journaling from the durable prefix."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(25)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=10_000))
    _ingest_block(mgr, rng, 20, 0)
    mgr.persist.close()
    wal = next(p for p in os.listdir(root) if p.startswith("wal-"))
    path = os.path.join(root, wal)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 7)               # rip the last frame apart
    restored = SegmentManager.restore(root)      # resume=True truncates
    assert restored.n_total == 0                 # only record was torn off
    assert os.path.getsize(path) < size - 7
    _ingest_block(restored, rng, 15, 20)         # journaling continues
    assert SegmentManager.restore(root, resume=False).n_total == 15


def test_failed_wal_append_leaves_manager_consistent(tmp_path):
    """An in-process WAL append failure (disk full, raising hook) must not
    leave phantom alive points: the append rolls back in the log and no
    in-memory state changes, so the manager keeps working after the
    error."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(27)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=10_000))
    _ingest_block(mgr, rng, 20, 0)
    wal_size = mgr.persist.wal.offset
    mgr.persist.wal.fault_hook = _FaultHook("wal.append")
    with pytest.raises(_Crash):
        _ingest_block(mgr, rng, 10, 20)
    # nothing acknowledged, nothing mutated, nothing torn on disk
    assert mgr.n_total == 20 and mgr.n_live == 20
    assert mgr.persist.wal.offset == wal_size
    assert sum(mgr.stats()["segment_live"]) + mgr.delta.n_live == mgr.n_live
    mgr.persist.wal.fault_hook = None
    _ingest_block(mgr, rng, 10, 20)        # recovers without restart
    assert SegmentManager.restore(root, resume=False).n_total == 30


def test_resume_after_wal_file_lost(tmp_path):
    """Regression: resuming a snapshot whose WAL file vanished (partial
    copy, external cleanup) must re-create a *valid* log — post-resume
    acknowledged writes have to survive the next restore."""
    root = str(tmp_path / "home")
    rng = np.random.default_rng(33)
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root, seal=25))
    _ingest_block(mgr, rng, 25, 0)         # seal -> checkpoint
    mgr.persist.close()
    wal = next(p for p in os.listdir(root) if p.startswith("wal-"))
    os.remove(os.path.join(root, wal))
    restored = SegmentManager.restore(root)      # resume=True
    assert restored.n_total == 25
    _ingest_block(restored, rng, 10, 25)         # acked post-resume
    again = SegmentManager.restore(root, resume=False)
    assert again.n_total == 35                   # nothing silently lost


def test_manifest_is_strict_json(tmp_path):
    """MANIFEST.json must parse under strict JSON (no Infinity/NaN tokens)
    even for the empty manager's -inf watermark and infinite ttl."""
    import json
    root = str(tmp_path / "home")
    SegmentManager(D, M, _stream_cfg(persist_dir=root, ttl=np.inf))

    def no_constants(_):
        raise AssertionError("non-standard JSON constant in manifest")

    man = json.loads(open(os.path.join(root, "MANIFEST.json")).read(),
                     parse_constant=no_constants)
    assert man["now"] is None and man["cfg"]["ttl"] is None
    restored = SegmentManager.restore(root, resume=False)
    assert restored.now == -np.inf and restored.cfg.ttl == np.inf


def test_restore_rejects_geometry_cfg_override(tmp_path):
    """Policy knobs may change on restore; on-disk geometry (store_chunk,
    time_dim) may not — silently re-keying the store would corrupt it."""
    root = str(tmp_path / "home")
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root))
    _ingest_block(mgr, np.random.default_rng(29), 30, 0)
    with pytest.raises(RestoreError):
        SegmentManager.restore(root, resume=False, cfg=StreamConfig(
            time_dim=TIME_DIM, store_chunk=128, index_cfg=IDX_CFG))
    with pytest.raises(RestoreError):
        SegmentManager.restore(root, resume=False, cfg=StreamConfig(
            time_dim=0, store_chunk=64, index_cfg=IDX_CFG))
    ok = SegmentManager.restore(root, resume=False, cfg=StreamConfig(
        time_dim=TIME_DIM, store_chunk=64, n_shards=4, seal_max_points=7,
        index_cfg=IDX_CFG))
    assert ok.cfg.n_shards == 4 and ok.n_total == 30


# ---------------------------------------------------------------------------
# Corruption / misuse guards
# ---------------------------------------------------------------------------
def test_restore_rejects_corrupt_state(tmp_path):
    """A flipped byte in the state blob fails the manifest checksum."""
    root = str(tmp_path / "home")
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root))
    _ingest_block(mgr, np.random.default_rng(51), 60, 0)
    state = next(p for p in os.listdir(root) if p.startswith("state-"))
    path = os.path.join(root, state)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(RestoreError):
        SegmentManager.restore(root)


def test_attach_to_existing_snapshot_refuses(tmp_path):
    """Constructing a fresh manager over a populated persist_dir must not
    silently shadow the existing snapshot."""
    root = str(tmp_path / "home")
    mgr = SegmentManager(D, M, _stream_cfg(persist_dir=root))
    _ingest_block(mgr, np.random.default_rng(61), 30, 0)
    with pytest.raises(ValueError):
        SegmentManager(D, M, _stream_cfg(persist_dir=root))


# ---------------------------------------------------------------------------
# core save/load regression + serving warm start
# ---------------------------------------------------------------------------
def test_load_index_survives_artifact_deletion(tmp_path):
    """Regression: ``load_index`` must materialize every array before the
    npz context closes — a loaded index stays fully queryable after its
    on-disk artifact is deleted."""
    x, s = make_dataset(400, D, M, seed=71)
    idx = CubeGraphIndex.build(x, s, IDX_CFG)
    q = np.random.default_rng(72).normal(size=(8, D)).astype(np.float32)
    f = IntervalFilter(dim=TIME_DIM, lo=np.float32(0.2))
    ids_a, d_a = idx.query(q, f, k=10, ef=64)
    art = str(tmp_path / "idx")
    save_index(idx, art)
    idx2 = load_index(art)
    shutil.rmtree(art)                      # artifact gone before first use
    ids_b, d_b = idx2.query(q, f, k=10, ef=64)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_allclose(d_a, d_b, rtol=1e-6)
    idx2.delete([0, 1])                     # valid stays a writable copy
    assert idx2.deleted_fraction() > 0


def test_load_index_mmap_warm_start(tmp_path):
    """``mmap_mode='r'`` serves the point arrays straight off the immutable
    artifact and answers identically to the materialized load."""
    x, s = make_dataset(300, D, M, seed=81)
    idx = CubeGraphIndex.build(x, s, IDX_CFG)
    art = str(tmp_path / "idx")
    save_index(idx, art)
    idx2 = load_index(art, mmap_mode="r")
    assert isinstance(idx2.s_np, np.memmap)
    q = np.random.default_rng(82).normal(size=(4, D)).astype(np.float32)
    f = IntervalFilter(dim=TIME_DIM, lo=np.float32(0.1))
    np.testing.assert_array_equal(idx.query(q, f, k=5, ef=48)[0],
                                  idx2.query(q, f, k=5, ef=48)[0])


def test_document_store_warm_start(tmp_path):
    """Serving path: snapshot a streaming DocumentStore, restore a replica,
    identical retrievals."""
    from repro.serving.rag import Document, DocumentStore
    x, s = make_dataset(200, D, M, seed=91)
    s[:, TIME_DIM] = np.arange(200) / 200.0
    rng = np.random.default_rng(92)
    docs = [Document(doc_id=i,
                     tokens=rng.integers(2, 99, size=6).astype(np.int32),
                     embedding=x[i], metadata=s[i]) for i in range(200)]
    store = DocumentStore(docs, IDX_CFG, streaming=True,
                          stream_cfg=_stream_cfg(seal=64))
    store.delete(np.arange(0, 20))
    snap = str(tmp_path / "snap")
    store.snapshot_to(snap)
    replica = DocumentStore.restore(docs, snap, resume=False)
    f = IntervalFilter(dim=TIME_DIM, lo=np.float32(0.3))
    got_a = store.retrieve(x[:6], f, k=5)
    got_b = replica.retrieve(x[:6], f, k=5)
    assert [[d.doc_id for d in row] for row in got_a] \
        == [[d.doc_id for d in row] for row in got_b]
    with pytest.raises(ValueError):
        DocumentStore.restore(docs[:10], snap, resume=False)
