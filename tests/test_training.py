"""Training substrate: loss decreases, schedules, optimizer, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.models import build_model, init_params
from repro.training.compression import (compress_residual, dequantize_int8,
                                        init_error_state, quantize_int8)
from repro.training.optimizer import (OptConfig, global_norm, init_opt_state,
                                      schedule_lr)
from repro.training.train_step import init_train_state, make_train_step


def test_loss_decreases_end_to_end():
    """2-layer model on learnable synthetic data: loss must drop."""
    cfg = get_config("codeqwen1.5-7b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    state = init_train_state(params)
    pipe = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                             global_batch=8, seed=1))
    step = jax.jit(make_train_step(model, OptConfig(
        lr=3e-3, warmup_steps=5, total_steps=60, schedule="cosine")))
    losses = []
    for i in range(45):
        batch = jax.tree.map(jnp.asarray, pipe.batch(i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    """accum_steps=4 produces (nearly) the same update as accum_steps=1."""
    cfg = get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    pipe = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=16,
                                             global_batch=8, seed=2))
    batch = jax.tree.map(jnp.asarray, pipe.batch(0))
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10, schedule="const")
    s1, m1 = jax.jit(make_train_step(model, oc, 1))(init_train_state(params), batch)
    s4, m4 = jax.jit(make_train_step(model, oc, 4))(init_train_state(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-2


@pytest.mark.parametrize("sched", ["cosine", "wsd", "const"])
def test_schedules(sched):
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule=sched)
    lrs = [float(schedule_lr(jnp.int32(s), cfg)) for s in range(0, 101, 5)]
    assert lrs[0] < cfg.lr                       # warmup
    assert max(lrs) <= cfg.lr + 1e-9
    if sched in ("cosine", "wsd"):
        assert lrs[-1] < 0.35 * cfg.lr           # decayed at the end
    if sched == "wsd":
        # stable phase: flat in the middle
        mid = lrs[4:16]
        assert max(mid) - min(mid) < 1e-9


def test_grad_clip():
    from repro.training.optimizer import clip_by_global_norm
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-4, 1e3))
def test_quantize_roundtrip_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6      # half-step quantization error


def test_error_feedback_accumulates():
    """Residual carries exactly the quantization error."""
    g = jnp.asarray([0.013, -0.5, 0.251], jnp.float32)
    q, s, resid = compress_residual(g)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s) + resid),
                               np.asarray(g), rtol=1e-6)


def test_compressed_psum_shardmap():
    """Compressed all-reduce inside shard_map equals the plain mean (within
    int8 quantization error), error feedback shrinks the bias over steps."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.training.compression import compressed_psum
    from repro.launch.mesh import mesh_compat_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_compat_kwargs(1))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32,)),
                          jnp.float32)}
    e = init_error_state(g)

    def f(g, e):
        return compressed_psum(g, e, "data")

    out, new_e = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                           out_specs=(P(), P()))(g, e)
    err = np.abs(np.asarray(out["w"] - g["w"]))
    assert err.max() < float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6
    np.testing.assert_allclose(np.asarray(out["w"] + new_e["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-7)
