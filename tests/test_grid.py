"""Grid math: cube ids, adjacency, layer selection (Prop. 1 bounds)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.grid import GridSpec


def _spec(m=2, L=5):
    return GridSpec(lo=np.zeros(m), hi=np.ones(m), n_layers=L)


def test_layer_granularity():
    spec = _spec()
    for l in range(spec.n_layers):
        layer = spec.layer(l)
        assert layer.g == 2 ** (l + 1)                      # Alg. 1 line 3
        assert np.allclose(layer.width, 1.0 / layer.g)
        assert layer.n_cubes == layer.g ** 2


def test_cube_id_roundtrip():
    spec = _spec(m=3)
    layer = spec.layer(2)
    rng = np.random.default_rng(0)
    s = rng.uniform(0, 1, size=(100, 3))
    flat = layer.cube_of(s)
    coords = layer.unflatten(flat)
    assert np.array_equal(layer.flat_of(coords), flat)
    lo, hi = layer.cube_bounds(flat)
    assert np.all(s >= lo - 1e-9) and np.all(s <= hi + 1e-9)


def test_face_neighbors():
    layer = _spec(m=2).layer(1)                             # 4x4 grid
    nb = layer.face_neighbors(5)                            # coords (1, 1)
    assert sorted(nb.tolist()) == sorted([1, 9, 4, 6])
    corner = layer.face_neighbors(0)
    assert (corner >= 0).sum() == 2                         # two OOB sides


def test_cubes_overlapping_box():
    layer = _spec(m=2).layer(1)                             # w = 0.25
    ids = layer.cubes_overlapping_box(np.array([0.3, 0.3]), np.array([0.6, 0.6]))
    # box spans cells 1..2 in both dims -> 2x2 cubes
    assert len(ids) == 4


@settings(max_examples=50, deadline=None)
@given(r=st.floats(1e-3, 0.99), m=st.integers(1, 4))
def test_layer_selection_bound(r, m):
    """Selected layer satisfies w <= r (and r/2 < w when representable)."""
    spec = GridSpec(lo=np.zeros(m), hi=np.ones(m), n_layers=6)
    l = spec.select_layer(r)
    w = float(spec.layer(l).width.max())
    deepest_w = float(spec.layer(spec.n_layers - 1).width.max())
    if r >= deepest_w:      # representable: Prop. 1 window must hold
        assert w <= r + 1e-12
        assert r / 2 < w + 1e-12
    else:                   # smaller than deepest cube: clamped (§5.1)
        assert l == spec.n_layers - 1


@settings(max_examples=50, deadline=None)
@given(r=st.floats(0.02, 0.9), m=st.integers(1, 3),
       cx=st.floats(0, 1), cy=st.floats(0, 1))
def test_prop1_cube_count(r, m, cx, cy):
    """A box with max side r at the selected layer hits <= 3^m cubes."""
    spec = GridSpec(lo=np.zeros(m), hi=np.ones(m), n_layers=8)
    l = spec.select_layer(r)
    w = float(spec.layer(l).width.max())
    if w > r:   # r below deepest layer width: bound does not apply
        return
    ctr = np.full(m, 0.5)
    ctr[0] = cx
    if m > 1:
        ctr[1] = cy
    lo = np.clip(ctr - r / 2, 0, 1 - 1e-9)
    hi = np.clip(lo + r, 0, 1 - 1e-9)
    ids = spec.layer(l).cubes_overlapping_box(lo, hi)
    assert len(ids) <= 3 ** m
