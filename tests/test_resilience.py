"""Resilience (``streaming/resilience.py``): deterministic fault
injection, supervised background workers, query deadlines, and the chaos
property the whole substrate exists to pin down — **no fault schedule
ever yields a silently wrong answer**: every outcome is bit-for-bit what
the fault-free oracle produces after recovery, or an explicit
``FaultError`` / explicitly ``degraded`` result."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import CubeGraphConfig, IntervalFilter
from repro.obs.metrics import MetricsRegistry
from repro.streaming import (FaultError, FaultInjector, QueryResult,
                             SegmentManager, StreamConfig, Supervisor)
from repro.streaming.planner import PlannerCosts, decide_bucket

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=3)
SCAN_BIASED = PlannerCosts(hop_cost=1e12)
D, SDIM = 24, 3

# every crash-capable fault point a chaos run may draw from (query.bucket
# only fires on the deadline dispatch path, so it is exercised separately)
CRASH_POINTS = ("wal.append", "wal.fsync", "segment.write",
                "manifest.rename", "pack.delta", "admission.stage",
                "admission.upload", "admission.install", "prefetch.round",
                "compaction.execute")


def _cfg(n_shards=1, budget=None, quantize=None, persist=None, **over):
    return StreamConfig(time_dim=2, seal_max_points=1 << 30,
                        n_shards=n_shards, compact_max_segments=3,
                        index_cfg=IDX_CFG, quantize=quantize,
                        device_budget_bytes=budget, graph_ef=128,
                        persist_dir=persist, wal_fsync_every=4, **over)


def _batches(seed, n=3, nb=60):
    r = np.random.default_rng(seed)
    out = []
    for i in range(n):
        x = r.normal(size=(nb, D)).astype(np.float32)
        s = r.uniform(size=(nb, SDIM))
        s[:, 2] = i * 0.3 + np.linspace(0.0, 0.05, nb)
        out.append((x, s))
    return out


def _sealed_manager(seed=5, n=4, **cfg_over):
    m = SegmentManager(D, SDIM, _cfg(**cfg_over))
    for x, s in _batches(seed, n=n, nb=100):
        m.ingest(x, s)
        m.seal()
    return m


def _q(seed=9, b=4):
    return np.random.default_rng(seed).normal(size=(b, D)) \
        .astype(np.float32)


# ---------------------------------------------------------------------------
# FaultInjector unit contract
# ---------------------------------------------------------------------------

def test_fault_injector_schedule_and_determinism():
    """Exact-placement schedules fire on the named hit; rate-mode firing
    is a pure function of ``(seed, point, hit)`` — two injectors with the
    same seed replay the identical fault sequence."""
    inj = FaultInjector(schedule={"wal.append": (2,)})
    inj("wal.append")                           # hit 1: clean
    with pytest.raises(FaultError):
        inj("wal.append")                       # hit 2: scheduled crash
    inj("wal.append")                           # hit 3: clean again
    assert inj.hits == {"wal.append": 3}
    assert inj.fired == [("wal.append", 2)]

    def drive(inj, order):
        fired = []
        for p in order:
            try:
                inj(p)
            except FaultError:
                fired.append((p, inj.hits[p]))
        return fired

    order = [CRASH_POINTS[i % 4] for i in range(200)]
    a = drive(FaultInjector(seed=7, rate=0.2), order)
    b = drive(FaultInjector(seed=7, rate=0.2), order)
    assert a and a == b                          # same seed, same sequence
    c = drive(FaultInjector(seed=8, rate=0.2), order)
    assert a != c                                # different seed differs
    # per-(point, hit) decisions are interleaving-independent: a point's
    # n-th hit crashes or not regardless of what other points did between
    only = [p for p in order if p == "wal.append"]
    d = drive(FaultInjector(seed=7, rate=0.2), only)
    assert d == [f for f in a if f[0] == "wal.append"]


def test_fault_injector_caps_delays_disarm():
    """``max_faults`` bounds injected crashes, ``disarm`` keeps counting
    without firing, and ``delays`` stalls instead of raising."""
    inj = FaultInjector(rate=1.0, max_faults=2)
    crashes = 0
    for _ in range(5):
        try:
            inj("pack.delta")
        except FaultError:
            crashes += 1
    assert crashes == 2 and inj.hits["pack.delta"] == 5
    inj.disarm()
    inj("pack.delta")
    assert inj.hits["pack.delta"] == 6 and len(inj.fired) == 2
    stall = FaultInjector(delays={"query.bucket": 0.0})
    stall("query.bucket")                        # stalls (0s), never raises
    assert stall.hits["query.bucket"] == 1


# ---------------------------------------------------------------------------
# Supervisor unit contract
# ---------------------------------------------------------------------------

def test_supervisor_retry_then_success():
    """A worker that fails once succeeds on the in-run retry: result is
    returned, error + retry are recorded, degraded never trips."""
    reg = MetricsRegistry()
    sup = Supervisor(registry=reg, max_retries=2, backoff_base_s=0.0,
                     sleep=lambda s: None)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("boom")
        return "ok"

    assert sup.run("w", flaky) == "ok"
    h = sup.health()["w"]
    assert h["runs"] == 1 and h["errors"] == 1 and h["retries"] == 1
    assert not h["degraded"] and "boom" in h["last_error"]
    snap = reg.snapshot()["counters"]
    assert snap['worker_errors_total{worker="w"}'] == 1
    assert snap['worker_retries_total{worker="w"}'] == 1


def test_supervisor_error_budget_trips_and_clears():
    """``error_budget`` consecutive failed runs trip the sticky degraded
    flag (gauge set, restarts counted); one success clears it."""
    reg = MetricsRegistry()
    sup = Supervisor(registry=reg, max_retries=0, error_budget=3,
                     sleep=lambda s: None)

    def bad():
        raise ValueError("poisoned")

    for i in range(3):
        assert sup.run("w", bad) is None
        assert sup.degraded("w") == (i >= 2)
    h = sup.health()["w"]
    assert h["degraded"] and h["consecutive_failures"] == 3
    assert h["restarts"] == 2                    # runs 2 and 3 restarted
    assert reg.snapshot()["gauges"]['worker_degraded{worker="w"}'] == 1.0
    assert sup.run("w", lambda: 42) == 42
    assert not sup.degraded("w")
    assert reg.snapshot()["gauges"]['worker_degraded{worker="w"}'] == 0.0


def test_supervisor_spawn_at_most_one_and_note_error():
    """``spawn`` keeps at most one live thread per worker name;
    ``note_error`` records inline failures against the same budget."""
    import threading
    sup = Supervisor(max_retries=0, sleep=lambda s: None)
    gate = threading.Event()
    t1 = sup.spawn("w", gate.wait)
    t2 = sup.spawn("w", gate.wait)
    assert t1 is t2
    gate.set()
    t1.join(5)
    sup.note_error("inline", RuntimeError("dropped delta"))
    h = sup.health()["inline"]
    assert h["errors"] == 1 and "dropped delta" in h["last_error"]


# ---------------------------------------------------------------------------
# Silent daemon-thread death is fixed: compaction + prefetch workers
# ---------------------------------------------------------------------------

def test_poisoned_compaction_retried_never_lost():
    """A compaction crash is retried by the supervisor (not dropped with
    the daemon thread), the error is visible in ``stats()["health"]``,
    and answers stay bit-for-bit."""
    m = _sealed_manager()
    m.delete(np.arange(0, 250))
    q = _q()
    g0, d0 = m.query(q, None, k=10)
    inj = FaultInjector(schedule={"compaction.execute": (1,)})
    m.install_fault_injector(inj)
    t = m.compact_async()
    t.join(60)
    assert inj.fired == [("compaction.execute", 1)]
    h = m.stats()["health"]["compactor"]
    assert h["errors"] >= 1 and h["retries"] >= 1 and h["runs"] >= 1
    assert not h["degraded"] and "FaultError" in h["last_error"]
    g1, d1 = m.query(q, None, k=10)
    assert np.array_equal(g0, g1) and np.array_equal(d0, d1)


def test_poisoned_compaction_trips_degraded_then_recovers():
    """Permanent poison: every run fails, the compactor trips degraded
    (work deferred, never lost); disarming lets the next run succeed and
    clear the flag."""
    m = _sealed_manager(seed=7)
    m.delete(np.arange(0, 250))
    inj = FaultInjector(schedule={"compaction.execute": tuple(range(1, 64))})
    m.install_fault_injector(inj)
    for _ in range(3):
        m.compact_async().join(60)
    h = m.stats()["health"]["compactor"]
    assert h["degraded"] and h["runs"] == 0
    assert m.supervisor.degraded("compactor")
    snap = m.obs.registry.snapshot()["counters"]
    assert snap['worker_errors_total{worker="compactor"}'] >= 3
    inj.disarm()
    m.compact_async().join(60)
    h2 = m.stats()["health"]["compactor"]
    assert not h2["degraded"] and h2["runs"] >= 1


def test_prefetch_worker_error_recorded():
    """A crash inside the prefetch round lands in health/metrics instead
    of dying silently with the daemon thread."""
    m = _sealed_manager(budget=1 << 15)
    q = _q()
    inj = FaultInjector(schedule={"prefetch.round": (1,)})
    m.install_fault_injector(inj)
    m.query(q, None, k=10)                       # warms pack, notes window
    t = m.maybe_prefetch()
    if t is not None:
        t.join(60)
    else:                                        # nothing to prefetch yet:
        m.supervisor.spawn("prefetcher", m._prefetch_once).join(60)
    assert inj.hits.get("prefetch.round", 0) >= 1
    h = m.stats()["health"]["prefetcher"]
    assert h["errors"] >= 1 and "FaultError" in h["last_error"]


# ---------------------------------------------------------------------------
# Mid-admission faults (extends exp16's budget-parity property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["admission.stage", "admission.upload",
                                   "admission.install"])
def test_admission_crash_is_exact_and_budgeted(point):
    """A crash at each stage of the admission trio leaves the bucket
    cold, the budget intact, and the next query bit-for-bit."""
    budget = 4 << 20
    m = _sealed_manager(budget=budget)
    q = _q()
    g0, d0 = m.query(q, None, k=10)              # builds + warm-admits
    pack = m._pack
    cap = next(iter(pack.buckets))
    assert pack.evict_bucket(cap)
    inj = FaultInjector(schedule={point: (1,)})
    m.install_fault_injector(inj)
    with pytest.raises(FaultError):
        m.tier_admit(cap)
    assert not m._pack.buckets[cap].resident     # stays cold, re-admittable
    assert m.stats()["tier"]["resident_bytes"] <= budget
    inj.disarm()
    g1, d1 = m.query(q, None, k=10)              # streams the cold block
    assert np.array_equal(g0, g1) and np.array_equal(d0, d1)
    bv = m.tier_admit(cap)                       # re-admission succeeds
    assert bv is not None and bv.resident
    g2, d2 = m.query(q, None, k=10)
    assert np.array_equal(g0, g2) and np.array_equal(d0, d2)


def test_admission_racing_pack_delta_discarded():
    """The staged-upload install is generation-checked: a pack delta
    racing the upload discards the stale install (bucket stays cold) and
    answers remain exact — the pack is epoch-consistent throughout."""
    m = _sealed_manager(budget=4 << 20)
    q = _q()
    m.query(q, None, k=10)
    pack = m._pack
    cap = next(iter(pack.buckets))
    assert pack.evict_bucket(cap)
    staged = pack.stage_admission(cap)
    assert staged is not None
    # race: one more sealed batch lands as a pack delta mid-upload
    x, s = _batches(77, n=1, nb=60)[0]
    m.ingest(x, s)
    m.seal()
    up = pack.upload_admission(staged)
    assert pack.install_admission(cap, *up) == 0   # stale: discarded
    assert not pack.buckets[cap].resident
    g0, d0 = m.query(q, None, k=10)              # cold view streams exact
    base = _sealed_manager()
    base.ingest(x, s)
    base.seal()
    gb, db = base.query(q, None, k=10)
    assert np.array_equal(g0, gb) and np.array_equal(d0, db)


# ---------------------------------------------------------------------------
# Durability fault points: crash -> restore -> bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["wal.append", "wal.fsync",
                                   "segment.write", "manifest.rename"])
def test_durability_crash_recovers_exact(point, tmp_path):
    """Crashing the 2nd hit of each durability fault point, restoring
    from disk, and conditionally re-applying the interrupted op converges
    on the fault-free oracle bit-for-bit."""
    batches = _batches(3, n=3)
    oracle = SegmentManager(D, SDIM, _cfg())
    for x, s in batches:
        oracle.ingest(x, s)
        oracle.seal()
    q = _q()
    og, od = oracle.query(q, None, k=10)

    root = str(tmp_path / point.replace(".", "_"))
    cfg = _cfg(persist=root)
    m = SegmentManager(D, SDIM, cfg)
    inj = FaultInjector(schedule={point: (2,)})
    m.install_fault_injector(inj)
    i = attempts = 0
    while i < len(batches):
        x, s = batches[i]
        pre_n = m.n_total
        try:
            m.ingest(x, s)
            m.seal()
            i += 1
        except FaultError:
            attempts += 1
            if attempts > 6:
                inj.disarm()
            m = SegmentManager.restore(root, cfg=cfg)
            m.install_fault_injector(inj)
            if m.n_total > pre_n:          # the batch was durable: one
                m.seal()                   # WAL record per ingest, so a
                i += 1                     # crash never half-applies it
    assert inj.fired == [(point, 2)]
    g, d = m.query(q, None, k=10)
    assert np.array_equal(og, g) and np.array_equal(od, d)
    m2 = SegmentManager.restore(root, cfg=cfg)   # and again from cold disk
    g2, d2 = m2.query(q, None, k=10)
    assert np.array_equal(og, g2) and np.array_equal(od, d2)


# ---------------------------------------------------------------------------
# Query deadlines: partial results are explicit, never silent
# ---------------------------------------------------------------------------

def test_deadline_generous_is_bit_for_bit():
    """A deadline the query easily meets changes nothing: same answer,
    ``degraded=False`` — the per-bucket dispatch split is exact."""
    for quantize in (None, "int8"):
        m = _sealed_manager(quantize=quantize)
        q = _q()
        r0 = m.query(q, None, k=10)
        assert isinstance(r0, QueryResult) and not r0.degraded
        r1 = m.query(q, None, k=10, deadline_ms=60_000.0)
        assert not r1.degraded and r1.reasons == {}
        assert np.array_equal(r0[0], r1[0])
        assert np.array_equal(r0[1], r1[1])


def test_deadline_overrun_marks_degraded():
    """An unmeetable deadline returns an explicitly degraded partial
    result with per-reason skip counters (never a silent wrong answer)."""
    m = _sealed_manager()
    q = _q()
    res = m.query(q, None, k=10, deadline_ms=1e-7)
    assert res.degraded and sum(res.reasons.values()) >= 1
    g, d = res                                   # tuple unpacking intact
    assert g.shape == (4, 10) and d.shape == (4, 10)
    snap = m.obs.registry.snapshot()["counters"]
    assert snap.get("query_degraded_queries_total", 0) >= 1
    assert any(k.startswith('query_degraded_total{reason="deadline')
               for k in snap)
    # config-level default deadline takes effect the same way
    m.cfg = dataclasses.replace(m.cfg, query_deadline_ms=1e-7)
    res2 = m.query(q, None, k=10)
    assert res2.degraded
    # per-call override beats the config default
    res3 = m.query(q, None, k=10, deadline_ms=60_000.0)
    assert not res3.degraded


def test_deadline_graph_leg_degrades_explicitly():
    """The stitched-traversal path honors the deadline between bucket
    traversals and reports its own skip reason."""
    m = _sealed_manager()
    q = _q()
    res = m.query(q, None, k=10, read_path="graph", deadline_ms=1e-7)
    assert res.degraded
    assert any(r.startswith("deadline") for r in res.reasons)


def test_deadline_result_arities_preserved():
    """``return_stats`` / ``return_trace`` arities keep both the tuple
    shape and the degraded metadata."""
    m = _sealed_manager()
    q = _q()
    r = m.query(q, None, k=10, return_stats=True)
    assert isinstance(r, QueryResult) and len(r) == 3
    rt = m.query(q, None, k=10, return_trace=True, deadline_ms=1e-7)
    assert len(rt) == 3 and rt.degraded


def test_planner_deadline_gate():
    """``decide_bucket`` refuses cold routes the remaining deadline
    cannot cover: mode ``skip`` / reason ``deadline``; resident buckets
    are never skipped (between-dispatch checks bound those)."""
    costs = PlannerCosts()
    kw = dict(active_rows=4096, n_seeds=8, graph_ready=False, stats=None,
              costs=costs, read_path="scan", resident=False,
              stage_bytes=1 << 20)
    free = decide_bucket(256, **kw)
    assert free.mode == "host_scan"
    dec = decide_bucket(256, deadline_cost=1.0, **kw)
    assert dec.mode == "skip" and dec.reason == "deadline"
    big = free.est_scan * costs.host_scan_multiplier * 2
    assert decide_bucket(256, deadline_cost=big, **kw).mode == "host_scan"
    res = dict(kw, resident=True)
    assert decide_bucket(256, deadline_cost=0.0, **res).mode == "scan"
    # auto: admission allowed only when the one-shot cost also fits
    auto = dict(kw, read_path="auto")
    dec2 = decide_bucket(256, deadline_cost=1.0, **auto)
    assert dec2.mode == "skip" and dec2.reason == "deadline"


def test_deadline_planner_refuses_cold_scan():
    """All-cold tiered manager + unmeetable deadline: the planner skips
    the cold buckets up front (reason counter ``deadline_planner``) and
    the result is explicitly degraded."""
    m = _sealed_manager(budget=0)
    q = _q()
    g0, d0 = m.query(q, None, k=10)              # no deadline: exact
    res = m.query(q, None, k=10, read_path="auto", deadline_ms=1e-7)
    assert res.degraded and "deadline_planner" in res.reasons
    inj = FaultInjector(delays={"query.bucket": 0.0})
    m.install_fault_injector(inj)                # stall point reachable
    r2 = m.query(q, None, k=10, deadline_ms=60_000.0)
    assert not r2.degraded
    assert np.array_equal(g0, r2[0]) and np.array_equal(d0, r2[1])


# ---------------------------------------------------------------------------
# Serving: one failing retrieve no longer black-holes the flush queue
# ---------------------------------------------------------------------------

class _FlakyStore:
    """Duck-typed store whose retrieve poisons one filter group."""

    def __init__(self, bad_lo):
        self.bad_lo = bad_lo
        self.metrics = MetricsRegistry()
        self.calls = 0

    def retrieve(self, q, filt, k, ef):
        self.calls += 1
        if filt is not None and float(filt.lo) == self.bad_lo:
            raise RuntimeError("segment store offline")
        return [[("doc", i)] * k for i in range(q.shape[0])]


def test_batcher_flush_isolates_failed_chunk():
    """A retrieve that raises mid-flush fails only its own chunk: those
    requests get explicit ``RetrievalFailure`` results and every other
    queued request still drains with real results."""
    from repro.serving.batching import (RetrievalBatcher, RetrievalFailure,
                                        RetrievalRequest)
    store = _FlakyStore(bad_lo=0.5)
    batcher = RetrievalBatcher(store, ef=8)
    emb = np.ones(D, np.float32)
    good = IntervalFilter(dim=2, lo=np.float32(0.0), hi=np.float32(1.0))
    bad = IntervalFilter(dim=2, lo=np.float32(0.5), hi=np.float32(1.0))
    for i in range(6):
        batcher.submit(RetrievalRequest(req_id=i, query_emb=emb,
                                        filt=bad if i % 2 else good, k=3))
    out = batcher.flush()
    assert len(out) == 6 and len(batcher) == 0
    for i in range(6):
        if i % 2:
            assert isinstance(out[i], RetrievalFailure)
            assert "segment store offline" in out[i].error
        else:
            assert out[i] and not isinstance(out[i], RetrievalFailure)
    snap = store.metrics.snapshot()["counters"]
    assert snap["retrieval_failed_total"] == 3
    assert store.calls == 2                      # both groups dispatched


# ---------------------------------------------------------------------------
# Health metrics render like any other metric
# ---------------------------------------------------------------------------

def test_obs_dump_renders_health_metrics():
    """Supervisor counters/gauges land in the registry snapshot and the
    Prometheus exposition (``tools/obs_dump.py``)."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "obs_dump", os.path.join(os.path.dirname(__file__), os.pardir,
                                 "tools", "obs_dump.py"))
    obs_dump = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(obs_dump)
    reg = MetricsRegistry()
    sup = Supervisor(registry=reg, max_retries=0, sleep=lambda s: None)
    sup.run("compactor", lambda: (_ for _ in ()).throw(RuntimeError("x")))
    text = obs_dump.render(reg.snapshot())
    assert 'cubegraph_worker_errors_total{worker="compactor"} 1' in text
    assert 'cubegraph_worker_degraded{worker="compactor"}' in text


# ---------------------------------------------------------------------------
# The chaos property: no fault schedule yields a silently wrong answer
# ---------------------------------------------------------------------------

def _chaos_ops(seed, n_ops=8):
    """A deterministic lifecycle-op tape with all payloads precomputed
    (batches AND delete gid draws), so the oracle and every recovery
    attempt replay byte-identical operations."""
    rng = np.random.default_rng(seed)
    ops, n = [], 0
    for j in range(n_ops):
        r = int(rng.integers(0, 5)) if n else 0
        if r == 0:
            nb = int(rng.integers(40, 90))
            x = rng.normal(size=(nb, D)).astype(np.float32)
            s = rng.uniform(size=(nb, SDIM))
            s[:, 2] = j * 0.3 + np.linspace(0.0, 0.05, nb)
            ops.append(("ingest", x, s))
            n += nb
        elif r == 1:
            ops.append(("delete", rng.integers(0, n, size=15)))
        elif r == 2:
            ops.append(("seal",))
        elif r == 3:
            ops.append(("compact",))
        else:
            ops.append(("query",
                        rng.normal(size=(3, D)).astype(np.float32)))
    ops.append(("seal",))
    return ops


def _apply_op(mgr, op):
    kind = op[0]
    if kind == "ingest":
        mgr.ingest(op[1], op[2])
    elif kind == "delete":
        mgr.delete(op[1])
    elif kind == "seal":
        mgr.seal()
    elif kind == "compact":
        mgr.compact()
    else:
        return mgr.query(op[1], None, k=10)
    return None


def check_chaos(seed, quantize, n_shards, budget, root):
    """THE property: drive one persistent manager through a lifecycle
    tape under a seeded fault schedule, recovering from every injected
    crash (restore from disk + conditionally re-apply); every query the
    run answers — and the final answers across filters, read paths, and
    a cold restore — must be bit-for-bit the fault-free oracle's."""
    ops = _chaos_ops(seed)
    oracle = SegmentManager(D, SDIM, _cfg(n_shards, budget, quantize))
    oracle_answers = [_apply_op(oracle, op) for op in ops]

    cfg = _cfg(n_shards, budget, quantize, persist=root)
    m = SegmentManager(D, SDIM, cfg)
    inj = FaultInjector(seed=seed, rate=0.18, max_faults=5,
                        points=CRASH_POINTS)
    m.install_fault_injector(inj)
    n_faults = 0
    for op, want in zip(ops, oracle_answers):
        for attempt in range(10):
            pre_n = m.n_total
            try:
                got = _apply_op(m, op)
            except FaultError:
                n_faults += 1
                if attempt >= 7:               # belt + braces on top of
                    inj.disarm()               # the max_faults cap
                if op[0] == "query":
                    continue       # reads mutate nothing durable: retry
                m = SegmentManager.restore(root, cfg=cfg)
                m.install_fault_injector(inj)
                if op[0] == "ingest" and m.n_total > pre_n:
                    break          # one WAL record per ingest: it landed
                continue           # delete/seal/compact are idempotent
            if op[0] == "query":
                assert np.array_equal(want[0], got[0]), (seed, op[0])
                assert np.array_equal(want[1], got[1]), (seed, op[0])
                assert not got.degraded        # no deadline set
            break
        else:
            raise AssertionError(f"op never converged (seed={seed})")

    q = _q(seed + 1)
    filters = [None, IntervalFilter(dim=2, lo=np.float32(0.2),
                                    hi=np.float32(1.2))]
    scan_biased = dataclasses.replace(m.cfg, planner_costs=SCAN_BIASED)
    legs = [("scan", None), ("auto", scan_biased)]
    for mgr in (m, SegmentManager.restore(root, cfg=cfg)):
        for filt in filters:
            for leg, cfg_over in legs:
                if cfg_over is not None:
                    keep_o, keep_m = oracle.cfg, mgr.cfg
                    oracle.cfg = dataclasses.replace(
                        oracle.cfg, planner_costs=SCAN_BIASED)
                    mgr.cfg = cfg_over
                try:
                    og, od = oracle.query(q, filt, k=10, read_path=leg)
                    gg, dd = mgr.query(q, filt, k=10, read_path=leg)
                finally:
                    if cfg_over is not None:
                        oracle.cfg, mgr.cfg = keep_o, keep_m
                assert np.array_equal(og, gg), (seed, leg, filt)
                assert np.array_equal(od, dd), (seed, leg, filt)
            if budget is not None:
                st = mgr.stats()["tier"]
                assert st["resident_bytes"] <= budget, (seed, st)
    return n_faults


@pytest.mark.parametrize("seed,quantize,n_shards,budget", [
    (11, None, 1, None),                  # fp32, unbudgeted
    (13, None, 3, 1 << 15),               # fp32, sharded, partial budget
    (17, "int8", 1, 0),                   # quantized, all-cold
    (29, "int8", 3, None),                # quantized, sharded
])
def test_chaos_schedules(seed, quantize, n_shards, budget, tmp_path):
    """Deterministic chaos schedules across dtype / shard / budget legs
    (the hypothesis variant below widens the space when available)."""
    check_chaos(seed, quantize, n_shards, budget, str(tmp_path / "chaos"))


def test_chaos_random_seed(tmp_path):
    """CI's randomized leg: ``REPRO_CHAOS_SEED`` picks the schedule; the
    seed is in every assertion message, so a red run is replayable."""
    seed = int(os.environ.get("REPRO_CHAOS_SEED", "101"))
    check_chaos(seed, None, 1, 1 << 15, str(tmp_path / "chaos"))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10_000),
           quantize=st.sampled_from([None, "int8"]),
           n_shards=st.sampled_from([1, 3]),
           budget=st.sampled_from([None, 0, 1 << 15]))
    def test_chaos_hypothesis(seed, quantize, n_shards, budget):
        """Hypothesis-driven fault schedules over the same property."""
        import tempfile
        check_chaos(seed, quantize, n_shards, budget,
                    os.path.join(tempfile.mkdtemp(), "chaos"))
except ImportError:                               # pragma: no cover
    pass
