"""Dynamic updates (§4.4): insertion, lazy deletion, compaction."""
import numpy as np
import pytest

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_box_filter, make_dataset,
                                  recall)

CFG = CubeGraphConfig(n_layers=3, m_intra=10, m_cross=3)


@pytest.fixture(scope="module")
def setup():
    x, s = make_dataset(2000, 24, 2, seed=1)
    rng = np.random.default_rng(2)
    q = x[rng.integers(0, 2000, 16)] + 0.05 * rng.normal(size=(16, 24)).astype(np.float32)
    f = make_box_filter(2, 0.08, seed=3)
    return x, s, q, f


def test_insert_discoverable(setup):
    """Inserted points are returned by subsequent queries."""
    x, s, q, f = setup
    idx = CubeGraphIndex.build(x[:1500], s[:1500], CFG)
    idx.insert_batch(x[1500:], s[1500:])
    assert idx.n == 2000
    gt, _ = ground_truth(x, s, q, f, 10)
    ids, _ = idx.query(q, f, k=10, ef=96)
    r = recall(ids, gt)
    assert r >= 0.8, f"post-insert recall {r}"
    # at least some results come from the inserted range when gt does
    gt_new = set(int(v) for row in gt for v in row if v >= 1500)
    if gt_new:
        got_new = set(int(v) for row in ids for v in row if v >= 1500)
        assert got_new & gt_new


def test_insert_vs_rebuild_equivalence(setup):
    """Incremental insert reaches recall close to rebuild-from-scratch."""
    x, s, q, f = setup
    gt, _ = ground_truth(x, s, q, f, 10)
    inc = CubeGraphIndex.build(x[:1600], s[:1600], CFG)
    inc.insert_batch(x[1600:], s[1600:])
    full = CubeGraphIndex.build(x, s, CFG)
    r_inc = recall(inc.query(q, f, k=10, ef=96)[0], gt)
    r_full = recall(full.query(q, f, k=10, ef=96)[0], gt)
    assert r_inc >= r_full - 0.1


def test_lazy_delete(setup):
    """Deleted ids never appear in results; recall vs remaining set holds."""
    x, s, q, f = setup
    idx = CubeGraphIndex.build(x, s, CFG)
    rng = np.random.default_rng(5)
    dead = rng.choice(2000, size=400, replace=False)
    idx.delete(dead)
    assert abs(idx.deleted_fraction() - 0.2) < 0.01
    ids, _ = idx.query(q, f, k=10, ef=96)
    assert not (set(ids[ids >= 0].tolist()) & set(dead.tolist()))
    alive = np.ones(2000, bool)
    alive[dead] = False
    gt, _ = ground_truth(x, s, q, f, 10, valid=alive)
    assert recall(ids, gt) >= 0.8


def test_compact_after_delete(setup):
    x, s, q, f = setup
    idx = CubeGraphIndex.build(x, s, CFG)
    rng = np.random.default_rng(6)
    dead = rng.choice(2000, size=1000, replace=False)
    idx.delete(dead)
    alive = np.ones(2000, bool)
    alive[dead] = False
    compacted = idx.compact()
    assert compacted.n == 1000
    # compacted index ids are re-based; just verify filtered recall works
    keep = np.nonzero(alive)[0]
    gt_c, _ = ground_truth(x[keep], s[keep], q, f, 10)
    ids, _ = compacted.query(q, f, k=10, ef=96)
    assert recall(ids, gt_c) >= 0.8


def test_insert_delete_compact_preserves_recall(setup):
    """Full update-path interplay: build -> insert_batch -> delete -> compact
    keeps filtered recall over the surviving points."""
    x, s, q, f = setup
    idx = CubeGraphIndex.build(x[:1200], s[:1200], CFG)
    idx.insert_batch(x[1200:], s[1200:])
    rng = np.random.default_rng(8)
    dead = rng.choice(2000, size=600, replace=False)
    idx.delete(dead)
    assert abs(idx.deleted_fraction() - 0.3) < 0.01
    # deletions hit both original and freshly-inserted points
    assert (dead < 1200).any() and (dead >= 1200).any()
    compacted = idx.compact()
    alive = np.ones(2000, bool)
    alive[dead] = False
    keep = np.nonzero(alive)[0]
    assert compacted.n == len(keep)
    assert compacted.deleted_fraction() == 0.0
    gt_c, _ = ground_truth(x[keep], s[keep], q, f, 10)
    ids, _ = compacted.query(q, f, k=10, ef=96)
    assert recall(ids, gt_c) >= 0.8


def test_save_load_roundtrip(tmp_path, setup):
    """Persisted index answers queries identically after reload."""
    from repro.core.cubegraph import load_index, save_index
    x, s, q, f = setup
    idx = CubeGraphIndex.build(x[:800], s[:800], CFG)
    ids_a, d_a = idx.query(q, f, k=10, ef=64)
    save_index(idx, str(tmp_path / "idx"))
    idx2 = load_index(str(tmp_path / "idx"))
    ids_b, d_b = idx2.query(q, f, k=10, ef=64)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_allclose(d_a, d_b, rtol=1e-6)
