"""Dry-run machinery on a small (2x4) mesh in a subprocess (8 host devices,
so the main test session keeps its single CPU device).

Covers: sharding rules produce valid NamedShardings for every arch family,
lower+compile succeeds for train and decode cells, collective parsing and
memory analysis run — the same code path as the 512-chip production sweep.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
jax.devices()   # lock the 8-device backend BEFORE importing repro.launch.dryrun
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.launch.dryrun import build_cell, compile_cell
from repro.distributed import hints

from repro.launch.mesh import mesh_compat_kwargs
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"),
            **mesh_compat_kwargs(2))

out = {}
for arch in %(archs)s:
    cfg = get_config(arch, smoke=True)
    for kind, shape in (("train", ShapeSpec("t", "train", 32, 8)),
                        ("decode", ShapeSpec("d", "decode", 64, 8))):
        rec = compile_cell(cfg, shape, mesh)
        out[f"{arch}/{kind}"] = {
            "collective_ops": rec["collectives"]["count"],
            "flops": rec["cost"]["flops"],
            "temp": rec["memory"]["temp_bytes"],
        }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.parametrize("archs", [
    ["codeqwen1.5-7b", "qwen2-moe-a2.7b"],
    ["falcon-mamba-7b", "zamba2-2.7b"],
    ["whisper-medium", "internvl2-2b"],
])
def test_dryrun_small_mesh(archs):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"archs": repr(archs)}],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert len(out) == 2 * len(archs)
    for key, rec in out.items():
        assert rec["flops"] > 0, key
        assert rec["temp"] > 0, key
