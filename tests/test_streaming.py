"""Streaming temporal index: segment lifecycle + unified fan-out query path."""
import numpy as np
import pytest

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        CubeGraphIndex, IntervalFilter)
from repro.core.workloads import ground_truth, make_dataset, recall
from repro.streaming import (SegmentManager, StreamConfig, temporal_bounds)

IDX_CFG = CubeGraphConfig(n_layers=3, m_intra=10, m_cross=3)


def _timed_dataset(n, d=24, m=3, seed=0):
    """Dataset whose last metadata dim is a monotone event-time in [0, 1)."""
    x, s = make_dataset(n, d, m, seed=seed)
    s[:, m - 1] = np.arange(n) / n
    return x, s


def _queries(x, b=8, seed=2):
    rng = np.random.default_rng(seed)
    return (x[rng.integers(0, len(x), b)]
            + 0.05 * rng.normal(size=(b, x.shape[1])).astype(np.float32))


def _window(t_lo, t_hi):
    """Spatial box (pass-all) AND a temporal interval on dim 2."""
    return ComposeFilter(
        BoxFilter(lo=np.zeros(3, np.float32), hi=np.ones(3, np.float32)),
        IntervalFilter(dim=2, lo=np.float32(t_lo), hi=np.float32(t_hi)),
        "and")


def test_seal_threshold_honored():
    """Delta freezes into sealed segments at the configured live-point count."""
    cfg = StreamConfig(time_dim=2, seal_max_points=500, index_cfg=IDX_CFG)
    x, s = _timed_dataset(1750)
    mgr = SegmentManager(24, 3, cfg)
    for lo in range(0, 1750, 250):
        mgr.ingest(x[lo:lo + 250], s[lo:lo + 250])
        assert mgr.delta.n_live < cfg.seal_max_points
    assert len(mgr.segments) == 3
    assert mgr.delta.n_live == 250
    assert mgr.n_live == 1750
    # sealed segments tile the time axis in order
    spans = [(g.t_min, g.t_max) for g in mgr.segments]
    assert spans == sorted(spans)


def test_ttl_expiry_drops_segments():
    """Retention drops whole out-of-window segments; queries never see them."""
    cfg = StreamConfig(time_dim=2, seal_max_points=400, ttl=0.45,
                       index_cfg=IDX_CFG)
    x, s = _timed_dataset(2000)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    n_before = len(mgr.segments)
    dropped = mgr.expire()
    assert dropped > 0
    assert len(mgr.segments) < n_before
    cutoff = mgr.now - cfg.ttl
    assert all(g.t_max >= cutoff for g in mgr.segments)
    # expired points are dead everywhere: never returned, liveness mask off
    ids, _ = mgr.query(_queries(x), None, k=10, ef=96)
    got = ids[ids >= 0]
    assert np.all(s[got, 2] >= cutoff - 0.25)  # only in-retention segments
    assert not mgr.alive[s[:, 2] < cutoff - 0.25].any()


def test_fanout_matches_monolithic():
    """Acceptance: after interleaved ingest/seal/expire, a time-filtered
    top-k from the SegmentManager matches (recall >= 0.95) the same query
    against a fresh monolithic CubeGraphIndex over the live points."""
    cfg = StreamConfig(time_dim=2, seal_max_points=700, ttl=0.5,
                       index_cfg=IDX_CFG)
    n = 3000
    x, s = _timed_dataset(n)
    mgr = SegmentManager(24, 3, cfg)
    rng = np.random.default_rng(7)
    for lo in range(0, n, 300):
        mgr.ingest(x[lo:lo + 300], s[lo:lo + 300])
        if lo == 1500:                      # mid-stream deletions
            dead = rng.choice(lo, size=200, replace=False)
            mgr.delete(dead)
        if lo == 2100:
            mgr.expire()                    # mid-stream retention pass
    assert len(mgr.segments) >= 2 and mgr.delta.n_live > 0   # mixed fan-out

    live = np.nonzero(mgr.alive)[0]
    mono = CubeGraphIndex.build(x[live], s[live], IDX_CFG)
    q = _queries(x)
    f = _window(0.55, 0.95)
    got, _ = mgr.query(q, f, k=10, ef=128)
    ref_local, _ = mono.query(q, f, k=10, ef=128)
    ref = np.where(ref_local >= 0, live[np.maximum(ref_local, 0)], -1)
    assert recall(got, ref) >= 0.95
    # and against the exact oracle over live points
    gt, _ = ground_truth(x, s, q, f, 10, valid=mgr.alive)
    assert recall(got, gt) >= 0.95


def test_temporal_pruning_skips_segments():
    """Segments whose time span misses the filter window are never searched."""
    cfg = StreamConfig(time_dim=2, seal_max_points=500, index_cfg=IDX_CFG)
    x, s = _timed_dataset(2000)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    f = _window(0.8, 1.0)
    assert temporal_bounds(f, 2) == (pytest.approx(0.8), pytest.approx(1.0))
    ids, _, stats = mgr.query(_queries(x), f, k=10, ef=96, return_stats=True)
    pruned = [t for t in stats if t.pruned]
    searched = [t for t in stats if not t.pruned]
    assert pruned and searched
    assert all(t.t_max < 0.8 for t in pruned)
    assert np.all(s[ids[ids >= 0], 2] >= 0.8 - 1e-9)


def test_halfopen_interval_query():
    """[t0, inf) windows need no synthetic upper bound anywhere in the path."""
    cfg = StreamConfig(time_dim=2, seal_max_points=400, index_cfg=IDX_CFG)
    x, s = _timed_dataset(1200)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    f = IntervalFilter(dim=2, lo=np.float32(0.6))
    q = _queries(x)
    ids, _ = mgr.query(q, f, k=10, ef=96)
    gt, _ = ground_truth(x, s, q, f, 10)
    assert recall(ids, gt) >= 0.9
    assert np.all(s[ids[ids >= 0], 2] >= 0.6)


def test_compaction_merges_and_gcs():
    """Compaction GCs heavily-deleted segments and bounds the segment count."""
    cfg = StreamConfig(time_dim=2, seal_max_points=250,
                       compact_max_segments=3, compact_deleted_fraction=0.3,
                       index_cfg=IDX_CFG)
    x, s = _timed_dataset(2000)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    assert len(mgr.segments) == 8
    rng = np.random.default_rng(3)
    dead = rng.choice(1000, size=500, replace=False)   # hammer early segments
    mgr.delete(dead)
    mgr.compact()
    assert len(mgr.segments) <= 3
    assert all(g.deleted_fraction() == 0.0 or g.n_live > 0
               for g in mgr.segments)
    # results stay correct after the rewrite
    q = _queries(x)
    alive = mgr.alive
    gt, _ = ground_truth(x, s, q, None, 10, valid=alive)
    ids, _ = mgr.query(q, None, k=10, ef=128)
    assert recall(ids, gt) >= 0.9
    assert not (set(ids[ids >= 0].tolist()) & set(dead.tolist()))


def test_interval_on_middle_dim_plans_correctly():
    """Regression: filters constraining only a prefix or a middle dim (bare
    IntervalFilter / BallFilter) must plan against an m-dim grid without
    broadcasting errors or over-constrained cube sets."""
    from repro.core import BallFilter
    x, s = _timed_dataset(1000)
    idx = CubeGraphIndex.build(x, s, IDX_CFG)
    q = _queries(x, b=4)
    f_mid = IntervalFilter(dim=1, lo=np.float32(0.3), hi=np.float32(0.7))
    ids, _ = idx.query(q, f_mid, k=10, ef=128)
    gt, _ = ground_truth(x, s, q, f_mid, 10)
    assert recall(ids, gt) >= 0.9
    f_ball = BallFilter(center=np.asarray([0.5, 0.5], np.float32),
                        radius=np.float32(0.3))      # 2D ball, m=3 index
    ids, _ = idx.query(q, f_ball, k=10, ef=128)
    gt, _ = ground_truth(x, s, q, f_ball, 10)
    assert recall(ids, gt) >= 0.9
    # and through the streaming manager with a non-last time dim
    cfg = StreamConfig(time_dim=1, seal_max_points=400, index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    ids, _ = mgr.query(q, f_mid, k=10, ef=128)
    gt_mid, _ = ground_truth(x, s, q, f_mid, 10)
    assert recall(ids, gt_mid) >= 0.9


def test_concurrent_compaction_never_returns_stale_points():
    """Acceptance: queries racing a background compaction never return a
    point that was deleted (or expired) before the query began — the
    snapshot + publish epoch guard plus the final liveness filter."""
    import threading
    cfg = StreamConfig(time_dim=2, seal_max_points=250,
                       compact_max_segments=2, compact_deleted_fraction=0.2,
                       index_cfg=IDX_CFG)
    x, s = _timed_dataset(2000)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    rng = np.random.default_rng(9)
    dead = rng.choice(2000, size=700, replace=False)
    mgr.delete(dead)
    dead_set = set(dead.tolist())
    q = _queries(x)

    t = mgr.compact_async()
    assert t is mgr.compact_async()       # at most one compactor at a time
    violations = []
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            ids, _ = mgr.query(q, None, k=10, ef=64)
            got = ids[ids >= 0]
            if set(got.tolist()) & dead_set or (~mgr.alive[got]).any():
                violations.append(got)

    workers = [threading.Thread(target=hammer) for _ in range(2)]
    for w in workers:
        w.start()
    mgr.wait_for_compaction()
    stop.set()
    for w in workers:
        w.join()
    assert not violations
    assert len(mgr.segments) <= cfg.compact_max_segments
    # post-compaction results still correct
    gt, _ = ground_truth(x, s, q, None, 10, valid=mgr.alive)
    ids, _ = mgr.query(q, None, k=10, ef=128)
    assert recall(ids, gt) >= 0.9


def test_point_store_gc_frees_retired_gids():
    """Acceptance: after TTL expiry + deletes, the chunked point store
    releases the chunks whose gids all retired; live lookups still work."""
    cfg = StreamConfig(time_dim=2, seal_max_points=400, ttl=0.45,
                       store_chunk=256, index_cfg=IDX_CFG)
    x, s = _timed_dataset(2000)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    assert mgr.store.resident_points == 2000
    bytes_before = mgr.store.nbytes
    mgr.expire()
    freed = mgr.gc_store()
    assert freed > 0 and freed % cfg.store_chunk == 0
    assert mgr.store.resident_points == 2000 - freed
    assert mgr.store.nbytes < bytes_before
    # retired ids are gone from the ledger; live ids still resolve
    dead_gid, live_gid = 0, 1999
    assert not mgr.alive[dead_gid] and mgr.alive[live_gid]
    xx, ss_, present = mgr.get_points([dead_gid, live_gid])
    assert not present[0] and present[1]
    assert np.allclose(xx[1], x[live_gid])
    # GC'd history never resurfaces in queries
    ids, _ = mgr.query(_queries(x), None, k=10, ef=96)
    got = ids[ids >= 0]
    assert mgr.alive[got].all()
    # a full maintenance tick (the serving-loop entry point) reports GC too
    out = mgr.maintenance()
    assert set(out) >= {"sealed", "expired_points", "compaction_ops",
                        "store_gc_points"}


def test_streaming_document_store_and_batcher():
    """Serving wiring: streaming DocumentStore ingest + grouped fan-out."""
    from repro.serving.batching import RetrievalBatcher, RetrievalRequest
    from repro.serving.rag import Document, DocumentStore
    x, s = _timed_dataset(900, d=16)
    rng = np.random.default_rng(4)
    docs = [Document(doc_id=i,
                     tokens=rng.integers(2, 99, size=8).astype(np.int32),
                     embedding=x[i], metadata=s[i]) for i in range(600)]
    store = DocumentStore(docs, IDX_CFG, streaming=True,
                          stream_cfg=StreamConfig(time_dim=2,
                                                  seal_max_points=250,
                                                  index_cfg=IDX_CFG))
    assert len(store.manager.segments) >= 1
    # streaming ingest of late-arriving documents
    late = [Document(doc_id=i, tokens=rng.integers(2, 99, size=8).astype(np.int32),
                     embedding=x[i], metadata=s[i]) for i in range(600, 900)]
    store.insert(late)
    assert store.manager.n_total == 900

    f_recent = IntervalFilter(dim=2, lo=np.float32(0.5))
    f_all = _window(0.0, 1.0)
    batcher = RetrievalBatcher(store, ef=96)
    for i in range(6):
        batcher.submit(RetrievalRequest(req_id=i, query_emb=x[i],
                                        filt=f_recent if i % 2 else f_all,
                                        k=5))
    out = batcher.flush()
    assert len(out) == 6 and len(batcher) == 0
    gt, _ = ground_truth(x[:900], s[:900], x[:6], f_recent, 5)
    for i, docs_i in out.items():
        assert docs_i, f"request {i} returned nothing"
        if i % 2:   # recent-window requests only return recent docs
            assert all(d.metadata[2] >= 0.5 for d in docs_i)

    store.delete(np.arange(0, 100))
    out2 = store.retrieve(x[:4], f_all, k=5)
    assert all(d.doc_id >= 100 for row in out2 for d in row)
    assert isinstance(store.maintenance(), dict)
    # off-path compaction through the serving wiring: the tick returns
    # immediately (compaction_ops unknown) and the batcher can drive it
    out3 = store.maintenance(async_compaction=True)
    assert out3["compaction_ops"] is None
    store.manager.wait_for_compaction()
    batcher2 = RetrievalBatcher(store, ef=96, maintenance_every=1)
    batcher2.submit(RetrievalRequest(req_id=99, query_emb=x[200], filt=f_all,
                                     k=3))
    assert 99 in batcher2.flush()
    store.manager.wait_for_compaction()


def test_graph_read_path_smoke():
    """Tier-1 smoke for the stitched graph traversal: a small multi-segment
    sealed corpus answers with high recall under ``read_path="graph"``, and
    ``"auto"`` with scan-biased costs stays bit-for-bit equal to ``"scan"``
    (the cost planner must never change scan answers)."""
    import dataclasses
    from repro.streaming.planner import PlannerCosts
    x, s = _timed_dataset(1200)
    q = _queries(x, b=4)
    f = _window(0.1, 0.9)
    cfg = StreamConfig(time_dim=2, seal_max_points=300, n_shards=1,
                       read_path="auto", graph_ef=192, index_cfg=IDX_CFG)
    mgr = SegmentManager(24, 3, cfg)
    mgr.ingest(x, s)
    mgr.seal()
    gt, _ = ground_truth(x, s, q, f, 10)
    gids_g, _ = mgr.query(q, f, k=10, read_path="graph")
    assert recall(gids_g, gt) >= 0.95
    assert mgr.last_plan and all(p.mode == "graph"
                                 for p in mgr.last_plan.values())
    mgr.cfg = dataclasses.replace(cfg, planner_costs=PlannerCosts(
        hop_cost=1e12))
    ga, da = mgr.query(q, f, k=10)
    assert all(p.mode == "scan" for p in mgr.last_plan.values())
    gs, ds = mgr.query(q, f, k=10, read_path="scan")
    assert np.array_equal(ga, gs) and np.array_equal(da, ds)
    # planner decisions are observable
    counters = mgr.stats()["obs"]["metrics"]["counters"]
    assert counters.get('planner_decision_total{mode="graph"}', 0) >= 1
    assert counters.get('planner_decision_total{mode="scan"}', 0) >= 1
