"""Tiered bucket storage (``streaming/tiering.py``): the budget-parity
exactness property over lifecycle interleavings, the budget invariant
under eviction/admission churn, synchronous prefetch determinism,
restore-under-budget, the cold-bucket planner pricing, the TierState
policy unit contract, and the host-side top-k tie-order invariants."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import CubeGraphConfig, IntervalFilter
from repro.core.workloads import make_box_filter
from repro.distributed.segment_shards import host_topk
from repro.streaming import SegmentManager, StreamConfig
from repro.streaming.planner import (PlannerCosts, decide_bucket,
                                     estimate_graph_cost)
from repro.streaming.tiering import TierState, host_reference_topk

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=3)

# Graph priced absurdly high: the auto planner must pick a scan-family
# mode everywhere (scan / host_scan / admit-then-scan — all exact), so
# budgeted answers stay bit-for-bit comparable while still exercising
# the admission pricing.
SCAN_BIASED = PlannerCosts(hop_cost=1e12)


def _cfg(n_shards, budget, quantize=None, **over):
    return StreamConfig(time_dim=2, seal_max_points=120, n_shards=n_shards,
                        compact_max_segments=3, ttl=1.5, index_cfg=IDX_CFG,
                        quantize=quantize, device_budget_bytes=budget,
                        graph_ef=128, **over)


def _apply_stream_ops(mgr, rng, ops, d=24):
    """Drive one manager through an interleaving of lifecycle ops (same op
    coding as tests/test_planner.py: ingest/delete/seal/compact/expire)."""
    t = getattr(mgr, "_test_t", 0.0)
    for op in ops:
        if op == 0 or mgr.n_total == 0:           # ingest
            nb = int(rng.integers(40, 150))
            x = rng.normal(size=(nb, d)).astype(np.float32)
            s = rng.uniform(size=(nb, 3))
            s[:, 2] = t + np.linspace(0.0, 0.05, nb)
            t += 0.25
            mgr.ingest(x, s)
        elif op == 1:                             # delete
            g = rng.integers(0, mgr.n_total, size=25)
            mgr.delete(g)
        elif op == 2:                             # seal
            mgr.seal()
        elif op == 3:                             # compact (merges + GC)
            mgr.compact()
        elif op == 4:                             # expire (finite ttl)
            mgr.expire()
    mgr._test_t = t


# ---------------------------------------------------------------------------
# The exactness property: a budgeted manager answers bit-for-bit like an
# unbudgeted one after any lifecycle interleaving, for any budget
# ---------------------------------------------------------------------------

def _check_budget_parity(seed, n_shards, ops, quantize, budget):
    """Two managers differing only in ``device_budget_bytes`` — driven
    through the same op interleaving — must answer every filter/read-path
    combination identically: cold buckets stream byte-identical host
    blocks through the same kernels, so residency is invisible to
    answers.  The budget invariant is re-checked after every query."""
    base = SegmentManager(24, 3, _cfg(n_shards, None, quantize))
    tiered = SegmentManager(24, 3, _cfg(n_shards, budget, quantize))
    for mgr in (base, tiered):
        _apply_stream_ops(mgr, np.random.default_rng(seed), ops)
        mgr.seal()
    assert base.tier is None
    assert tiered.tier is not None
    assert tiered.tier.budget_bytes == budget
    q = np.random.default_rng(seed + 1).normal(size=(4, 24)) \
        .astype(np.float32)
    filters = [None, make_box_filter(3, 0.6, seed=seed),
               IntervalFilter(dim=2, lo=np.float32(0.2),
                              hi=np.float32(1.2))]
    cfg_b = tiered.cfg
    for filt in filters:
        # forced legs pin the mode on both sides (scan <-> host_scan,
        # graph in place over the cold adjacency block); the auto leg
        # runs the real planner with graph priced out, which exercises
        # the admit_cheaper / cold_scan_cheaper pricing while keeping
        # every chosen mode exact
        for leg in ("scan", "graph", "auto"):
            if leg == "auto":
                tiered.cfg = dataclasses.replace(
                    cfg_b, planner_costs=SCAN_BIASED)
                base.cfg = dataclasses.replace(
                    base.cfg, planner_costs=SCAN_BIASED)
                ga, da = base.query(q, filt, k=10, read_path="auto")
                gb, db = tiered.query(q, filt, k=10, read_path="auto")
                tiered.cfg = cfg_b
            else:
                ga, da = base.query(q, filt, k=10, read_path=leg)
                gb, db = tiered.query(q, filt, k=10, read_path=leg)
            assert np.array_equal(ga, gb), (filt, leg)
            assert np.array_equal(da, db), (filt, leg)
            st = tiered.stats()["tier"]
            assert st["resident_bytes"] <= budget, (filt, leg, st)
    if budget == 0 and tiered.stats()["pack_nbytes"] == 0:
        # all-cold: the whole sealed corpus lives host-side
        assert tiered.stats()["tier"]["host_bytes"] >= 0


@pytest.mark.parametrize("seed,n_shards,ops,quantize,budget", [
    (7, 1, [0, 1, 2, 0, 3, 1, 4], None, 0),        # all-cold, fp32
    (19, 3, [0, 2, 1, 3, 0, 0, 4, 2], None, 1 << 16),  # partial, sharded
    (23, 1, [0, 1, 2, 0, 3], "int8", 0),           # all-cold, quantized
    (31, 3, [0, 2, 0, 2, 1, 3], "int8", 1 << 15),  # partial, quantized
])
def test_budget_parity(seed, n_shards, ops, quantize, budget):
    """Deterministic interleavings of the budget-parity property (always
    run; the hypothesis variant widens the search space when available)."""
    _check_budget_parity(seed, n_shards, ops, quantize, budget)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000), n_shards=st.sampled_from([1, 3]),
           ops=st.lists(st.integers(0, 4), min_size=3, max_size=7),
           quantize=st.sampled_from([None, "int8"]),
           budget=st.sampled_from([0, 1 << 14, 1 << 17]))
    def test_budget_parity_hypothesis(seed, n_shards, ops, quantize,
                                      budget):
        """Hypothesis-driven interleavings of the same property."""
        _check_budget_parity(seed, n_shards, ops, quantize, budget)
except ImportError:                               # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# Eviction/prefetch churn under a drifting window: invariant + counters
# ---------------------------------------------------------------------------

def _era_managers(budget_frac=2):
    """Two managers (unbudgeted / budgeted) over an era'd stream whose
    segment sizes differ per era, so each era lands in its own capacity
    bucket and the buckets tile the time axis — a drifting window then
    forces real residency churn.  Returns (base, tiered, budget)."""
    d = 16
    eras = ((3, 300), (2, 600), (1, 1200))        # (segments, points)
    rng = np.random.default_rng(71)
    n = sum(k * sz for k, sz in eras)
    x = rng.normal(size=(n, d)).astype(np.float32)
    s = rng.uniform(size=(n, 3))
    s[:, 2] = np.linspace(0.0, 9.0, n)

    def _ingest(mgr):
        lo = 0
        for n_segs, size in eras:
            for _ in range(n_segs):
                mgr.ingest(x[lo:lo + size], s[lo:lo + size])
                mgr.seal()
                lo += size

    def _mk(budget):
        return SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=1 << 30, n_shards=2,
            device_budget_bytes=budget, index_cfg=IDX_CFG))

    base = _mk(None)
    _ingest(base)
    q = x[rng.integers(0, n, 4)].copy()
    base.query(q, None, k=10)                     # build + size the pack
    budget = max(base.stats()["pack_nbytes"] // budget_frac, 1)
    tiered = _mk(budget)
    _ingest(tiered)
    return base, tiered, budget, q


def test_tier_churn_budget_invariant_and_counters():
    """A window drifting across the eras keeps resident bytes <= budget
    at every step, answers bit-for-bit the unbudgeted manager's, and
    moves the eviction / prefetch-admission / miss counters; a second
    synchronous prefetch round for the same window is a no-op."""
    base, tiered, budget, q = _era_managers()
    for lo in np.linspace(0.0, 6.0, 7):
        f = IntervalFilter(dim=2, lo=np.float32(lo), hi=np.float32(lo + 3))
        g_b, d_b = base.query(q, f, k=10, read_path="scan")
        g_t, d_t = tiered.query(q, f, k=10, read_path="scan")
        tiered._prefetch_once()                   # deterministic round
        assert np.array_equal(g_b, g_t)
        assert np.array_equal(d_b, d_t)
        st = tiered.stats()["tier"]
        assert st["resident_bytes"] <= budget
        assert st["resident_bytes"] + st["host_bytes"] > 0
    # the window parked: everything it needs is staged, so another
    # synchronous round admits nothing
    assert tiered._prefetch_once() == 0
    counters = tiered.stats()["obs"]["metrics"]["counters"]
    assert counters.get("tier_evictions_total", 0) > 0
    assert counters.get("tier_prefetch_admissions_total", 0) > 0
    assert counters.get("tier_miss_total", 0) > 0
    # gauges track the same numbers the stats block reports
    gauges = tiered.stats()["obs"]["metrics"]["gauges"]
    assert gauges["tier_budget_bytes"] == budget
    assert gauges["tier_resident_bytes"] <= budget


def test_prefetch_disabled_and_thread_discipline():
    """``tier_prefetch=False`` turns maybe_prefetch into a no-op; enabled,
    it runs at most one daemon round that respects the budget."""
    _, tiered, budget, q = _era_managers()
    tiered.query(q, IntervalFilter(dim=2, lo=np.float32(0.0),
                                   hi=np.float32(3.0)), k=10)
    tiered.query(q, IntervalFilter(dim=2, lo=np.float32(3.0),
                                   hi=np.float32(6.0)), k=10)
    off = dataclasses.replace(tiered.cfg, tier_prefetch=False)
    tiered.cfg = off
    assert tiered.maybe_prefetch() is None
    tiered.cfg = dataclasses.replace(off, tier_prefetch=True)
    t = tiered.maybe_prefetch()
    if t is not None:
        t.join(timeout=30)
        assert not t.is_alive()
    assert tiered.stats()["tier"]["resident_bytes"] <= budget


# ---------------------------------------------------------------------------
# Restore under a budget: no full resident cold-build, same answers
# ---------------------------------------------------------------------------

def test_restore_under_budget_parity(tmp_path):
    """A budgeted replica of an unbudgeted writer's snapshot serves its
    first query from a partially resident pack (resident <= budget, the
    rest host-side) with bit-identical answers."""
    base, _, budget, q = _era_managers()
    snap = os.path.join(str(tmp_path), "snap")
    base.snapshot_to(snap)
    f = IntervalFilter(dim=2, lo=np.float32(6.0), hi=np.float32(9.0))
    g0, d0 = base.query(q, f, k=10, read_path="scan")
    cfg = StreamConfig(time_dim=2, seal_max_points=1 << 30, n_shards=2,
                       device_budget_bytes=budget, index_cfg=IDX_CFG)
    m2 = SegmentManager.restore(snap, cfg=cfg, resume=False)
    g1, d1 = m2.query(q, f, k=10, read_path="scan")
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)
    st = m2.stats()["tier"]
    assert st["resident_bytes"] <= budget
    assert st["host_bytes"] > 0                   # corpus > budget: some
    assert st["resident_bytes"] > 0               # ...but not all cold


# ---------------------------------------------------------------------------
# Planner: cold-bucket pricing + the query path acting on it
# ---------------------------------------------------------------------------

def test_decide_bucket_cold_pricing():
    """Forced reads on a cold bucket never admit; auto weighs the
    one-shot staging cost against streaming on every dispatch."""
    c = PlannerCosts()
    d = decide_bucket(1024, 8, 9, True, None, c, "graph", resident=False)
    assert (d.mode, d.reason) == ("graph", "forced")
    d = decide_bucket(1024, 8, 9, True, None, c, "scan", resident=False)
    assert (d.mode, d.reason) == ("host_scan", "forced")
    cheap = dataclasses.replace(c, admit_cost_per_byte=0.0,
                                host_scan_multiplier=100.0)
    d = decide_bucket(1024, 8, 0, False, None, cheap, "auto",
                      resident=False, stage_bytes=1 << 20)
    assert (d.mode, d.reason) == ("scan", "admit_cheaper")
    dear = dataclasses.replace(c, admit_cost_per_byte=1e9)
    d = decide_bucket(1024, 8, 0, False, None, dear, "auto",
                      resident=False, stage_bytes=1 << 20)
    assert (d.mode, d.reason) == ("host_scan", "cold_scan_cheaper")


def test_estimate_graph_cost_uses_live_fill():
    """The live-point estimate lowers the hop count vs. the padded
    ``active_rows * cap`` bound (the exp15 crossover bugfix)."""
    c = PlannerCosts()
    padded = estimate_graph_cost(4096, 64, 0, c)
    live = estimate_graph_cost(4096, 64, 0, c, n_points=1000.0)
    assert live < padded


def test_query_path_admits_when_planner_prices_admission():
    """End-to-end admit_cheaper: evict a bucket, price streaming out of
    the market, and the next auto query re-admits the block mid-query —
    same answer, bucket resident again, admission counter moved."""
    rng = np.random.default_rng(47)
    mgr = SegmentManager(16, 3, StreamConfig(
        time_dim=2, seal_max_points=1 << 30, n_shards=1,
        device_budget_bytes=1 << 30, index_cfg=IDX_CFG))
    x = rng.normal(size=(300, 16)).astype(np.float32)
    mgr.ingest(x, rng.uniform(size=(300, 3)))
    mgr.seal()
    q = rng.normal(size=(3, 16)).astype(np.float32)
    g0, d0 = mgr.query(q, None, k=5)
    with mgr._lock:
        pack = mgr._pack
        cap = next(iter(pack.buckets))
        assert pack.evict_bucket(cap) > 0
    base_cfg = mgr.cfg

    # leg 1: streaming priced cheap -> the bucket stays cold (host_scan),
    # answers unchanged, and each cold dispatch counts a tier miss
    mgr.cfg = dataclasses.replace(base_cfg, planner_costs=PlannerCosts(
        hop_cost=1e12, admit_cost_per_byte=1e9))
    g1, d1 = mgr.query(q, None, k=5, read_path="auto")
    assert np.array_equal(g0, g1) and np.array_equal(d0, d1)
    assert [p.reason for p in mgr.last_plan.values()] == \
        ["cold_scan_cheaper"]
    assert not mgr._pack.buckets[cap].resident
    counters = mgr.stats()["obs"]["metrics"]["counters"]
    assert counters.get("tier_miss_total", 0) > 0

    # leg 2: staging priced free -> admit_cheaper, admission happens
    # inside the query, and the block is resident afterwards
    mgr.cfg = dataclasses.replace(base_cfg, planner_costs=PlannerCosts(
        hop_cost=1e12, admit_cost_per_byte=0.0,
        host_scan_multiplier=1e9))
    g2, d2 = mgr.query(q, None, k=5, read_path="auto")
    assert np.array_equal(g0, g2) and np.array_equal(d0, d2)
    assert [p.reason for p in mgr.last_plan.values()] == ["admit_cheaper"]
    assert mgr._pack.buckets[cap].resident
    counters = mgr.stats()["obs"]["metrics"]["counters"]
    assert counters.get("tier_admissions_total", 0) >= 1
    mgr.cfg = base_cfg


# ---------------------------------------------------------------------------
# TierState policy unit contract
# ---------------------------------------------------------------------------

def test_tier_state_window_drift_and_policy():
    """Window bookkeeping rejects junk, predicts by mean center drift,
    and the heat order evicts never-touched old buckets before observed
    ones before window-overlapping ones."""
    ts = TierState(1000)
    assert ts.recent_window() is None
    assert ts.predicted_window() is None
    ts.note_window(np.inf, np.inf)                # non-finite: ignored
    ts.note_window(2.0, 1.0)                      # inverted: ignored
    assert ts.recent_window() is None
    ts.note_window(0.0, 4.0)
    assert ts.predicted_window() == (0.0, 4.0)    # stationary: unshifted
    ts.note_window(1.0, 5.0)
    ts.note_window(2.0, 6.0)
    lo, hi = ts.predicted_window()
    assert np.isclose(lo, 3.0) and np.isclose(hi, 7.0)

    def m(cap, resident, t_min, t_max, dispatches=None):
        return {"cap": cap, "resident": resident, "nbytes": 100,
                "t_min": t_min, "t_max": t_max,
                "stats": None if dispatches is None
                else {"dispatches": dispatches}}

    meta = [m(256, True, 0.0, 1.0, dispatches=50),   # observed, stale span
            m(512, True, 5.5, 8.0),                  # overlaps windows
            m(1024, False, 6.5, 9.0),                # cold, predicted hit
            m(2048, True, -9.0, -8.0)]               # never touched
    assert ts.heat(meta[1]) > 1e8                    # window bonus wins
    assert ts.heat(meta[3]) == 0.0
    # coldest-first until enough freed: untouched-old, then observed
    assert ts.pick_victims(meta, 150) == [2048, 256]
    # need more than everything resident: every resident cap, cold never
    assert set(ts.pick_victims(meta, 10 ** 6)) == {256, 512, 2048}
    assert ts.prefetch_targets(meta) == [1024]


# ---------------------------------------------------------------------------
# Host-side oracles: host_reference_topk contract + host_topk tie order
# ---------------------------------------------------------------------------

def test_host_reference_topk_matches_kernel_answers():
    """The pure-numpy oracle reproduces the fused kernel's filtered
    top-k per bucket: identical gids (no ties in gaussian data) and
    allclose distances — the independent check behind the cold-read
    exactness property."""
    rng = np.random.default_rng(9)
    mgr = SegmentManager(24, 3, _cfg(2, None))
    x = rng.normal(size=(200, 24)).astype(np.float32)
    s = rng.uniform(size=(200, 3))
    mgr.ingest(x, s)
    mgr.seal()
    q = rng.normal(size=(5, 24)).astype(np.float32)
    for filt in (None, IntervalFilter(dim=2, lo=np.float32(0.3),
                                      hi=np.float32(0.9)),
                 make_box_filter(3, 0.7, seed=5)):
        g, dd = mgr.query(q, filt, k=10, read_path="scan")
        epoch, segments, _ = mgr.snapshot()
        view = mgr.shard_pack(epoch,
                              [s_ for s_ in segments if s_.n_live > 0])
        gs, ds = [], []
        for bv in view.buckets:
            bg, bd = host_reference_topk(bv, q, filt, 10, -np.inf,
                                         np.inf, m=3)
            gs.append(bg)
            ds.append(bd)
        og, od = host_topk(np.concatenate(gs, axis=1),
                           np.concatenate(ds, axis=1), 10)
        assert np.array_equal(g, og), filt
        assert np.allclose(dd, od, rtol=1e-4, atol=1e-3), filt


def test_host_reference_topk_rejects_quantized():
    """Quantized buckets have no single host-side distance (asymmetric
    codes + exact rerank) — the oracle refuses instead of guessing."""
    rng = np.random.default_rng(11)
    mgr = SegmentManager(16, 3, _cfg(1, None, "int8"))
    mgr.ingest(rng.normal(size=(150, 16)).astype(np.float32),
               rng.uniform(size=(150, 3)))
    mgr.seal()
    q = rng.normal(size=(2, 16)).astype(np.float32)
    mgr.query(q, None, k=5)
    epoch, segments, _ = mgr.snapshot()
    view = mgr.shard_pack(epoch, [s_ for s_ in segments if s_.n_live > 0])
    with pytest.raises(ValueError, match="fp32"):
        host_reference_topk(view.buckets[0], q, None, 5, -np.inf, np.inf)


def test_host_topk_ambiguous_tie_reselection():
    """A finite distance tie straddling the k-th position takes the
    full-lexsort path: selection follows the total (dist, gid) order, not
    argpartition's input-order accident."""
    d = np.array([[0.1, 0.5, 0.5, 0.5, 0.9, 0.2]], np.float32)
    g = np.array([[5, 4, 3, 2, 1, 0]], np.int64)
    gg, dd = host_topk(g, d, 3)
    assert gg.tolist() == [[5, 0, 2]]             # tie at 0.5 -> min gid
    assert np.allclose(dd, [[0.1, 0.2, 0.5]])
    # short rows pad with (-1, +inf); dead gids never surface
    gg, dd = host_topk(np.array([[3, -1]], np.int64),
                       np.array([[0.4, 0.1]], np.float32), 4)
    assert gg.tolist() == [[3, -1, -1, -1]]
    assert dd[0, 0] == np.float32(0.4) and np.isinf(dd[0, 1:]).all()


def test_host_topk_block_order_invariance():
    """Permuting the candidate concatenation order never changes the
    selected (gid, dist) rows — heavy exact ties and dead entries
    included (the merge-order half of cold-read determinism)."""
    rng = np.random.default_rng(3)
    d = rng.choice([0.125, 0.25, 0.5, 1.0], size=(4, 40)) \
        .astype(np.float32)
    g = np.broadcast_to(np.arange(40, dtype=np.int64), (4, 40)).copy()
    g[rng.random((4, 40)) < 0.2] = -1
    g0, d0 = host_topk(g, d, 7)
    for _ in range(10):
        p = rng.permutation(40)
        g1, d1 = host_topk(g[:, p], d[:, p], 7)
        assert np.array_equal(g0, g1)
        assert np.array_equal(d0, d1)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 12),
           n=st.integers(1, 30))
    def test_host_topk_order_invariance_hypothesis(seed, k, n):
        """Hypothesis-driven permutation invariance of host_topk over
        tie-heavy candidate rows with random dead entries."""
        rng = np.random.default_rng(seed)
        d = rng.choice([0.25, 0.5, 0.5, 1.0], size=(2, n)) \
            .astype(np.float32)
        g = np.broadcast_to(np.arange(n, dtype=np.int64), (2, n)).copy()
        g[rng.random((2, n)) < 0.2] = -1
        ref_g, ref_d = host_topk(g, d, k)
        p = rng.permutation(n)
        out_g, out_d = host_topk(g[:, p], d[:, p], k)
        assert np.array_equal(ref_g, out_g)
        assert np.array_equal(ref_d, out_d)
except ImportError:                               # pragma: no cover
    pass
