"""Quantized read path: codec contract (scale-bounded round-trip error),
asymmetric-distance kernel vs the dequantized oracle, two-stage exactness
and the deterministic (dist, gid) tie-break, fp32 A/B parity with
``quantize=None``, snapshot/restore without re-encoding, and dispatch
compile warming."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import BoxFilter, ComposeFilter, CubeGraphConfig, IntervalFilter
from repro.core.workloads import (ground_truth, make_box_filter, make_dataset,
                                  make_polygon_filter, recall)
from repro.distributed.segment_shards import (SegmentShardSource,
                                              build_bucketed_pack,
                                              build_shard_pack, host_topk,
                                              pack_search)
from repro.kernels import (dispatch_trace_count, quant_meta_rows,
                           sharded_quant_filtered_topk, warm_sharded_shapes)
from repro.quant import dequantize, encode_segment, fit_scales, quantize
from repro.streaming import SegmentManager, StreamConfig

IDX_CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=3)


# ---------------------------------------------------------------------------
# Codec contract
# ---------------------------------------------------------------------------
def _check_codec_contract(x):
    sq = encode_segment(x)
    assert sq.codes.dtype == np.int8
    assert np.abs(sq.codes.astype(np.int32)).max(initial=0) <= 127
    deq = dequantize(sq.codes, sq.scales)
    # per-dimension scale bound: |x - deq| <= scale/2 (+ fp32 slack)
    bound = sq.scales[None, :] * 0.5 * (1 + 1e-5) + 1e-12
    assert (np.abs(x - deq) <= bound).all()
    # stored norms are the *dequantized* norms, bit-for-bit
    assert np.allclose(sq.xsq, np.einsum("nd,nd->n", deq, deq), rtol=1e-6)


@pytest.mark.parametrize("seed,n,d,spread", [
    (0, 200, 8, 1.0), (1, 50, 32, 100.0), (2, 1, 4, 0.01), (3, 300, 16, 1e4),
])
def test_codec_roundtrip_error_within_scale_bound(seed, n, d, spread):
    """Deterministic codec property incl. wildly different per-dim ranges
    and an all-zero dimension (scale floor)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    x *= spread * rng.uniform(0.01, 1.0, size=(1, d)).astype(np.float32)
    x[:, d // 2] = 0.0                      # zero-variance dim stays exact
    _check_codec_contract(x)
    deq = dequantize(quantize(x, fit_scales(x)), fit_scales(x))
    assert (deq[:, d // 2] == 0.0).all()


try:                                     # richer search space when available
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 120),
           d=st.integers(1, 48),
           log_spread=st.floats(-3, 5, allow_nan=False))
    def test_codec_roundtrip_error_hypothesis(seed, n, d, log_spread):
        """Hypothesis variant of the scale-bound contract."""
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(n, d)) * 10.0 ** log_spread).astype(np.float32)
        _check_codec_contract(x)
except ImportError:                      # pragma: no cover - optional dep
    pass


# ---------------------------------------------------------------------------
# Asymmetric-distance kernel
# ---------------------------------------------------------------------------
def _quant_stack(seed, g, n, d=32, m=3, cap=768):
    """Transposed quantized shard stack + per-shard dequantized oracles."""
    from repro.kernels import PAD_META
    rng = np.random.default_rng(seed)
    dq, mq = max(32, -(-d // 32) * 32), quant_meta_rows(m)
    x = rng.normal(size=(g, n, d)).astype(np.float32)
    s = rng.uniform(size=(g, n, m)).astype(np.float32)
    codes = np.zeros((g, dq, cap), np.int8)
    stt = np.full((g, mq, cap), PAD_META, np.float32)
    scales = np.zeros((g, dq), np.float32)
    deqs = []
    for gi in range(g):
        sq = encode_segment(x[gi])
        codes[gi, :d, :n] = sq.codes.T
        stt[gi, :, :n] = 0.0
        stt[gi, :m, :n] = s[gi].T
        stt[gi, mq - 1, :n] = sq.xsq
        scales[gi, :d] = sq.scales
        deqs.append(dequantize(sq.codes, sq.scales))
    return x, s, codes, stt, scales, deqs


@pytest.mark.parametrize("seed,g,n,k", [(0, 1, 300, 5), (1, 3, 700, 17)])
def test_quant_kernel_matches_dequantized_oracle(seed, g, n, k):
    """The fused int8 kernel's distances equal exact fp32 distances against
    the *dequantized* vectors, for every filter kind incl. the jnp
    fallback — i.e. quantization error lives only in the codes, never in
    the kernel."""
    import jax.numpy as jnp
    x, s, codes, stt, scales, deqs = _quant_stack(seed, g, n)
    rng = np.random.default_rng(seed + 9)
    q = rng.normal(size=(5, 32)).astype(np.float32)
    filters = [None,
               make_box_filter(3, 0.5, seed=seed),
               ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                       hi=np.ones(3, np.float32)),
                             IntervalFilter(dim=2, lo=np.float32(0.3)),
                             "and"),
               make_polygon_filter(3, 0.6, seed=seed)]   # jnp fallback
    for filt in filters:
        ids, dd = sharded_quant_filtered_topk(q, codes, stt, scales, filt,
                                              k, m=3)
        ids, dd = np.asarray(ids), np.asarray(dd)
        for gi in range(g):
            dist = ((q[:, None, :] - deqs[gi][None, :, :]) ** 2).sum(-1)
            if filt is not None:
                ok = np.asarray(filt.contains(jnp.asarray(s[gi])))
                dist = np.where(ok[None, :], dist, np.inf)
            ref = np.sort(dist, axis=1)[:, :k]
            got = dd[gi]
            fin = np.isfinite(ref)
            assert np.allclose(got[fin], ref[fin], rtol=1e-4, atol=1e-4), \
                f"filter {filt}"
            assert (ids[gi][~np.isfinite(got)] == -1).all()


# ---------------------------------------------------------------------------
# Two-stage path: exactness, tie-break, A/B parity
# ---------------------------------------------------------------------------
def _quant_sources(seed, n_segments, d=24, m=3):
    rng = np.random.default_rng(seed)
    sources, gid0 = [], 0
    for sid in range(n_segments):
        n = int(rng.integers(150, 500))
        x = rng.normal(size=(n, d)).astype(np.float32)
        s = rng.uniform(size=(n, m))
        g = np.arange(gid0, gid0 + n, dtype=np.int64)
        gid0 += n
        q8 = encode_segment(x)
        sources.append(SegmentShardSource(
            sid, x, s, g, float(s[:, m - 1].min()), float(s[:, m - 1].max()),
            codes=q8.codes, scales=q8.scales, xsq=q8.xsq))
    return sources


def _lookup_for(sources):
    x_all = np.concatenate([s.x for s in sources])
    g_all = np.concatenate([s.gids for s in sources])
    by_gid = np.zeros((int(g_all.max()) + 1, x_all.shape[1]), np.float32)
    by_gid[g_all] = x_all
    return lambda gids: (by_gid[np.asarray(gids, np.int64)], None,
                         np.ones(len(gids), bool))


def test_two_stage_equals_fp32_path_with_full_overfetch():
    """With the over-fetch covering every live point, the reranked
    quantized result must recover exactly the fp32 pack's gids (the rerank
    is exact, so only candidate misses could differ — and there are
    none)."""
    sources = _quant_sources(7, 3)
    lookup = _lookup_for(sources)
    qp = build_bucketed_pack(sources, n_shards=2, quantize="int8")
    fp = build_shard_pack(sources, n_shards=2)
    rng = np.random.default_rng(8)
    q = rng.normal(size=(6, 24)).astype(np.float32)
    for filt in (None, make_box_filter(3, 0.6, seed=7)):
        gi, di = pack_search(qp, q, filt, k=10, lookup=lookup,
                             rerank_multiple=10_000)
        gf, df = pack_search(fp, q, filt, k=10)
        assert np.array_equal(gi, gf)
        assert np.allclose(np.where(np.isfinite(di), di, 0),
                           np.where(np.isfinite(df), df, 0), atol=1e-4)


def test_reranked_tiebreak_is_deterministic_dist_gid():
    """Duplicated vectors in different segments produce exact distance
    ties; the reranked output must order them by ascending gid — the same
    contract ``host_topk`` / ``merge_topk`` enforce — regardless of
    segment insertion order."""
    rng = np.random.default_rng(21)
    base = rng.normal(size=(40, 24)).astype(np.float32)
    dup = base[:3].copy()                    # rows duplicated in every seg
    orders = [(0, 1, 2), (2, 0, 1)]
    results = []
    for perm in orders:
        sources = []
        for slot, sid in enumerate(perm):
            x = np.concatenate([dup, base[10 + 10 * sid: 20 + 10 * sid]])
            s = rng.uniform(size=(len(x), 3))
            g = np.arange(sid * 1000, sid * 1000 + len(x), dtype=np.int64)
            q8 = encode_segment(x)
            sources.append(SegmentShardSource(
                sid, x, s, g, 0.0, 1.0, codes=q8.codes, scales=q8.scales,
                xsq=q8.xsq))
        lookup = _lookup_for(sources)
        pack = build_bucketed_pack(sorted(sources, key=lambda t: t.seg_id),
                                   n_shards=2, quantize="int8")
        gi, di = pack_search(pack, dup[:1], None, k=5, lookup=lookup,
                             rerank_multiple=100)
        results.append((gi, di))
    g0, d0 = results[0]
    for gi, di in results[1:]:
        assert np.array_equal(g0, gi) and np.array_equal(d0, di)
    # the three exact duplicates tie at distance 0 -> ascending gid
    assert g0[0, :3].tolist() == [0, 1000, 2000]
    assert np.allclose(d0[0, :3], d0[0, 0])
    # and the ordering matches host_topk's on the same (gid, dist) rows
    hg, hd = host_topk(g0, d0, 5)
    assert np.array_equal(hg, g0) and np.array_equal(hd, d0)


def test_fp32_path_bit_for_bit_unchanged_when_quantize_none():
    """A/B parity: with ``quantize=None`` the bucketed pack holds fp32
    blocks (no codes), dispatches the fp32 kernel, and answers bit-for-bit
    like the legacy monolithic fp32 pack — proving the quant plumbing
    changed nothing on the baseline path."""
    sources = _quant_sources(13, 3)
    pack = build_bucketed_pack(sources, n_shards=2)          # quantize=None
    assert pack.quantize is None
    for b in pack.buckets.values():
        assert b.codes is None and b.x is not None
    view = pack.view()
    assert view.quantize is None
    legacy = build_shard_pack(sources, n_shards=2)
    rng = np.random.default_rng(13)
    q = rng.normal(size=(5, 24)).astype(np.float32)
    for filt in (None, make_box_filter(3, 0.5, seed=13)):
        gb, db = pack_search(pack, q, filt, k=12)
        gl, dl = pack_search(legacy, q, filt, k=12)
        assert np.array_equal(db, dl)                        # bit-for-bit
        uniq = np.ones_like(gb, bool)
        uniq[:, 1:] &= db[:, 1:] != db[:, :-1]
        uniq[:, :-1] &= db[:, :-1] != db[:, 1:]
        assert np.array_equal(gb[uniq], gl[uniq])


# ---------------------------------------------------------------------------
# Manager integration
# ---------------------------------------------------------------------------
def _mgr(quantize, seed=31, n=1600, d=24, rerank_multiple=4):
    x, s = make_dataset(n, d, 3, seed=seed)
    s[:, 2] = np.arange(n) / n
    mgr = SegmentManager(d, 3, StreamConfig(
        time_dim=2, seal_max_points=400, n_shards=2, quantize=quantize,
        rerank_multiple=rerank_multiple, index_cfg=IDX_CFG))
    mgr.ingest(x, s)
    return mgr, x, s


def test_manager_quantized_recall_and_memory():
    """End-to-end acceptance mirror: the quantized manager reaches
    recall@10 >= 0.95 at the default over-fetch while holding >= 3x fewer
    sealed-pack device bytes than the fp32 manager on the same stream."""
    mq, x, s = _mgr("int8")
    mf, _, _ = _mgr(None)
    rng = np.random.default_rng(32)
    q = (x[rng.integers(0, len(x), 8)]
         + 0.05 * rng.normal(size=(8, 24)).astype(np.float32))
    f = ComposeFilter(BoxFilter(lo=np.zeros(3, np.float32),
                                hi=np.ones(3, np.float32)),
                      IntervalFilter(dim=2, lo=np.float32(0.1)), "and")
    gt, _ = ground_truth(x, s, q, f, 10, valid=mq.alive)
    ids_q, _ = mq.query(q, f, k=10)
    ids_f, _ = mf.query(q, f, k=10)
    assert recall(ids_f, gt) >= 0.99          # fp32 path is exact
    assert recall(ids_q, gt) >= 0.95          # acceptance bar
    nb_q = mq.stats()["pack_nbytes"]
    nb_f = mf.stats()["pack_nbytes"]
    assert nb_q > 0 and nb_f / nb_q >= 3.0
    assert mq.stats()["quantize"] == "int8"


def test_quantized_incremental_pack_matches_cold_rebuild():
    """Deletes / compaction / reseals keep the incrementally maintained
    quantized pack answering identically to a forced cold rebuild of the
    same segments (codes are attached to segments, so both paths stack the
    same bytes)."""
    mgr, x, s = _mgr("int8", seed=41)
    rng = np.random.default_rng(42)
    q = rng.normal(size=(5, 24)).astype(np.float32)
    mgr.query(q, None, k=8)                   # cold-build + record sigs
    mgr.delete(rng.integers(0, len(x), 150))
    mgr.ingest(x[:300] + 1.0, s[:300] * [1, 1, 0] + [0, 0, 1.5])
    mgr.seal()
    mgr.compact()
    for filt in (None, make_box_filter(3, 0.6, seed=41)):
        gi, di = mgr.query(q, filt, k=12)
        mgr._pack = None                      # force from-scratch rebuild
        gr, dr = mgr.query(q, filt, k=12)
        assert np.array_equal(di, dr)
        assert np.array_equal(gi, gr)


def test_quantized_snapshot_restore_never_requantizes(tmp_path,
                                                      monkeypatch):
    """Snapshot/restore round-trips the codec payload bit-for-bit: the
    restored replica answers identically and never calls the encoder."""
    mgr, x, s = _mgr("int8", seed=51, n=1200)
    mgr.delete(np.arange(0, 300, 3))
    rng = np.random.default_rng(52)
    q = rng.normal(size=(6, 24)).astype(np.float32)
    ids0, dd0 = mgr.query(q, None, k=10)
    snap = os.path.join(str(tmp_path), "snap")
    mgr.snapshot_to(snap)

    import repro.quant.codec as codec

    def _boom(*a, **k):
        raise AssertionError("restore re-quantized a segment")
    monkeypatch.setattr(codec, "encode_segment", _boom)
    m2 = SegmentManager.restore(snap, resume=False)
    for s1, s2 in zip(mgr.segments, m2.segments):
        assert s2.quant is not None and s2.quant.kind == "int8"
        assert np.array_equal(s1.quant.codes, s2.quant.codes)
        assert np.array_equal(s1.quant.scales, s2.quant.scales)
    ids1, dd1 = m2.query(q, None, k=10)
    assert np.array_equal(ids0, ids1) and np.array_equal(dd0, dd1)


def test_live_snapshot_rows_stay_aligned_after_deletes():
    """``SealedSegment.live_snapshot`` derives vectors, metadata, gids AND
    the codec payload from one read of the validity mask, so its row
    counts always agree — the input contract of the lock-free cold pack
    build."""
    mgr, x, s = _mgr("int8", seed=81, n=900)
    seg = mgr.segments[0]
    mgr.delete(seg.gids[::3])
    xl, sl, gl, quant = seg.live_snapshot()
    assert len(xl) == len(sl) == len(gl) == quant.n
    assert quant.n == seg.n_live
    # payload rows are the sealed codes of exactly the surviving rows
    keep = np.nonzero(seg.index.valid)[0]
    assert np.array_equal(quant.codes, seg.quant.codes[keep])


def test_pre_quant_snapshot_gains_codec_at_compaction(tmp_path):
    """A pre-quantization snapshot restored under ``quantize='int8'``
    works immediately (on-the-fly pack encode) and a compaction GC-rewrite
    upgrades the rewritten segment with a persisted codec payload."""
    mgr, x, s = _mgr(None, seed=91, n=900)
    snap = os.path.join(str(tmp_path), "snap")
    mgr.snapshot_to(snap)
    cfg = StreamConfig(time_dim=2, seal_max_points=400, n_shards=2,
                       quantize="int8", index_cfg=IDX_CFG)
    m2 = SegmentManager.restore(snap, cfg=cfg, resume=False)
    assert all(seg.quant is None for seg in m2.segments)
    rng = np.random.default_rng(92)
    q = rng.normal(size=(4, 24)).astype(np.float32)
    ids, _ = m2.query(q, None, k=8)           # on-the-fly encode fallback
    assert (ids >= 0).any()
    victim = m2.segments[0]
    m2.delete(victim.gids[: int(0.6 * len(victim.gids))])
    m2.compact()                              # GC rewrite -> codec fitted
    rewritten = [seg for seg in m2.segments if seg.seg_id == victim.seg_id]
    assert rewritten and rewritten[0].quant is not None
    assert rewritten[0].quant.kind == "int8"
    ids2, _ = m2.query(q, None, k=8)
    assert (ids2 >= 0).any()


def test_config_validation_and_serving_plumb():
    """Invalid quantize configs fail fast; DocumentStore(quantize=) wires
    the knob into the streaming manager."""
    with pytest.raises(ValueError, match="n_shards"):
        SegmentManager(8, 3, StreamConfig(quantize="int8", n_shards=0))
    with pytest.raises(ValueError, match="unknown quantize"):
        SegmentManager(8, 3, StreamConfig(quantize="int3", n_shards=1))
    with pytest.raises(ValueError, match="incremental_pack"):
        SegmentManager(8, 3, StreamConfig(quantize="int8", n_shards=1,
                                          incremental_pack=False))
    from repro.serving.rag import Document, DocumentStore
    rng = np.random.default_rng(61)
    docs = [Document(i, np.arange(4, dtype=np.int32),
                     rng.normal(size=16).astype(np.float32),
                     rng.uniform(size=3)) for i in range(600)]
    with pytest.raises(ValueError, match="streaming"):
        DocumentStore(docs, quantize="int8")
    store = DocumentStore(
        docs, streaming=True, quantize="int8",
        stream_cfg=StreamConfig(seal_max_points=200, index_cfg=IDX_CFG))
    assert store.manager.cfg.quantize == "int8"
    assert store.manager.cfg.n_shards >= 1
    hits = store.retrieve(docs[5].embedding, None, k=3)
    assert docs[5] in hits[0]


# ---------------------------------------------------------------------------
# Compile warming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [None, "int8"])
def test_bucket_growth_is_pre_traced_off_the_query_path(quantize):
    """After a query has recorded its dispatch signature, a bucket
    doubling (or a fresh bucket) is pre-traced AND pre-compiled at seal
    time — including the mesh sharding of the real blocks, since jit
    caches per input sharding — so the next query triggers zero new
    dispatch traces and zero new executables (the exp12 residual-spike
    fix)."""
    from repro.distributed.segment_shards import make_shard_mesh
    from repro.kernels import ops
    rng = np.random.default_rng(71)

    def batch(n, t0):
        x = rng.normal(size=(n, 16)).astype(np.float32)
        s = rng.uniform(size=(n, 3))
        s[:, 2] = t0 + np.linspace(0, .1, n)
        return x, s

    mgr = SegmentManager(16, 3, StreamConfig(
        time_dim=2, seal_max_points=1 << 30, n_shards=2, quantize=quantize,
        index_cfg=IDX_CFG), shard_mesh=make_shard_mesh())
    x, s = batch(300, 0.0)
    mgr.ingest(x, s)
    mgr.seal()
    q = rng.normal(size=(4, 16)).astype(np.float32)
    mgr.query(q, None, k=5)                   # record sig + cold build
    for i in range(3):                        # grow past the initial slots
        x, s = batch(300, float(i + 1))
        mgr.ingest(x, s)
        mgr.seal()
    # the dispatch the query path uses for this config (k=5 -> kpad=8)
    factory = (ops._sharded_quant_dispatch if quantize
               else ops._sharded_kernel_dispatch)
    dispatch = factory("none", 8, "l2", 64, 256, True)
    compiled_before = dispatch._cache_size()
    traces_before = dispatch_trace_count()
    ids, _ = mgr.query(q, None, k=5)
    assert dispatch_trace_count() == traces_before
    assert dispatch._cache_size() == compiled_before
    assert (ids >= 0).any()
    # manual warming API: a recorded signature warms matching shapes
    mode = "int8" if quantize else "fp32"
    spec = ({"mode": "int8", "rows": 8, "cap": 512, "dq": 32,
             "mq": quant_meta_rows(3)} if quantize
            else {"mode": "fp32", "rows": 8, "cap": 512, "dpad": 128})
    assert warm_sharded_shapes([spec]) >= 1, mode
