"""Baseline behaviour matches the paper's qualitative claims (§2.2, §6.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.baselines import (AcornIndex, PostFilteringIndex,
                                  PreFilteringIndex, TreeGraphIndex)
from repro.core.filters import BoxFilter
from repro.core.workloads import ground_truth, make_box_filter, make_dataset, recall


@pytest.fixture(scope="module")
def data():
    x, s = make_dataset(3000, 32, 2, seed=1)
    rng = np.random.default_rng(2)
    q = x[rng.integers(0, 3000, 24)] + 0.05 * rng.normal(size=(24, 32)).astype(np.float32)
    f = make_box_filter(2, 0.05, seed=3)
    gt, _ = ground_truth(x, s, q, f, 10)
    return x, s, q, f, gt


def test_postfilter_pure_ann(data):
    """Sanity: the monolithic graph is navigable (recall ~1 unfiltered)."""
    x, s, q, f, gt = data
    idx = PostFilteringIndex(x, s)
    f_all = BoxFilter(lo=jnp.asarray([-1.0, -1.0]), hi=jnp.asarray([2.0, 2.0]))
    gt_all, _ = ground_truth(x, s, q, f_all, 10)
    ids, _ = idx.query(q, f_all, k=10, ef=64)
    assert recall(ids, gt_all) >= 0.95


def test_postfilter_degrades_at_low_selectivity(data):
    """PostFiltering needs much larger ef to reach the same recall (§2.2)."""
    x, s, q, f, gt = data
    idx = PostFilteringIndex(x, s)
    r_small = recall(idx.query(q, f, k=10, ef=64)[0], gt)
    r_large = recall(idx.query(q, f, k=10, ef=1024)[0], gt)
    assert r_small < 0.8                  # wasteful at small budget
    assert r_large >= 0.9                 # recovers with massive budget


def test_prefilter_catastrophic(data):
    """PreFiltering fragments the routing graph at 5% selectivity (§2.2)."""
    x, s, q, f, gt = data
    idx = PreFilteringIndex(x, s)
    assert recall(idx.query(q, f, k=10, ef=64)[0], gt) < 0.7


def test_acorn_beats_prefilter(data):
    x, s, q, f, gt = data
    pre = PreFilteringIndex(x, s)
    acorn = AcornIndex(x, s, gamma=12)
    r_pre = recall(pre.query(q, f, k=10, ef=64)[0], gt)
    r_ac = recall(acorn.query(q, f, k=10, ef=64)[0], gt)
    assert r_ac > r_pre
    assert r_ac >= 0.6


def test_treegraph_subquery_explosion(data):
    """Tree-Graph reaches recall but via many independent subqueries (§3)."""
    x, s, q, f, gt = data
    idx = TreeGraphIndex(x, s, leaf_size=256)
    ids, _, nsub = idx.query(q, f, k=10, ef=64, return_n_subqueries=True)
    assert recall(ids, gt) >= 0.85
    assert nsub >= 2                      # decoupled sub-index invocations


def test_cubegraph_dominates_at_matched_budget(data):
    """The paper's headline: CubeGraph >= baselines at the same ef (Exp-1)."""
    x, s, q, f, gt = data
    cg = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=4, m_intra=12,
                                                    m_cross=4))
    r_cg = recall(cg.query(q, f, k=10, ef=64)[0], gt)
    post = PostFilteringIndex(x, s)
    r_post = recall(post.query(q, f, k=10, ef=64)[0], gt)
    pre = PreFilteringIndex(x, s)
    r_pre = recall(pre.query(q, f, k=10, ef=64)[0], gt)
    assert r_cg >= 0.9
    assert r_cg > r_post
    assert r_cg > r_pre
