"""Exp-6 (Fig. 9): impact of merged-cube count — the same filter executed at
finer layers forces 4 / 16 / 64 / 128-cube merges; recall/QPS degrade with
merge count (validates Prop. 1 layer selection)."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import (ground_truth, make_box_filter, make_dataset)

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

EFS = (32, 64, 128)
K = 20


def run():
    x, s = make_dataset(BENCH_N, BENCH_D, 2, seed=12)
    rng = np.random.default_rng(13)
    q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    idx = CubeGraphIndex.build(x, s, CubeGraphConfig(n_layers=6, m_intra=16,
                                                     m_cross=4))
    # a ~0.25-side box: layer l covers it with ~(0.25 * 2^{l+1})^2 cubes
    f = make_box_filter(2, 0.0625, seed=14)     # side ~0.25
    gt, _ = ground_truth(x, s, q, f, K)
    out = {}
    for layer in range(idx.n_built_layers):
        ids, _, st = idx.query(q, f, k=K, ef=64, layer=layer,
                               return_stats=True)
        cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef, layer=layer)[0],
                   EFS, q, gt, K)
        out[f"layer{layer}_merge{st.n_active_cubes}"] = cu
        best = max(cu, key=lambda r: r["recall"])
        csv_row(f"exp6/merge{st.n_active_cubes}", best["us_per_query"],
                f"recall={best['recall']};qps={best['qps']}")
    record("exp6_merge_count", out)
    return out


if __name__ == "__main__":
    run()
