"""Exp-16: tiered bucket storage — HBM as a budgeted cache over the
sealed corpus (``streaming/tiering.py``).

Drives a moving-window ``IntervalFilter`` workload (the paper's temporal
drift pattern) over a corpus whose pack is >= 3x the device budget and
measures:

  * **budget invariant** — reported resident device bytes stay <= budget
    at every sampled point of the workload (admissions, evictions, and
    pack deltas all re-enforce before releasing the lock),
  * **exactness** — recall@10 of the budgeted manager against the
    all-resident baseline's answers (scan-path cold reads are bit-for-bit
    identical, so this reports 1.0 by construction; the assertion is the
    point),
  * **hot-window latency** — median query latency inside a stable recent
    window once the prefetcher has warmed, vs. the all-resident baseline
    (the <= 1.5x acceptance bound: after warm-up the hot buckets are
    resident, so the tier costs only the budget bookkeeping),
  * **restore under budget** — ``SegmentManager.restore`` +
    first-query time with a budget vs. without (exp11's 700 ms
    restored-first-query came from cold-building the *whole* pack
    resident; a budgeted restore uploads only what fits).
"""
from __future__ import annotations

import statistics
import tempfile
import time

import numpy as np

from repro.core import CubeGraphConfig, IntervalFilter
from repro.core.workloads import recall
from repro.streaming import SegmentManager, StreamConfig

from .common import BENCH_D, BENCH_Q, csv_row, record, timed_query_samples

CFG = CubeGraphConfig(n_layers=2, m_intra=8, m_cross=4)


# Era'd stream: each 4-"day" era seals segments of a different size, so
# each era lands in its own capacity bucket (the pack buckets by padded
# *per-shard* capacity — sizes are per n_shards=2) and the buckets' time
# spans tile the stream — which is what lets a moving query window make
# residency decisions matter.  A uniform stream would collapse into one
# bucket spanning everything.  Counts halve as sizes double, so the four
# bucket blocks end up byte-comparable and a budget of ~total/3 holds
# one era with headroom: the drifting window forces real admit/evict
# churn instead of a single never-fitting block.
_ERAS = ((12, 500), (6, 1000), (3, 2000), (2, 4000))  # (segments, points)


def _mgr(budget, persist_dir=None):
    return SegmentManager(BENCH_D, 3, StreamConfig(
        time_dim=2, seal_max_points=1 << 30, n_shards=2,
        device_budget_bytes=budget, persist_dir=persist_dir,
        index_cfg=CFG))


def _workload(seed=61):
    rng = np.random.default_rng(seed)
    n = sum(k * sz for k, sz in _ERAS)
    x = rng.normal(size=(n, BENCH_D)).astype(np.float32)
    s = rng.uniform(size=(n, 3))
    s[:, 2] = np.linspace(0.0, 16.0, n)       # 16 "days" of stream time
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
    return x, s, q


def _ingest_eras(mgr, x, s):
    lo = 0
    for n_segs, size in _ERAS:
        for _ in range(n_segs):
            mgr.ingest(x[lo:lo + size], s[lo:lo + size])
            mgr.seal()
            lo += size


def run():
    x, s, q = _workload()
    n = x.shape[0]

    base = _mgr(None)
    _ingest_eras(base, x, s)
    base.query(q, IntervalFilter(2, 0.0, 16.0), k=10)   # build + compile
    full_bytes = base.stats()["pack_nbytes"]
    budget = max(full_bytes // 3, 1)                    # corpus >= 3x budget

    tiered = _mgr(budget)
    _ingest_eras(tiered, x, s)

    # moving-window sweep: the filter drifts across the stream's time
    # axis, so the hot bucket set keeps changing and the tier must evict
    # behind the window while the prefetcher stages ahead of it
    resident_samples, miss_recalls = [], []
    for lo in np.linspace(0.0, 12.0, 13):
        f = IntervalFilter(2, float(lo), float(lo) + 4.0)
        g_b, _ = base.query(q, f, k=10)
        g_t, _ = tiered.query(q, f, k=10)
        # run the prefetch round synchronously: the daemon thread the
        # query path kicks off is the production shape, but benchmark
        # counters should not race it
        tiered._prefetch_once()
        miss_recalls.append(recall(g_t, g_b))
        st = tiered.stats()["tier"]
        resident_samples.append(st["resident_bytes"])
        assert st["resident_bytes"] <= budget, \
            f"budget violated: {st['resident_bytes']} > {budget}"
    assert min(miss_recalls) >= 0.95, miss_recalls

    # hot-window steady state: park the window, warm the prefetcher
    # synchronously (the daemon thread races benchmarks), then compare
    hot = IntervalFilter(2, 11.0, 15.0)
    base.query(q, hot, k=10)
    tiered.query(q, hot, k=10)
    tiered._prefetch_once()
    base_lats, _ = timed_query_samples(lambda: base.query(q, hot, k=10)[0],
                                       reps=7)
    hot_lats, g_hot = timed_query_samples(
        lambda: tiered.query(q, hot, k=10)[0], reps=7)
    g_base, _ = base.query(q, hot, k=10)
    hot_us = statistics.median(hot_lats) / BENCH_Q * 1e6
    base_us = statistics.median(base_lats) / BENCH_Q * 1e6

    obs = tiered.stats()["obs"]["metrics"]["counters"]
    out = {
        "n_points": n, "budget_bytes": budget, "full_pack_bytes": full_bytes,
        "over_budget_ratio": round(full_bytes / budget, 2),
        "resident_bytes_max": int(max(resident_samples)),
        "recall_at_10": round(min(miss_recalls), 4),
        "hot_recall_at_10": round(recall(g_hot, g_base), 4),
        "us_per_query": round(hot_us, 1),
        "latency_samples": [{"us_per_query": round(dt / BENCH_Q * 1e6, 1)}
                            for dt in hot_lats],
        "allresident_us_per_query": round(base_us, 1),
        "hot_latency_ratio": round(hot_us / max(base_us, 1e-9), 3),
        "tier_admissions": obs.get("tier_admissions_total", 0),
        "tier_evictions": obs.get("tier_evictions_total", 0),
        "tier_prefetch_admissions": obs.get("tier_prefetch_admissions_total",
                                            0),
        "tier_misses": obs.get("tier_miss_total", 0),
    }

    # restore under budget: the budgeted replica must not cold-build the
    # full resident pack before its first answer
    with tempfile.TemporaryDirectory() as root:
        base.snapshot_to(root)
        for tag, cfg_budget in (("unbudgeted", None), ("budgeted", budget)):
            cfg = StreamConfig(time_dim=2, seal_max_points=1024, n_shards=2,
                               device_budget_bytes=cfg_budget, index_cfg=CFG)
            t0 = time.perf_counter()
            restored = SegmentManager.restore(root, cfg=cfg, resume=False)
            t1 = time.perf_counter()
            g_r, _ = restored.query(q, hot, k=10)
            dt = (time.perf_counter() - t1) * 1e3
            key = ("restored_first_query_ms" if tag == "budgeted"
                   else "unbudgeted_restored_first_query_ms")
            out[key] = round(dt, 2)
            out[f"{tag}_restore_ms"] = round((t1 - t0) * 1e3, 2)
            if cfg_budget is not None:
                st = restored.stats()["tier"]
                out["restored_resident_bytes"] = st["resident_bytes"]
                assert st["resident_bytes"] <= budget
                assert np.array_equal(g_r, g_base)

    csv_row("exp16/tiered_storage", out["us_per_query"],
            f"over_budget={out['over_budget_ratio']}x;"
            f"recall={out['recall_at_10']};"
            f"hot_latency_ratio={out['hot_latency_ratio']};"
            f"evictions={out['tier_evictions']};"
            f"prefetch={out['tier_prefetch_admissions']}")
    record("exp16_tiered_storage", out)
    return out


if __name__ == "__main__":
    run()
