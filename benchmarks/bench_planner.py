"""Exp-15: cost-based sealed read path — latency vs. resident corpus size.

Sweeps the resident corpus size; at each size ONE manager ingests the
stream, seals, and compacts (the read-optimized steady state: compaction
merges per-segment graph components, which is what keeps traversal recall
high as the corpus grows), then the same sealed pack is queried three
ways via the per-call ``read_path`` override:

  * ``scan`` — the fused-kernel bucket scan (exact; the pre-planner
    baseline whose latency is linear in padded resident rows),
  * ``graph`` — the stitched beam traversal forced everywhere a bucket
    carries a usable graph (per-hop cost independent of corpus size, hop
    count ~ log(points) — the sub-linear curve),
  * ``auto`` — ``streaming.planner`` picking scan vs. traversal per
    bucket per dispatch from BucketStats + :class:`PlannerCosts`.

Each mode reports windowed-filter query latency and recall@10 against
brute-force fp32 ground truth (the paper's operating point is
recall@10 >= 0.95 — asserted for every recorded row), plus the planner's
per-bucket decisions for the ``auto`` pass.  The harness overrides
``PlannerCosts.hop_cost`` with a value calibrated for this CPU
interpret-mode rig so the scan/graph crossover the model predicts matches
the measured wall-clock crossover (scan cheaper at the small sizes, the
traversal cheaper at the largest); ROADMAP item 5's measured rooflines
replace these constants on real accelerators.  The ``scan_``/``graph_``
baseline prefixes keep the BENCH_streaming.json digest summarizing only
the production ``auto`` path (exp13's ``fp32_`` convention).
"""
from __future__ import annotations

import numpy as np

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import ground_truth, make_dataset, recall
from repro.streaming import SegmentManager, StreamConfig
from repro.streaming.planner import PlannerCosts

from .common import BENCH_D, BENCH_Q, csv_row, record, timed_queries

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)
# interpret-mode CPU calibration: measured on this rig, a traversal hop
# costs ~150 padded-row scans, which places the modeled crossover between
# the 12k point (scan measured cheaper) and the 36k point (traversal
# measured ~2x cheaper) — matching wall clock
COSTS = PlannerCosts(hop_cost=150.0)
# all sizes >= seal_max_points so every swept point has sealed data for
# the planner to route (below that the whole corpus sits in the delta
# buffer and the sealed read path never dispatches)
SIZES = (3_000, 12_000, 36_000)


def _window(t_lo, t_hi):
    return ComposeFilter(
        BoxFilter(lo=np.zeros(3, np.float32), hi=np.ones(3, np.float32)),
        IntervalFilter(dim=2, lo=np.float32(t_lo), hi=np.float32(t_hi)),
        "and")


def run():
    d = BENCH_D
    rng = np.random.default_rng(61)
    out = {"d": d, "sizes": [], "planner_costs": {
        "hop_cost": COSTS.hop_cost, "base_hops": COSTS.base_hops,
        "hops_per_log2": COSTS.hops_per_log2,
        "min_graph_rows": COSTS.min_graph_rows}}
    f = _window(0.1, 0.95)
    for n in SIZES:
        x, s = make_dataset(n, d, 3, seed=60)
        s[:, 2] = np.arange(n) / n
        q = x[rng.integers(0, n, BENCH_Q)] \
            + 0.05 * rng.normal(size=(BENCH_Q, d)).astype(np.float32)
        gt, _ = ground_truth(x, s, q, f, 10)
        row = {"n_points": n}
        mgr = SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=2048, n_shards=2,
            compact_max_segments=3, read_path="auto",
            planner_costs=COSTS, graph_ef=192, index_cfg=CFG))
        mgr.ingest(x, s)
        mgr.seal()
        mgr.compact()
        row["n_segments"] = len(mgr.segments)
        for mode in ("scan", "graph", "auto"):
            tag = "" if mode == "auto" else f"{mode}_"
            rp = None if mode == "auto" else mode
            dt, ids = timed_queries(
                lambda: mgr.query(q, f, k=10, read_path=rp)[0], reps=5)
            row[tag + "us_per_query"] = round(dt / BENCH_Q * 1e6, 1)
            row[tag + "recall_at_10"] = round(recall(ids, gt), 4)
            assert row[tag + "recall_at_10"] >= 0.95, (mode, n)
            if mode == "auto":
                plan = mgr.last_plan or {}
                row["auto_modes"] = {str(cap): dec.mode
                                     for cap, dec in sorted(plan.items())}
        out["sizes"].append(row)
        csv_row(f"exp15/n{n}", row["us_per_query"],
                f"scan_us={row['scan_us_per_query']};"
                f"graph_us={row['graph_us_per_query']};"
                f"auto_modes={'+'.join(row['auto_modes'].values()) or '-'};"
                f"recall={row['recall_at_10']}")

    # scaling exponents: slope of log(latency) over log(n) across the sweep
    # (1.0 = linear in corpus size; the planner's point is that auto's
    # tail bends onto the traversal curve once the crossover is inside
    # the swept range, so auto scales strictly better than the scan)
    ln = np.log([r["n_points"] for r in out["sizes"]])
    for tag in ("scan_", "graph_", ""):
        lat = np.log([r[tag + "us_per_query"] for r in out["sizes"]])
        out[(tag or "auto_") + "scaling_exponent"] = round(
            float(np.polyfit(ln, lat, 1)[0]), 3)
    assert out["auto_scaling_exponent"] < out["scan_scaling_exponent"]
    csv_row("exp15/summary", 0.0,
            f"scan_exp={out['scan_scaling_exponent']};"
            f"graph_exp={out['graph_scaling_exponent']};"
            f"auto_exp={out['auto_scaling_exponent']}")
    record("exp15_read_path_planner", out)
    return out


if __name__ == "__main__":
    run()
