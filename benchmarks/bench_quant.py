"""Exp-13: quantized sealed-segment read path — int8 codes + exact fp32
rerank vs the fp32 scan.

Drives the same ingest stream through two managers that differ only in
``StreamConfig(quantize=)``:

  * device bytes held by the sealed-segment pack (the HBM budget that caps
    resident corpus size) for the fp32 blocks vs the int8 code blocks,
  * steady-state query latency of both paths (windowed filter + no-filter),
  * recall@10 of the quantized two-stage path against brute-force fp32
    ground truth (the fp32 path is exact by construction and is asserted
    so),
  * a sweep over ``rerank_multiple`` showing the over-fetch / recall knee.

The fp32 baseline's keys are ``fp32_``-prefixed so the BENCH_streaming.json
digest summarizes only the production quantized path (same convention as
exp12's ``rebuild_`` prefix).
"""
from __future__ import annotations

import numpy as np

from repro.core import (BoxFilter, ComposeFilter, CubeGraphConfig,
                        IntervalFilter)
from repro.core.workloads import ground_truth, make_dataset, recall
from repro.streaming import SegmentManager, StreamConfig

from .common import (BENCH_D, BENCH_N, BENCH_Q, csv_row, record,
                     timed_queries, timed_query_samples)

CFG = CubeGraphConfig(n_layers=3, m_intra=12, m_cross=4)


def _window(t_lo, t_hi):
    return ComposeFilter(
        BoxFilter(lo=np.zeros(3, np.float32), hi=np.ones(3, np.float32)),
        IntervalFilter(dim=2, lo=np.float32(t_lo), hi=np.float32(t_hi)),
        "and")


def run():
    n = max(BENCH_N, 8000)
    d = BENCH_D
    x, s = make_dataset(n, d, 3, seed=51)
    s[:, 2] = np.arange(n) / n
    rng = np.random.default_rng(52)
    q = x[rng.integers(0, n, BENCH_Q)] \
        + 0.05 * rng.normal(size=(BENCH_Q, d)).astype(np.float32)
    f = _window(0.2, 0.9)
    gt_f, _ = ground_truth(x, s, q, f, 10)
    gt_n, _ = ground_truth(x, s, q, None, 10)

    out = {"n_points": n, "d": d, "modes": {}}
    managers = {}
    for mode, quantize in (("fp32", None), ("int8", "int8")):
        tag = "fp32_" if quantize is None else ""
        mgr = SegmentManager(d, 3, StreamConfig(
            time_dim=2, seal_max_points=2048, n_shards=2,
            quantize=quantize, rerank_multiple=4, index_cfg=CFG))
        mgr.ingest(x, s)
        managers[mode] = mgr
        dts, ids_f = timed_query_samples(lambda: mgr.query(q, f, k=10)[0],
                                         reps=5)
        dt_f = sum(dts) / len(dts)
        dt_n, ids_n = timed_queries(
            lambda: mgr.query(q, None, k=10)[0], reps=5)
        st = mgr.stats()
        row = {
            tag + "us_per_query": round(dt_f / BENCH_Q * 1e6, 1),
            tag + "us_per_query_nofilter": round(dt_n / BENCH_Q * 1e6, 1),
            tag + "recall_at_10": round(min(recall(ids_f, gt_f),
                                            recall(ids_n, gt_n)), 4),
            tag + "pack_nbytes": st["pack_nbytes"],
        }
        if not tag:     # per-rep rows -> the digest's median is real
            row["latency_samples"] = [
                {"us_per_query": round(dt / BENCH_Q * 1e6, 1)}
                for dt in dts]
        out["modes"][mode] = row
        csv_row(f"exp13/{mode}", dt_f * 1e6,
                f"recall={row[tag + 'recall_at_10']};"
                f"pack_nbytes={row[tag + 'pack_nbytes']}")

    fp, i8 = out["modes"]["fp32"], out["modes"]["int8"]
    out["device_bytes_ratio"] = round(
        fp["fp32_pack_nbytes"] / max(i8["pack_nbytes"], 1), 2)
    out["latency_ratio"] = round(
        fp["fp32_us_per_query"] / max(i8["us_per_query"], 1e-9), 3)

    # over-fetch knee: recall@10 as the rerank multiple shrinks
    sweep = []
    mgr = managers["int8"]
    base_cfg = mgr.cfg
    for rm in (1, 2, 4, 8):
        import dataclasses
        mgr.cfg = dataclasses.replace(base_cfg, rerank_multiple=rm)
        ids, _ = mgr.query(q, f, k=10)
        sweep.append({"rerank_multiple": rm,
                      "sweep_recall": round(recall(ids, gt_f), 4)})
    mgr.cfg = base_cfg
    out["rerank_sweep"] = sweep
    csv_row("exp13/summary", 0.0,
            f"device_bytes_ratio={out['device_bytes_ratio']}x;"
            f"latency_ratio={out['latency_ratio']}x;"
            f"recall={i8['recall_at_10']}")
    record("exp13_quantized_scan", out)
    return out


if __name__ == "__main__":
    run()
