"""Exp-2 (Fig. 6): multi-dimensional filters (2D / 3D / 4D boxes)."""
from __future__ import annotations

import numpy as np

from repro.core import CubeGraphConfig, CubeGraphIndex
from repro.core.workloads import ground_truth, make_box_filter, make_dataset

from .common import BENCH_D, BENCH_N, BENCH_Q, csv_row, curve, record

EFS = (32, 64, 128)
K = 20


def run():
    out = {}
    rng = np.random.default_rng(3)
    for m in (2, 3, 4):
        x, s = make_dataset(BENCH_N, BENCH_D, m, seed=m)
        q = x[rng.integers(0, BENCH_N, BENCH_Q)] \
            + 0.05 * rng.normal(size=(BENCH_Q, BENCH_D)).astype(np.float32)
        idx = CubeGraphIndex.build(x, s, CubeGraphConfig(
            n_layers=5 if m == 2 else 4, m_intra=16, m_cross=4))
        for ratio in (0.05, 0.10):
            f = make_box_filter(m, ratio, seed=m * 10 + int(ratio * 100))
            gt, _ = ground_truth(x, s, q, f, K)
            cu = curve(lambda ef: idx.query(q, f, k=K, ef=ef)[0],
                       EFS, q, gt, K)
            out[f"m{m}_r{ratio}"] = cu
            best = max(cu, key=lambda r: r["recall"])
            csv_row(f"exp2/m{m}/r{ratio}", best["us_per_query"],
                    f"recall={best['recall']};qps={best['qps']}")
    record("exp2_multidim", out)
    return out


if __name__ == "__main__":
    run()
